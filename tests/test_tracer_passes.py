"""Tracer + pass invariants: flops accounting, TP/EP rewrites, fusion,
quantization, recompute, pipeline schedules."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tracer
from repro.core.ir import Graph
from repro.core.passes.base import ParallelConfig, PassContext
from repro.core.passes.fusion import FusionPass
from repro.core.passes.parallelism import ExpertParallelPass, TensorParallelPass
from repro.core.passes.pipeline import make_schedule, schedule_1f1b, schedule_gpipe
from repro.core.passes.quantize import QuantizePass
from repro.core.passes.recompute import RecomputePass


def _mlp_graph(tp_friendly=True):
    F = 512 if tp_friendly else 511

    def f(x, w1, w2):
        return jax.nn.silu(x @ w1) @ w2

    xa = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    w1 = jax.ShapeDtypeStruct((256, F), jnp.float32)
    w2 = jax.ShapeDtypeStruct((F, 256), jnp.float32)
    return tracer.trace(f, xa, w1, w2)


def test_trace_flops_exact():
    g = _mlp_graph()
    mm = g.by_kind()["matmul"]
    assert mm == 2 * 64 * 256 * 512 * 2


def test_trace_matches_xla_cost_analysis():
    def f(x, w):
        return jnp.tanh(x @ w).sum()
    xa = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    wa = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    g = tracer.trace(f, xa, wa)
    ca = jax.jit(f).lower(xa, wa).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jaxlib: one dict per device
        ca = ca[0]
    xla = ca["flops"]
    ours = g.total("flops")
    assert abs(ours - xla) / xla < 0.05


def test_scan_repeat_multiplier():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=9)[0]
    xa = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    wa = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    g = tracer.trace(f, xa, wa)
    assert g.total("flops") == 9 * 2 * 32 * 32 * 32


def test_tp_pass_divides_and_inserts_collectives():
    g = _mlp_graph()
    base_flops = g.total("flops")
    ctx = PassContext(parallel=ParallelConfig(tp=4))
    g2 = TensorParallelPass().apply(g, ctx)
    kinds = g2.by_kind()
    assert g2.total("flops", pred=lambda n: not n.is_comm) == base_flops / 4
    assert "all_reduce" in kinds  # row-parallel second matmul


def test_tp_pass_skips_nondivisible():
    g = _mlp_graph(tp_friendly=False)
    ctx = PassContext(parallel=ParallelConfig(tp=4))
    g2 = TensorParallelPass().apply(g, ctx)
    assert "all_reduce" not in g2.by_kind()


def test_ep_pass_alltoall_pair():
    g = Graph("moe")
    a = g.op("matmul", out_shape=(8, 64, 128), flops=1e9, bytes_in=1e6, bytes_out=1e6)
    b = g.op("matmul", deps=[a.name], out_shape=(8, 64, 128), flops=1e9,
             bytes_in=1e6, bytes_out=1e6)
    c = g.op("elementwise", deps=[b.name], out_shape=(64, 128), flops=1e3,
             bytes_in=1e6, bytes_out=1e6)
    ctx = PassContext(parallel=ParallelConfig(tp=1, ep=4))
    g2 = ExpertParallelPass(num_experts=8).apply(g, ctx)
    kinds = g2.by_kind()
    a2a = [n for n in g2 if n.kind == "all_to_all"]
    assert len(a2a) == 2  # dispatch + combine
    assert g2.total("flops", pred=lambda n: n.kind == "matmul") == 2e9 / 4


def test_fusion_pass_merges_chain():
    g = Graph("f")
    a = g.op("norm", out_shape=(64, 256), flops=1e5, bytes_in=1e5, bytes_out=1e5)
    b = g.op("matmul", deps=[a.name], out_shape=(64, 512), flops=1e7,
             bytes_in=2e5, bytes_out=1e5)
    g2 = FusionPass().apply(g)
    assert len(g2) == 1
    node = next(iter(g2))
    assert node.kind == "fused" and node.flops == 1e5 + 1e7
    assert node.bytes_in == 1e5 and node.bytes_out == 1e5


def test_quantize_scales_bytes():
    g = _mlp_graph()
    before = g.total("total_bytes", pred=lambda n: n.kind == "matmul")
    g2 = QuantizePass("int8").apply(g)
    after = g2.total("total_bytes", pred=lambda n: n.kind == "matmul")
    assert after == pytest.approx(before / 4)  # f32 -> int8


def test_recompute_adds_bwd_clones():
    g = _mlp_graph()
    n_fwd = len(g)
    g2 = RecomputePass("block").apply(g)
    assert len(g2) == 2 * n_fwd
    assert sum(1 for n in g2 if n.phase == "bwd") == n_fwd


# ---------------- pipeline schedules ----------------

def test_1f1b_bubble_formula():
    p, m, tf, tb = 4, 16, 1.0, 2.0
    s = schedule_1f1b(p, m, tf, tb, 0.0)
    expect = (m * (tf + tb) + (p - 1) * (tf + tb))  # classic 1F1B makespan
    assert s.total_time == pytest.approx(expect, rel=1e-6)


def test_gpipe_worse_than_1f1b_bubble():
    for m in (4, 8, 32):
        g = schedule_gpipe(4, m, 1.0, 2.0, 0.0)
        f = schedule_1f1b(4, m, 1.0, 2.0, 0.0)
        assert f.total_time <= g.total_time + 1e-9


def test_dualpipe_beats_1f1b():
    f = make_schedule("1f1b", 8, 16, 1.0, 2.0, 0.05)
    d = make_schedule("dualpipe", 8, 16, 1.0, 2.0, 0.05)
    assert d.total_time < f.total_time
    assert d.bubble_fraction < f.bubble_fraction


@settings(max_examples=20, deadline=None)
@given(p=st.integers(1, 8), m=st.integers(1, 24),
       tf=st.floats(0.1, 5), tb=st.floats(0.1, 5))
def test_1f1b_schedule_valid(p, m, tf, tb):
    """Events never overlap per rank and respect stage dependencies."""
    s = make_schedule("1f1b" if p > 1 else "none", p, m, tf, tb, 0.0)
    ideal = m * (tf + tb)
    assert s.total_time >= ideal - 1e-9
    for r in range(p):
        evs = sorted(s.rank_events(r), key=lambda e: e.start)
        for e1, e2 in zip(evs, evs[1:]):
            assert e2.start >= e1.end - 1e-9
    fwd = {(e.rank, e.microbatch): e for e in s.events if e.kind == "F"}
    for e in s.events:
        if e.kind == "F" and e.rank > 0:
            assert e.start >= fwd[(e.rank - 1, e.microbatch)].end - 1e-9


def test_interleaved_beats_plain_1f1b():
    from repro.core.passes.pipeline import schedule_interleaved
    f = make_schedule("1f1b", 8, 16, 1.0, 2.0, 0.01)
    i = schedule_interleaved(8, 16, 1.0, 2.0, 0.01, v=2)
    assert i.bubble_fraction < f.bubble_fraction
    assert i.total_time < f.total_time

"""End-to-end behaviour tests: serving engine, dynamic SP planner,
HLO analysis, dry-run artifact integrity, multi-device MoE equivalence."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_tiny_config, supports_shape
from repro.models import Model
from repro.serving import Request, ServingEngine, plan_batch

REPO = Path(__file__).resolve().parents[1]


# ---------------- serving engine ----------------

def test_serving_engine_continuous_batching_matches_sequential():
    cfg = get_tiny_config("gemma-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, cache_len=64)
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 5, 5, 5, 5]]  # 3 reqs, 2 slots
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    finished = eng.run_until_drained(max_steps=200)
    assert len(finished) == 3
    # sequential reference for request 0
    req = finished[[r.rid for r in finished].index(0)]
    toks = list(prompts[0])
    out = []
    logits, cache = model.prefill(params, {"tokens": jnp.asarray([toks], jnp.int32)},
                                  cache_len=64)
    tok = int(jnp.argmax(logits[0, -1]))
    out.append(tok)
    for _ in range(4):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": jnp.asarray([[tok]], jnp.int32)})
        tok = int(jnp.argmax(logits[0, 0]))
        out.append(tok)
    assert req.tokens == out


def test_serving_engine_virtual_clock_trace_replay():
    """Caller-supplied arrival_s (including 0.0) must be honored and TTFT
    computed on the injected clock's timebase, not wall-clock."""
    from repro.serving import VirtualClock
    cfg = get_tiny_config("gemma-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    clk = VirtualClock()
    eng = ServingEngine(cfg, params, slots=2, cache_len=64, clock=clk)
    traced = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2, arrival_s=0.0)
    eng.submit(traced)
    assert traced.arrival_s == 0.0          # was silently replaced pre-fix
    stamped = Request(rid=1, prompt=[4, 5], max_new_tokens=2)
    clk.advance_to(0.125)
    eng.submit(stamped)
    assert stamped.arrival_s == 0.125       # engine stamps via the clock
    clk.advance_to(0.25)
    finished = eng.run_until_drained(max_steps=50)
    assert len(finished) == 2
    assert finished[0].ttft_s >= 0.0
    by_rid = {r.rid: r for r in finished}
    assert by_rid[0].ttft_s == pytest.approx(0.25)   # prefill at t=0.25
    assert by_rid[1].ttft_s == pytest.approx(0.125)


def test_dynamic_sp_beats_static_zigzag():
    seq_lens = [512, 1024, 8192, 256, 16384, 768]
    static = plan_batch(seq_lens, d_head=128, n_heads=64, sp_world=8, dynamic=False)
    dynamic = plan_batch(seq_lens, d_head=128, n_heads=64, sp_world=8, dynamic=True)
    assert dynamic.makespan_us < static.makespan_us
    # short requests choose narrow SP
    short = dynamic.choices[3]
    assert short.sp <= 2


# ---------------- HLO analysis ----------------

def test_hlo_analysis_trip_counts():
    from repro.launch.hlo_analysis import analyze_module

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=7)[0]

    xa = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    wa = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    txt = jax.jit(f).lower(xa, wa).compile().as_text()
    st = analyze_module(txt)
    assert st["flops"] == pytest.approx(7 * 2 * 64 ** 3, rel=1e-6)
    assert any(w["trip_count"] == 7 for w in st["while_loops"])


# ---------------- dry-run artifacts (deliverable e) ----------------

def test_dryrun_artifacts_complete_and_ok():
    d = REPO / "results" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run sweep not executed yet")
    missing, bad = [], []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                f = d / f"{arch}__{shape}__{mesh}.json"
                if not f.exists():
                    missing.append(f.name)
                    continue
                rec = json.loads(f.read_text())
                ok = rec["status"] == "ok"
                skipped = rec["status"] == "skipped"
                expect_skip = not supports_shape(get_config(arch), SHAPES[shape])
                if expect_skip and not skipped:
                    bad.append((f.name, "should be skipped"))
                if not expect_skip and not ok:
                    bad.append((f.name, rec.get("error", rec["status"])))
    assert not missing, missing
    assert not bad, bad


def test_dryrun_records_have_roofline_inputs():
    d = REPO / "results" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run sweep not executed yet")
    rec = json.loads((d / "gemma-7b__train_4k__single.json").read_text())
    assert rec["flops_per_device"] > 0
    assert rec["hbm_bytes_per_device"] > 0
    assert rec["collectives"]["traffic_bytes"] > 0
    assert rec["memory_analysis"]["temp_bytes"] > 0


# ---------------- multi-device MoE equivalence (shard_map EP path) --------

@pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType not available in this jax version")
def test_moe_sharded_matches_local():
    """Run the tiny MoE under a real 4-device mesh (subprocess so the fake
    device count cannot leak into this process)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_tiny_config
from repro.distributed.sharding import ShardingEnv, activate
from repro.models import Model, init_params
from repro.training.train_step import param_pspecs, to_named

cfg = get_tiny_config("olmoe-1b-7b").replace(capacity_factor=8.0,
                                             dtype="float32", param_dtype="float32")
m = Model(cfg)
params = init_params(cfg, jax.random.PRNGKey(0))
B, S = 4, 16
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
ref, _ = m.forward(params, {"tokens": toks})   # single-device path

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
env = ShardingEnv(mesh)
with activate(env), mesh:
    p_ns = to_named(env, param_pspecs(cfg, env, 0))
    params_s = jax.device_put(params, p_ns)
    toks_s = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
    out, _ = jax.jit(lambda p, t: m.forward(p, {"tokens": t}))(params_s, toks_s)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-4, err
print("SHARDED_OK", err)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": str(REPO / "src"),
                                       "PATH": "/usr/bin:/bin"},
                       cwd=str(REPO), timeout=600)
    assert "SHARDED_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]

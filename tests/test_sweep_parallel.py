"""PR-5 sweep-throughput overhaul invariants.

Four contracts:

* ``price_batch`` (vectorized roofline + fused-engine batch path) is
  bit-identical to scalar pricing over a randomized node corpus, on every
  hardware spec, including the cache hit/miss accounting.
* The flow-compressed ``schedule_times(overlap="bandwidth")`` fast path
  reproduces the interval-building ``apply_bandwidth_aware`` exactly.
* ``sweep(space, workers=2)`` (reuse-sharded multiprocess evaluation)
  produces the same rankings, reports and pruned reasons as the serial sweep.
* The persistent SimCache tier round-trips bit-identically and is
  invalidated by engine-state and package-version bumps; batch
  extrapolation in ingest is bit-exact or self-disabling.
"""
import dataclasses
import random

import pytest

from repro.api import (
    Cluster, DecodeWorkload, PrefillWorkload, SimSpec, SweepSpace,
    TrainWorkload, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.core.backend.analytical import AnalyticalEngine
from repro.core.backend.engine import FusedEngine
from repro.core.backend.hardware import HARDWARE, TPU_V5E
from repro.core.ir import Graph, OpNode
from repro.core.overlap import apply_bandwidth_aware
from repro.core.scheduler import schedule, schedule_times

CFG = get_config("xlstm-125m")


# ------------------------- vectorized pricing -----------------------------

def _random_nodes(n=400, seed=0):
    rng = random.Random(seed)
    kinds = ["matmul", "attention", "elementwise", "norm", "copy", "scatter",
             "reduce", "fused", "all_reduce", "all_gather", "reduce_scatter",
             "send"]
    nodes = []
    for i in range(n):
        k = rng.choice(kinds)
        node = OpNode(
            f"n{i}", k, dtype=rng.choice(["bf16", "f32", "int8", "f8"]),
            flops=rng.choice([0.0, rng.random() * 1e12]),
            bytes_in=rng.choice([0.0, rng.random() * 1e9]),
            bytes_out=rng.random() * 1e8,
            comm_bytes=rng.random() * 1e8 if k.startswith(("all", "red", "se"))
            else 0.0,
            comm_group=rng.choice(["tp", "dp", "pod"]),
            comm_size=rng.choice([2, 4, 8]))
        if k in ("matmul", "fused") and rng.random() < 0.8:
            node.attrs["mm_dims"] = (rng.randrange(1, 4096),
                                     rng.randrange(1, 4096),
                                     rng.randrange(1, 4096))
        if k == "scatter":
            node.attrs["operand_bytes"] = rng.random() * 1e9
        nodes.append(node)
    return nodes


@pytest.mark.parametrize("hw_name", sorted(HARDWARE))
def test_price_batch_matches_scalar_exactly(hw_name):
    hw = HARDWARE[hw_name]
    nodes = _random_nodes()
    scalar = [AnalyticalEngine(hw).latency_us(n) for n in nodes]
    assert AnalyticalEngine(hw).price_batch(nodes) == scalar
    fe = FusedEngine([AnalyticalEngine(hw)])
    assert fe.price_batch(nodes) == scalar
    # stats accounting matches the scalar call sequence (dup sigs hit)
    fe2 = FusedEngine([AnalyticalEngine(hw)])
    assert [fe2.latency_us(n) for n in nodes] == scalar
    assert (fe.stats.hits, fe.stats.misses) == (fe2.stats.hits,
                                                fe2.stats.misses)


def test_price_batch_profile_db_fallback_per_node():
    # a profile-DB-backed engine claims its nodes per-node; the rest
    # still go through the vectorized analytical path — and a DB mutation
    # invalidates the batch-primed price memo exactly like the scalar one
    from repro.core.backend.profiling import ProfileDB, node_key
    db = ProfileDB(path="/nonexistent/empty.json")
    sim = Simulator("tpu_v5e", engine="profiling", db=db)
    nodes = _random_nodes(100, seed=1)
    scalar = [Simulator("tpu_v5e", engine="profiling",
                        db=ProfileDB(path="/nonexistent/empty.json"))
              .engine.latency_us(n) for n in nodes]
    assert sim.engine.price_batch(nodes) == scalar
    mm = next(n for n in nodes if n.kind == "matmul")
    db.put(node_key(mm, sim.hw.name), 123.0, {})
    assert sim.engine.price_batch([mm]) == [123.0]
    assert sim.engine.engine_for(mm) == "profiling"


def test_schedule_uses_batch_pricing_consistently():
    g = Graph("g")
    a = g.op("matmul", flops=1e9, bytes_in=1e6, bytes_out=1e6,
             attrs={"mm_dims": (64, 512, 512)})
    c = g.op("all_reduce", deps=[a.name], comm_bytes=4e6, comm_group="tp",
             comm_size=8, overlappable=True, stream="tp_comm")
    g.op("elementwise", deps=[a.name, c.name], bytes_in=1e6, bytes_out=1e6,
         repeat=3)
    eng = AnalyticalEngine(TPU_V5E)
    tl = schedule(g, eng)
    per_node = {n.name: eng.latency_us(n) for n in g}
    for iv in tl.intervals:
        assert iv.end == iv.start + per_node[iv.name] * g.nodes[iv.name].repeat


# ---------------- bandwidth-aware flow-compressed fast path ----------------

def _comm_heavy_graph():
    g = Graph("bw")
    a = g.op("matmul", flops=2e9, bytes_in=4e6, bytes_out=4e6)
    c1 = g.op("all_reduce", deps=[a.name], comm_bytes=64e6, comm_group="tp",
              comm_size=8, overlappable=True, stream="tp_comm")
    c2 = g.op("all_gather", deps=[a.name], comm_bytes=32e6, comm_group="dp",
              comm_size=4, overlappable=True, stream="dp_comm")
    b = g.op("matmul", deps=[a.name], flops=3e9, bytes_in=4e6, bytes_out=4e6)
    c3 = g.op("reduce_scatter", deps=[b.name], comm_bytes=16e6,
              comm_group="dp", comm_size=4, overlappable=True,
              stream="dp_comm")
    g.op("elementwise", deps=[b.name, c1.name, c2.name, c3.name],
         bytes_in=4e6, bytes_out=4e6, repeat=2)
    return g


def test_bandwidth_fast_path_matches_interval_path_graph_level():
    g = _comm_heavy_graph()
    eng = AnalyticalEngine(TPU_V5E)
    tl = apply_bandwidth_aware(schedule(g, eng), TPU_V5E)
    total, by_kind = schedule_times(g, eng, TPU_V5E, overlap="bandwidth")
    assert total == tl.total_time
    assert by_kind == tl.by_kind()


def test_bandwidth_fast_path_matches_interval_path_simulator():
    sim = Simulator("tpu_v5e", engine="analytical", overlap="bandwidth")
    for spec in (
        SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                workload=DecodeWorkload(global_batch=8, seq_len=1024)),
        SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=2, pp=2,
                                             microbatches=2),
                workload=TrainWorkload(global_batch=16, seq_len=512)),
    ):
        fast = sim.run(spec)
        slow = sim.run(spec, keep_timelines=True)
        assert fast.step_time_us == pytest.approx(slow.step_time_us,
                                                  rel=1e-12)
        assert fast.kind_us == pytest.approx(slow.kind_us, rel=1e-12)


# ------------------------- multiprocess sweeps -----------------------------

def _space(memory_limit=16e9):
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=16,
                                        memory_limit=memory_limit),
                   workload=DecodeWorkload(seq_len=1024))
    return SweepSpace(base, {"tp": (1, 2, 4), "pp": (1, 2),
                             "batch": (8, 16, 32)})


def _result_key(res):
    return (
        [(r.cand.key(), r.report.step_time_us, r.report.mfu,
          sorted(r.report.kind_us.items()), r.report.memory.total)
         for r in res.evaluated],
        [(r.cand.key(), r.reason) for r in res.pruned],
        [(r.cand.key(), r.report.step_time_us) for r in res.ranked()],
        [r.cand.key() for r in res.pareto()],
    )


def test_parallel_sweep_bit_identical_to_serial():
    serial = sweep(_space())
    parallel = sweep(_space(), workers=2)
    assert _result_key(serial) == _result_key(parallel)
    assert parallel.workers == 2 and serial.workers == 1
    # merged worker cache stats cover the same layers
    for layer in ("ingest", "block_times", "pricing", "collectives"):
        assert layer in parallel.cache_stats
    # every candidate was evaluated exactly once across shards
    assert len(parallel.evaluated) + len(parallel.pruned) \
        == len(serial.evaluated) + len(serial.pruned)


def test_parallel_sweep_memory_pruning_matches():
    serial = sweep(_space(memory_limit=2e9))
    parallel = sweep(_space(memory_limit=2e9), workers=2)
    assert [(p.cand.key(), p.reason) for p in serial.pruned] \
        == [(p.cand.key(), p.reason) for p in parallel.pruned]


def test_shard_items_keeps_trace_families_together():
    from repro.api.sweep import _shard_items
    items = []
    idx = 0
    for spec in _space().points():
        from repro.core.explorer import Candidate
        items.append((idx, spec, Candidate(spec.parallel,
                                           spec.workload.global_batch)))
        idx += 1
    shards = _shard_items(items, 2)
    assert sum(len(s) for s in shards) == len(items)
    # no (B_local, seq, cache) ingest family straddles two shards
    def fams(shard):
        return {(s.B_local(), s.workload.seq_len, s.workload.cache_len)
                for _, s, _ in shard}
    inter = fams(shards[0]) & fams(shards[1]) if len(shards) > 1 else set()
    assert not inter


# ------------------------- persistent cache --------------------------------

def test_persistent_cache_roundtrip_bit_identical(tmp_path):
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    fresh = Simulator("tpu_v5e").run(spec)
    s1 = Simulator("tpu_v5e", persist=str(tmp_path))
    r1 = s1.run(spec)
    assert s1.save_cache() is not None
    s2 = Simulator("tpu_v5e", persist=str(tmp_path))
    r2 = s2.run(spec)
    for a, b in ((r1, fresh), (r2, fresh)):
        assert a.step_time_us == b.step_time_us
        assert a.kind_us == b.kind_us
        assert a.memory.total == b.memory.total
    # exact repeat is served whole from the reports tier...
    assert s2.cache_stats()["reports"]["hits"] == 1
    assert s2.cache.loaded_sizes.get("ingest", 0) >= 1
    # ...and a changed shard config (same B_local, so same traced shapes)
    # skips tracing via the persisted ingest entry
    variant = dataclasses.replace(
        spec, parallel=ParallelConfig(tp=1, dp=4))
    s2.run(variant)
    assert s2.cache_stats()["ingest"]["hits"] >= 1
    assert s2.cache_stats()["ingest"]["misses"] == 0


def test_persistent_cache_disabled_by_default(tmp_path):
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    sim = Simulator("tpu_v5e")
    sim.run(spec)
    assert not sim.cache.persistent
    assert sim.save_cache() is None
    assert sim.cache_stats()["reports"]["hits"] == 0
    assert sim.cache_stats()["reports"]["misses"] == 0


def test_persistent_cache_invalidated_on_package_version_bump(tmp_path):
    import repro
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    s1 = Simulator("tpu_v5e", persist=str(tmp_path))
    s1.run(spec)
    s1.save_cache()
    old = repro.__version__
    try:
        repro.__version__ = old + ".post-bump"
        s2 = Simulator("tpu_v5e", persist=str(tmp_path))
        assert s2.cache.loaded_sizes == {}          # wholesale invalidation
        s2.run(spec)
        assert s2.cache_stats()["reports"]["misses"] == 1
        assert s2.cache_stats()["reports"]["hits"] == 0
    finally:
        repro.__version__ = old


def test_persistent_cache_invalidated_on_engine_state_bump(tmp_path):
    from repro.core.backend.profiling import ProfileDB
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    db = ProfileDB(path="/nonexistent/empty.json")
    s1 = Simulator("tpu_v5e", engine="profiling", db=db,
                   persist=str(tmp_path))
    r1 = s1.run(spec)
    s1.save_cache()
    # same engine state loads warm
    s2 = Simulator("tpu_v5e", engine="profiling",
                   db=ProfileDB(path="/nonexistent/empty.json"),
                   persist=str(tmp_path))
    assert s2.cache.loaded_sizes.get("reports", 0) == 1
    assert s2.run(spec).step_time_us == r1.step_time_us
    # a profile-DB with different contents must invalidate wholesale
    db3 = ProfileDB(path="/nonexistent/empty.json")
    db3.put("tpu_v5e|matmul|1,1,1|bf16", 1.0, {})
    s3 = Simulator("tpu_v5e", engine="profiling", db=db3,
                   persist=str(tmp_path))
    assert s3.cache.loaded_sizes == {}
    # in-process mutation after attach: the reports key carries the engine
    # state version, so the stale report is never served
    db3.put("tpu_v5e|matmul|2,2,2|bf16", 2.0, {})
    v0 = s3.engine._state_version()
    s3.run(spec)
    db3.put("tpu_v5e|matmul|3,3,3|bf16", 3.0, {})
    s3.run(spec)
    assert s3.engine._state_version() != v0
    assert s3.cache_stats()["reports"]["misses"] == 2
    # save_cache() after the mutation must stamp the file with the *mutated*
    # state (recomputed at save time): a process whose DB matches the
    # construction-time state may never load entries priced post-mutation
    s3.save_cache()
    s4 = Simulator("tpu_v5e", engine="profiling",
                   db=ProfileDB(path="/nonexistent/empty.json"),
                   persist=str(tmp_path))
    assert s4.cache.loaded_sizes == {}


def test_persistent_cache_corrupt_file_is_cold_start(tmp_path):
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    s1 = Simulator("tpu_v5e", persist=str(tmp_path))
    s1.run(spec)
    path = s1.save_cache()
    path.write_bytes(b"not a pickle")
    s2 = Simulator("tpu_v5e", persist=str(tmp_path))
    assert s2.cache.loaded_sizes == {}
    assert s2.run(spec).step_time_us == s1.run(spec).step_time_us


# --------------------- ingest batch extrapolation --------------------------

def test_ingest_extrapolation_bit_exact_and_self_verifying():
    from repro.core.model_ingest import (
        block_graphs, ingest_extrapolation_clear,
        ingest_extrapolation_stats, ingest_graphs,
    )

    def sig(mg):
        return [
            (bg.kind, bg.repeat,
             [(n.name, n.kind, n.dtype, n.flops, n.bytes_in, n.bytes_out,
               tuple(n.out_shape), tuple(sorted(n.attrs.items())),
               tuple(n.deps), n.repeat)
              for g in (bg.fwd, bg.joint) if g is not None
              for n in g.toposort()])
            for bg in mg.all_blocks()]

    ingest_extrapolation_clear()
    try:
        for B in (1, 2, 4, 8, 16, 32, 64):
            a = ingest_graphs(CFG, B, 1, "decode", cache_len=512)
            b = block_graphs(CFG, B, 1, "decode", cache_len=512)
            assert sig(a) == sig(b), f"extrapolation diverged at B={B}"
        st = ingest_extrapolation_stats()
        # anchors (2,4) + verification (8,16) traced; 32/64 extrapolated
        assert st["extrapolated"] >= 2
        assert st["traced"] <= 5
    finally:
        ingest_extrapolation_clear()

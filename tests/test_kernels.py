"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw


def _qkv(B, H, Hkv, Sq, Sk, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, D), jnp.float32).astype(dtype)
    return q, k, v


FA_CASES = [
    # (B, H, Hkv, Sq, Sk, D, causal, window, dtype, tol)
    (1, 2, 2, 128, 128, 64, True, 0, jnp.float32, 2e-6),
    (2, 4, 2, 192, 192, 64, True, 0, jnp.float32, 2e-6),   # GQA + ragged blocks
    (1, 4, 1, 128, 256, 32, False, 0, jnp.float32, 2e-6),  # MQA cross
    (2, 2, 2, 160, 160, 64, True, 64, jnp.float32, 2e-6),  # sliding window
    (1, 2, 2, 128, 128, 128, True, 0, jnp.bfloat16, 2e-2),
    (1, 8, 4, 96, 96, 64, True, 0, jnp.bfloat16, 2e-2),
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_sweep(case):
    B, H, Hkv, Sq, Sk, D, causal, window, dtype, tol = case
    q, k, v = _qkv(B, H, Hkv, Sq, Sk, D, dtype)
    out = fa_raw(q, k, v, causal=causal, window=window, interpret=True,
                 block_q=64, block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               atol=tol, rtol=tol)


DEC_CASES = [
    (2, 4, 2, 256, 64, jnp.float32, 2e-6),
    (1, 8, 1, 300, 64, jnp.float32, 2e-6),   # MQA, ragged splits
    (2, 4, 4, 512, 128, jnp.bfloat16, 2e-2),
]


@pytest.mark.parametrize("case", DEC_CASES)
def test_decode_attention_sweep(case):
    B, H, Hkv, T, D, dtype, tol = case
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, D), jnp.float32).astype(dtype)
    vl = jnp.asarray([T // 2, T][:B], jnp.int32)
    out = ops.decode_attention(q, k, v, vl)
    want = ref.decode_attention_ref(q, k, v, kv_valid_len=vl)
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 300), d=st.sampled_from([128, 256, 512]),
       offset=st.booleans(), bf16=st.booleans())
def test_rmsnorm_property(rows, d, offset, bf16):
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, d), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(d), (d,), jnp.float32) * 0.1 + 1.0
    out = ops.rmsnorm(x, w, offset=offset)
    want = ref.rmsnorm_ref(x, w, offset=offset)
    tol = 3e-2 if bf16 else 2e-6
    np.testing.assert_allclose(out.astype(jnp.float32), want.astype(jnp.float32),
                               atol=tol, rtol=tol)


def test_rmsnorm_fused_residual():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 10, 256))
    r = jax.random.normal(jax.random.PRNGKey(1), (4, 10, 256))
    w = jnp.ones((256,))
    out = ops.rmsnorm_residual(x, r, w)
    want = ref.rmsnorm_ref(x, w, residual=r)
    np.testing.assert_allclose(out, want, atol=2e-6, rtol=2e-6)


def test_flash_matches_model_layout():
    """bshd wrapper agrees with the model's blockwise attention path."""
    from repro.models import layers as L
    B, S, Hkv, G, D = 2, 128, 2, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hkv, G, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, D))
    out = ops.flash_attention_bshd(q, k, v, causal=True)
    want = L.attend_blockwise(q, k, v, q_offset=0, causal=True,
                              q_block=64, kv_block=64)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

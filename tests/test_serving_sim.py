"""Request-level serving simulator: deterministic workloads, conservation
invariants under every batching policy, oracle memoization, and the
goodput-vs-step-time objective divergence in the explorer."""
import pytest

from repro.api import Cluster, DecodeWorkload, ServingWorkload, SimSpec, \
    SweepSpace, sweep
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.serving.sim import (
    SLO, ChunkedPrefill, ContinuousBatching, DisaggregatedPD, LengthDist,
    Pool, ServingSimulator, StaticBatching, Workload,
    pow2_bucket, synthesize,
)
from repro.serving.sim.workload import SimRequest

CFG = get_config("xlstm-125m")
PAR = ParallelConfig(tp=2)


@pytest.fixture(scope="module")
def sim():
    # module-scoped: the serving oracle's misses (cold simulate calls) are
    # the slow part; every test after the first runs warm
    return Simulator("tpu_v5e", engine="analytical")


def _wl(n=80, seed=3, rate=40.0):
    return synthesize(
        n, rate_rps=rate,
        prompt=LengthDist("lognormal", median=64.0, sigma=0.6, cap=256),
        output=LengthDist("lognormal", median=12.0, sigma=0.5, cap=48),
        seed=seed)


# ---------------- workload generation ----------------

def test_workload_determinism():
    key = lambda wl: [(r.arrival_s, r.prompt_len, r.output_len)
                      for r in wl.requests]
    assert key(_wl(seed=5)) == key(_wl(seed=5))
    assert key(_wl(seed=5)) != key(_wl(seed=6))
    wl = _wl(seed=5)
    arrivals = [r.arrival_s for r in wl.requests]
    assert arrivals == sorted(arrivals)
    assert all(r.prompt_len >= 1 and r.output_len >= 1 for r in wl.requests)


def test_bursty_and_uniform_arrivals():
    for arrival in ("bursty", "uniform"):
        wl = synthesize(50, arrival=arrival, rate_rps=20.0, seed=1)
        arrivals = [r.arrival_s for r in wl.requests]
        assert arrivals == sorted(arrivals) and len(set(arrivals)) > 1


def test_trace_replay_and_shard():
    wl = Workload.from_trace([(2.0, 5, 3), (0.5, 7, 1), (1.0, 2, 2)])
    assert [r.arrival_s for r in wl.requests] == [0.5, 1.0, 2.0]
    assert [r.prompt_len for r in wl.requests] == [7, 2, 5]
    half = wl.shard(2)
    assert [r.rid for r in half.requests] == [0, 2]
    assert [r.rid for r in wl.shard(2, offset=1).requests] == [1]
    # sharded copies are reset clones, not aliases
    half.requests[0].decoded = 99
    assert wl.requests[0].decoded == 0


def test_thin_is_deprecated_shard():
    from repro.api.spec import CharonDeprecationWarning
    wl = Workload.from_trace([(0.5, 7, 1), (1.0, 2, 2), (2.0, 5, 3)])
    with pytest.warns(CharonDeprecationWarning):
        thinned = wl.thin(2)
    assert ([(r.rid, r.arrival_s, r.prompt_len) for r in thinned.requests]
            == [(r.rid, r.arrival_s, r.prompt_len)
                for r in wl.shard(2).requests])


def test_thin_external_call_warns_and_matches_shard():
    """The deprecation contract as an *external* caller sees it: pytest.ini
    escalates CharonDeprecationWarning to an error for intra-repo callers,
    but external users run with default filters — thin() must emit exactly
    one warning there, keep working, and stay bit-identical to shard()
    (both offsets, all request fields, reset decode state)."""
    import warnings

    from repro.api.spec import CharonDeprecationWarning
    wl = synthesize(40, arrival="bursty", rate_rps=25.0, seed=7)
    for offset in (0, 1):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("default")   # external-style filters
            thinned = wl.thin(3, offset)
        ours = [w for w in caught
                if issubclass(w.category, CharonDeprecationWarning)]
        assert len(ours) == 1
        assert "FleetSpec(replicas=k)" in str(ours[0].message)
        sharded = wl.shard(3, offset)
        assert ([(r.rid, r.arrival_s, r.prompt_len, r.output_len, r.decoded)
                 for r in thinned.requests]
                == [(r.rid, r.arrival_s, r.prompt_len, r.output_len,
                     r.decoded) for r in sharded.requests])
    # shim results are reset clones, never aliases of the source workload
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        t = wl.thin(2)
    t.requests[0].decoded = 123
    assert wl.requests[0].decoded == 0
    # and the escalation path external CI setups opt into still raises
    with warnings.catch_warnings():
        warnings.simplefilter("error", CharonDeprecationWarning)
        with pytest.raises(CharonDeprecationWarning):
            wl.thin(2)


def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert pow2_bucket(3, floor=64) == 64
    assert pow2_bucket(100, floor=64) == 128


# ---------------- event-loop conservation ----------------

POLICIES = [
    ContinuousBatching(8),
    ContinuousBatching(8, admit_cap=2),
    ChunkedPrefill(8, token_budget=128),
    StaticBatching(8),
    DisaggregatedPD(prefill_batch=2, decode_batch=8, transfer_s=0.002),
]


@pytest.mark.parametrize("policy", POLICIES, ids=lambda p: p.name)
def test_conservation_invariants(sim, policy):
    wl = _wl()
    rep = ServingSimulator(sim, CFG, par=PAR, policy=policy).run(
        wl, slo=SLO(ttft_s=1.0, tpot_ms=50.0))
    # every submitted request finishes exactly once
    assert rep.n_requests == wl.n_requests
    assert sorted(r.rid for r in rep.requests) == \
        sorted(r.rid for r in wl.requests)
    for r in rep.requests:
        assert r.prefilled == r.prompt_len
        assert r.decoded == r.output_len
        assert r.arrival_s <= r.start_s <= r.first_token_s <= r.finished_s
    # token conservation
    assert rep.prompt_tokens == wl.prompt_tokens
    assert rep.output_tokens == wl.output_tokens
    # the workload itself is never mutated (runs operate on reset copies)
    assert all(r.decoded == 0 and r.finished_s is None for r in wl.requests)


def test_run_is_deterministic(sim):
    wl = _wl(seed=9)
    ssim = ServingSimulator(sim, CFG, par=PAR, policy=ContinuousBatching(8))
    a, b = ssim.run(wl).summary(), ssim.run(wl).summary()
    a.pop("oracle_stats"), b.pop("oracle_stats")  # hit/miss split differs
    assert a == b


def test_disaggregated_pool_roles(sim):
    rep = ServingSimulator(
        sim, CFG, par=PAR,
        policy=DisaggregatedPD(prefill_batch=2, decode_batch=8)).run(_wl())
    assert set(rep.utilization) == {"prefill", "decode"}
    assert "decode_frac" not in rep.utilization["prefill"]
    assert "prefill_frac" not in rep.utilization["decode"]


# ---------------- policy unit behaviour (no oracle) ----------------

def _fake_reqs(n, prompt_len=100):
    return [SimRequest(rid=i, arrival_s=0.0, prompt_len=prompt_len,
                       output_len=4) for i in range(n)]


def test_static_waits_for_full_gang():
    pool = Pool("p", None)
    pool.queue.extend(_fake_reqs(2))
    pool.pending_arrivals = 5
    pol = StaticBatching(4)
    assert pol.plan(pool, 0.0) is None          # more arrivals may top it up
    pool.pending_arrivals = 0
    plan = pol.plan(pool, 0.0)                  # drain: partial gang admitted
    assert plan.kind == "prefill" and len(plan.prefill) == 2
    assert pol.plan(pool, 0.0) is None          # cohort in flight: no re-admit


def test_chunked_prefill_respects_token_budget():
    pool = Pool("p", None)
    pool.running.extend(_fake_reqs(3))
    pool.queue.extend(_fake_reqs(1, prompt_len=500))
    pol = ChunkedPrefill(max_batch=8, token_budget=16)
    plan = pol.plan(pool, 0.0)
    assert plan.kind == "mixed"
    assert len(plan.decode) == 3
    [(head, chunk)] = plan.prefill
    assert chunk == 16 - 3                      # decode tokens eat the budget
    head.prefilled += chunk
    plan2 = pol.plan(pool, 0.0)                 # same head keeps chunking
    assert plan2.prefill[0][0] is head


def test_continuous_admission_cap():
    pool = Pool("p", None)
    pool.queue.extend(_fake_reqs(6))
    plan = ContinuousBatching(8, admit_cap=2).plan(pool, 0.0)
    assert plan.kind == "prefill" and len(plan.prefill) == 2


# ---------------- oracle memoization ----------------

def test_oracle_memoization_across_sweep():
    s = Simulator("tpu_v5e", engine="analytical")
    ssim = ServingSimulator(s, CFG, par=PAR, policy=ContinuousBatching(8))
    wl = _wl(n=60)
    first = ssim.run(wl)
    # bucketing keeps distinct step keys tiny vs thousands of lookups
    assert first.oracle_stats["hits"] > 20 * first.oracle_stats["misses"]
    second = ssim.run(wl)
    assert second.oracle_stats["misses"] == 0   # fully served from SimCache
    assert second.oracle_stats["hit_rate"] == 1.0
    assert s.cache_stats()["serving"]["hits"] > 0


def test_oracle_invalidated_on_engine_state_mutation():
    # same workflow as the block-stage cache test: profile-then-resimulate
    # must never serve stale priced steps from the serving bucket
    from repro.core.backend.profiling import ProfileDB

    db = ProfileDB(path="/nonexistent/empty.json")
    s = Simulator("tpu_v5e", engine="profiling", db=db)
    ssim = ServingSimulator(s, CFG, par=PAR, policy=ContinuousBatching(8))
    wl = _wl(n=20)
    ssim.run(wl)
    misses0 = s.cache_stats()["serving"]["misses"]
    db.put("tpu_v5e|matmul|1,1,1|bf16", 1.0, {})   # any external put
    second = ssim.run(wl)
    # the version bump keys every step lookup afresh (no stale hits)
    assert second.oracle_stats["misses"] > 0
    assert s.cache_stats()["serving"]["misses"] > misses0


def test_oracle_front_memos_evict_and_respect_cache_toggle():
    from repro.serving.sim.oracle import StepOracle

    s = Simulator("tpu_v5e", engine="analytical")
    oracle = StepOracle(s, CFG, PAR)
    oracle.decode_step_s(4, 300)
    oracle.prefill_s(2, 128)
    assert len(oracle._raw) == 2 and len(oracle._price) == 2
    # a state-version change evicts stale front-memo entries wholesale
    # instead of leaking them (keys no longer carry the version)
    orig = s.engine._state_version
    s.engine._state_version = lambda: ("bumped",)
    try:
        oracle.decode_step_s(4, 300)
        assert len(oracle._raw) == 1 and len(oracle._price) == 1
    finally:
        s.engine._state_version = orig
    # with the sim cache disabled the memos are never populated
    s2 = Simulator("tpu_v5e", engine="analytical")
    s2.cache.enabled = False
    o2 = StepOracle(s2, CFG, PAR)
    o2.decode_step_s(4, 300)
    o2.prefill_s(2, 128)
    assert not o2._raw and not o2._price


# ---------------- explorer goodput objective ----------------

def test_goodput_ranking_diverges_from_step_time(sim):
    # under heavy load small batches win on step time but starve admission;
    # the documented scenario in docs/serving.md
    scen = ServingWorkload(
        n_requests=160, rate_rps=2000.0,
        prompt=LengthDist("lognormal", median=64.0, sigma=0.5, cap=256),
        output=LengthDist("fixed", value=24), seed=11,
        slo=SLO(ttft_s=0.05, tpot_ms=2.0))
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=8),
                   workload=DecodeWorkload(seq_len=512))
    res = sweep(SweepSpace(base, {"tp": (1, 2), "pp": (1,),
                                  "batch": (8, 32)}),
                sim=sim, objective="goodput", scenario=scen)
    assert res.evaluated and all(r.serving is not None for r in res.evaluated)
    by_step = res.ranked("step_time")
    by_goodput = res.ranked("goodput")
    assert [r.cand.key() for r in by_step] != \
        [r.cand.key() for r in by_goodput]
    assert by_goodput[0].goodput_rps > by_step[0].goodput_rps
    # the goodput winner trades per-step latency for admission capacity
    assert by_goodput[0].cand.global_batch > by_step[0].cand.global_batch


def test_step_time_objective_requires_no_serving(sim):
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=4),
                   workload=DecodeWorkload(seq_len=512))
    space = SweepSpace(base, {"tp": (1, 2), "pp": (1,), "batch": (8,)})
    res = sweep(space, sim=sim)
    assert res.ranked("step_time")
    with pytest.raises(ValueError):
        res.ranked("goodput")
    with pytest.raises(ValueError):
        sweep(space, sim=sim, objective="nonsense")

"""Cache correctness + simulation-throughput invariants.

The memoization layers (simcache / pricing / block-stage / toposort) must be
invisible in the numbers: cached and cold ``simulate()`` produce bit-identical
``Report``s, the interval-free scheduling fast path reproduces the interval
path exactly, and repeated sweeps are deterministic."""
import pytest

from repro.api import (
    Cluster, DecodeWorkload, PrefillWorkload, SimSpec, SweepSpace,
    TrainWorkload, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.core.backend.analytical import AnalyticalEngine
from repro.core.backend.hardware import TPU_V5E
from repro.core.explorer import Candidate, rule_memory_fit
from repro.core.ir import Graph
from repro.core.overlap import apply_ratio_overlap
from repro.core.scheduler import schedule, schedule_times

CFG = get_config("xlstm-125m")

SPECS = [
    SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=2, pp=2, microbatches=2),
            workload=TrainWorkload(global_batch=16, seq_len=512)),
    SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=2),
            workload=PrefillWorkload(global_batch=4, seq_len=512)),
    SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
            workload=DecodeWorkload(global_batch=8, seq_len=1024)),
]

DEC_SPEC = SPECS[2]


def _reports(sim, specs=SPECS):
    return [sim.run(s) for s in specs]


def _grid(seq_len=1024, chips=16, tp=(1, 2, 4), pp=(1, 2), batch=(8, 16, 32),
          memory_limit=0.0):
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=chips,
                                        memory_limit=memory_limit),
                   workload=DecodeWorkload(seq_len=seq_len))
    return SweepSpace(base, {"tp": tp, "pp": pp, "batch": batch})


def test_cached_vs_cold_bit_identical_reports():
    cold = _reports(Simulator("tpu_v5e", engine="analytical", cache=False))
    sim = Simulator("tpu_v5e", engine="analytical", cache=True)
    warm1 = _reports(sim)
    warm2 = _reports(sim)   # second pass: everything served from cache
    assert sim.cache_stats()["block_times"]["hits"] >= 3
    assert sim.cache_stats()["memory"]["hits"] >= 3
    for c, w1, w2 in zip(cold, warm1, warm2):
        for r in (w1, w2):
            assert r.step_time_us == c.step_time_us
            assert r.breakdown_us == c.breakdown_us
            assert r.kind_us == c.kind_us
            assert r.memory.total == c.memory.total
            assert r.mfu == c.mfu


def test_fast_path_matches_interval_path():
    # keep_timelines=True forces the Interval-building path; both must agree
    sim = Simulator("tpu_v5e", engine="analytical")
    fast = sim.run(DEC_SPEC)
    slow = sim.run(DEC_SPEC, keep_timelines=True)
    assert fast.step_time_us == pytest.approx(slow.step_time_us, rel=1e-12)
    assert fast.kind_us == pytest.approx(slow.kind_us, rel=1e-12)
    assert slow.block_timelines and not fast.block_timelines


def test_schedule_times_equals_schedule_plus_overlap():
    g = Graph("g")
    a = g.op("matmul", flops=1e9, bytes_in=1e6, bytes_out=1e6)
    c = g.op("all_reduce", deps=[a.name], comm_bytes=4e6, comm_group="tp",
             comm_size=8, overlappable=True, stream="tp_comm")
    b = g.op("matmul", deps=[a.name], flops=2e9, bytes_in=1e6, bytes_out=1e6)
    g.op("elementwise", deps=[b.name, c.name], bytes_in=1e6, bytes_out=1e6,
         repeat=3)
    eng = AnalyticalEngine(TPU_V5E)
    tl = apply_ratio_overlap(schedule(g, eng), TPU_V5E)
    total, by_kind = schedule_times(g, eng, TPU_V5E)
    assert total == tl.total_time
    assert by_kind == tl.by_kind()


def test_toposort_cache_invalidation():
    g = Graph("g")
    a = g.op("matmul")
    first = g.toposort()
    assert g.toposort() is first            # cached
    b = g.op("matmul", deps=[a.name])
    order = g.toposort()
    assert order is not first and len(order) == 2
    g.remove(b.name)
    assert len(g.toposort()) == 1


def test_explore_pricing_cache_hit_rate_and_stats():
    sim = Simulator("tpu_v5e", engine="analytical")
    res = sweep(_grid(), sim=sim)
    assert res.evaluated and res.configs_per_sec > 0 and res.n_groups > 0
    pr = res.cache_stats["pricing"]
    assert pr["hits"] > 0
    assert pr["hits"] / (pr["hits"] + pr["misses"]) > 0.3
    # candidates sharing (tp, B_local) reuse whole priced block stages
    assert res.cache_stats["block_times"]["hits"] > 0
    assert res.cache_stats["ingest"]["misses"] < len(res.evaluated)


def test_explore_deterministic_pareto():
    def frontier():
        sim = Simulator("tpu_v5e", engine="analytical")
        res = sweep(_grid(), sim=sim)
        return [(r.cand.key(), r.report.step_time_us, r.tps_per_chip)
                for r in res.pareto()]
    f1, f2 = frontier(), frontier()
    assert f1 == f2

    # a warm simulator must reproduce its own cold frontier too
    sim = Simulator("tpu_v5e", engine="analytical")
    r1 = sweep(_grid(), sim=sim)
    r2 = sweep(_grid(), sim=sim)
    key = lambda res: [(r.cand.key(), r.report.step_time_us) for r in res.pareto()]
    assert key(r1) == key(r2)


def test_rule_memory_fit_prunes_before_simulation():
    rule = rule_memory_fit(1e6, mode="decode", seq_len=4096)  # 1 MB: nothing fits
    c = Candidate(ParallelConfig(tp=2, dp=8), 32)
    assert "memory-fit" in rule(CFG, c)
    roomy = rule_memory_fit(1e15, mode="decode", seq_len=4096)
    assert roomy(CFG, c) is None

    # in a sweep, infeasible candidates are pruned without being simulated
    sim = Simulator("tpu_v5e", engine="analytical")
    res = sweep(_grid(tp=(1, 2), pp=(1,), batch=(8, 16), memory_limit=1e6),
                sim=sim)
    assert not res.evaluated
    assert all(p.report is None and "memory-fit" in p.reason
               for p in res.pruned)


def test_memory_fit_estimate_is_lower_bound():
    # prune rule must never reject a candidate the simulator would accept:
    # the closed-form estimate stays below the simulated total
    sim = Simulator("tpu_v5e", engine="analytical")
    for tp, gb in [(1, 8), (2, 16), (4, 32)]:
        par = ParallelConfig(tp=tp, dp=16 // tp)
        rep = sim.run(SimSpec(CFG, parallel=par,
                              workload=DecodeWorkload(global_batch=gb,
                                                      seq_len=1024)))
        limit = rep.memory.total
        rule = rule_memory_fit(limit, mode="decode", seq_len=1024)
        assert rule(CFG, Candidate(par, gb)) is None


def test_pricing_cache_invalidated_on_profile_db_mutation():
    # the §3.3 workflow: simulate with an empty DB (analytical fallback),
    # then add measured profiles — re-simulation must pick them up
    from repro.core.backend.profiling import ProfileDB, node_key
    from repro.core.ir import OpNode

    db = ProfileDB(path="/nonexistent/empty.json")
    sim = Simulator("tpu_v5e", engine="profiling", db=db)
    node = OpNode("mm", "matmul", flops=1e9, bytes_in=1e6, bytes_out=1e6,
                  attrs={"mm_dims": (256, 256, 256)})
    t_fallback = sim.engine.latency_us(node)
    assert sim.engine.engine_for(node) == "analytical"   # db empty
    db.put(node_key(node, sim.hw.name), 123.0, {})
    assert sim.engine.latency_us(node) == 123.0
    assert sim.engine.engine_for(node) == "profiling"
    assert t_fallback != 123.0


def test_block_stage_cache_invalidated_on_profile_db_mutation():
    from repro.core.backend.profiling import ProfileDB

    db = ProfileDB(path="/nonexistent/empty.json")
    sim = Simulator("tpu_v5e", engine="profiling", db=db)
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    r1 = sim.run(spec)
    db.put("tpu_v5e|matmul|1,1,1|bf16", 1.0, {})   # any external put
    r2 = sim.run(spec)
    # that key matches no node, so results are equal — but they must have
    # been recomputed, not served from a stale stage (block_times missed)
    assert r2.step_time_us == r1.step_time_us
    assert sim.cache_stats()["block_times"]["misses"] >= 2


def test_collective_time_memoized_and_self_invalidating():
    from dataclasses import replace

    from repro.core.backend.collectives import (
        GroupSpec, _hierarchical_uncached, collective_memo_clear,
        collective_memo_stats, hierarchical_collective_time_us,
    )
    from repro.core.backend.hardware import TPU_V5E

    collective_memo_clear()
    args = ("all_reduce", 64e6, GroupSpec(intra_size=8, inter_size=2))
    t1 = hierarchical_collective_time_us(*args, TPU_V5E)
    assert t1 == _hierarchical_uncached(*args, TPU_V5E)   # memo is invisible
    before = collective_memo_stats().hits
    t2 = hierarchical_collective_time_us(*args, TPU_V5E)
    assert t2 == t1 and collective_memo_stats().hits == before + 1

    # the key carries the link-domain fields: different hardware (or a
    # recalibrated link) can never be served a stale entry
    slow = replace(TPU_V5E, name="slow",
                   intra=replace(TPU_V5E.intra, bandwidth=1e9))
    t_slow = hierarchical_collective_time_us(*args, slow)
    assert t_slow > t1

    collective_memo_clear()
    assert collective_memo_stats().total == 0


def test_simulate_exposes_collective_memo_stats():
    sim = Simulator("tpu_v5e", engine="analytical")
    sim.cache_clear()
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    sim.run(spec)
    sim.run(spec)
    st = sim.cache_stats()["collectives"]
    assert st["hits"] > 0                        # repeat p2p terms memoized


def test_simulate_does_not_mutate_caller_parallel_config():
    sim = Simulator("tpu_v5e", engine="analytical")
    par = ParallelConfig(tp=2, dp=2)
    snapshot = par.key()
    sim.run(SimSpec(CFG, parallel=par,
                    workload=DecodeWorkload(global_batch=8, seq_len=512)))
    assert par.key() == snapshot

"""Checkpointing (incl. restart + retention), data pipeline determinism,
optimizers, fault-tolerance supervision, sharding resolver."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny_config
from repro.distributed.sharding import (
    DEFAULT_RULES, ShardingEnv, activate, fsdp_spec, resolve_spec,
)
from repro.training.checkpoint import CheckpointManager
from repro.training.data import SyntheticTokenPipeline
from repro.training.fault_tolerance import ElasticPlan, StepMonitor, run_with_restarts
from repro.training.optimizer import (
    adafactor, adamw, cosine_schedule, int8_compress_decompress, make_optimizer,
)


# ---------------- optimizers ----------------

def test_adamw_matches_manual_first_step():
    lr = lambda step: jnp.asarray(0.1)
    opt = adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    st = opt.init(p)
    new_p, st = opt.update(g, st, p)
    # bias-corrected first step = -lr * g/|g| elementwise (adam property)
    np.testing.assert_allclose(new_p["w"], [1.0 - 0.1, 2.0 + 0.1], rtol=1e-4)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = make_optimizer(name, peak_lr=0.05)
    p = {"w": jnp.ones((8, 8))}
    st = opt.init(p)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    l0 = float(loss(p))
    for _ in range(60):
        g = jax.grad(loss)(p)
        p, st = opt.update(g, st, p)
    assert float(loss(p)) < l0 * 0.7


def test_adafactor_state_is_factored():
    opt = make_optimizer("adafactor")
    p = {"w": jnp.ones((64, 32))}
    st = opt.init(p)
    sizes = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(st["f"]))
    assert sizes == 64 + 32  # vr + vc, not 64*32


def test_int8_compression_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    gq = int8_compress_decompress(g)
    assert float(jnp.max(jnp.abs(g - gq))) <= float(jnp.max(jnp.abs(g))) / 127 + 1e-6


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


# ---------------- checkpoint ----------------

def test_checkpoint_roundtrip_and_retention(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
             "step": jnp.asarray(7, jnp.int32)}
    for s in (1, 2, 3):
        ckpt.save(s, state, extra={"data_step": s * 10})
    assert ckpt.all_steps() == [2, 3]  # retention
    target = jax.tree.map(jnp.zeros_like, state)
    restored, extra = ckpt.restore(target)
    assert extra["data_step"] == 30
    np.testing.assert_array_equal(restored["a"], state["a"])
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_restart_resumes_stream(tmp_path):
    cfg = get_tiny_config("xlstm-125m")
    pipe = SyntheticTokenPipeline(cfg, global_batch=2, seq_len=8, seed=3)
    b0, b1, b2 = next(pipe), next(pipe), next(pipe)
    pipe.close()
    pipe2 = SyntheticTokenPipeline(cfg, global_batch=2, seq_len=8, seed=3,
                                   start_step=2)
    b2b = next(pipe2)
    pipe2.close()
    np.testing.assert_array_equal(b2["tokens"], b2b["tokens"])


def test_run_with_restarts_recovers(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    calls = []

    def loop(start):
        calls.append(start)
        if len(calls) == 1:
            ckpt.save(4, {"x": jnp.ones(())})
            raise RuntimeError("simulated node failure")
        return 10

    assert run_with_restarts(loop, ckpt, max_restarts=2) == 10
    assert calls == [0, 5]  # restarted after the step-4 checkpoint


def test_elastic_plan_rescale():
    plan = ElasticPlan(tp=4, pp=2, dp=8, global_batch=64)
    new = plan.rescale(surviving_chips=48)  # lost 16 of 64
    assert new.tp == 4 and new.pp == 2
    assert new.dp == 6 and new.global_batch == 48


def test_elastic_plan_rescale_batch_accounting():
    plan = ElasticPlan(tp=2, pp=2, dp=4, global_batch=32)
    per_dp = plan.global_batch // plan.dp
    for chips in (16, 12, 8, 5, 3):
        new = plan.rescale(chips)
        assert new.dp == max(chips // 4, 1)
        # per-replica batch is preserved exactly; global batch follows dp
        assert new.global_batch == per_dp * new.dp
        assert new.global_batch % new.dp == 0
    # even losing everything but one chip leaves a runnable dp=1 plan
    assert plan.rescale(1).dp == 1


def test_step_monitor_stop_before_start_raises():
    mon = StepMonitor()
    with pytest.raises(RuntimeError, match="before start"):
        mon.stop()
    # and stop() consumes the start: a second stop needs a fresh start
    mon.start()
    mon.stop()
    with pytest.raises(RuntimeError, match="before start"):
        mon.stop()


def test_run_with_restarts_budget_resets_on_progress(tmp_path):
    # 4 transient failures, each after a *new* checkpoint: with
    # max_restarts=2 an absolute budget would raise on the 3rd, but the
    # progress-aware budget keeps going because every attempt advanced.
    ckpt = CheckpointManager(tmp_path, keep=10)
    calls = []

    def loop(start):
        calls.append(start)
        if len(calls) <= 4:
            ckpt.save(len(calls) * 10, {"x": jnp.ones(())})
            raise RuntimeError("transient fault")
        return 99

    assert run_with_restarts(loop, ckpt, max_restarts=2) == 99
    assert calls == [0, 11, 21, 31, 41]


def test_run_with_restarts_crash_loop_still_raises(tmp_path):
    # No checkpoint progress between failures -> the budget is NOT reset
    # and the loop gives up after max_restarts retries.
    ckpt = CheckpointManager(tmp_path)
    calls = []

    def loop(start):
        calls.append(start)
        raise RuntimeError("persistent fault")

    with pytest.raises(RuntimeError, match="persistent"):
        run_with_restarts(loop, ckpt, max_restarts=2)
    assert calls == [0, 0, 0]  # initial try + 2 retries


def test_checkpoint_restore_rejects_dtype_mismatch(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    ckpt.save(1, {"w": jnp.ones((2, 2), jnp.float32)})
    target = {"w": jnp.zeros((2, 2), jnp.int32)}
    with pytest.raises(ValueError, match="dtype mismatch"):
        ckpt.restore(target)
    # bf16 target vs float32 on disk is the save-widening round trip, OK
    ckpt.save(2, {"b": jnp.ones((3,), jnp.bfloat16)})
    restored, _ = ckpt.restore({"b": jnp.zeros((3,), jnp.bfloat16)}, step=2)
    assert restored["b"].dtype == jnp.bfloat16


def test_checkpoint_ignores_leftover_tmp_dir(tmp_path):
    # a crash mid-write leaves .tmp_step_*; it must be invisible to
    # all_steps()/latest_step() and a later save of that step must succeed
    ckpt = CheckpointManager(tmp_path)
    ckpt.save(3, {"x": jnp.ones(())})
    crashed = tmp_path / ".tmp_step_000000007"
    crashed.mkdir()
    (crashed / "arrays.npz").write_bytes(b"garbage")
    assert ckpt.all_steps() == [3]
    assert ckpt.latest_step() == 3
    ckpt.save(7, {"x": jnp.full((), 2.0)})   # reuses + replaces the tmp dir
    assert ckpt.all_steps() == [3, 7]
    restored, _ = ckpt.restore({"x": jnp.zeros(())}, step=7)
    assert float(restored["x"]) == 2.0


def test_checkpoint_async_wait_ordering(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=10, async_save=True)
    state = {"x": jnp.arange(4, dtype=jnp.float32)}
    # back-to-back async saves: each save waits for the previous writer,
    # so publishes land in order and wait() makes the last one durable
    for s in (1, 2, 3):
        ckpt.save(s, {"x": jnp.full((4,), float(s))})
    ckpt.wait()
    assert ckpt.all_steps() == [1, 2, 3]
    restored, _ = ckpt.restore(state)
    np.testing.assert_array_equal(restored["x"], np.full((4,), 3.0))


def test_checkpoint_resharding_restore_roundtrip(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    state = {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4)}
    ckpt.save(1, state)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = {"w": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}
    restored, _ = ckpt.restore(jax.tree.map(jnp.zeros_like, state),
                               shardings=sh)
    np.testing.assert_array_equal(restored["w"], state["w"])
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)


def test_step_monitor_detects_straggler():
    mon = StepMonitor(window=50, z_threshold=2.0)
    import time as _t
    for i in range(12):
        mon.start()
        _t.sleep(0.001)
        mon.stop()
    mon.start()
    _t.sleep(0.08)
    mon.stop()
    assert mon.stragglers


# ---------------- sharding resolver ----------------

# jax.sharding.AxisType landed after the pinned jax 0.4.37; skip (instead of
# CI-level --deselect) so a local `pytest -x -q` matches CI with no flags
needs_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType not available in this jax version")


def _env(shape=(4, 2), axes=("data", "model")):
    # AbstractMesh: the resolver only needs axis names/sizes (1-device CI)
    mesh = jax.sharding.AbstractMesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return ShardingEnv(mesh)


@needs_axis_type
def test_resolver_divisibility_fallback():
    env = _env()
    # 6 heads on a 2-wide model axis: shardable; 7: dropped
    spec = resolve_spec(env, ("batch", "kv_heads"), (8, 6))
    assert spec == jax.sharding.PartitionSpec(("data",), "model") or \
        spec == jax.sharding.PartitionSpec("data", "model")
    spec2 = resolve_spec(env, ("batch", "kv_heads"), (8, 7))
    assert len(spec2) == 1  # model axis dropped


@needs_axis_type
def test_resolver_no_axis_reuse():
    env = _env()
    spec = resolve_spec(env, ("heads", "ffn"), (4, 4))  # both want 'model'
    used = [s for s in spec if s is not None]
    assert used.count("model") <= 1


@needs_axis_type
def test_fsdp_spec_adds_data_axis():
    env = _env()
    spec = fsdp_spec(env, ("layer", None, "ffn"), (3, 8, 4), skip_leading=1)
    # dim1 (=8) divisible by data(4): gets the fsdp axis
    assert spec[1] == "data" or spec[1] == ("data",)

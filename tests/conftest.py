# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py fakes 512 devices.
import jax
import pytest

# ---------------------------------------------------------------------------
# hypothesis fallback: the container image lacks the package, which turned
# three test modules into collection errors.  When the real library is absent
# we install a minimal deterministic shim (seeded uniform sampling; supports
# the strategy subset the suite uses) so property tests still run.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def integers(min_value=0, max_value=100, **_):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def lists(elem, min_size=0, max_size=10, **_):
        def sample(rng):
            n = rng.randint(min_size, max_size)
            return [elem.sample(rng) for _ in range(n)]
        return _Strategy(sample)

    def given(**strategies):
        # note: no functools.wraps — pytest would introspect the wrapped
        # signature and demand fixtures for the strategy parameters
        def deco(fn):
            def wrapper():
                rng = random.Random(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    fn(**{k: s.sample(rng) for k, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = 10
            return wrapper
        return deco

    def settings(max_examples=10, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.floats = floats
    st_mod.integers = integers
    st_mod.booleans = booleans
    st_mod.sampled_from = sampled_from
    st_mod.lists = lists
    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py fakes 512 devices.
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

"""Spec-API invariants: the legacy kwargs shims are bit-identical to the
spec path, specs are hashable round-trippable cache keys, and SweepSpace
expresses (and correctly evaluates) axes the old ``explore()`` could not."""
import dataclasses
import warnings

import pytest

from repro.api import (
    CharonDeprecationWarning, Cluster, DecodeWorkload, PrefillWorkload,
    ServingWorkload, SimSpec, SweepSpace, TrainWorkload, spec_replace, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.core.explorer import explore

CFG = get_config("xlstm-125m")

LEGACY_CASES = [
    # (simulate kwargs, equivalent workload)
    (dict(mode="train", global_batch=16, seq_len=512,
          par=ParallelConfig(tp=2, dp=2, pp=2, microbatches=2),
          remat="block", optimizer="adamw"),
     TrainWorkload(global_batch=16, seq_len=512)),
    (dict(mode="train", global_batch=16, seq_len=512,
          par=ParallelConfig(tp=2, dp=4), remat="dots", fusion=True,
          quantize="int8", optimizer="adafactor"),
     TrainWorkload(global_batch=16, seq_len=512, remat="dots", fusion=True,
                   quantize="int8", optimizer="adafactor")),
    (dict(mode="prefill", global_batch=4, seq_len=512,
          par=ParallelConfig(tp=2, dp=2), remat="none"),
     PrefillWorkload(global_batch=4, seq_len=512)),
    # remat/optimizer are inert outside training: the legacy defaults
    # ("block"/"adamw") must map onto the same spec result
    (dict(mode="prefill", global_batch=4, seq_len=256,
          par=ParallelConfig(tp=2, dp=2)),
     PrefillWorkload(global_batch=4, seq_len=256)),
    (dict(mode="decode", global_batch=8, seq_len=1024,
          par=ParallelConfig(tp=2, dp=4), remat="none"),
     DecodeWorkload(global_batch=8, seq_len=1024)),
    (dict(mode="decode", global_batch=8, seq_len=256, cache_len=2048,
          par=ParallelConfig(tp=2, dp=4), remat="none"),
     DecodeWorkload(global_batch=8, seq_len=256, cache_len=2048)),
]


def _bit_identical(a, b):
    assert a.step_time_us == b.step_time_us
    assert a.breakdown_us == b.breakdown_us
    assert a.kind_us == b.kind_us
    assert a.memory.total == b.memory.total
    assert a.memory.summary() == b.memory.summary()
    assert a.mfu == b.mfu
    assert a.tokens_per_s == b.tokens_per_s


@pytest.mark.parametrize("case", range(len(LEGACY_CASES)))
def test_legacy_simulate_shim_bit_identical(case):
    kw, workload = LEGACY_CASES[case]
    kw = dict(kw)
    par = kw.pop("par")
    sim = Simulator("tpu_v5e", engine="analytical")
    spec = SimSpec(CFG, parallel=par, workload=workload)
    via_spec = sim.run(spec)
    with pytest.warns(CharonDeprecationWarning):
        via_legacy = sim.simulate(CFG, par=par, **kw)
    _bit_identical(via_spec, via_legacy)
    # and against a cold simulator, so the equality is not just cache reuse
    cold = Simulator("tpu_v5e", engine="analytical", cache=False)
    _bit_identical(cold.run(spec), via_spec)


def _specs():
    out = [SimSpec(CFG, parallel=par, workload=w)
           for kw, w in LEGACY_CASES for par in [kw["par"]]]
    out.append(SimSpec(CFG, cluster=Cluster("h100_sxm", chips=16, pods=2,
                                            memory_limit=40e9),
                       parallel=ParallelConfig(tp=2, dp=4),
                       workload=DecodeWorkload(global_batch=32, seq_len=4096)))
    out.append(SimSpec(CFG, parallel=ParallelConfig(tp=2),
                       workload=ServingWorkload(n_requests=50, rate_rps=20.0,
                                                arrival="bursty", seed=7)))
    return out


def test_spec_roundtrip_asdict_equal_hash():
    for spec in _specs():
        back = SimSpec.from_dict(spec.asdict())
        assert back == spec
        assert hash(back) == hash(spec)


def test_spec_is_a_cache_key():
    a, b = _specs()[0], _specs()[0]
    assert a is not b
    d = {a: "priced"}
    assert d[b] == "priced"                      # equal specs collide
    c = spec_replace(a, {"workload.global_batch": 999})
    assert c not in d


def test_cluster_normalizes_hardware_spec_and_pods():
    from repro.core.backend.hardware import TPU_V5E
    assert Cluster(TPU_V5E).hardware == "tpu_v5e"
    assert Cluster(TPU_V5E) == Cluster("tpu_v5e")
    assert Cluster(TPU_V5E).resolve() is TPU_V5E
    with pytest.raises(KeyError):
        Cluster("not_a_chip")
    # cluster pods default the parallel pod count; conflicts raise
    s = SimSpec(CFG, cluster=Cluster("tpu_v5e", pods=2),
                parallel=ParallelConfig(tp=2, dp=2))
    assert s.parallel.pods == 2
    with pytest.raises(ValueError):
        SimSpec(CFG, cluster=Cluster("tpu_v5e", pods=2),
                parallel=ParallelConfig(tp=2, dp=2, pods=4))


def test_cluster_replace_rederives_custom_hardware():
    from dataclasses import replace

    from repro.core.backend.hardware import TPU_V5E
    custom = replace(TPU_V5E, name="my_chip")
    c = Cluster(custom)
    assert c.resolve() is custom
    # non-hardware replace keeps the custom spec; renaming drops it
    assert replace(c, chips=8).resolve() is custom
    c2 = replace(c, hardware="h100_sxm")
    assert c2.resolve().name == "h100_sxm"
    with pytest.raises(KeyError):
        replace(c, hardware="not_a_chip")
    # and a custom-hardware spec round-trips through asdict/from_dict
    spec = SimSpec(CFG, cluster=Cluster(custom), workload=DecodeWorkload())
    back = SimSpec.from_dict(spec.asdict())
    assert back == spec and back.cluster.resolve() == custom


def test_sweep_rejects_serving_workload_base():
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=4),
                   workload=ServingWorkload(n_requests=5))
    with pytest.raises(TypeError):
        sweep(SweepSpace(base, {"tp": (1, 2)}))


def test_sweep_axis_typos_fail_fast():
    base = SimSpec(CFG, workload=DecodeWorkload())
    with pytest.raises(KeyError):
        SweepSpace(base, {"workload.seq_length": (512,)})   # dotted typo
    with pytest.raises(KeyError):
        SweepSpace(base, {"seq_length": (512,)})            # bare typo
    with pytest.raises(KeyError):
        SweepSpace(base, {"engine.tp": (1,)})               # bad component
    with pytest.raises(TypeError):
        SweepSpace(base, {"hardware": "h100_sxm"})          # bare string
    with pytest.raises(ValueError):
        with pytest.warns(CharonDeprecationWarning):
            explore(Simulator("tpu_v5e", engine="analytical"), CFG,
                    chips=4, memory_limit=0.0)              # ambiguous limit


def test_run_rejects_wrong_hardware_and_serving_workloads():
    sim = Simulator("tpu_v5e", engine="analytical")
    with pytest.raises(ValueError):
        sim.run(SimSpec(CFG, cluster=Cluster("h100_sxm"),
                        workload=DecodeWorkload()))
    with pytest.raises(TypeError):
        sim.run(SimSpec(CFG, workload=ServingWorkload(n_requests=5)))


# ---------------- sweep equivalence ----------------

GRID = dict(tp_choices=(1, 2, 4), pp_choices=(1, 2),
            batch_choices=(8, 16, 100))


def _space(memory_limit=0.0):
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=16,
                                        memory_limit=memory_limit),
                   workload=DecodeWorkload(seq_len=1024))
    return SweepSpace(base, {"tp": GRID["tp_choices"],
                             "pp": GRID["pp_choices"],
                             "batch": GRID["batch_choices"]})


def test_legacy_explore_shim_bit_identical_rankings():
    with pytest.warns(CharonDeprecationWarning):
        legacy = explore(Simulator("tpu_v5e", engine="analytical"), CFG,
                         mode="decode", seq_len=1024, chips=16,
                         memory_limit=16e9, **GRID)
    new = sweep(_space(memory_limit=16e9),
                sim=Simulator("tpu_v5e", engine="analytical"))
    key = lambda res: [(r.cand.key(), r.report.step_time_us, r.tps_per_chip)
                       for r in res.ranked()]
    assert key(legacy) == key(new)
    assert [(p.cand.key(), p.reason) for p in legacy.pruned] == \
        [(p.cand.key(), p.reason) for p in new.pruned]
    assert legacy.n_groups == new.n_groups
    assert [(r.cand.key(),) for r in legacy.pareto()] == \
        [(r.cand.key(),) for r in new.pareto()]
    # reuse-grouping + cache layers behave identically under both surfaces
    for layer in ("block_times", "pricing", "ingest"):
        assert legacy.cache_stats[layer] == new.cache_stats[layer]
    # every new-path result carries its full spec
    assert all(r.spec is not None for r in new.evaluated)


def test_sweep_axes_beyond_the_legacy_grid():
    # seq_len x quantize x hardware in ONE space: inexpressible with
    # explore(tp_choices=...) — the old surface hardcoded tp/pp/batch/micro
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=8),
                   parallel=ParallelConfig(),
                   workload=DecodeWorkload(global_batch=16))
    space = SweepSpace(base, {"tp": (1, 2), "seq_len": (512, 2048),
                              "quantize": (None, "int8"),
                              "hardware": ("tpu_v5e", "h100_sxm")})
    assert space.size() == 16
    res = sweep(space)
    assert len(res.evaluated) == 16
    hw = {r.spec.cluster.hardware for r in res.evaluated}
    assert hw == {"tpu_v5e", "h100_sxm"}
    # quantization must matter: int8 beats bf16 step time on equal shapes
    by = {(r.spec.cluster.hardware, r.spec.parallel.tp,
           r.spec.workload.seq_len, r.spec.workload.quantize):
          r.report.step_time_us for r in res.evaluated}
    for h in ("tpu_v5e", "h100_sxm"):
        assert by[(h, 2, 2048, "int8")] < by[(h, 2, 2048, None)]
    # reuse grouping still reports: every distinct (hw, shapes) is a group
    assert res.n_groups == 16
    assert res.cache_stats["pricing"]["hits"] > 0


def test_sweep_derives_dp_and_skips_nondivisible():
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=8),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    res = sweep(SweepSpace(base, {"tp": (1, 2, 3)}))  # tp=3 !| 8 chips
    tps = sorted(r.spec.parallel.tp for r in res.evaluated)
    assert tps == [1, 2]
    assert all(r.spec.parallel.chips == 8 for r in res.evaluated)


def test_memory_liveness_memoized_across_candidates():
    sim = Simulator("tpu_v5e", engine="analytical")
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    r1 = sim.run(spec)
    st = sim.cache_stats()["memory"]
    assert st == {"hits": 0, "misses": 1, "hit_rate": 0.0}
    # dp-only change shares the transformed first block -> liveness hit
    r2 = sim.run(spec_replace(spec, {"parallel.dp": 8,
                                     "workload.global_batch": 16}))
    st = sim.cache_stats()["memory"]
    assert st["hits"] == 1 and st["misses"] == 1
    assert r1.memory.activations_peak == r2.memory.activations_peak


def test_serving_spec_run_matches_legacy_construction():
    from repro.serving.sim import ContinuousBatching, ServingSimulator
    sim = Simulator("tpu_v5e", engine="analytical")
    par = ParallelConfig(tp=2)
    sw = ServingWorkload(n_requests=40, rate_rps=40.0, seed=3, max_batch=8,
                         policy="continuous")
    spec = SimSpec(CFG, parallel=par, workload=sw)
    via_spec = ServingSimulator(sim).run(spec)
    legacy = ServingSimulator(sim, CFG, par=par,
                              policy=ContinuousBatching(8)).run(
        sw.build(), slo=sw.slo)
    a, b = via_spec.summary(), legacy.summary()
    a.pop("oracle_stats"), b.pop("oracle_stats")  # hit/miss split differs
    assert a == b


# ---------------------------------------------------------------------------
# Frozen-spec JSON round-trip property test — the runtime twin of charon-lint
# rule R3 (spec-surface drift): every frozen spec dataclass, discovered
# automatically, must survive to_json/from_dict round-trips with equality,
# json_hash() and hash() intact.  The "maximal" specs below set every public
# field of every spec class to a non-default value, so a field silently
# dropped by from_dict (or excluded from __eq__) fails here even before the
# linter sees the source.
# ---------------------------------------------------------------------------

def _discovered_spec_classes():
    import inspect

    import repro.api.spec as spec_mod
    out = {}
    for name, obj in vars(spec_mod).items():
        if (inspect.isclass(obj) and dataclasses.is_dataclass(obj)
                and obj.__module__ == spec_mod.__name__
                and obj.__dataclass_params__.frozen
                and not name.startswith("_")):
            out[name] = obj
    return out


def _maximal_specs():
    from repro.api.spec import (
        AutoscalerSpec, CheckpointSpec, Cluster, DecodeWorkload, FaultModel,
        FleetSpec, PrefillWorkload, ReplicaFaultSpec, ResilienceSpec,
        RouterSpec, ServingWorkload, TrainWorkload,
    )
    from repro.serving.sim.report import SLO
    from repro.serving.sim.workload import LengthDist

    cluster = Cluster(hardware="tpu_v5p", chips=16, pods=2,
                      memory_limit=123e9)
    par = ParallelConfig(tp=2, dp=2, pods=2)
    train = SimSpec(CFG, cluster=cluster, parallel=par,
                    workload=TrainWorkload(
                        global_batch=16, seq_len=256, cache_len=128,
                        fusion=True, quantize="int8", remat="dots",
                        optimizer="adafactor",
                        resilience=ResilienceSpec(
                            total_steps=777,
                            faults=FaultModel(chip_mtbf_s=9e6,
                                              host_mtbf_s=4e5,
                                              link_mtbf_s=8e6,
                                              dist="weibull",
                                              weibull_shape=0.9, seed=3),
                            ckpt=CheckpointSpec(interval_steps=50,
                                                mode="async",
                                                write_gbps=1.5,
                                                restore_factor=1.2,
                                                async_overhead=0.1),
                            chips_per_host=4, spares=2, elastic=False,
                            restart_delay_s=33.0, repair_s=444.0,
                            straggler_prob=0.1, straggler_mult=1.5,
                            optimize_interval=False, max_wall_factor=99.0)))
    prefill = SimSpec(CFG, workload=PrefillWorkload(
        global_batch=4, seq_len=512, cache_len=64, fusion=True,
        quantize="f8"))
    decode = SimSpec(CFG, workload=DecodeWorkload(
        global_batch=4, seq_len=1, cache_len=1024, fusion=True,
        quantize="int8"))
    serving = SimSpec(CFG, workload=ServingWorkload(
        n_requests=33, arrival="bursty", rate_rps=5.5, burst_factor=2.0,
        switch_prob=0.2, period_s=100.0, diurnal_amp=0.5,
        flash_start_s=10.0, flash_dur_s=5.0, flash_mult=3.0, sessions=4,
        prompt=LengthDist("uniform", lo=2, hi=64),
        output=LengthDist("fixed", value=7),
        seed=9, trace=((0.5, 7, 3), (1.0, 2, 1)),
        slo=SLO(ttft_s=1.5, tpot_ms=80.0),
        policy="chunked", max_batch=16, token_budget=128, ctx_floor=128,
        fleet=FleetSpec(
            replicas=3,
            router=RouterSpec("least_loaded", fallback="round_robin"),
            autoscaler=AutoscalerSpec(min_replicas=2, max_replicas=5,
                                      scale_up_queue=9.0,
                                      scale_down_queue=2.0, interval_s=3.0,
                                      cooldown_s=5.0, provision_s=6.0),
            prefill_replicas=1, prefill_batch=2, transfer_s=0.005,
            faults=ReplicaFaultSpec(mtbf_s=500.0, restart_s=20.0,
                                    dist="weibull", weibull_shape=0.8,
                                    seed=5))))
    return [train, prefill, decode, serving]


def _walk_dataclasses(obj, acc):
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        acc.append(obj)
        for f in dataclasses.fields(obj):
            _walk_dataclasses(getattr(obj, f.name), acc)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            _walk_dataclasses(v, acc)
    elif isinstance(obj, dict):
        for v in obj.values():
            _walk_dataclasses(v, acc)


def test_every_frozen_spec_class_appears_in_maximal_specs():
    """A new frozen spec class added to repro.api.spec without a home in
    the maximal specs above fails here — forcing the round-trip test (and
    from_dict) to learn about it."""
    discovered = _discovered_spec_classes()
    instances = []
    for spec in _maximal_specs():
        _walk_dataclasses(spec, instances)
    covered = {type(i).__name__ for i in instances}
    missing = set(discovered) - covered
    assert not missing, (
        f"frozen spec classes with no instance in the maximal specs: "
        f"{sorted(missing)} — add one so the JSON round-trip covers them")


def test_every_spec_field_is_non_default_somewhere():
    """Every public init field of every frozen spec class must differ from
    its default in at least one maximal-spec instance; a field stuck at its
    default would round-trip trivially and hide a from_dict omission."""
    discovered = _discovered_spec_classes()
    instances = []
    for spec in _maximal_specs():
        _walk_dataclasses(spec, instances)
    by_type = {}
    for i in instances:
        by_type.setdefault(type(i).__name__, []).append(i)
    stuck = []
    for name, cls in discovered.items():
        for f in dataclasses.fields(cls):
            if not f.init or f.name.startswith("_"):
                continue
            if f.default is dataclasses.MISSING \
                    and f.default_factory is dataclasses.MISSING:
                continue                      # required: always "set"
            default = (f.default if f.default is not dataclasses.MISSING
                       else f.default_factory())
            if not any(getattr(i, f.name) != default
                       for i in by_type.get(name, [])):
                stuck.append(f"{name}.{f.name}")
    assert not stuck, (
        f"spec fields never set to a non-default value in the maximal "
        f"specs: {stuck}")


def test_frozen_spec_json_roundtrip_preserves_equality_and_hash():
    for spec in _maximal_specs():
        rt = SimSpec.from_json(spec.to_json())
        assert rt == spec, f"JSON round-trip changed the spec: {spec}"
        assert rt.json_hash() == spec.json_hash()
        assert hash(rt) == hash(spec)
        assert SimSpec.from_dict(spec.asdict()) == spec
        # pickling must round-trip too, *without* the process-salted hash
        # memo (the PR 5 __getstate__ class)
        import pickle
        hash(spec)                       # force the memo before pickling
        assert "_hash" not in spec.__getstate__()
        pk = pickle.loads(pickle.dumps(spec))
        assert pk == spec and hash(pk) == hash(spec)

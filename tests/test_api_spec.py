"""Spec-API invariants: the legacy kwargs shims are bit-identical to the
spec path, specs are hashable round-trippable cache keys, and SweepSpace
expresses (and correctly evaluates) axes the old ``explore()`` could not."""
import dataclasses
import warnings

import pytest

from repro.api import (
    CharonDeprecationWarning, Cluster, DecodeWorkload, PrefillWorkload,
    ServingWorkload, SimSpec, SweepSpace, TrainWorkload, spec_replace, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.core.explorer import explore

CFG = get_config("xlstm-125m")

LEGACY_CASES = [
    # (simulate kwargs, equivalent workload)
    (dict(mode="train", global_batch=16, seq_len=512,
          par=ParallelConfig(tp=2, dp=2, pp=2, microbatches=2),
          remat="block", optimizer="adamw"),
     TrainWorkload(global_batch=16, seq_len=512)),
    (dict(mode="train", global_batch=16, seq_len=512,
          par=ParallelConfig(tp=2, dp=4), remat="dots", fusion=True,
          quantize="int8", optimizer="adafactor"),
     TrainWorkload(global_batch=16, seq_len=512, remat="dots", fusion=True,
                   quantize="int8", optimizer="adafactor")),
    (dict(mode="prefill", global_batch=4, seq_len=512,
          par=ParallelConfig(tp=2, dp=2), remat="none"),
     PrefillWorkload(global_batch=4, seq_len=512)),
    # remat/optimizer are inert outside training: the legacy defaults
    # ("block"/"adamw") must map onto the same spec result
    (dict(mode="prefill", global_batch=4, seq_len=256,
          par=ParallelConfig(tp=2, dp=2)),
     PrefillWorkload(global_batch=4, seq_len=256)),
    (dict(mode="decode", global_batch=8, seq_len=1024,
          par=ParallelConfig(tp=2, dp=4), remat="none"),
     DecodeWorkload(global_batch=8, seq_len=1024)),
    (dict(mode="decode", global_batch=8, seq_len=256, cache_len=2048,
          par=ParallelConfig(tp=2, dp=4), remat="none"),
     DecodeWorkload(global_batch=8, seq_len=256, cache_len=2048)),
]


def _bit_identical(a, b):
    assert a.step_time_us == b.step_time_us
    assert a.breakdown_us == b.breakdown_us
    assert a.kind_us == b.kind_us
    assert a.memory.total == b.memory.total
    assert a.memory.summary() == b.memory.summary()
    assert a.mfu == b.mfu
    assert a.tokens_per_s == b.tokens_per_s


@pytest.mark.parametrize("case", range(len(LEGACY_CASES)))
def test_legacy_simulate_shim_bit_identical(case):
    kw, workload = LEGACY_CASES[case]
    kw = dict(kw)
    par = kw.pop("par")
    sim = Simulator("tpu_v5e", engine="analytical")
    spec = SimSpec(CFG, parallel=par, workload=workload)
    via_spec = sim.run(spec)
    with pytest.warns(CharonDeprecationWarning):
        via_legacy = sim.simulate(CFG, par=par, **kw)
    _bit_identical(via_spec, via_legacy)
    # and against a cold simulator, so the equality is not just cache reuse
    cold = Simulator("tpu_v5e", engine="analytical", cache=False)
    _bit_identical(cold.run(spec), via_spec)


def _specs():
    out = [SimSpec(CFG, parallel=par, workload=w)
           for kw, w in LEGACY_CASES for par in [kw["par"]]]
    out.append(SimSpec(CFG, cluster=Cluster("h100_sxm", chips=16, pods=2,
                                            memory_limit=40e9),
                       parallel=ParallelConfig(tp=2, dp=4),
                       workload=DecodeWorkload(global_batch=32, seq_len=4096)))
    out.append(SimSpec(CFG, parallel=ParallelConfig(tp=2),
                       workload=ServingWorkload(n_requests=50, rate_rps=20.0,
                                                arrival="bursty", seed=7)))
    return out


def test_spec_roundtrip_asdict_equal_hash():
    for spec in _specs():
        back = SimSpec.from_dict(spec.asdict())
        assert back == spec
        assert hash(back) == hash(spec)


def test_spec_is_a_cache_key():
    a, b = _specs()[0], _specs()[0]
    assert a is not b
    d = {a: "priced"}
    assert d[b] == "priced"                      # equal specs collide
    c = spec_replace(a, {"workload.global_batch": 999})
    assert c not in d


def test_cluster_normalizes_hardware_spec_and_pods():
    from repro.core.backend.hardware import TPU_V5E
    assert Cluster(TPU_V5E).hardware == "tpu_v5e"
    assert Cluster(TPU_V5E) == Cluster("tpu_v5e")
    assert Cluster(TPU_V5E).resolve() is TPU_V5E
    with pytest.raises(KeyError):
        Cluster("not_a_chip")
    # cluster pods default the parallel pod count; conflicts raise
    s = SimSpec(CFG, cluster=Cluster("tpu_v5e", pods=2),
                parallel=ParallelConfig(tp=2, dp=2))
    assert s.parallel.pods == 2
    with pytest.raises(ValueError):
        SimSpec(CFG, cluster=Cluster("tpu_v5e", pods=2),
                parallel=ParallelConfig(tp=2, dp=2, pods=4))


def test_cluster_replace_rederives_custom_hardware():
    from dataclasses import replace

    from repro.core.backend.hardware import TPU_V5E
    custom = replace(TPU_V5E, name="my_chip")
    c = Cluster(custom)
    assert c.resolve() is custom
    # non-hardware replace keeps the custom spec; renaming drops it
    assert replace(c, chips=8).resolve() is custom
    c2 = replace(c, hardware="h100_sxm")
    assert c2.resolve().name == "h100_sxm"
    with pytest.raises(KeyError):
        replace(c, hardware="not_a_chip")
    # and a custom-hardware spec round-trips through asdict/from_dict
    spec = SimSpec(CFG, cluster=Cluster(custom), workload=DecodeWorkload())
    back = SimSpec.from_dict(spec.asdict())
    assert back == spec and back.cluster.resolve() == custom


def test_sweep_rejects_serving_workload_base():
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=4),
                   workload=ServingWorkload(n_requests=5))
    with pytest.raises(TypeError):
        sweep(SweepSpace(base, {"tp": (1, 2)}))


def test_sweep_axis_typos_fail_fast():
    base = SimSpec(CFG, workload=DecodeWorkload())
    with pytest.raises(KeyError):
        SweepSpace(base, {"workload.seq_length": (512,)})   # dotted typo
    with pytest.raises(KeyError):
        SweepSpace(base, {"seq_length": (512,)})            # bare typo
    with pytest.raises(KeyError):
        SweepSpace(base, {"engine.tp": (1,)})               # bad component
    with pytest.raises(TypeError):
        SweepSpace(base, {"hardware": "h100_sxm"})          # bare string
    with pytest.raises(ValueError):
        with pytest.warns(CharonDeprecationWarning):
            explore(Simulator("tpu_v5e", engine="analytical"), CFG,
                    chips=4, memory_limit=0.0)              # ambiguous limit


def test_run_rejects_wrong_hardware_and_serving_workloads():
    sim = Simulator("tpu_v5e", engine="analytical")
    with pytest.raises(ValueError):
        sim.run(SimSpec(CFG, cluster=Cluster("h100_sxm"),
                        workload=DecodeWorkload()))
    with pytest.raises(TypeError):
        sim.run(SimSpec(CFG, workload=ServingWorkload(n_requests=5)))


# ---------------- sweep equivalence ----------------

GRID = dict(tp_choices=(1, 2, 4), pp_choices=(1, 2),
            batch_choices=(8, 16, 100))


def _space(memory_limit=0.0):
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=16,
                                        memory_limit=memory_limit),
                   workload=DecodeWorkload(seq_len=1024))
    return SweepSpace(base, {"tp": GRID["tp_choices"],
                             "pp": GRID["pp_choices"],
                             "batch": GRID["batch_choices"]})


def test_legacy_explore_shim_bit_identical_rankings():
    with pytest.warns(CharonDeprecationWarning):
        legacy = explore(Simulator("tpu_v5e", engine="analytical"), CFG,
                         mode="decode", seq_len=1024, chips=16,
                         memory_limit=16e9, **GRID)
    new = sweep(_space(memory_limit=16e9),
                sim=Simulator("tpu_v5e", engine="analytical"))
    key = lambda res: [(r.cand.key(), r.report.step_time_us, r.tps_per_chip)
                       for r in res.ranked()]
    assert key(legacy) == key(new)
    assert [(p.cand.key(), p.reason) for p in legacy.pruned] == \
        [(p.cand.key(), p.reason) for p in new.pruned]
    assert legacy.n_groups == new.n_groups
    assert [(r.cand.key(),) for r in legacy.pareto()] == \
        [(r.cand.key(),) for r in new.pareto()]
    # reuse-grouping + cache layers behave identically under both surfaces
    for layer in ("block_times", "pricing", "ingest"):
        assert legacy.cache_stats[layer] == new.cache_stats[layer]
    # every new-path result carries its full spec
    assert all(r.spec is not None for r in new.evaluated)


def test_sweep_axes_beyond_the_legacy_grid():
    # seq_len x quantize x hardware in ONE space: inexpressible with
    # explore(tp_choices=...) — the old surface hardcoded tp/pp/batch/micro
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=8),
                   parallel=ParallelConfig(),
                   workload=DecodeWorkload(global_batch=16))
    space = SweepSpace(base, {"tp": (1, 2), "seq_len": (512, 2048),
                              "quantize": (None, "int8"),
                              "hardware": ("tpu_v5e", "h100_sxm")})
    assert space.size() == 16
    res = sweep(space)
    assert len(res.evaluated) == 16
    hw = {r.spec.cluster.hardware for r in res.evaluated}
    assert hw == {"tpu_v5e", "h100_sxm"}
    # quantization must matter: int8 beats bf16 step time on equal shapes
    by = {(r.spec.cluster.hardware, r.spec.parallel.tp,
           r.spec.workload.seq_len, r.spec.workload.quantize):
          r.report.step_time_us for r in res.evaluated}
    for h in ("tpu_v5e", "h100_sxm"):
        assert by[(h, 2, 2048, "int8")] < by[(h, 2, 2048, None)]
    # reuse grouping still reports: every distinct (hw, shapes) is a group
    assert res.n_groups == 16
    assert res.cache_stats["pricing"]["hits"] > 0


def test_sweep_derives_dp_and_skips_nondivisible():
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=8),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    res = sweep(SweepSpace(base, {"tp": (1, 2, 3)}))  # tp=3 !| 8 chips
    tps = sorted(r.spec.parallel.tp for r in res.evaluated)
    assert tps == [1, 2]
    assert all(r.spec.parallel.chips == 8 for r in res.evaluated)


def test_memory_liveness_memoized_across_candidates():
    sim = Simulator("tpu_v5e", engine="analytical")
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    r1 = sim.run(spec)
    st = sim.cache_stats()["memory"]
    assert st == {"hits": 0, "misses": 1, "hit_rate": 0.0}
    # dp-only change shares the transformed first block -> liveness hit
    r2 = sim.run(spec_replace(spec, {"parallel.dp": 8,
                                     "workload.global_batch": 16}))
    st = sim.cache_stats()["memory"]
    assert st["hits"] == 1 and st["misses"] == 1
    assert r1.memory.activations_peak == r2.memory.activations_peak


def test_serving_spec_run_matches_legacy_construction():
    from repro.serving.sim import ContinuousBatching, ServingSimulator
    sim = Simulator("tpu_v5e", engine="analytical")
    par = ParallelConfig(tp=2)
    sw = ServingWorkload(n_requests=40, rate_rps=40.0, seed=3, max_batch=8,
                         policy="continuous")
    spec = SimSpec(CFG, parallel=par, workload=sw)
    via_spec = ServingSimulator(sim).run(spec)
    legacy = ServingSimulator(sim, CFG, par=par,
                              policy=ContinuousBatching(8)).run(
        sw.build(), slo=sw.slo)
    a, b = via_spec.summary(), legacy.summary()
    a.pop("oracle_stats"), b.pop("oracle_stats")  # hit/miss split differs
    assert a == b

"""Self-tests for the correctness tooling (repro.analysis).

Layer 1: charon-lint rule fixtures — for every rule a snippet it MUST flag
(true positive) and a clean equivalent it must NOT flag (false-positive
guard), plus disable-comment accounting, scope normalization and the CLI.

Layer 2: sanitizer — the cache-poisoning detector must raise on a
deliberately mutated cached value (and stay silent otherwise), the oracle
memo cross-check must catch an injected stale price, and check_determinism
must pass on a healthy spec.

Day-one fixes: regression tests pinning the frozen (tuple) report fields
and the determinism of the refactored overlap fluid model.
"""
from __future__ import annotations

import dataclasses
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.lint import run_lint
from repro.analysis.lint.engine import parse_disables
from repro.analysis.sanitize import (
    CacheSanitizerError, SanitizingSimCache, check_determinism, diff_values,
    structural_fingerprint,
)
from repro.api.spec import Cluster, ServingWorkload, SimSpec, TrainWorkload
from repro.configs import get_config
from repro.core.passes.base import ParallelConfig
from repro.core.simulator import Simulator

CFG = dataclasses.replace(get_config("gemma-7b"), name="lint-tiny",
                          num_layers=2, d_model=128, num_heads=2,
                          num_kv_heads=2, d_ff=256, vocab_size=512)


def lint_snippet(tmp_path: Path, rel: str, code: str, rules=None):
    """Write *code* at *rel* under a fixture tree and lint it."""
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(code)
    return run_lint([tmp_path], rules=rules)


def active_rules(report):
    return sorted({f.rule for f in report.active})


# ======================================================================
# R1: cache aliasing
# ======================================================================

def test_r1_flags_returned_mutable_cache_value(tmp_path):
    rep = lint_snippet(tmp_path, "core/bad.py", """
def timeline(self, key):
    return self.cache.get("memory", key, lambda: [1, 2, 3])
""")
    assert active_rules(rep) == ["R1"]


def test_r1_flags_named_then_returned_mutable_build(tmp_path):
    rep = lint_snippet(tmp_path, "core/bad.py", """
def stage(self, key):
    def build():
        return {"t": 1.0}
    out = self.cache.get("block_times", key, build)
    return out
""")
    assert active_rules(rep) == ["R1"]


def test_r1_flags_mutation_of_cache_fetched_value(tmp_path):
    rep = lint_snippet(tmp_path, "core/bad.py", """
def poke(self, key, build):
    rep = self.cache.get("reports", key, build)
    rep.kind_us["matmul"] = 0.0
    rep.breakdown.update({"fwd": 1})
    return rep.step_time_us
""")
    assert active_rules(rep) == ["R1"] and len(rep.active) == 2


def test_r1_passes_dataclass_build_and_copied_return(tmp_path):
    rep = lint_snippet(tmp_path, "core/good.py", """
def stage(self, key):
    def build():
        return Stage(t_fwd=1.0)
    return self.cache.get("block_times", key, build)

def copied(self, key):
    out = self.cache.get("memory", key, lambda: compute(key))
    return out

def plain_dict_get(d, key):
    # 2-arg dict.get is not a cache bucket get
    return d.get(key, [])
""")
    assert rep.active == ()


# ======================================================================
# R2: nondeterminism sources
# ======================================================================

def test_r2_flags_wall_clock_and_global_random(tmp_path):
    rep = lint_snippet(tmp_path, "serving/sim/bad.py", """
import os
import random
import time


def jitter():
    t = time.time()
    r = random.random()
    u = os.urandom(4)
    g = random.Random()
    return t, r, u, g
""")
    assert active_rules(rep) == ["R2"] and len(rep.active) == 4


def test_r2_flags_id_keys_and_set_iteration(tmp_path):
    rep = lint_snippet(tmp_path, "core/bad.py", """
def order(flows, table):
    extra = {}
    for f in flows:
        extra[id(f)] = 1.0
        table.get(id(f))
    kinds = {f.kind for f in flows}
    return [k for k in kinds]
""")
    assert active_rules(rep) == ["R2"] and len(rep.active) == 3


def test_r2_passes_seeded_rng_sorted_sets_and_out_of_scope(tmp_path):
    rep = lint_snippet(tmp_path, "resilience/good.py", """
import random


def trace(seed, flows):
    rng = random.Random(seed)
    kinds = {f.kind for f in flows}
    ordered = sorted(kinds)
    if "x" in kinds:            # membership is order-free: fine
        ordered.append("x")
    return rng.random(), ordered
""")
    assert rep.active == ()
    # time.time is fine OUTSIDE the deterministic scopes (obs/, benchmarks)
    rep = lint_snippet(tmp_path, "obs/clock2.py", """
import time


def wall():
    return time.time()
""")
    assert rep.active == ()


def test_r2_perf_counter_exempt_only_in_measurement_engines(tmp_path):
    code = """
import time


def measure():
    return time.perf_counter()
"""
    assert active_rules(lint_snippet(
        tmp_path, "core/backend/profiling.py", code)) == []
    assert active_rules(lint_snippet(
        tmp_path, "core/backend/other.py", code)) == ["R2"]


# ======================================================================
# R3: spec-surface drift
# ======================================================================

_R3_HEADER = """
from dataclasses import dataclass, field
"""


def test_r3_flags_compare_false_and_unwired_nested_spec(tmp_path):
    rep = lint_snippet(tmp_path, "api/spec.py", _R3_HEADER + """
@dataclass(frozen=True)
class Inner:
    x: int = 0


@dataclass(frozen=True)
class Outer:
    tag: str = field(default="", compare=False)
    inner: Inner = field(default_factory=Inner)
""")
    # tag: compare=False; inner: no "inner" string literal -> not in from_dict
    assert active_rules(rep) == ["R3"] and len(rep.active) == 2


def test_r3_flags_manual_hash_missing_field(tmp_path):
    rep = lint_snippet(tmp_path, "api/spec.py", _R3_HEADER + """
@dataclass(frozen=True)
class Spec:
    a: int = 0
    b: int = 0

    def __hash__(self):
        return hash(self.a)
""")
    assert active_rules(rep) == ["R3"]
    assert "b" in rep.active[0].message


def test_r3_passes_wired_spec(tmp_path):
    rep = lint_snippet(tmp_path, "api/spec.py", _R3_HEADER + """
@dataclass(frozen=True)
class Inner:
    x: int = 0


@dataclass(frozen=True)
class Outer:
    inner: Inner = field(default_factory=Inner)
    _memo: int = field(default=0, compare=False)   # private: allowed

    @classmethod
    def from_dict(cls, d):
        return cls(inner=Inner(**d["inner"]))

    def __hash__(self):
        return hash((self.inner,))
""")
    assert rep.active == ()


def test_r3_real_spec_module_is_clean():
    root = Path(__file__).resolve().parent.parent
    rep = run_lint([root / "src" / "repro" / "api" / "spec.py"])
    assert [f for f in rep.active if f.rule == "R3"] == []


# ======================================================================
# R4: memo dicts vs the state-version guard
# ======================================================================

def test_r4_flags_unguarded_pricing_memo(tmp_path):
    rep = lint_snippet(tmp_path, "serving/sim/bad.py", """
class LeakyOracle:
    def __init__(self, sim):
        self.sim = sim
        self._price = {}

    def price(self, key):
        ver = self.sim.engine._state_version()
        if key not in self._price:
            self._price[key] = self.sim.run(key)
        return self._price[key]
""")
    assert active_rules(rep) == ["R4"]
    assert "_price" in rep.active[0].message


def test_r4_passes_guarded_memo_and_pure_spec_table(tmp_path):
    rep = lint_snippet(tmp_path, "serving/sim/good.py", """
class Oracle:
    def __init__(self, sim):
        self.sim = sim
        self._price = {}
        self._specs = {}
        self._ver = None

    def _live(self):
        ver = self.sim.engine._state_version()
        if ver != self._ver:
            self._price.clear()
            self._ver = ver

    def price(self, key):
        self._live()
        if key not in self._price:
            self._price[key] = self.sim.run(key)
        return self._price[key]

    def spec_for(self, key):
        # pure key->spec table: no pricing call in this method, exempt
        if key not in self._specs:
            self._specs[key] = ("spec", key)
        return self._specs[key]
""")
    assert rep.active == ()


# ======================================================================
# R5: recorder/metrics threading
# ======================================================================

def test_r5_flags_run_without_observability_params(tmp_path):
    rep = lint_snippet(tmp_path, "serving/sim/bad.py", """
class BlindSimulator:
    def run(self, spec):
        return price(spec)
""")
    assert active_rules(rep) == ["R5"] and len(rep.active) == 2


def test_r5_flags_unforwarded_delegation(tmp_path):
    rep = lint_snippet(tmp_path, "serving/sim/bad.py", """
class OuterSimulator:
    def run(self, spec, *, recorder=None, metrics=None):
        inner = InnerSimulator(self.sim)
        return inner.run(spec.build())
""")
    assert active_rules(rep) == ["R5"]
    assert "recorder" in rep.active[0].message


def test_r5_passes_forwarded_and_pricing_calls(tmp_path):
    rep = lint_snippet(tmp_path, "serving/sim/good.py", """
class OuterSimulator:
    def run(self, spec, *, recorder=None, metrics=None):
        base = self.sim.run(spec.base())     # pricing call: exempt
        inner = InnerSimulator(self.sim)
        return inner.run(spec.build(), recorder=recorder, metrics=metrics)


class Helper:
    def run(self, x):
        # not a *Simulator class: no observability contract
        return x
""")
    assert rep.active == ()


# ======================================================================
# R6: exception hygiene in crash-recovery scopes
# ======================================================================

def test_r6_flags_bare_except(tmp_path):
    rep = lint_snippet(tmp_path, "api/pool.py", """
def retry(task):
    try:
        return task()
    except:
        return None
""")
    assert active_rules(rep) == ["R6"]
    assert "bare" in rep.active[0].message


def test_r6_flags_swallowed_control_exceptions(tmp_path):
    rep = lint_snippet(tmp_path, "api/sweep.py", """
def drain(q):
    try:
        return q.get()
    except (KeyboardInterrupt, SystemExit):
        return None


def run(pool):
    try:
        pool.step()
    except BaseException as e:
        log(e)
""")
    assert active_rules(rep) == ["R6"] and len(rep.active) == 2
    assert "KeyboardInterrupt" in rep.active[0].message


def test_r6_passes_cleanup_then_reraise_and_narrow_handlers(tmp_path):
    rep = lint_snippet(tmp_path, "core/simcache.py", """
def atomic_write(path, blob):
    try:
        dump(path, blob)
    except BaseException:
        cleanup(path)
        raise


def evaluate(task):
    try:
        return task()
    except Exception as e:       # retryable: narrow catch is the contract
        return failed(e)
""")
    assert rep.active == ()


def test_r6_scoped_to_recovery_files(tmp_path):
    # the same swallow outside pool/sweep/chaos/simcache is not R6's beat
    code = """
def f(x):
    try:
        return x()
    except BaseException:
        return None
"""
    assert active_rules(lint_snippet(tmp_path, "core/engine2.py", code)) == []
    assert active_rules(
        lint_snippet(tmp_path, "analysis/chaos.py", code)) == ["R6"]


# ======================================================================
# engine mechanics: disable comments, scoping, CLI
# ======================================================================

def test_disable_comment_suppresses_but_counts(tmp_path):
    rep = lint_snippet(tmp_path, "core/bad.py", """
import time


def wall():
    return time.time()  # charon-lint: disable=R2
""")
    assert rep.active == () and len(rep.disabled) == 1
    assert rep.ok
    assert "1 disabled suppression(s)" in rep.render()
    assert "suppressed:" in rep.render()


def test_disable_comment_is_rule_specific(tmp_path):
    rep = lint_snippet(tmp_path, "core/bad.py", """
import time


def wall():
    return time.time()  # charon-lint: disable=R1
""")
    assert active_rules(rep) == ["R2"]   # wrong rule id: not suppressed


def test_parse_disables_multi_rule():
    d = parse_disables(["x = 1  # charon-lint: disable=R1,R2", "y = 2"])
    assert d == {1: {"R1", "R2"}}


def test_scope_normalization_matches_real_tree_and_fixtures(tmp_path):
    # the same snippet must be flagged whether it lives in a fixture tree
    # (core/x.py) or the real one (src/repro/core/x.py)
    code = "import time\nT = time.time()\n"
    assert active_rules(lint_snippet(tmp_path, "core/x.py", code)) == ["R2"]
    assert active_rules(lint_snippet(
        tmp_path, "src/repro/core/y.py", code)) == ["R2"]


def test_syntax_errors_are_reported_not_fatal(tmp_path):
    rep = lint_snippet(tmp_path, "core/broken.py", "def broken(:\n")
    assert not rep.ok and rep.errors and rep.active == ()


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "core"
    bad.mkdir()
    (bad / "bad.py").write_text("import time\nT = time.time()\n")
    root = Path(__file__).resolve().parent.parent
    env_path = str(root / "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(tmp_path)],
        capture_output=True, text=True, env={"PYTHONPATH": env_path,
                                             "PATH": "/usr/bin:/bin"})
    assert r.returncode == 1 and "R2" in r.stdout
    (bad / "bad.py").write_text("X = 1\n")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(tmp_path)],
        capture_output=True, text=True, env={"PYTHONPATH": env_path,
                                             "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0 and "0 finding(s)" in r.stdout


def test_repo_tree_is_lint_clean_with_zero_suppressions():
    """The acceptance bar: the shipped tree has no findings and no disable
    comments (real violations get fixed, not suppressed)."""
    root = Path(__file__).resolve().parent.parent
    rep = run_lint([root / "src"])
    assert rep.active == (), "\n" + rep.render()
    assert rep.disabled == (), "disable comments crept into src/"


# ======================================================================
# sanitizer: fingerprints, poisoning detection, determinism harness
# ======================================================================

def test_structural_fingerprint_properties():
    a = {"x": [1, 2.5, (3, "s")], "y": {"n": None}, "z": {7, 8}}
    b = {"z": {8, 7}, "y": {"n": None}, "x": [1, 2.5, (3, "s")]}
    assert structural_fingerprint(a) == structural_fingerprint(b)
    b["x"].append(4)
    assert structural_fingerprint(a) != structural_fingerprint(b)
    # floats by bit pattern, nan stable; int/float/bool distinguished
    assert structural_fingerprint(float("nan")) \
        == structural_fingerprint(float("nan"))
    assert structural_fingerprint(1) != structural_fingerprint(1.0)
    assert structural_fingerprint(True) != structural_fingerprint(1)
    # cycles terminate
    cyc = []
    cyc.append(cyc)
    assert structural_fingerprint(cyc)


def test_sanitizing_cache_detects_injected_mutation():
    c = SanitizingSimCache()
    v = c.get("reports", "k", lambda: {"t": [1.0, 2.0]})
    assert c.get("reports", "k", lambda: None) is v     # clean hit
    v["t"].append(3.0)                                  # poison it
    with pytest.raises(CacheSanitizerError) as ei:
        c.get("reports", "k", lambda: None)
    assert ei.value.bucket == "reports" and ei.value.key == "k"


def test_sanitizing_cache_off_paths_match_simcache():
    c = SanitizingSimCache(enabled=False)
    assert c.get("reports", "k", lambda: [1]) == [1]    # pass-through
    c2 = SanitizingSimCache()
    unhashable = ["list-key"]
    assert c2.get("reports", unhashable, lambda: 7) == 7


def test_simulator_sanitize_flag_and_env(monkeypatch):
    from repro.core.simcache import SimCache
    sim = Simulator("tpu_v5e", engine="analytical")
    assert type(sim.cache) is SimCache           # default: plain cache
    sim = Simulator("tpu_v5e", engine="analytical", sanitize=True)
    assert isinstance(sim.cache, SanitizingSimCache)
    monkeypatch.setenv("CHARON_SANITIZE", "1")
    sim = Simulator("tpu_v5e", engine="analytical")
    assert isinstance(sim.cache, SanitizingSimCache)
    monkeypatch.setenv("CHARON_SANITIZE", "0")
    sim = Simulator("tpu_v5e", engine="analytical")
    assert type(sim.cache) is SimCache


def test_sanitizer_catches_poisoned_block_stage_end_to_end():
    spec = SimSpec(CFG, cluster=Cluster("tpu_v5e"),
                   parallel=ParallelConfig(),
                   workload=TrainWorkload(global_batch=8, seq_len=128))
    sim = Simulator("tpu_v5e", engine="analytical", sanitize=True)
    r1 = sim.run(spec)
    # mutate a cached block stage behind the cache's back
    key = next(iter(sim.cache._data["block_times"]))
    sim.cache._data["block_times"][key].kind_us["matmul"] = 1e9
    with pytest.raises(CacheSanitizerError) as ei:
        sim.run(spec)
    assert ei.value.bucket == "block_times"
    assert r1.step_time_us > 0


def test_sanitized_serving_run_matches_default_run():
    sw = ServingWorkload(n_requests=30, rate_rps=30.0, seed=3, max_batch=8)
    spec = SimSpec(CFG, workload=sw)
    from repro.serving.sim import ServingSimulator
    plain = ServingSimulator(Simulator("tpu_v5e")).run(spec)
    sane = ServingSimulator(Simulator("tpu_v5e", sanitize=True)).run(spec)
    a, b = plain.summary(), sane.summary()
    a.pop("oracle_stats"), b.pop("oracle_stats")  # verify recounts hits
    assert a == b


def test_oracle_memo_cross_check_catches_stale_price():
    from repro.serving.sim.oracle import StepOracle
    sim = Simulator("tpu_v5e", sanitize=True)
    oracle = StepOracle(sim, CFG)
    good = oracle.decode_step_s(4, 300)
    assert oracle.decode_step_s(4, 300) == good         # clean memo hit
    oracle._raw[("decode", 4, 300)] = good * 2          # inject staleness
    with pytest.raises(CacheSanitizerError) as ei:
        oracle.decode_step_s(4, 300)
    assert ei.value.bucket == "oracle._raw"
    # _price memo staleness is caught by the same cross-check
    fast = next(iter(oracle._price))
    oracle._price[fast] = oracle._price[fast] * 2
    with pytest.raises(CacheSanitizerError) as ei:
        oracle._priced_s(*fast)
    assert ei.value.bucket == "oracle._price"


def test_check_determinism_passes_on_healthy_specs():
    step = SimSpec(CFG, workload=TrainWorkload(global_batch=8, seq_len=128))
    rep = check_determinism(step)
    assert rep.ok, rep.render()
    assert set(rep.variants) == {"warm", "uncached", "pickled"}
    serving = SimSpec(CFG, workload=ServingWorkload(
        n_requests=20, rate_rps=20.0, seed=1, max_batch=8))
    rep = check_determinism(serving)
    assert rep.ok, rep.render()


def test_diff_values_reports_field_paths():
    @dataclasses.dataclass
    class D:
        x: float
        items: tuple

    a = D(1.0, (1, 2))
    assert diff_values(a, D(1.0, (1, 2))) == []
    diffs = diff_values(a, D(2.0, (1, 3)), path="r")
    assert {d[0] for d in diffs} == {"r.x", "r.items[1]"}
    assert diff_values([1], [1, 2]) == [("report", "len=1", "len=2")]
    # nan == nan under the exact-float rule
    assert diff_values(float("nan"), float("nan")) == []


# ======================================================================
# day-one fixes: frozen report fields stay frozen (regression per fix)
# ======================================================================

def test_serving_and_fleet_report_fields_are_tuples():
    from repro.api.spec import FleetSpec
    from repro.serving.sim import ServingSimulator
    sim = Simulator("tpu_v5e")
    spec = SimSpec(CFG, workload=ServingWorkload(
        n_requests=20, rate_rps=20.0, seed=1, max_batch=8))
    rep = ServingSimulator(sim).run(spec)
    assert isinstance(rep.requests, tuple)
    fleet_spec = SimSpec(CFG, workload=ServingWorkload(
        n_requests=20, rate_rps=20.0, seed=1, max_batch=8,
        fleet=FleetSpec(replicas=2)))
    frep = ServingSimulator(sim).run(fleet_spec)
    assert isinstance(frep.requests, tuple)
    assert isinstance(frep.replicas, tuple)
    assert isinstance(frep.autoscaler_trace, tuple)
    assert isinstance(frep.failure_trace, tuple)
    for per in frep.replicas:
        assert isinstance(per.requests, tuple)


def test_exploration_result_fields_are_tuples():
    from repro.api import DecodeWorkload, SweepSpace, sweep
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=4),
                   workload=DecodeWorkload(seq_len=128))
    space = SweepSpace(base, {"tp": (1, 2), "batch": (8,)})
    res = sweep(space, sim=Simulator("tpu_v5e"))
    assert isinstance(res.evaluated, tuple)
    assert isinstance(res.pruned, tuple)
    assert res.evaluated


def test_memory_report_timeline_stays_tuple():
    spec = SimSpec(CFG, workload=TrainWorkload(global_batch=8, seq_len=128))
    rep = Simulator("tpu_v5e").run(spec)
    assert rep.memory is not None
    assert isinstance(rep.memory.timeline, tuple)


def test_overlap_fluid_model_is_replayable():
    """The id()->index refactor keeps the fluid model a pure function of
    its input: two structurally equal interval lists produce identical
    adjusted end times (object identity no longer leaks into keys)."""
    from repro.core.overlap import bandwidth_aware_comm
    from repro.core.scheduler import Interval

    def mk():
        return [Interval(f"f{i}", "comm", "ici", 0.1 * (i % 3), 1.0 + i,
                         "fwd", "g", 1e6 * (1 + i), 1, "analytical")
                for i in range(6)]

    ends1 = [iv.end for iv in bandwidth_aware_comm(mk())]
    ends2 = [iv.end for iv in bandwidth_aware_comm(mk())]
    assert ends1 == ends2

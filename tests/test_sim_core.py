"""Simulator invariants: engines, overlap models, memory liveness,
collective formulas, scheduler, explorer pruning/Pareto."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (
    Cluster, DecodeWorkload, SimSpec, SweepSpace, TrainWorkload,
    spec_replace, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.core.backend.analytical import AnalyticalEngine
from repro.core.backend.collectives import (
    GroupSpec, collective_time_us, hierarchical_collective_time_us,
    link_traffic_bytes,
)
from repro.core.backend.hardware import TPU_V5E
from repro.core.backend.prediction import RandomForest
from repro.core.ir import Graph, OpNode
from repro.core.memory import graph_liveness_peak
from repro.core.overlap import apply_ratio_overlap, bandwidth_aware_comm
from repro.core.scheduler import Interval, Timeline, schedule


# ---------------- collectives ----------------

def test_collective_byte_formulas():
    n, b = 8, 1024.0
    assert link_traffic_bytes("all_reduce", b, n) == pytest.approx(2 * 7 / 8 * b)
    assert link_traffic_bytes("all_gather", b, n) == pytest.approx(7 / 8 * b)
    assert link_traffic_bytes("reduce_scatter", b, n) == pytest.approx(7 / 8 * b)
    assert link_traffic_bytes("all_to_all", b, n) == pytest.approx(7 / 8 * b)


@settings(max_examples=25, deadline=None)
@given(payload=st.floats(1e3, 1e9), n=st.integers(2, 64))
def test_collective_time_monotone_in_payload(payload, n):
    t1 = collective_time_us("all_reduce", payload, n, TPU_V5E.intra)
    t2 = collective_time_us("all_reduce", payload * 2, n, TPU_V5E.intra)
    assert t2 >= t1


def test_hierarchical_crosspod_slower_than_intra():
    b = 64e6
    intra = hierarchical_collective_time_us("all_reduce", b, GroupSpec(16, 1), TPU_V5E)
    cross = hierarchical_collective_time_us("all_reduce", b, GroupSpec(16, 2), TPU_V5E)
    assert cross > intra


# ---------------- analytical engine ----------------

def test_roofline_compute_vs_memory_bound():
    eng = AnalyticalEngine(TPU_V5E)
    compute_heavy = OpNode("a", "matmul", flops=1e12, bytes_in=1e6, bytes_out=1e6,
                           attrs={"mm_dims": (1024, 1024, 1024)})
    mem_heavy = OpNode("b", "elementwise", flops=1e6, bytes_in=1e9, bytes_out=1e9)
    t_c = eng.latency_us(compute_heavy)
    t_m = eng.latency_us(mem_heavy)
    assert t_c == pytest.approx(1e12 / (TPU_V5E.peak_flops["bf16"] * 0.85) * 1e6 + 0.3, rel=0.05)
    assert t_m == pytest.approx(2e9 / (TPU_V5E.hbm_bw * 0.8) * 1e6 + 0.3, rel=0.05)


def test_mxu_misalignment_penalty():
    eng = AnalyticalEngine(TPU_V5E)
    aligned = OpNode("a", "matmul", flops=1e12, attrs={"mm_dims": (1024, 1024, 1024)})
    skinny = OpNode("b", "matmul", flops=1e12, attrs={"mm_dims": (1024, 5, 1024)})
    assert eng.latency_us(skinny) > eng.latency_us(aligned)


# ---------------- scheduler + overlap ----------------

def _tl(specs):
    return Timeline(intervals=[Interval(f"i{k}", kind, stream, s, e,
                                        comm_bytes=cb)
                               for k, (kind, stream, s, e, cb) in enumerate(specs)])


def test_ratio_overlap_only_extends():
    tl = _tl([("matmul", "compute", 0, 100, 0),
              ("all_reduce", "dp_comm", 0, 80, 1e6)])
    before = [i.dur for i in tl.intervals]
    out = apply_ratio_overlap(tl, TPU_V5E)
    for iv, b in zip(out.intervals, before):
        assert iv.dur >= b


def test_no_overlap_no_change():
    tl = _tl([("matmul", "compute", 0, 100, 0),
              ("all_reduce", "dp_comm", 100, 180, 1e6)])
    out = apply_ratio_overlap(tl, TPU_V5E)
    assert out.intervals[0].dur == 100
    assert out.intervals[1].dur == 80


def test_bandwidth_aware_single_flow_unchanged():
    tl = [Interval("a", "all_gather", "c1", 0, 100, comm_bytes=1e6)]
    out = bandwidth_aware_comm(tl)
    assert out[0].end == pytest.approx(100)


def test_bandwidth_aware_two_flows_share():
    """Two identical concurrent flows each take ~2x alone-time (paper Fig 6)."""
    tl = [Interval("a", "all_gather", "c1", 0, 100, comm_bytes=1e6),
          Interval("b", "all_gather", "c2", 0, 100, comm_bytes=1e6)]
    out = bandwidth_aware_comm(tl)
    for iv in out:
        assert iv.end == pytest.approx(200, rel=1e-6)


@settings(max_examples=25, deadline=None)
@given(starts=st.lists(st.floats(0, 50), min_size=1, max_size=6),
       durs=st.lists(st.floats(1, 40), min_size=6, max_size=6))
def test_bandwidth_aware_never_faster(starts, durs):
    tl = [Interval(f"f{i}", "all_gather", f"s{i}", s, s + d, comm_bytes=d * 1e5)
          for i, (s, d) in enumerate(zip(starts, durs))]
    out = bandwidth_aware_comm(tl)
    for before, after in zip(sorted(tl, key=lambda i: i.start), out):
        assert after.end >= before.end - 1e-6


def test_scheduler_respects_deps():
    g = Graph("g")
    a = g.op("matmul", flops=1e9)
    b = g.op("matmul", deps=[a.name], flops=1e9)
    tl = schedule(g, AnalyticalEngine(TPU_V5E))
    iv = {i.name: i for i in tl.intervals}
    assert iv[b.name].start >= iv[a.name].end


# ---------------- memory liveness ----------------

def test_liveness_chain_vs_fanout():
    chain = Graph("chain")
    prev = None
    for i in range(5):
        prev = chain.op("elementwise", deps=[prev.name] if prev else [],
                        bytes_out=100.0)
    peak_chain, _ = graph_liveness_peak(chain)
    assert peak_chain == pytest.approx(200.0)  # producer + consumer live

    fan = Graph("fan")
    root = fan.op("elementwise", bytes_out=100.0)
    mids = [fan.op("elementwise", deps=[root.name], bytes_out=100.0) for _ in range(4)]
    fan.op("elementwise", deps=[m.name for m in mids], bytes_out=100.0)
    peak_fan, _ = graph_liveness_peak(fan)
    assert peak_fan > peak_chain  # all four mids alive together


@settings(max_examples=20, deadline=None)
@given(sizes=st.lists(st.floats(1, 1e6), min_size=1, max_size=20))
def test_liveness_peak_bounds(sizes):
    g = Graph("g")
    prev = None
    for s in sizes:
        prev = g.op("elementwise", deps=[prev.name] if prev else [], bytes_out=s)
    peak, _ = graph_liveness_peak(g)
    assert peak >= max(sizes) - 1e-9
    assert peak <= sum(sizes) + 1e-9


# ---------------- random forest ----------------

def test_random_forest_fits_smooth_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 10, (400, 3))
    y = X[:, 0] * 2 + np.sin(X[:, 1]) + 0.1 * X[:, 2]
    rf = RandomForest(n_trees=12, max_depth=8).fit(X[:300], y[:300])
    pred = rf.predict(X[300:])
    mae = np.mean(np.abs(pred - y[300:]))
    assert mae < 0.8


# ---------------- simulator end-to-end sanity ----------------

def test_simulator_sane_mfu_and_scaling():
    sim = Simulator("tpu_v5e", engine="analytical")
    cfg = get_config("gemma-7b")
    par = ParallelConfig(tp=16, dp=16, sp=16, zero_stage=1)
    spec = SimSpec(cfg, parallel=par,
                   workload=TrainWorkload(global_batch=256, seq_len=4096))
    r = sim.run(spec)
    assert 0.02 < r.mfu < 1.0
    assert r.memory.total > 0
    # doubling batch should not reduce tokens/s
    r2 = sim.run(spec_replace(spec, {"workload.global_batch": 512}))
    assert r2.tokens_per_s >= r.tokens_per_s * 0.95


def test_simulator_decode_batch_throughput_monotone():
    sim = Simulator("tpu_v5e", engine="analytical")
    cfg = get_config("gemma-7b")
    par = ParallelConfig(tp=16, dp=16)
    spec = SimSpec(cfg, parallel=par,
                   workload=DecodeWorkload(global_batch=16, seq_len=8192))
    t8 = sim.run(spec)
    t64 = sim.run(spec_replace(spec, {"workload.global_batch": 64}))
    assert t64.tps_per_chip > t8.tps_per_chip  # weights amortise over batch


def test_explorer_pruning_and_pareto():
    sim = Simulator("tpu_v5e", engine="analytical")
    cfg = get_config("xlstm-125m")
    base = SimSpec(cfg, cluster=Cluster("tpu_v5e", chips=16),
                   workload=DecodeWorkload(seq_len=2048))
    res = sweep(SweepSpace(base, {"tp": (1, 2, 4), "pp": (1,),
                                  "batch": (8, 16, 100)}), sim=sim)
    assert res.pruned, "divisibility rule should prune batch=100 w/ dp"
    front = res.pareto()
    xs = [1e6 / r.report.step_time_us for r in front]
    ys = [r.tps_per_chip for r in front]
    assert xs == sorted(xs, reverse=True) or len(front) == 1
    best = res.best_under_slo(tpot_ms=1e9)
    assert best is not None
    assert best.tps_per_chip == max(r.tps_per_chip for r in res.evaluated)


# ---------------- analysis passes ----------------

def test_analysis_pipeline_flops_pre_post_recompute():
    from repro.core.passes.analysis import AnalysisPipeline, FlopsAnalysis, mfu
    from repro.core.passes.base import PassContext
    from repro.core.passes.recompute import RecomputePass
    g = Graph("g")
    a = g.op("matmul", flops=1e9, bytes_in=1e6, bytes_out=1e6, phase="fwd")
    g.op("matmul", deps=[a.name], flops=1e9, bytes_in=1e6, bytes_out=1e6, phase="bwd")
    pipe = AnalysisPipeline(post_passes=[RecomputePass("block")])
    res = pipe.run(g, PassContext(parallel=ParallelConfig()))
    assert res["model_flops"] == pytest.approx(2e9)
    assert res["executed_flops"] == pytest.approx(3e9)  # fwd recomputed in bwd
    assert res["recompute_overhead"] == pytest.approx(0.5)
    assert 0 < mfu(1e12, 1e6, 1, 197e12) < 1

"""Crash-safe sweep execution: the worker pool's recovery contracts.

The headline invariant, exercised under every injected fault kind: a sweep
under any deterministic :class:`~repro.analysis.chaos.FaultPlan` schedule
that does not exhaust a candidate's retries produces rankings, reports and
pruned reasons **bit-identical** to the fault-free serial sweep.  Faults
live purely in the execution layer — they can delay a result or quarantine
a candidate, never change a simulated number.

Covered here:

* worker crash (``os._exit`` mid-candidate) -> respawn + retry, identical;
* worker hang -> per-candidate timeout -> kill + retry, identical;
* poison candidate (raises on every attempt) -> bounded retry -> quarantine,
  with serial and pooled sweeps quarantining the *same* candidates;
* ``strict=True`` fail-fast on both paths;
* journal/resume: SIGKILL the sweep process mid-run, resume from the
  journal, merged result bit-identical (torn final lines tolerated,
  mismatched headers rejected);
* persistent-cache write-back through per-worker shards, including
  truncated-shard quarantine;
* the ``CHARON_FAULTS`` grammar and the chaos schedule's determinism.

Seeds below are pinned to schedules verified to actually fire on this
18-candidate space (blake2b is uniform, but any *specific* seed may miss);
if the space changes, re-scan seeds rather than loosening assertions.
"""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

from repro.analysis.chaos import ChaosError, FaultPlan, corrupt_shard
from repro.api import (
    Cluster, DecodeWorkload, SimSpec, SweepSpace, sweep,
)
from repro.api.pool import (
    CandidateFailedError, RetryPolicy, SweepJournal, get_pool,
    shutdown_pools,
)
from repro.configs import get_config
from repro.core.simulator import Simulator, merge_cache_shards
from repro.obs.metrics import MetricsRegistry

CFG = get_config("xlstm-125m")

# a short per-candidate timeout keeps the hang test fast; generous enough
# that a legitimate candidate (~50ms here) never trips it
FAST = RetryPolicy(timeout_s=5.0, backoff_s=0.01, backoff_max_s=0.1)


@pytest.fixture(scope="module", autouse=True)
def _pool_teardown():
    yield
    shutdown_pools()


def _space(memory_limit=16e9):
    base = SimSpec(CFG, cluster=Cluster("tpu_v5e", chips=16,
                                        memory_limit=memory_limit),
                   workload=DecodeWorkload(global_batch=8, seq_len=1024))
    return SweepSpace(base, {"tp": (1, 2, 4), "pp": (1, 2),
                             "batch": (8, 16, 32)})


def _result_key(res):
    return (
        [(r.cand.key(), r.report.step_time_us, r.report.mfu,
          sorted(r.report.kind_us.items()), r.report.memory.total)
         for r in res.evaluated],
        [(r.cand.key(), r.reason) for r in res.pruned],
        [(r.cand.key(), r.report.step_time_us) for r in res.ranked()],
    )


def _counters(res):
    return res.metrics.get("counters", {})


# ======================================================================
# recoverable faults: bit-identity under crash / hang / poison candidate
# ======================================================================

def test_worker_crash_recovery_bit_identical():
    serial = sweep(_space())
    chaotic = sweep(_space(), workers=2, retry=FAST,
                    faults=FaultPlan(seed=3, worker_crash=0.3))
    assert _result_key(serial) == _result_key(chaotic)
    assert chaotic.failed == ()
    c = _counters(chaotic)
    # the schedule verifiably fired: deaths happened, retries recovered them
    assert c.get("pool.worker_deaths", 0) >= 1
    assert c.get("pool.retries", 0) >= 1
    assert c.get("pool.respawns", 0) >= 1
    assert c.get("pool.quarantined", 0) == 0


def test_worker_hang_timeout_recovery_bit_identical():
    serial = sweep(_space())
    chaotic = sweep(
        _space(), workers=2,
        retry=RetryPolicy(timeout_s=2.0, backoff_s=0.01, backoff_max_s=0.1),
        faults=FaultPlan(seed=0, worker_hang=0.15, hang_s=60.0))
    assert _result_key(serial) == _result_key(chaotic)
    assert chaotic.failed == ()
    c = _counters(chaotic)
    assert c.get("pool.timeouts", 0) >= 1
    assert c.get("pool.retries", 0) >= 1


def test_candidate_error_recovery_bit_identical_serial_and_pool():
    plan = FaultPlan(seed=1, candidate_error=0.2)   # first attempt only
    clean = sweep(_space())
    ser = sweep(_space(), faults=plan)
    par = sweep(_space(), workers=2, retry=FAST, faults=plan)
    assert _result_key(clean) == _result_key(ser) == _result_key(par)
    assert ser.failed == () and par.failed == ()
    for res in (ser, par):
        c = _counters(res)
        assert c.get("pool.candidate_errors", 0) >= 1
        assert c.get("pool.retries", 0) >= 1


# ======================================================================
# quarantine: retries exhausted -> FailedCandidate, never an abort
# ======================================================================

# fires on every attempt for 4 of the 18 candidates (verified schedule)
POISON = FaultPlan(seed=1, candidate_error=0.2, repeat=True)
ONE_RETRY = RetryPolicy(max_retries=1, timeout_s=5.0, backoff_s=0.01,
                        backoff_max_s=0.1)


def test_quarantine_is_symmetric_between_serial_and_pool():
    ser = sweep(_space(), faults=POISON, retry=ONE_RETRY)
    par = sweep(_space(), workers=2, faults=POISON, retry=ONE_RETRY)
    assert len(ser.failed) == len(par.failed) == 4
    assert [f.spec.json_hash() for f in ser.failed] \
        == [f.spec.json_hash() for f in par.failed]
    for f in ser.failed + par.failed:
        assert f.attempts == 2                       # 1 try + 1 retry
        assert "ChaosError" in f.reason
    # the poisoned candidates are *missing* from evaluated, not silently
    # re-classified as pruned
    assert len(ser.evaluated) + len(ser.pruned) == 18 - 4
    assert _result_key(ser) == _result_key(par)
    assert _counters(par).get("pool.quarantined", 0) == 4
    assert _counters(par).get("sweep.failed", 0) == 4


def test_strict_mode_fails_fast():
    with pytest.raises(ChaosError):
        sweep(_space(), faults=POISON, retry=ONE_RETRY, strict=True)
    with pytest.raises(CandidateFailedError) as ei:
        sweep(_space(), workers=2, faults=POISON, retry=ONE_RETRY,
              strict=True)
    assert ei.value.failed.attempts == 2
    # the abort path reset the pool: the next sweep must be clean
    clean = sweep(_space(), workers=2, retry=FAST)
    assert _result_key(clean) == _result_key(sweep(_space()))


def test_manifest_records_failed_rows(tmp_path):
    man = tmp_path / "manifest.json"
    res = sweep(_space(), faults=POISON, retry=ONE_RETRY, manifest=str(man))
    doc = json.loads(man.read_text())
    statuses = {}
    for row in doc["candidates"]:
        statuses[row["status"]] = statuses.get(row["status"], 0) + 1
    assert statuses["failed"] == doc["n_failed"] == len(res.failed) == 4
    assert statuses["completed"] == len(res.evaluated)
    frow = next(r for r in doc["candidates"] if r["status"] == "failed")
    assert frow["attempts"] == 2 and "ChaosError" in frow["reason"]
    assert frow["rank"] is None and frow["traceback"]


# ======================================================================
# journal / resume
# ======================================================================

def test_journal_full_resume_skips_all_work(tmp_path):
    jr = tmp_path / "sweep.jsonl"
    first = sweep(_space(), journal=str(jr))
    second = sweep(_space(), journal=str(jr))
    assert _result_key(first) == _result_key(second)
    assert _counters(second).get("sweep.resumed", 0) == 18
    assert _counters(second).get("sweep.evaluated", 0) \
        + _counters(second).get("sweep.pruned", 0) == 18


def test_journal_tolerates_torn_final_line(tmp_path):
    jr = tmp_path / "sweep.jsonl"
    sweep(_space(), journal=str(jr))
    lines = jr.read_text().splitlines()
    # keep header + 7 rows, then a mid-write kill: half a JSON row
    jr.write_text("\n".join(lines[:8]) + "\n" + lines[8][: len(lines[8]) // 2])
    resumed = sweep(_space(), workers=2, retry=FAST, journal=str(jr))
    assert _result_key(resumed) == _result_key(sweep(_space()))
    assert _counters(resumed).get("sweep.resumed", 0) == 7


def test_journal_header_mismatch_is_rejected(tmp_path):
    jr = tmp_path / "sweep.jsonl"
    sweep(_space(), journal=str(jr))
    other = SweepSpace(_space().base, {"tp": (1, 2), "batch": (8, 16)})
    with pytest.raises(ValueError, match="different sweep"):
        sweep(other, journal=str(jr))
    with pytest.raises(ValueError, match="different sweep"):
        sweep(_space(), resume=str(jr), objective="goodput")


def test_journal_failed_rows_are_reattempted_on_resume(tmp_path):
    jr = tmp_path / "sweep.jsonl"
    broken = sweep(_space(), faults=POISON, retry=ONE_RETRY,
                   journal=str(jr))
    assert len(broken.failed) == 4
    # resume without faults: the quarantined candidates get their second
    # chance and the merged result matches a clean run exactly
    healed = sweep(_space(), journal=str(jr))
    assert healed.failed == ()
    assert _result_key(healed) == _result_key(sweep(_space()))
    assert _counters(healed).get("sweep.resumed", 0) == 14


_KILL_HARNESS = """
import sys
from repro.api import Cluster, DecodeWorkload, SimSpec, SweepSpace, sweep
from repro.configs import get_config

base = SimSpec(get_config("xlstm-125m"),
               cluster=Cluster("tpu_v5e", chips=16, memory_limit=16e9),
               workload=DecodeWorkload(global_batch=8, seq_len=1024))
space = SweepSpace(base, {"tp": (1, 2, 4), "pp": (1, 2),
                          "batch": (8, 16, 32)})
print("READY", flush=True)
sweep(space, workers=2, journal=sys.argv[1])
print("DONE", flush=True)
"""


def test_sigkill_mid_sweep_then_resume_bit_identical(tmp_path):
    """The crash-safety headline: SIGKILL a pooled sweep process mid-run
    (its workers become orphans and must exit on their own), then resume
    from the journal — the merged result is bit-identical to an
    uninterrupted serial sweep."""
    jr = tmp_path / "sweep.jsonl"
    script = tmp_path / "harness.py"
    script.write_text(_KILL_HARNESS)
    root = Path(__file__).resolve().parent.parent
    proc = subprocess.Popen(
        [sys.executable, str(script), str(jr)],
        env={**os.environ, "PYTHONPATH": str(root / "src")},
        stdout=subprocess.PIPE, text=True)
    try:
        # wait until a few candidates are journaled, then kill -9
        deadline = time.monotonic() + 120.0
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                pytest.fail("harness finished before it could be killed: "
                            f"{proc.stdout.read()}")
            if jr.exists() and len(jr.read_text().splitlines()) >= 4:
                break
            time.sleep(0.02)
        else:
            pytest.fail("journal never accumulated rows")
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait(timeout=30.0)
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.stdout.close()
    rows = jr.read_text().splitlines()
    assert 4 <= len(rows) < 19            # partial: header + some results
    resumed = sweep(_space(), journal=str(jr))
    assert _result_key(resumed) == _result_key(sweep(_space()))
    assert _counters(resumed).get("sweep.resumed", 0) >= 3


# ======================================================================
# persistent-cache write-back through shards
# ======================================================================

def test_pooled_sweep_writes_back_merged_cache(tmp_path):
    res = sweep(_space(), workers=2, retry=FAST, persist=str(tmp_path))
    assert res.failed == ()
    cache_files = list(tmp_path.glob("*.pkl"))
    assert cache_files, "pooled sweep left no merged cache file"
    # shards are consumed by the merge, never left behind
    assert not list(tmp_path.glob("*.shard"))
    assert _counters(res).get("pool.cache_shards_merged", 0) >= 1
    # a serial run warm-starts from the worker-written entries
    warm = sweep(_space(), persist=str(tmp_path))
    assert _result_key(res) == _result_key(warm)
    assert warm.cache_stats["reports"]["hits"] >= 1


def test_corrupt_shard_is_quarantined_not_fatal(tmp_path):
    # every worker's shard is truncated mid-file after writing: the merge
    # must rename them *.corrupt and carry on; results are unaffected
    # (they flowed through the result queue, not the cache)
    res = sweep(_space(), workers=2, retry=FAST, persist=str(tmp_path),
                faults=FaultPlan(cache_corrupt=1.0))
    assert _result_key(res) == _result_key(sweep(_space()))
    assert _counters(res).get("pool.cache_shards_quarantined", 0) >= 1
    assert list(tmp_path.glob("*.corrupt"))
    assert not list(tmp_path.glob("*.shard"))


def test_merge_cache_shards_truncated_file_direct(tmp_path):
    from repro.core import ParallelConfig
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=2, dp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    s1 = Simulator("tpu_v5e", persist=str(tmp_path))
    s1.run(spec)
    good = s1.save_cache_shard("t1")
    bad = s1.save_cache_shard("t2")
    corrupt_shard(str(bad))
    reg = MetricsRegistry()
    out = merge_cache_shards(str(s1.cache.persist_path), [str(good),
                                                          str(bad)],
                             metrics=reg)
    assert out["merged"] == 1 and out["quarantined"] == 1
    assert reg.counters.get("pool.cache_shards_quarantined") == 1
    assert bad.with_name(bad.name + ".corrupt").exists()
    assert not good.exists()                     # consumed by the merge
    # the merged main file round-trips: a fresh simulator loads it
    s2 = Simulator("tpu_v5e", persist=str(tmp_path))
    assert s2.cache.loaded_sizes.get("reports", 0) >= 1
    assert s2.run(spec).step_time_us == s1.run(spec).step_time_us


# ======================================================================
# chaos plan mechanics + pool plumbing
# ======================================================================

def test_fault_plan_is_deterministic_and_attempt_aware():
    plan = FaultPlan(seed=5, worker_crash=0.5)
    rolls = [plan.roll("worker_crash", f"h{i}") for i in range(64)]
    assert rolls == [FaultPlan(seed=5, worker_crash=0.5)
                     .roll("worker_crash", f"h{i}") for i in range(64)]
    assert any(rolls) and not all(rolls)
    fired = next(f"h{i}" for i in range(64)
                 if plan.roll("worker_crash", f"h{i}"))
    assert plan.should("worker_crash", (fired,), attempt=1)
    assert not plan.should("worker_crash", (fired,), attempt=2)
    rep = FaultPlan(seed=5, worker_crash=0.5, repeat=True)
    assert rep.should("worker_crash", (fired,), attempt=2)
    # different seeds give different schedules
    assert rolls != [FaultPlan(seed=6, worker_crash=0.5)
                     .roll("worker_crash", f"h{i}") for i in range(64)]


def test_charon_faults_env_grammar():
    plan = FaultPlan.from_env({"CHARON_FAULTS":
                               "worker_crash:0.05, worker_hang:0.01,"
                               "cache_corrupt:0.02,seed:7,repeat:1,"
                               "hang_s:12.5"})
    assert plan == FaultPlan(worker_crash=0.05, worker_hang=0.01,
                             cache_corrupt=0.02, seed=7, repeat=True,
                             hang_s=12.5)
    assert FaultPlan.from_env({}) is None
    assert FaultPlan.from_env({"CHARON_FAULTS": "  "}) is None
    with pytest.raises(ValueError, match="unknown CHARON_FAULTS kind"):
        FaultPlan.from_env({"CHARON_FAULTS": "meteor_strike:1.0"})
    with pytest.raises(ValueError, match="not 'kind:value'"):
        FaultPlan.from_env({"CHARON_FAULTS": "worker_crash"})
    with pytest.raises(ValueError, match="rate must be in"):
        FaultPlan(worker_crash=1.5)


def test_sweep_reads_charon_faults_env(monkeypatch):
    monkeypatch.setenv("CHARON_FAULTS", "candidate_error:0.2,seed:1")
    res = sweep(_space())
    monkeypatch.delenv("CHARON_FAULTS")
    assert _result_key(res) == _result_key(sweep(_space()))
    assert _counters(res).get("pool.candidate_errors", 0) >= 1


def test_retry_policy_contract():
    p = RetryPolicy(backoff_s=0.1, backoff_max_s=0.5)
    assert p.backoff_for(2) == pytest.approx(0.1)
    assert p.backoff_for(3) == pytest.approx(0.2)
    assert p.backoff_for(10) == pytest.approx(0.5)     # capped
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(timeout_s=0.0)


def test_pool_is_long_lived_across_sweeps():
    p1 = get_pool(2)
    sweep(_space(), workers=2, retry=FAST)
    p2 = get_pool(2)
    assert p2 is p1 and p1.alive
    # worker PIDs survived the sweep: no respawn between calls
    pids = sorted(s.proc.pid for s in p1._slots)
    sweep(_space(), workers=2, retry=FAST)
    assert sorted(s.proc.pid for s in p1._slots) == pids


def test_journal_roundtrips_results(tmp_path):
    jr_path = tmp_path / "j.jsonl"
    res = sweep(_space(), journal=str(jr_path))
    rows = SweepJournal.load(str(jr_path))
    assert len(rows) == 18
    some = next(iter(rows.values()))
    rehydrated = SweepJournal.result_from(some)
    assert rehydrated.spec.json_hash() == some["h"]
    # the payload round-trips the numbers exactly
    orig = next(r for r in res.evaluated + res.pruned
                if r.spec.json_hash() == some["h"])
    assert rehydrated.pruned == orig.pruned
    assert rehydrated.reason == orig.reason
    if orig.report is not None:
        assert rehydrated.report.step_time_us == orig.report.step_time_us
        assert rehydrated.report.kind_us == orig.report.kind_us

"""Fleet-scale serving: spec round trips, router/autoscaler behaviour,
request conservation, shim↔spec bit-identity, report aggregation and the
fleet goodput sweep (serial == parallel, manifest provenance)."""
import json
import warnings

import pytest

from repro.api import (
    AutoscalerSpec, Cluster, FleetSpec, RouterSpec, ServingWorkload, SimSpec,
    SweepSpace, spec_replace, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.serving.sim import (
    SLO, FleetReport, FleetSimulator, LengthDist, ServingReport,
    ServingSimulator, Workload, make_router, synthesize,
)

CFG = get_config("xlstm-125m")
PAR = ParallelConfig(tp=2)
SHORT = dict(prompt=LengthDist("lognormal", median=64.0, sigma=0.6, cap=256),
             output=LengthDist("lognormal", median=12.0, sigma=0.5, cap=48))


@pytest.fixture(scope="module")
def sim():
    # module-scoped: the shared oracle's cold misses dominate; every test
    # after the first runs warm
    return Simulator("tpu_v5e", engine="analytical")


def _spec(n=200, rate=48.0, seed=3, arrival="poisson", fleet=None, **kw):
    return SimSpec(CFG, cluster=Cluster("tpu_v5e"), parallel=PAR,
                   workload=ServingWorkload(
                       n_requests=n, arrival=arrival, rate_rps=rate,
                       seed=seed, fleet=fleet or FleetSpec(), **SHORT, **kw))


# ---------------- spec types ----------------

def test_fleet_spec_roundtrip_and_hash():
    fleet = FleetSpec(replicas=4, router=RouterSpec("session_affinity"),
                      autoscaler=AutoscalerSpec(max_replicas=6),
                      prefill_replicas=2, prefill_batch=8)
    spec = _spec(fleet=fleet, sessions=16)
    again = SimSpec.from_json(spec.to_json())
    assert again == spec and hash(again) == hash(spec)
    assert again.json_hash() == spec.json_hash()
    assert again.workload.fleet.autoscaler == fleet.autoscaler
    # no-autoscaler fleets round-trip the None
    spec2 = _spec(fleet=FleetSpec(replicas=2))
    assert SimSpec.from_json(spec2.to_json()) == spec2


def test_fleet_spec_validation():
    with pytest.raises(ValueError):
        FleetSpec(replicas=0)
    with pytest.raises(ValueError):
        RouterSpec("best_effort")
    with pytest.raises(ValueError):
        RouterSpec("session_affinity", fallback="session_affinity")
    with pytest.raises(ValueError):
        AutoscalerSpec(scale_up_queue=2.0, scale_down_queue=4.0)
    with pytest.raises(ValueError):
        AutoscalerSpec(min_replicas=5, max_replicas=2)
    assert FleetSpec().trivial
    assert not FleetSpec(replicas=2).trivial
    assert not FleetSpec(autoscaler=AutoscalerSpec()).trivial


def test_fleet_fields_are_sweep_axes():
    spec = _spec()
    out = spec_replace(spec, {"workload.fleet.replicas": 8,
                              "workload.fleet.router": RouterSpec(
                                  "least_loaded")})
    assert out.workload.fleet.replicas == 8
    assert out.workload.fleet.router.kind == "least_loaded"
    assert spec.workload.fleet.replicas == 1      # frozen base untouched
    with pytest.raises(KeyError):
        spec_replace(spec, {"workload.fleet.nope": 1})
    with pytest.raises(KeyError):
        # descending through a None autoscaler is an explicit error
        spec_replace(spec, {"workload.fleet.autoscaler.min_replicas": 2})


# ---------------- shim <-> spec identity ----------------

def test_round_robin_fleet_matches_sharded_single_runs(sim):
    """Replica i of a round-robin fleet sees exactly ``shard(k, i)``; its
    per-replica report must be bit-identical to a standalone run of that
    shard (the property that retires ``Workload.thin``)."""
    spec = _spec(n=150, fleet=FleetSpec(replicas=3))
    w = spec.workload
    frep = ServingSimulator(sim).run(spec)
    assert isinstance(frep, FleetReport) and frep.n_replicas == 3
    for i in range(3):
        solo = ServingSimulator(sim, CFG, par=PAR, policy=w.make_policy(),
                                ctx_floor=w.ctx_floor).run(
            w.build().shard(3, i), slo=w.slo)
        per = frep.replicas[i]
        assert per.n_requests == solo.n_requests
        assert per.ttft_s == solo.ttft_s
        assert per.tpot_ms == solo.tpot_ms
        assert per.n_steps == solo.n_steps
        assert per.utilization == solo.utilization


def test_thin_shim_equals_router_delivery():
    wl = synthesize(60, rate_rps=20.0, seed=7, **SHORT)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        thinned = wl.thin(4, offset=2)
    key = lambda w: [(r.rid, r.arrival_s, r.prompt_len, r.output_len)
                     for r in w.requests]
    assert key(thinned) == key(wl.shard(4, offset=2))
    # and shard(k, i) is what the round-robin router hands replica i
    class Rep:
        def __init__(self, index):
            self.index = index
    reps = [Rep(i) for i in range(4)]
    router = make_router(RouterSpec())
    routed = [[] for _ in reps]
    for r in wl.requests:
        routed[router.route(r, reps, r.arrival_s).index].append(r)
    assert [r.rid for r in routed[2]] == [r.rid for r in thinned.requests]


# ---------------- determinism + conservation ----------------

@pytest.mark.parametrize("router", ["round_robin", "least_loaded",
                                    "session_affinity"])
def test_fleet_conservation_and_determinism(sim, router):
    fleet = FleetSpec(replicas=3, router=RouterSpec(router))
    spec = _spec(n=200, arrival="bursty", seed=11, sessions=12, fleet=fleet)
    a = ServingSimulator(sim).run(spec)
    b = ServingSimulator(sim).run(spec)
    assert a.n_requests == 200                  # conservation (else the
    assert sum(a.replica_requests.values()) == 200   # loop raised)
    assert a.ttft_s == b.ttft_s and a.tpot_ms == b.tpot_ms
    assert a.replica_requests == b.replica_requests
    # everything but the oracle cache counters (cold first run, warm second)
    sa, sb = a.summary(), b.summary()
    sa.pop("oracle_stats"), sb.pop("oracle_stats")
    assert sa == sb


@pytest.mark.parametrize("n", [30, 45, 200])
def test_static_gang_fleet_drains(sim, n):
    """Regression: a gang-scheduling replica idling on a partial batch is
    unblocked by the *fleet-wide* last arrival — which usually lands on a
    different replica — so a static-policy fleet must drain, not deadlock."""
    fleet = FleetSpec(replicas=2)
    spec = _spec(n=n, seed=3, policy="static", max_batch=8, fleet=fleet)
    a = ServingSimulator(sim).run(spec)
    assert a.n_requests == n
    assert sum(a.replica_requests.values()) == n
    b = ServingSimulator(sim).run(spec)
    assert a.ttft_s == b.ttft_s and a.tpot_ms == b.tpot_ms


def test_disaggregated_fleet_uses_policy_decode_batch(sim):
    """Fleet-level disaggregation with a per-replica DisaggregatedPD policy
    must cap decode replicas at the policy's decode_batch, not a default."""
    from repro.serving.sim.policies import DisaggregatedPD

    fsim = FleetSimulator(sim, CFG, par=PAR,
                          policy=DisaggregatedPD(decode_batch=7),
                          fleet=FleetSpec(replicas=2, prefill_replicas=1))
    _, serve, _ = fsim._replicas()
    assert {p.policy.max_batch for rep in serve for p in rep.pools} == {7}


def test_disaggregated_fleet_conserves(sim):
    spec = _spec(n=150, fleet=FleetSpec(replicas=2, prefill_replicas=1,
                                        prefill_batch=4))
    rep = ServingSimulator(sim).run(spec)
    assert rep.n_requests == 150
    # requests are attributed to their decode replica; prefill replicas
    # finish nothing themselves (single-token requests aside)
    assert set(rep.replica_utilization) >= {"r0/decode", "r1/decode",
                                            "r2/prefill"}
    assert rep.replica_utilization["r2/prefill"]["steps"] > 0


def test_least_loaded_beats_round_robin_on_bursty(sim):
    """Under bursty arrivals, load-aware routing must differ from blind
    round-robin — and not be worse on p99 queueing."""
    reps = {}
    for kind in ("round_robin", "least_loaded"):
        spec = _spec(n=250, arrival="bursty", rate=64.0, seed=5,
                     fleet=FleetSpec(replicas=3, router=RouterSpec(kind)))
        reps[kind] = ServingSimulator(sim).run(spec)
    rr, ll = reps["round_robin"], reps["least_loaded"]
    assert rr.replica_requests != ll.replica_requests
    assert ll.queue_delay_s.p99 <= rr.queue_delay_s.p99


def test_session_affinity_is_sticky(sim):
    spec = _spec(n=200, sessions=8,
                 fleet=FleetSpec(replicas=4,
                                 router=RouterSpec("session_affinity")))
    rep = ServingSimulator(sim).run(spec)
    by_session = {}
    for i, per in enumerate(rep.replicas):
        for r in per.requests:
            by_session.setdefault(r.session, set()).add(i)
    # every session lands on exactly one replica
    assert by_session and all(len(v) == 1 for v in by_session.values())
    # and more than one replica takes traffic overall
    assert len({next(iter(v)) for v in by_session.values()}) > 1


# ---------------- autoscaler ----------------

def test_autoscaler_no_thrash_on_flat_trace(sim):
    """Hysteresis: a steady low-rate trace inside the deadband produces no
    scale actions at all."""
    fleet = FleetSpec(replicas=2, autoscaler=AutoscalerSpec(
        min_replicas=2, max_replicas=4, scale_up_queue=12.0,
        scale_down_queue=0.0 + 1e-9, interval_s=1.0))
    spec = _spec(n=150, arrival="uniform", rate=8.0, fleet=fleet)
    rep = ServingSimulator(sim).run(spec)
    assert rep.n_requests == 150
    assert rep.autoscaler_trace == ()   # frozen: cache-shared reports are immutable


def test_autoscaler_scales_up_on_flash_crowd(sim):
    fleet = FleetSpec(replicas=1, router=RouterSpec("least_loaded"),
                      autoscaler=AutoscalerSpec(
                          min_replicas=1, max_replicas=4, scale_up_queue=6.0,
                          scale_down_queue=0.5, interval_s=1.0, cooldown_s=3.0,
                          provision_s=0.5))
    spec = _spec(n=500, arrival="flash_crowd", rate=10.0, seed=2,
                 flash_start_s=5.0, flash_dur_s=25.0, flash_mult=12.0,
                 fleet=fleet)
    rep = ServingSimulator(sim).run(spec)
    ups = [e for e in rep.autoscaler_trace
           if e["action"].startswith("scale_up")]
    downs = [e for e in rep.autoscaler_trace
             if e["action"].startswith("scale_down")]
    assert ups, "flash crowd must trigger scale-up"
    assert downs, "post-flash lull must scale back down"
    assert rep.n_requests == 500                 # drain on scale-down
    # the extra replicas actually took traffic
    assert sum(1 for v in rep.replica_requests.values() if v > 0) > 1


# ---------------- report aggregation ----------------

def test_fleet_report_equals_hand_merge(sim):
    spec = _spec(n=120, fleet=FleetSpec(replicas=3))
    rep = ServingSimulator(sim).run(spec)
    merged = [r for per in rep.replicas for r in per.requests]
    hand = ServingReport.build(merged, [], rep.slo, {})
    assert rep.n_requests == hand.n_requests == 120
    assert rep.ttft_s == hand.ttft_s
    assert rep.tpot_ms == hand.tpot_ms
    assert rep.e2e_s == hand.e2e_s
    assert rep.makespan_s == hand.makespan_s
    assert rep.slo_attainment == hand.slo_attainment
    assert abs(rep.goodput_rps - hand.goodput_rps) < 1e-12
    assert rep.n_steps == sum(per.n_steps for per in rep.replicas)


def test_fleet_report_is_system_level():
    assert FleetReport.system_level and not ServingReport.system_level


# ---------------- arrival generators ----------------

def test_diurnal_and_flash_generators():
    di = synthesize(800, arrival="diurnal", rate_rps=20.0, period_s=40.0,
                    diurnal_amp=0.9, seed=4, **SHORT)
    arr = [r.arrival_s for r in di.requests]
    assert arr == sorted(arr)
    assert synthesize(800, arrival="diurnal", rate_rps=20.0, period_s=40.0,
                      diurnal_amp=0.9, seed=4, **SHORT).requests[-1].arrival_s \
        == arr[-1]
    # rate modulation: the peak-quarter of the cycle is denser than the
    # trough-quarter (sin > 0 vs sin < 0)
    import math
    phase = [math.sin(2 * math.pi * t / 40.0) for t in arr]
    assert sum(1 for p in phase if p > 0.5) > 2 * sum(
        1 for p in phase if p < -0.5)

    fl = synthesize(600, arrival="flash_crowd", rate_rps=10.0,
                    flash_start_s=10.0, flash_dur_s=10.0, flash_mult=8.0,
                    seed=4, **SHORT)
    t = [r.arrival_s for r in fl.requests]
    in_flash = sum(1 for x in t if 10.0 <= x < 20.0)
    before = sum(1 for x in t if 0.0 <= x < 10.0)
    assert in_flash > 3 * max(before, 1)


# ---------------- fleet goodput sweep ----------------

def test_fleet_sweep_ranks_and_manifest(sim, tmp_path):
    base = _spec(n=250, arrival="diurnal", rate=120.0, seed=1,
                 slo=SLO(ttft_s=0.5, tpot_ms=60.0))
    space = SweepSpace(base, {"workload.fleet.replicas": (1, 2, 4)})
    path = tmp_path / "manifest.json"
    res = sweep(space, sim=sim, objective="goodput", manifest=str(path))
    ranked = res.ranked()
    assert len(ranked) == 3
    # the trace saturates small fleets: more replicas -> strictly better
    # goodput, and the biggest fleet wins
    goodputs = {r.spec.workload.fleet.replicas: r.goodput_rps for r in ranked}
    assert goodputs[4] > goodputs[2] > goodputs[1]
    assert ranked[0].spec.workload.fleet.replicas == 4
    # FleetReports are system-level: goodput is NOT scaled by dp*pods
    assert ranked[0].goodput_rps == ranked[0].serving.goodput_rps

    doc = json.loads(path.read_text())
    assert doc["kind"] == "charon-sweep-manifest"
    assert doc["base_hash"] == base.json_hash()
    assert doc["axes"] == {"workload.fleet.replicas": [1, 2, 4]}
    assert len(doc["candidates"]) == 3 and len(doc["ranking"]) == 3
    assert doc["ranking"][0] == ranked[0].spec.json_hash()
    hashes = {row["json_hash"] for row in doc["candidates"]}
    assert set(doc["ranking"]) == hashes
    # every row's spec JSON reconstructs the exact spec it hashes to
    for row in doc["candidates"]:
        rebuilt = SimSpec.from_json(json.dumps(row["spec"]))
        assert rebuilt.json_hash() == row["json_hash"]


def test_fleet_sweep_parallel_bit_identical(sim):
    """workers=2 shards the fleet candidates over processes; rankings and
    every objective value must match the serial sweep exactly."""
    base = _spec(n=150, arrival="diurnal", rate=64.0, seed=1,
                 slo=SLO(ttft_s=1.0, tpot_ms=80.0))
    space = SweepSpace(base, {"workload.fleet.replicas": (1, 2),
                              "workload.fleet.prefill_replicas": (0, 1)})
    ser = sweep(space, sim=sim, objective="goodput")
    par = sweep(space, objective="goodput", workers=2)
    key = lambda res: [(r.spec.json_hash(), r.goodput_rps,
                        r.report.step_time_us) for r in res.ranked()]
    assert key(ser) == key(par)
    assert par.workers == 2


def test_serving_base_requires_goodput(sim):
    space = SweepSpace(_spec(), {"workload.fleet.replicas": (1, 2)})
    with pytest.raises(TypeError):
        sweep(space, sim=sim)                   # objective defaults step_time
    with pytest.raises(TypeError):
        sweep(space, sim=sim, objective="goodput",
              scenario=_spec().workload)        # spec already IS the scenario

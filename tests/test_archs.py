"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + finiteness; prefill/decode consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_shape, get_tiny_config, supports_shape
from repro.models import Model, count_params
from repro.training.optimizer import make_optimizer
from repro.training.train_step import make_train_step
from repro.configs.base import RunConfig, ShapeConfig


def _batch(cfg, B, S, *, labels=False, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks}
    if labels:
        b["labels"] = jnp.roll(toks, -1, axis=1)
    if cfg.rope_style == "mrope":
        b["positions"] = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
    if cfg.encoder_layers:
        b["frame_embeds"] = jax.random.normal(jax.random.PRNGKey(2), (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.frontend == "vision_patches":
        b["patch_embeds"] = jax.random.normal(jax.random.PRNGKey(3), (B, 8, cfg.d_model)) * 0.1
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = get_tiny_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    logits, aux = m.forward(params, _batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_nothing_nan(arch):
    cfg = get_tiny_config(arch)
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=4.0)
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 16, 2, "train"))
    opt = make_optimizer("adamw", peak_lr=1e-3)
    step = jax.jit(make_train_step(cfg, run, opt))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}
    batch = _batch(cfg, 2, 16, labels=True)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_match_forward(arch):
    cfg = get_tiny_config(arch)
    if cfg.num_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    full = _batch(cfg, B, S + 1)
    pre = {k: (v[:, :S] if v.ndim >= 2 and v.shape[1] == S + 1 else v)
           for k, v in full.items()}
    lf, _ = m.forward(params, full)
    lp, cache = m.prefill(params, pre, cache_len=S + 4)
    db = {"tokens": full["tokens"][:, S:S + 1]}
    if cfg.rope_style == "mrope":
        db["positions"] = jnp.full((B, 1, 3), S, jnp.int32)
    ld, cache2 = m.decode_step(params, cache, db)
    tol = 0.08  # bf16 absorbed-vs-expanded MLA reordering
    assert float(jnp.max(jnp.abs(lp - lf[:, S - 1:S]))) < tol
    assert float(jnp.max(jnp.abs(ld - lf[:, S:S + 1]))) < tol
    assert int(cache2["pos"][0]) == S + 1


def test_param_counts_full_configs():
    """Exact configs instantiate abstractly and land in the right ballpark."""
    expect = {
        "qwen2.5-32b": (31e9, 34e9),
        "phi4-mini-3.8b": (3.2e9, 4.4e9),
        "gemma-7b": (8.0e9, 9.5e9),
        "yi-34b": (33e9, 36e9),
        "deepseek-v3-671b": (640e9, 720e9),
        "olmoe-1b-7b": (6.5e9, 7.5e9),
        "recurrentgemma-9b": (8.5e9, 11.5e9),
        "qwen2-vl-7b": (7e9, 8.5e9),
        "whisper-large-v3": (1.4e9, 1.9e9),
        "xlstm-125m": (0.10e9, 0.18e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"


def test_shape_applicability():
    assert supports_shape(get_config("recurrentgemma-9b"), get_shape("long_500k"))
    assert supports_shape(get_config("xlstm-125m"), get_shape("long_500k"))
    assert not supports_shape(get_config("qwen2.5-32b"), get_shape("long_500k"))
    assert supports_shape(get_config("qwen2.5-32b"), get_shape("decode_32k"))

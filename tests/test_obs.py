"""Observability layer: trace recorder schema, zero-overhead-when-off
bit-identity, metrics registry, explain() attribution, sweep progress.

Contracts asserted here:

* every exported trace is Perfetto-loadable: required keys on every event,
  microsecond timestamps sorted non-decreasing, non-negative durations,
  JSON round-trip;
* ``recorder=None`` (the default) and an attached ``MetricsRegistry``
  change no report field — observability is a pure tap on all four
  simulators (core step, serving, fleet, resilience);
* truncation is loud: the interval expander and the per-request lanes emit
  a ``charon:*_truncated`` metadata instant and bump a metrics counter
  instead of silently dropping events;
* the resilience timeline's colored spans partition wall time the same way
  the report's bucket accounting does.
"""
import dataclasses
import json

import pytest

from repro.api import (
    CheckpointSpec, Cluster, FaultModel, FleetSpec, ResilienceSpec,
    RouterSpec, ServingWorkload, SimSpec, SweepSpace, TrainWorkload, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.obs import (
    CNAMES, NULL_RECORDER, HistStat, MetricsRegistry, TraceRecorder,
    compact_report, critical_path, explain_report,
)
from repro.resilience import ResilienceSimulator
from repro.serving.sim import SLO, LengthDist, ServingSimulator

CFG = get_config("xlstm-125m")
PAR = ParallelConfig(tp=2)
SHORT = dict(prompt=LengthDist("lognormal", median=64.0, sigma=0.6, cap=256),
             output=LengthDist("lognormal", median=12.0, sigma=0.5, cap=48))


def _sim():
    return Simulator("tpu_v5e", engine="analytical")


def _step_spec():
    return SimSpec(CFG, cluster=Cluster("tpu_v5e"), parallel=PAR,
                   workload=TrainWorkload(global_batch=32, seq_len=512))


def _serving_spec(n=120, fleet=None, **kw):
    if fleet is not None:
        kw["fleet"] = fleet
    kw.setdefault("rate_rps", 48.0)
    return SimSpec(CFG, cluster=Cluster("tpu_v5e"), parallel=PAR,
                   workload=ServingWorkload(
                       n_requests=n, seed=3,
                       slo=SLO(ttft_s=1.0, tpot_ms=50.0),
                       **SHORT, **kw))


def _resilience_spec():
    # 32 chips over 4 hosts, system MTBF ~300s across an ~800s run: a
    # handful of failures, rework, downtime and straggler tails all occur
    res = ResilienceSpec(
        total_steps=400, faults=FaultModel(host_mtbf_s=1200.0, seed=11),
        ckpt=CheckpointSpec(interval_steps=10), chips_per_host=8,
        restart_delay_s=30.0, repair_s=600.0, straggler_prob=0.05,
        straggler_mult=1.5, optimize_interval=False)
    return SimSpec(CFG, cluster=Cluster("tpu_v5e"),
                   parallel=ParallelConfig(tp=4, dp=8),
                   workload=TrainWorkload(global_batch=256, seq_len=2048,
                                          resilience=res))


def _assert_perfetto_valid(events):
    assert events, "trace is empty"
    last_ts = -1.0
    for ev in events:
        for key in ("name", "ph", "ts", "pid", "tid"):
            assert key in ev, f"event missing {key}: {ev}"
        assert ev["ph"] in ("X", "i", "C", "M")
        assert ev["ts"] >= last_ts, "timestamps must be non-decreasing"
        last_ts = ev["ts"]
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0


# ---------------- recorder primitives ----------------

def test_recorder_schema_and_roundtrip(tmp_path):
    rec = TraceRecorder()
    rec.span("p", "t", "a", 1.0, 0.5, cat="step", args={"k": 1})
    rec.span("p", "t", "b", 0.5, 0.25, cname=CNAMES["useful"])
    rec.instant("p", "t2", "evt", 0.75)
    rec.counter("p", "q", 2.0, {"depth": 3})
    events = rec.events()
    _assert_perfetto_valid(events)
    # seconds in, microseconds out
    assert events[0]["ts"] == pytest.approx(0.5e6)
    assert events[-1]["name"] in ("a", "q")
    doc = rec.to_json()
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    assert json.loads(json.dumps(doc)) == doc
    path = tmp_path / "trace.json"
    rec.write(path)
    assert json.loads(path.read_text())["traceEvents"] == events


def test_recorder_clamps_negative_durations():
    rec = TraceRecorder()
    rec.span("p", "t", "x", 1.0, -0.5)
    assert rec.events()[0]["dur"] == 0.0


def test_null_recorder_is_disabled_and_inert():
    assert NULL_RECORDER.enabled is False
    NULL_RECORDER.span("p", "t", "x", 0.0, 1.0)
    NULL_RECORDER.instant("p", "t", "x", 0.0)
    assert NULL_RECORDER.events() == []
    # an empty *enabled* recorder is falsy (len 0) but must still record:
    # code paths guard on `is not None` / `.enabled`, never truthiness
    rec = TraceRecorder()
    assert len(rec) == 0 and rec.enabled


# ---------------- metrics registry ----------------

def test_metrics_registry_counters_histograms_diff():
    reg = MetricsRegistry()
    reg.inc("a.b")
    reg.inc("a.b", 2)
    reg.set("gauge", 7.5)
    reg.observe("lat", 1.0)
    reg.observe("lat", 3.0)
    snap = reg.snapshot()
    assert snap["counters"]["a.b"] == 3.0 and snap["counters"]["gauge"] == 7.5
    lat = snap["histograms"]["lat"]
    assert lat["count"] == 2 and lat["total"] == 4.0
    assert lat["min"] == 1.0 and lat["max"] == 3.0
    before = snap
    reg.inc("a.b", 5)
    d = MetricsRegistry.diff(reg.snapshot(), before)
    assert d["counters"]["a.b"] == 5.0
    h = HistStat()
    h.observe(2.0)
    assert h.as_dict()["count"] == 1


def test_metrics_update_nested_flattens():
    reg = MetricsRegistry()
    reg.update_nested({"pricing": {"hits": 4, "misses": 1}}, prefix="cache")
    snap = reg.snapshot()["counters"]
    assert snap["cache.pricing.hits"] == 4.0
    assert snap["cache.pricing.misses"] == 1.0


# ---------------- core step simulator ----------------

def test_core_run_bit_identical_and_traced():
    spec = _step_spec()
    rep_off = _sim().run(spec)
    rec = TraceRecorder()
    rep_on = _sim().run(spec, recorder=rec)
    # recording forces keep_timelines, so compare the priced fields (the
    # timelines are the recorder's input, not part of the pricing contract)
    for f in ("step_time_us", "tokens_per_s", "tps_per_chip", "mfu",
              "breakdown_us", "kind_us"):
        assert getattr(rep_on, f) == getattr(rep_off, f), f
    events = rec.events()
    _assert_perfetto_valid(events)
    # per-kind lanes exist and spans carry compute/comm categories
    cats = {ev.get("cat") for ev in events if ev["ph"] == "X"}
    assert cats & {"compute", "comm"}


def test_report_explain_and_compact():
    rep = _sim().run(_step_spec())
    text = rep.explain()
    assert "top ops" in text.lower() or "op" in text.lower()
    d = rep.explain_dict()
    assert d["top_ops_by_time_us"]
    c = compact_report(rep)
    assert set(c) >= {"dominant_phase", "compute_frac", "comm_frac"}
    assert 0.0 <= c["compute_frac"] <= 1.0


def test_critical_path_covers_timeline():
    sim = _sim()
    rep = sim.run(_step_spec(), keep_timelines=True)
    d = explain_report(rep)
    assert d["top_ops_by_time_us"][0][1] > 0.0
    cp = d["critical_path"]
    assert cp["n_ops"] == len(critical_path(
        max(rep.block_timelines.values(), key=lambda t: t.total_time)))
    assert cp["total_us"] > 0.0


# ---------------- serving + fleet ----------------

def test_serving_bit_identical_with_recorder_and_metrics():
    spec = _serving_spec()
    rep_off = ServingSimulator(_sim()).run(spec)
    rec, reg = TraceRecorder(), MetricsRegistry()
    rep_on = ServingSimulator(_sim()).run(spec, recorder=rec, metrics=reg)
    assert rep_on.summary() == rep_off.summary()
    _assert_perfetto_valid(rec.events())
    snap = reg.snapshot()["counters"]
    assert snap["serving.requests"] == spec.workload.n_requests
    assert snap["serving.steps"] > 0
    # request lanes: queued/prefill/decode spans on per-request tids
    req_tids = {ev["tid"] for ev in rec.events()
                if ev["pid"].endswith("requests")}
    assert any(t.startswith("req") for t in req_tids)


def test_request_lane_truncation_is_loud():
    spec = _serving_spec(n=40)
    rec, reg = TraceRecorder(max_request_lanes=4), MetricsRegistry()
    ServingSimulator(_sim()).run(spec, recorder=rec, metrics=reg)
    names = {ev["name"] for ev in rec.events()}
    assert "charon:request_lanes_truncated" in names
    assert reg.snapshot()["counters"]["trace.dropped_request_lanes"] == 40 - 4
    lanes = {ev["tid"] for ev in rec.events()
             if ev["pid"].endswith("requests") and ev["ph"] == "X"}
    assert len(lanes) == 4


def test_fleet_bit_identical_and_lanes():
    fleet = FleetSpec(replicas=3, router=RouterSpec("least_loaded"))
    spec = _serving_spec(n=150, fleet=fleet)
    rep_off = ServingSimulator(_sim()).run(spec)
    rec, reg = TraceRecorder(), MetricsRegistry()
    rep_on = ServingSimulator(_sim()).run(spec, recorder=rec, metrics=reg)
    assert rep_on.summary() == rep_off.summary()
    events = rec.events()
    _assert_perfetto_valid(events)
    pids = {ev["pid"] for ev in events}
    assert {"replica0", "replica1", "replica2"} <= pids
    assert reg.snapshot()["counters"]["fleet.requests"] == 150
    d = rep_on.explain_dict()
    assert "dominant_violation" in d or "slo" in json.dumps(d).lower()


def test_serving_explain_names_dominant_cause():
    rep = ServingSimulator(_sim()).run(_serving_spec(n=150, rate_rps=400.0))
    text = rep.explain()
    assert isinstance(text, str) and text
    d = rep.explain_dict()
    assert json.loads(json.dumps(d)) == d    # manifest-embeddable


# ---------------- resilience ----------------

def test_resilience_bit_identical_and_span_partition():
    spec = _resilience_spec()
    rep_off = ResilienceSimulator(_sim()).run(spec)
    rec, reg = TraceRecorder(), MetricsRegistry()
    rep_on = ResilienceSimulator(_sim()).run(spec, recorder=rec, metrics=reg)
    assert rep_on.summary() == rep_off.summary()
    events = rec.events()
    _assert_perfetto_valid(events)
    # colored useful spans must re-derive the report's useful_s bucket
    useful_us = sum(ev["dur"] for ev in events
                    if ev.get("cname") == CNAMES["useful"])
    assert useful_us / 1e6 == pytest.approx(rep_on.useful_s, rel=1e-9)
    assert rep_on.n_failures and reg.snapshot()["counters"]["resilience.failures"] == \
        sum(rep_on.n_failures.values())
    names = {ev["name"] for ev in events}
    assert any(n.startswith("FAILURE:") for n in names)
    d = rep_on.explain_dict()
    assert d["dominant_loss"] in ("rework", "checkpoint", "downtime",
                                  "straggler", None)
    assert sum(d["bucket_fracs"].values()) == pytest.approx(1.0, abs=2e-3)


# ---------------- chrome-trace exporter ----------------

def test_chrome_trace_truncation_is_loud():
    from repro.core.timeline import to_chrome_trace
    sim = _sim()
    rep = sim.run(_step_spec(), keep_timelines=True)
    tl = next(iter(rep.block_timelines.values()))
    reg = MetricsRegistry()
    events = to_chrome_trace(tl, expand_limit=2, metrics=reg)
    names = {ev["name"] for ev in events}
    assert "charon:trace_truncated" in names
    assert reg.snapshot()["counters"]["trace.dropped_intervals"] > 0
    full = to_chrome_trace(tl)
    assert len(full) > len(events)


def test_merge_traces_sorts():
    from repro.core.timeline import merge_traces
    a = [{"name": "x", "ph": "i", "ts": 5.0, "pid": "p", "tid": "t", "s": "t"}]
    b = [{"name": "y", "ph": "i", "ts": 1.0, "pid": "p", "tid": "t", "s": "t"}]
    merged = merge_traces(a, b)
    assert [ev["ts"] for ev in merged] == [1.0, 5.0]


# ---------------- memory report aliasing (regression) ----------------

def test_memory_timeline_is_immutable_tuple():
    rep = _sim().run(_step_spec())
    assert isinstance(rep.memory.timeline, tuple)
    for entry in rep.memory.timeline:
        assert isinstance(entry, tuple)


# ---------------- sweep ----------------

def test_sweep_metrics_trace_and_progress(capsys):
    space = SweepSpace(_step_spec(), {
        "parallel.tp": (2,), "workload.global_batch": (16, 32, 64)})
    rec, reg = TraceRecorder(), MetricsRegistry()
    res = sweep(space, sim=_sim(), recorder=rec, metrics=reg, progress=True)
    err = capsys.readouterr().err
    assert "sweep 3/3" in err and "cfg/s" in err
    assert res.metrics["counters"]["sweep.configs_done"] == 3.0
    assert res.metrics["counters"]["sweep.evaluated"] == len(res.evaluated)
    events = rec.events()
    _assert_perfetto_valid(events)
    assert any(ev["tid"].startswith("worker") for ev in events)
    # identical ranking with observability off
    res_off = sweep(space, sim=_sim())
    key = lambda r: r.cand.key()
    assert [key(r) for r in res.ranked()] == [key(r) for r in res_off.ranked()]
    assert res_off.metrics["counters"]["sweep.configs_done"] == 3.0


def test_sweep_manifest_rows_carry_explain(tmp_path):
    space = SweepSpace(_step_spec(), {"workload.global_batch": (16, 32)})
    manifest = tmp_path / "m.json"
    res = sweep(space, sim=_sim(), manifest=str(manifest))
    doc = json.loads(manifest.read_text())
    assert doc["metrics"]["counters"]["sweep.configs_done"] == 2.0
    rows = [r for r in doc["candidates"] if not r["pruned"]]
    assert rows and all(r["explain"]["step"]["dominant_phase"]
                        for r in rows)
    assert res.evaluated

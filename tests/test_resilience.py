"""Resilience subsystem: seeded fault traces, checkpoint pricing, the
replay timeline's accounting identity, interval optimization, the
goodput-under-failures sweep objective, and fleet replica-fault injection.

Determinism contracts asserted here:

* the failure trace is a pure function of (FaultModel, component counts) —
  independent of the checkpoint schedule, so interval sweeps replay the
  *same* trace;
* a full ResilienceReport is bit-identical across runs and across
  ``sweep(workers=N)``;
* an inactive fault model with checkpointing off reproduces the
  failure-free report exactly (goodput == 1.0).
"""
import dataclasses
import math

import pytest

from repro.api import (
    AutoscalerSpec, CheckpointSpec, Cluster, FaultModel, FleetSpec,
    ReplicaFaultSpec, ResilienceSpec, RouterSpec, ServingWorkload, SimSpec,
    SweepSpace, TrainWorkload, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.resilience import FailureGen, ResilienceSimulator
from repro.serving.sim import SLO, LengthDist, ServingSimulator

CFG = get_config("xlstm-125m")

# 32 chips over 4 hosts; host MTBF 1200s -> system MTBF 300s, a handful of
# failures across the ~800s ideal runtime (400 steps x ~1.9s)
FAULTS = FaultModel(host_mtbf_s=1200.0, seed=11)
RES = ResilienceSpec(total_steps=400, faults=FAULTS,
                     ckpt=CheckpointSpec(interval_steps=10),
                     chips_per_host=8, restart_delay_s=30.0, repair_s=600.0,
                     optimize_interval=False)


def _sim():
    return Simulator("tpu_v5e", engine="analytical")


def _spec(res):
    return SimSpec(CFG, cluster=Cluster("tpu_v5e"),
                   parallel=ParallelConfig(tp=4, dp=8),
                   workload=TrainWorkload(global_batch=256, seq_len=2048,
                                          resilience=res))


# ---------------- failure traces ----------------

def test_failure_trace_deterministic_and_seed_sensitive():
    def first(n, seed):
        gen = FailureGen(FaultModel(host_mtbf_s=3600.0, chip_mtbf_s=1e6,
                                    seed=seed),
                         n_chips=16, n_hosts=4, n_links=4)
        return [gen.pop() for _ in range(n)]

    a, b = first(50, seed=3), first(50, seed=3)
    assert a == b
    assert [e.t_s for e in a] == sorted(e.t_s for e in a)
    assert first(50, seed=4) != a


def test_weibull_gaps_keep_configured_mean():
    gen = FailureGen(FaultModel(host_mtbf_s=100.0, dist="weibull",
                                weibull_shape=0.7, seed=1),
                     n_chips=0, n_hosts=1, n_links=0)
    ts = [gen.pop().t_s for _ in range(4000)]
    gaps = [b - a for a, b in zip([0.0] + ts, ts)]
    mean = sum(gaps) / len(gaps)
    assert mean == pytest.approx(100.0, rel=0.1)


def test_inactive_fault_model_yields_no_failures():
    gen = FailureGen(FaultModel(), n_chips=8, n_hosts=1, n_links=1)
    assert gen.peek() == math.inf
    assert not FaultModel().active
    assert FAULTS.active


# ---------------- resilience simulation ----------------

def test_goodput_under_failures_and_accounting_identity():
    rep = ResilienceSimulator(_sim()).run(_spec(RES))
    assert rep.completed and rep.steps_done == 400
    assert 0.0 < rep.goodput < 1.0
    assert rep.n_restarts > 0 and rep.failure_trace
    assert rep.n_failures.get("host", 0) > 0
    # every wall-clock second is attributed to exactly one bucket
    parts = (rep.useful_s + rep.rework_s + rep.straggler_s
             + rep.checkpoint_s + rep.downtime_s)
    assert rep.wall_s == pytest.approx(parts, rel=1e-9)
    assert rep.wall_s > rep.ideal_s
    assert rep.n_checkpoints > 0 and rep.checkpoint_s > 0


def test_report_bit_deterministic_across_simulators():
    r1 = ResilienceSimulator(_sim()).run(_spec(RES))
    r2 = ResilienceSimulator(_sim()).run(_spec(RES))
    assert r1.summary() == r2.summary()
    assert r1.failure_trace == r2.failure_trace
    assert r1.goodput == r2.goodput and r1.wall_s == r2.wall_s


def test_trace_independent_of_checkpoint_schedule():
    dense = ResilienceSimulator(_sim()).run(
        _spec(dataclasses.replace(RES, ckpt=CheckpointSpec(interval_steps=5))))
    sparse = ResilienceSimulator(_sim()).run(
        _spec(dataclasses.replace(RES, ckpt=CheckpointSpec(interval_steps=100))))
    # failures are exogenous wall-clock events: both runs start from the
    # same seeded renewal processes (prefix relation — the longer run reads
    # further into the same stream)
    n = min(len(dense.failure_trace), len(sparse.failure_trace))
    assert n > 0
    assert dense.failure_trace[:n] == sparse.failure_trace[:n]


def test_mtbf_infinity_reproduces_failure_free_report():
    res = ResilienceSpec(total_steps=400, faults=FaultModel(),
                         ckpt=CheckpointSpec(interval_steps=0),
                         optimize_interval=False)
    sim = _sim()
    rep = ResilienceSimulator(sim).run(_spec(res))
    plain = sim.run(_spec(None))
    assert rep.goodput == 1.0
    assert rep.wall_s == pytest.approx(rep.ideal_s, rel=1e-12)
    assert rep.failure_trace == () and rep.n_restarts == 0
    assert rep.downtime_s == 0 and rep.rework_s == 0 and rep.checkpoint_s == 0
    # the embedded failure-free report is the plain report, bit-identical
    assert rep.step_report.step_time_us == plain.step_time_us
    assert rep.step_report.kind_us == plain.kind_us
    assert rep.tokens_per_s == pytest.approx(
        plain.tokens_per_step / (plain.step_time_us / 1e6), rel=1e-9)


def test_checkpoint_pricing_from_memory_report():
    sim = _sim()
    rep = ResilienceSimulator(sim).run(_spec(RES))
    mem = rep.step_report.memory
    assert rep.state_bytes_per_device == mem.weights + mem.opt_state
    # default write path is the cluster interconnect
    assert rep.write_gbps == pytest.approx(sim.hw.inter.bandwidth / 1e9)
    assert rep.save_s == pytest.approx(
        rep.state_bytes_per_device / (rep.write_gbps * 1e9))
    # explicit write bandwidth overrides, halving bandwidth doubles save_s
    slow = dataclasses.replace(
        RES, ckpt=CheckpointSpec(interval_steps=10,
                                 write_gbps=rep.write_gbps / 2))
    rep2 = ResilienceSimulator(sim).run(_spec(slow))
    assert rep2.save_s == pytest.approx(2 * rep.save_s)
    assert rep2.restore_s == pytest.approx(
        slow.ckpt.restore_factor * rep2.save_s)


def test_async_checkpoint_stalls_less_than_sync():
    sim = _sim()
    sync = ResilienceSimulator(sim).run(_spec(RES))
    async_rep = ResilienceSimulator(sim).run(_spec(dataclasses.replace(
        RES, ckpt=CheckpointSpec(interval_steps=10, mode="async"))))
    assert async_rep.checkpoint_s < sync.checkpoint_s
    parts = (async_rep.useful_s + async_rep.rework_s + async_rep.straggler_s
             + async_rep.checkpoint_s + async_rep.downtime_s)
    assert async_rep.wall_s == pytest.approx(parts, rel=1e-9)


def test_elastic_resharding_and_spares():
    sim = _sim()
    elastic = ResilienceSimulator(sim).run(_spec(RES))
    # hosts are down for repair_s=600s >> restart_delay: the elastic run
    # resharded onto fewer hosts and priced degraded steps
    assert elastic.n_reshards > 0 and elastic.degraded_steps > 0
    rigid = ResilienceSimulator(sim).run(
        _spec(dataclasses.replace(RES, elastic=False)))
    assert rigid.degraded_steps == 0
    assert rigid.downtime_s > elastic.downtime_s  # waits out every repair
    spared = ResilienceSimulator(sim).run(
        _spec(dataclasses.replace(RES, spares=4)))
    assert spared.n_spare_swaps > 0
    assert spared.degraded_steps == 0             # swaps keep the mesh full
    assert spared.goodput > elastic.goodput


def test_straggler_slowdown_deterministic():
    res = dataclasses.replace(RES, straggler_prob=0.05, straggler_mult=2.0)
    sim = _sim()
    a = ResilienceSimulator(sim).run(_spec(res))
    b = ResilienceSimulator(sim).run(_spec(res))
    assert a.straggler_s > 0
    assert a.summary() == b.summary()
    clean = ResilienceSimulator(sim).run(_spec(RES))
    assert clean.straggler_s == 0
    assert a.goodput < clean.goodput


def test_young_daly_and_simulated_optimum_reported():
    res = dataclasses.replace(RES, optimize_interval=True)
    rep = ResilienceSimulator(_sim()).run(_spec(res))
    yd = rep.young_daly_interval_steps
    assert yd is not None and yd >= 1
    # closed form against the report's own inputs
    base_step_s = rep.step_report.step_time_us / 1e6
    assert yd == max(1, round(
        math.sqrt(2.0 * rep.save_s * rep.mtbf_system_s) / base_step_s))
    assert rep.mtbf_system_s == pytest.approx(1200.0 / 4)
    opt = rep.simulated_optimal_interval_steps
    assert opt in rep.goodput_by_interval
    assert rep.goodput_by_interval[opt] == max(rep.goodput_by_interval.values())
    # the configured interval is always a candidate
    assert rep.interval_steps in rep.goodput_by_interval


def test_resilience_requires_train_mode():
    from repro.api import DecodeWorkload
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=4),
                   workload=DecodeWorkload(global_batch=8, seq_len=512))
    with pytest.raises(TypeError, match="TrainWorkload"):
        ResilienceSimulator(_sim()).run(spec)


# ---------------- spec surface ----------------

def test_resilience_spec_json_roundtrip_preserves_hash():
    spec = _spec(dataclasses.replace(
        RES, faults=FaultModel(host_mtbf_s=3600.0, chip_mtbf_s=1e7,
                               dist="weibull", weibull_shape=0.8, seed=9),
        spares=2, straggler_prob=0.01, straggler_mult=3.0))
    back = SimSpec.from_json(spec.to_json())
    assert back == spec
    assert back.json_hash() == spec.json_hash()
    assert back.workload.resilience.faults.dist == "weibull"


def test_fleet_faults_json_roundtrip_and_trivial():
    fleet = FleetSpec(replicas=2, router=RouterSpec("round_robin"),
                      faults=ReplicaFaultSpec(mtbf_s=120.0, restart_s=15.0,
                                              seed=3))
    spec = SimSpec(CFG, parallel=ParallelConfig(tp=4),
                   workload=ServingWorkload(n_requests=4, fleet=fleet))
    back = SimSpec.from_json(spec.to_json())
    assert back == spec and back.json_hash() == spec.json_hash()
    assert back.workload.fleet.faults.mtbf_s == 120.0
    # faults force the fleet path even for a single replica
    assert not FleetSpec(replicas=1,
                         faults=ReplicaFaultSpec(mtbf_s=1.0)).trivial
    assert FleetSpec(replicas=1).trivial


def test_fault_model_validation():
    with pytest.raises(ValueError):
        FaultModel(host_mtbf_s=-1.0)
    with pytest.raises(ValueError):
        FaultModel(dist="lognormal")
    with pytest.raises(ValueError):
        CheckpointSpec(mode="mirrored")
    with pytest.raises(ValueError):
        ResilienceSpec(total_steps=0)
    with pytest.raises(ValueError):
        ReplicaFaultSpec(mtbf_s=-2.0)


# ---------------- sweep objective ----------------

def _res_space():
    base = _spec(dataclasses.replace(
        RES, total_steps=200, ckpt=CheckpointSpec(interval_steps=50)))
    return SweepSpace(base, {
        "workload.resilience.ckpt.interval_steps": (10, 50, 200),
        "workload.resilience.spares": (0, 1)})


def test_sweep_goodput_under_failures_ranks_by_useful_tokens():
    res = sweep(_res_space(), objective="goodput_under_failures")
    ranked = res.ranked()
    assert len(ranked) == 6
    assert all(r.resilience is not None for r in ranked)
    rates = [r.resilience.tokens_per_s for r in ranked]
    assert rates == sorted(rates, reverse=True)
    # every candidate replayed the same seeded failure trace prefix
    n = min(len(r.resilience.failure_trace) for r in ranked)
    assert n > 0
    first = ranked[0].resilience.failure_trace[:n]
    assert all(r.resilience.failure_trace[:n] == first for r in ranked)


def test_sweep_goodput_under_failures_workers_bit_identical(tmp_path):
    def key(res):
        return [(r.spec.json_hash(), r.resilience.goodput,
                 r.resilience.wall_s, r.resilience.failure_trace)
                for r in res.ranked()]

    man = tmp_path / "manifest.json"
    serial = sweep(_res_space(), objective="goodput_under_failures",
                   manifest=str(man))
    parallel = sweep(_res_space(), objective="goodput_under_failures",
                     workers=2)
    assert key(serial) == key(parallel)
    import json
    doc = json.loads(man.read_text())
    assert doc["objective"] == "goodput_under_failures"
    rows = doc["candidates"]
    assert rows and all(row["goodput_under_failures"] is not None
                        for row in rows if not row["pruned"])


def test_sweep_goodput_under_failures_requires_resilience():
    base = _spec(None)
    with pytest.raises(TypeError, match="resilience"):
        sweep(SweepSpace(base, {"tp": (2, 4)}),
              objective="goodput_under_failures")


# ---------------- fleet replica faults ----------------

def _fleet_spec(faults, *, replicas=3, autoscaler=None, n=300):
    # rate high enough that replicas hold queued/in-flight work when a
    # failure lands (so displacement + rerouting actually happens)
    return SimSpec(CFG, cluster=Cluster("tpu_v5e"),
                   parallel=ParallelConfig(tp=4),
                   workload=ServingWorkload(
                       n_requests=n, arrival="poisson", rate_rps=150.0,
                       prompt=LengthDist("lognormal", median=128.0,
                                         sigma=0.5, cap=512),
                       output=LengthDist("lognormal", median=48.0,
                                         sigma=0.5, cap=192),
                       seed=5, slo=SLO(ttft_s=0.25, tpot_ms=80.0),
                       max_batch=16,
                       fleet=FleetSpec(replicas=replicas,
                                       router=RouterSpec("least_loaded"),
                                       autoscaler=autoscaler,
                                       faults=faults)))


def test_fleet_faults_conserve_requests_and_degrade_goodput():
    sim = _sim()
    clean = ServingSimulator(sim).run(_fleet_spec(None))
    assert clean.n_replica_failures == 0 and clean.n_rerouted == 0
    faulty = ServingSimulator(sim).run(_fleet_spec(
        ReplicaFaultSpec(mtbf_s=1.0, restart_s=0.5, seed=5)))
    # conservation: every request still finishes, displaced ones reroute
    assert faulty.n_requests == 300
    assert faulty.n_replica_failures > 0 and faulty.n_rerouted > 0
    assert faulty.slo_attainment < clean.slo_attainment
    assert faulty.summary()["n_replica_failures"] == faulty.n_replica_failures


def test_fleet_fault_trace_bit_deterministic():
    spec = _fleet_spec(ReplicaFaultSpec(mtbf_s=1.0, restart_s=0.5, seed=5))
    a = ServingSimulator(_sim()).run(spec)
    b = ServingSimulator(_sim()).run(spec)
    assert a.failure_trace == b.failure_trace
    assert a.goodput_rps == b.goodput_rps
    assert a.ttft_s == b.ttft_s and a.n_rerouted == b.n_rerouted


def test_fleet_faults_with_autoscaler_conserve_requests():
    asc = AutoscalerSpec(min_replicas=1, max_replicas=4, scale_up_queue=6.0,
                         scale_down_queue=1.0, interval_s=2.0, cooldown_s=4.0,
                         provision_s=5.0)
    rep = ServingSimulator(_sim()).run(_fleet_spec(
        ReplicaFaultSpec(mtbf_s=1.5, restart_s=0.5, seed=2),
        replicas=2, autoscaler=asc))
    assert rep.n_requests == 300
    assert rep.n_replica_failures > 0
    for row in rep.failure_trace:
        assert set(row) == {"t", "replica"}


def test_single_replica_with_faults_uses_fleet_path():
    rep = ServingSimulator(_sim()).run(_fleet_spec(
        ReplicaFaultSpec(mtbf_s=0.8, restart_s=0.3, seed=1), replicas=1,
        n=200))
    assert rep.n_requests == 200
    assert rep.n_replica_failures > 0   # FleetReport, failures injected

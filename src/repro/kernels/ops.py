"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode for
correctness validation; on a TPU runtime they compile to Mosaic.  The
wrappers auto-select, and layout-adapt from the model's (B, S, H, D) tensors
to the kernels' (B, H, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,Sq,D); k/v: (B,Hkv,Sk,D)."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention_bshd(q, k, v, *, causal: bool = True, window: int = 0):
    """Model layout: q (B,S,Hkv,G,D); k/v (B,T,Hkv,D) -> (B,S,Hkv,G,D)."""
    B, S, Hkv, G, D = q.shape
    qh = q.reshape(B, S, Hkv * G, D).transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    o = _fa.flash_attention(qh, kh, vh, causal=causal, window=window,
                            interpret=_interpret())
    return o.transpose(0, 2, 1, 3).reshape(B, S, Hkv, G, D)


@jax.jit
def decode_attention(q, k, v, kv_valid_len=None):
    """q: (B,H,D); k/v: (B,Hkv,T,D)."""
    return _dec.decode_attention(q, k, v, kv_valid_len=kv_valid_len,
                                 interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "offset"))
def rmsnorm(x, w, *, eps: float = 1e-6, offset: bool = False):
    return _rn.rmsnorm(x, w, eps=eps, offset=offset, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "offset"))
def rmsnorm_residual(x, residual, w, *, eps: float = 1e-6, offset: bool = False):
    return _rn.rmsnorm(x, w, eps=eps, offset=offset, residual=residual,
                       interpret=_interpret())

"""Flash-attention forward Pallas TPU kernel.

Online-softmax attention with explicit VMEM tiling: grid =
(batch, q_heads, num_q_blocks, num_kv_blocks); the innermost (kv) grid axis
is sequential on TPU, so fp32 scratch accumulators (m, l, acc) persist across
kv blocks and the output is written once at the last kv block.  GQA is
handled in the BlockSpec index map (kv head = q head // group), so K/V are
never materialised per-q-head.  Causal and sliding-window masks are applied
with iota comparisons against absolute positions.

Block sizes default to (128, 512) — q tile rows are a multiple of the 8-row
MXU subtile and kv tiles a multiple of the 128 lane dim; the (BQ, D) +
2*(BK, D) + (BQ, BK) working set stays well under the ~128 MB VMEM budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, scale: float, seq_q: int,
                  seq_k: int, block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    # ragged tail blocks are padded with unspecified values: zero padded V
    # rows so 0-weight x garbage cannot poison the accumulator
    t_valid = (ki * block_k +
               jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)) < seq_k
    v = jnp.where(t_valid, v, 0.0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (BQ, BK)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (q_pos < seq_q) & (k_pos < seq_k)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    # floor the max so fully-masked (padded-q) rows give exp(-inf)=0, not NaN
    m_new = jnp.maximum(m_new, -1e30)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D) with H % Hkv == 0.
    Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 128))
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, scale=scale,
        seq_q=Sq, seq_k=Sk, block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, D), jnp.float32),   # output accum
        ],
        interpret=interpret,
    )(q, k, v)

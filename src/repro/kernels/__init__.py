from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm

__all__ = ["ops", "ref", "decode_attention", "flash_attention", "rmsnorm"]

"""Fused RMSNorm (+ optional residual-add) Pallas TPU kernel.

One pass over HBM: read x (+residual), compute the fp32 mean-square on chip,
scale, write.  Grid tiles rows; the full feature dim stays resident in VMEM
(d_model <= ~8k fits easily: 128 rows x 8192 cols x 4 B = 4 MB)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, offset: bool,
                    n_rows: int, block_rows: int):
    ri = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                     # (BR, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    scale = (1.0 + w) if offset else w
    row = ri * block_rows + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    y = jnp.where(row < n_rows, y * scale, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            offset: bool = False, residual: jax.Array | None = None,
            block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = False) -> jax.Array:
    """x: (..., D); w: (D,).  Fused residual: normalises (x + residual)."""
    orig_shape = x.shape
    D = x.shape[-1]
    if residual is not None:
        x = x + residual
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    br = min(block_rows, max(R, 8))
    nr = pl.cdiv(R, br)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, offset=offset,
                               n_rows=R, block_rows=br)
    out = pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)

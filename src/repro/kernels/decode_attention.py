"""Flash-decode (split-KV) Pallas TPU kernel.

Single-token decode attends a (B, Hkv, T, D) cache.  The KV sequence splits
across the grid; every split writes a partial (m, l, o) triple; a cheap jnp
combine merges the partials (log-sum-exp reduction).  This is the
FlashDecoding split-K adaptation for TPU: the long T axis becomes grid
parallelism instead of one long sequential scan, keeping the MXU fed at
batch=1 decode shapes.  Ring caches pass ``kv_valid_len`` to mask dead slots.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

DEFAULT_BLOCK_T = 1024


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, *,
                   scale: float, block_t: int, seq_t: int, group: int):
    si = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)              # (G, D) — q heads of this kv head
    k = k_ref[0, 0].astype(jnp.float32)              # (BT, D)
    v = v_ref[0, 0].astype(jnp.float32)
    tv = (si * block_t +
          jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)) < seq_t
    v = jnp.where(tv, v, 0.0)                        # sanitize padded rows
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, BT)
    t_pos = si * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = valid_ref[0]
    s = jnp.where((t_pos < seq_t) & (t_pos < valid), s, NEG_INF)
    m = jnp.maximum(s.max(axis=1, keepdims=True), -1e30)   # (G, 1)
    p = jnp.exp(s - m)
    l = p.sum(axis=1, keepdims=True)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))  # (G, D)
    o_ref[0, 0, 0] = (o / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
    m_ref[0, 0, 0] = m[:, 0].astype(jnp.float32)
    l_ref[0, 0, 0] = l[:, 0].astype(jnp.float32)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     kv_valid_len: jax.Array | None = None,
                     scale: float | None = None,
                     block_t: int = DEFAULT_BLOCK_T,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D) one token per sequence; k/v: (B, Hkv, T, D).
    Returns (B, H, D)."""
    B, H, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_t = min(block_t, max(T, 128))
    ns = pl.cdiv(T, block_t)
    if kv_valid_len is None:
        kv_valid_len = jnp.full((B,), T, jnp.int32)
    qg = q.reshape(B, Hkv, group, D)

    kernel = functools.partial(_decode_kernel, scale=scale, block_t=block_t,
                               seq_t=T, group=group)
    o_part, m_part, l_part = pl.pallas_call(
        kernel,
        grid=(B, Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_t, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_t, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, group, D), lambda b, h, s: (b, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, group), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, group), lambda b, h, s: (b, h, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, ns, group, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, ns, group), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, ns, group), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, kv_valid_len)

    # combine splits: weighted by l * exp(m - m_max)
    m_max = m_part.max(axis=2, keepdims=True)                    # (B,Hkv,1,G)
    w = l_part * jnp.exp(m_part - m_max)                         # (B,Hkv,S,G)
    denom = jnp.maximum(w.sum(axis=2), 1e-30)                    # (B,Hkv,G)
    o = (o_part * w[..., None]).sum(axis=2) / denom[..., None]
    return o.reshape(B, H, D).astype(q.dtype)

"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B,H,Sq,D); k/v: (B,Hkv,Sk,D) -> (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qp = jnp.arange(Sq)[:, None]
    tp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= tp <= qp
    if window > 0:
        mask &= tp > qp - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vv.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, *, kv_valid_len=None, scale=None):
    """q: (B,H,D); k/v: (B,Hkv,T,D) -> (B,H,D)."""
    B, H, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = H // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if kv_valid_len is not None:
        s = jnp.where(jnp.arange(T)[None, None, :] < kv_valid_len[:, None, None],
                      s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, w, *, eps=1e-6, offset=False, residual=None):
    if residual is not None:
        x = x + residual
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if offset else w.astype(jnp.float32)
    return (y * scale).astype(x.dtype)

from repro.training.optimizer import adafactor, adamw, make_optimizer
from repro.training.train_step import (
    batch_pspecs, cross_entropy, make_loss_fn, make_train_step, opt_pspecs,
    param_pspecs, state_pspecs, to_named,
)

__all__ = [
    "adafactor", "adamw", "make_optimizer", "batch_pspecs", "cross_entropy",
    "make_loss_fn", "make_train_step", "opt_pspecs", "param_pspecs",
    "state_pspecs", "to_named",
]

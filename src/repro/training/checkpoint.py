"""Distributed checkpointing with resharding restore (fault tolerance).

Checkpoints are step-scoped directories of flat-keyed ``.npz`` shards plus a
JSON manifest (shapes, dtypes, step, data-pipeline state).  Restore accepts a
*different* mesh/sharding than the save used — arrays are re-placed under the
target NamedShardings (elastic rescale after node failure).  Saves are atomic
(tmp dir + rename) and optionally asynchronous; a retention policy garbage
collects old steps.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, jax.Array]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, state, *, extra: dict | None = None):
        """Snapshot to host then write (async-safe: device buffers are
        materialised before the writer thread starts)."""
        flat = _flatten(state)
        host = {}
        for k, v in flat.items():
            a = np.asarray(jax.device_get(v))
            if a.dtype == jnp.bfloat16:   # npz has no native bf16: widen
                a = a.astype(np.float32)
            host[k] = a
        manifest = {
            "step": int(step),
            "time": time.time(),
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "extra": extra or {},
        }
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, manifest), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, manifest)

    def _write(self, step: int, host: dict, manifest: dict):
        tmp = self.dir / f".tmp_step_{step:09d}"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **{k: v for k, v in host.items()})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target_state, *, step: int | None = None,
                shardings=None) -> tuple[object, dict]:
        """Restore into ``target_state``'s structure.  ``shardings`` (same
        structure, NamedSharding leaves) re-places arrays on a possibly
        different mesh — the elastic-rescale path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        recorded = manifest.get("arrays", {})
        flat_target = _flatten(target_state)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        leaves, treedef = jax.tree_util.tree_flatten(target_state)
        keys = list(_flatten(target_state).keys())
        out_leaves = []
        with np.load(d / "arrays.npz") as arrays:
            for key, tgt in zip(keys, flat_target.values()):
                a = arrays[key]
                want = tuple(tgt.shape)
                if tuple(a.shape) != want:
                    raise ValueError(
                        f"shape mismatch for {key}: {a.shape} vs {want}")
                stored = recorded.get(key, {}).get("dtype", str(a.dtype))
                if hasattr(tgt, "dtype"):
                    tdt = str(tgt.dtype)
                    # bf16 is widened to f32 on save (npz has no bf16), so a
                    # float32-on-disk / bfloat16-target pair is the round
                    # trip, not a mismatch
                    if stored != tdt and not (tdt == "bfloat16"
                                              and stored == "float32"):
                        raise ValueError(
                            f"dtype mismatch for {key}: checkpoint has "
                            f"{stored}, target wants {tdt}")
                arr = jnp.asarray(a)
                if hasattr(tgt, "dtype"):
                    arr = arr.astype(tgt.dtype)  # bf16 back from widened fp32
                s = flat_shard.get(key)
                out_leaves.append(
                    jax.device_put(arr, s) if s is not None else arr)
        return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest["extra"]

"""Sharded training step: loss, grad accumulation, optimizer, ZeRO specs.

``make_train_step`` returns the jittable step plus the sharding trees needed
by the launcher / dry-run: params, optimizer state (ZeRO-staged), batch.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import ShardingEnv, fsdp_spec, resolve_spec
from repro.models import Model, abstract_params, param_logical_axes
from repro.training.optimizer import Optimizer, maybe_compress

Pytree = Any


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mean next-token CE over labels >= 0.  logits f32 (B,S,V); labels (B,S)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    tok = jnp.maximum(mask.sum(), 1.0)
    return -(ll * mask).sum() / tok, tok


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        ce, tok = cross_entropy(logits, batch["labels"])
        return ce + aux, {"loss": ce + aux, "ce": ce, "aux_loss": aux, "tokens": tok}
    return loss_fn


# --------------------------------------------------------------------------
# Sharding specs (params / optimizer state / batch)
# --------------------------------------------------------------------------

def param_pspecs(cfg: ModelConfig, env: ShardingEnv, zero_stage: int) -> Pytree:
    axes = param_logical_axes(cfg)
    shapes = abstract_params(cfg)

    def f(ax, sds):
        skip = 1 if ax and ax[0] == "layer" else 0
        if zero_stage >= 3:
            return fsdp_spec(env, ax, sds.shape, skip_leading=skip)
        return resolve_spec(env, ax, sds.shape)

    return jax.tree.map(f, axes, shapes, is_leaf=lambda x: isinstance(x, tuple))


def _moment_spec(env, ax, shape, zero_stage):
    """Spec for an fp32 moment with same shape as its param: ZeRO>=1 shards
    optimizer state over the data axis."""
    skip = 1 if ax and ax[0] == "layer" else 0
    if zero_stage >= 1:
        return fsdp_spec(env, ax, shape, skip_leading=skip)
    return resolve_spec(env, ax, shape)


def opt_pspecs(cfg: ModelConfig, env: ShardingEnv, run: RunConfig) -> Pytree:
    axes = param_logical_axes(cfg)
    shapes = abstract_params(cfg)
    zs = run.zero_stage

    if run.optimizer == "adamw":
        mspec = jax.tree.map(lambda ax, s: _moment_spec(env, ax, s.shape, zs),
                             axes, shapes, is_leaf=lambda x: isinstance(x, tuple))
        return {"m": mspec, "v": mspec, "step": P()}

    # adafactor: flat list aligned with param leaves
    ax_leaves = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    sh_leaves = jax.tree.leaves(shapes)
    f_specs = []
    for ax, s in zip(ax_leaves, sh_leaves):
        shape = s.shape
        if len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1:
            f_specs.append({
                "vr": _moment_spec(env, ax[:-1], shape[:-1], zs),
                "vc": _moment_spec(env, (*ax[:-2], ax[-1]), (*shape[:-2], shape[-1]), zs),
            })
        else:
            f_specs.append({"v": _moment_spec(env, ax, shape, zs)})
    return {"f": f_specs, "step": P()}


def batch_pspecs(cfg: ModelConfig, env: ShardingEnv, global_batch: int,
                 *, kind: str = "train") -> dict:
    """Specs resolved against the *actual* batch size (long_500k has batch=1,
    which must degrade to replicated)."""
    bs = resolve_spec(env, ("batch",), (global_batch,))
    batch_axes = bs[0] if len(bs) else None
    specs = {"tokens": P(batch_axes, None)}
    if kind == "train":
        specs["labels"] = P(batch_axes, None)
    if cfg.rope_style == "mrope":
        specs["positions"] = P(batch_axes, None, None)
    if kind != "decode":   # modality stubs feed prefill/train only
        if cfg.encoder_layers > 0:
            specs["frame_embeds"] = P(batch_axes, None, None)
        if cfg.frontend == "vision_patches":
            specs["patch_embeds"] = P(batch_axes, None, None)
    return specs


# --------------------------------------------------------------------------
# Train step
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, run: RunConfig, optimizer: Optimizer):
    model = Model(cfg, remat_policy=run.remat_policy)
    loss_fn = make_loss_fn(model)
    k = run.microbatches

    def train_step(state, batch):
        params = state["params"]
        if k <= 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            micro = jax.tree.map(lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

            def acc_step(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32) / k, g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b / k, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": 0.0, "ce": 0.0, "aux_loss": 0.0, "tokens": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = jax.lax.scan(acc_step, (g0, m0), micro)
        grads = maybe_compress(grads, run.grad_compression)
        new_params, new_opt = optimizer.update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        metrics = dict(metrics)
        metrics["grad_norm"] = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
        return new_state, metrics

    return train_step


def state_pspecs(cfg: ModelConfig, env: ShardingEnv, run: RunConfig) -> dict:
    return {
        "params": param_pspecs(cfg, env, run.zero_stage),
        "opt": opt_pspecs(cfg, env, run),
        "step": P(),
    }


def to_named(env: ShardingEnv, tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(env.mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))

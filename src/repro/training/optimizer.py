"""Optimizers in pure JAX (optax is not available offline).

* ``adamw``      — AdamW with fp32 first/second moments (16 B/param states).
* ``adafactor``  — factored second moments (sub-byte/param states); the shipped
                   optimizer for deepseek-v3-671b, whose Adam states cannot fit
                   a v5e-256 pod (see EXPERIMENTS.md memory ledger).

Both support int8 gradient "compression" (quantise-dequantise transform that
models the numerics of compressed DP all-reduce; the simulator prices the
bytes reduction, see core/passes/data_parallel.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params) -> (new_params, new_state)


# --------------------------------------------------------------------------
# LR schedules
# --------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int = 100, total: int = 10_000,
                    final_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


# --------------------------------------------------------------------------
# Gradient compression (int8 quant-dequant; models compressed DP all-reduce)
# --------------------------------------------------------------------------

def int8_compress_decompress(g: jax.Array) -> jax.Array:
    if g.dtype == jnp.int32 or g.ndim == 0:
        return g
    absmax = jnp.max(jnp.abs(g.astype(jnp.float32)), keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def maybe_compress(grads, mode: str):
    if mode == "int8":
        return jax.tree.map(int8_compress_decompress, grads)
    return grads


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            u = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = treedef.unflatten([l[0] for l in leaves])
        new_m = treedef.unflatten([l[1] for l in leaves])
        new_v = treedef.unflatten([l[2] for l in leaves])
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Adafactor (factored second moment, update clipping)
# --------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(lr_fn, eps1: float = 1e-30, eps2: float = 1e-3,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    """Factored state is kept as a flat list aligned with tree leaves (avoids
    dict-in-dict structure ambiguity with parameter trees)."""

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": [st(p) for p in jax.tree.leaves(params)],
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        beta2 = 1.0 - step.astype(jnp.float32) ** -0.8

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps1
            if _factored(g.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(-2)
                denom = (vr / jnp.maximum(vr.mean(-1, keepdims=True), eps1))[..., None] * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps1))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps1))
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            pf = p.astype(jnp.float32)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(pf))), eps2)
            new_p = pf - lr * scale * u - lr * weight_decay * pf
            return new_p.astype(p.dtype), new_s

        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = jax.tree.leaves(params)
        outs = [upd(g, s, p) for g, s, p in zip(g_leaves, state["f"], p_leaves)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        return new_p, {"f": [o[1] for o in outs], "step": step}

    return Optimizer(init, update)


def make_optimizer(name: str, peak_lr: float = 3e-4, **kw) -> Optimizer:
    lr_fn = cosine_schedule(peak_lr)
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(name)

"""Deterministic synthetic data pipeline with background prefetch.

State (the step counter) is checkpointable, so restart resumes the exact
token stream.  Per-host sharding follows (host_id, num_hosts); batches carry
``tokens`` and next-token ``labels`` plus modality stubs per config.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticTokenPipeline:
    def __init__(self, cfg: ModelConfig, *, global_batch: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1,
                 prefetch: int = 2, start_step: int = 0):
        assert global_batch % num_hosts == 0
        self.cfg = cfg
        self.batch = global_batch // num_hosts
        self.seq = seq_len
        self.seed = seed
        self.host = host_id
        self.num_hosts = num_hosts
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, self.host, step))
        toks = rng.integers(0, self.cfg.vocab_size,
                            (self.batch, self.seq + 1), dtype=np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if self.cfg.rope_style == "mrope":
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32)[None, :, None],
                                  (self.batch, self.seq, 3))
            batch["positions"] = np.ascontiguousarray(pos)
        if self.cfg.encoder_layers > 0:
            batch["frame_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.encoder_seq, self.cfg.d_model),
                dtype=np.float32) * 0.02
        if self.cfg.frontend == "vision_patches":
            batch["patch_embeds"] = rng.standard_normal(
                (self.batch, 256, self.cfg.d_model), dtype=np.float32) * 0.02
        return batch

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put(( step, self._make(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def __iter__(self):
        return self

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def close(self):
        self._stop.set()

"""Fault tolerance & straggler mitigation for long-running training.

* ``StepMonitor`` — per-step wall-time statistics with z-score straggler
  detection (on multi-host fleets each host reports; here single-host).
* ``run_with_restarts`` — supervision loop: on failure, restore the latest
  checkpoint (optionally onto a smaller/larger mesh = elastic rescale via
  CheckpointManager's resharding restore) and continue.
* ``ElasticPlan`` — recompute (dp, batch) after losing nodes while keeping
  tp/pp intact; the dry-run proves target meshes compile ahead of time.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable

from repro.training.checkpoint import CheckpointManager


@dataclass
class StepMonitor:
    window: int = 50
    z_threshold: float = 3.0
    times: list[float] = field(default_factory=list)
    stragglers: list[tuple[int, float]] = field(default_factory=list)
    _t0: float | None = None
    step: int = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        if self._t0 is None:
            raise RuntimeError("StepMonitor.stop() before start(): call "
                               "start() at the top of each step")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.times.append(dt)
        self.times = self.times[-self.window:]
        self.step += 1
        if len(self.times) >= 10:
            mean = sum(self.times) / len(self.times)
            var = sum((t - mean) ** 2 for t in self.times) / len(self.times)
            std = math.sqrt(var)
            if std > 0 and (dt - mean) / std > self.z_threshold:
                self.stragglers.append((self.step, dt))
        return dt

    @property
    def mean_step_s(self) -> float:
        return sum(self.times) / max(len(self.times), 1)


@dataclass
class ElasticPlan:
    """Rescale DP after node loss, preserving tp/pp shards."""
    tp: int
    pp: int
    dp: int
    global_batch: int

    def rescale(self, surviving_chips: int) -> "ElasticPlan":
        shard = self.tp * self.pp
        new_dp = max(surviving_chips // shard, 1)
        # keep per-replica batch constant; shrink global batch accordingly
        per_dp = self.global_batch // self.dp
        return ElasticPlan(self.tp, self.pp, new_dp, per_dp * new_dp)


def run_with_restarts(train_loop: Callable[[int], int], ckpt: CheckpointManager,
                      *, max_restarts: int = 3,
                      on_restart: Callable[[int, Exception], None] | None = None) -> int:
    """``train_loop(start_step) -> final_step``; restarts from the latest
    checkpoint on failure.

    ``max_restarts`` bounds *consecutive* unproductive restarts: whenever a
    failed attempt checkpointed past the previous high-water step, the
    budget resets — a long run peppered with transient faults keeps going,
    while a crash loop that never advances still raises after
    ``max_restarts`` tries.
    """
    restarts = 0

    def latest() -> int:
        step = ckpt.latest_step()
        return -1 if step is None else step

    best = latest()
    while True:
        start = latest() + 1
        try:
            return train_loop(start)
        except Exception as e:  # noqa: BLE001 — supervision boundary
            now = latest()
            if now > best:      # durable progress since the last failure
                best = now
                restarts = 0
            restarts += 1
            if on_restart:
                on_restart(restarts, e)
            if restarts > max_restarts:
                raise

from repro.distributed.sharding import (
    ShardingEnv, activate, active_env, axis_size, logical_constraint,
    logical_sharding, resolve_spec,
)

__all__ = [
    "ShardingEnv", "activate", "active_env", "axis_size", "logical_constraint",
    "logical_sharding", "resolve_spec",
]

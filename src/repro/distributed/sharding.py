"""Logical-axis sharding with divisibility-aware resolution.

MaxText-style: model code annotates tensors with *logical* axis names; a rule
table maps logical names to mesh axes.  The resolver drops mesh axes that do
not divide the concrete dimension (e.g. qwen2.5's 40 heads on a 16-wide model
axis), which is what makes one model implementation lower correctly across
every (arch x shape x mesh) cell.

Usage:
    env = ShardingEnv(mesh)            # rules default to DEFAULT_RULES
    with activate(env):
        lowered = jax.jit(step).lower(...)

Inside model code:
    x = logical_constraint(x, ("batch", "seq", "embed"))
is a no-op when no env is active (single-device tests).
"""
from __future__ import annotations

import contextlib
import math
import threading
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes, in order; multi-axis entries shard jointly.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),                 # unsharded by default
    "seq_sp": ("model",),      # Megatron-SP residual stream (norms, embeddings, logits)
    "seq_cp": ("model",),      # context-parallel attention (Ulysses-style)
    "kv_seq": ("model",),      # decode-time KV sequence sharding (flash-decode)
    "embed": (),
    "embed_tp": ("model",),    # row-parallel input dim
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "q_per_kv": (),
    "head_dim": (),
    "ffn": ("model",),
    "expert": ("model",),
    "expert_group": ("pod", "data"),   # MoE dispatch groups track the DP axes
    "expert_ffn": (),
    "lru_width": ("model",),
    "conv": (),
    "layer": (),               # scan-stacked leading dim: never sharded
    "fsdp": ("data",),         # ZeRO-3 parameter sharding axis
    "none": (),
}


@dataclass(frozen=True)
class ShardingEnv:
    mesh: Mesh
    rules: dict[str, tuple[str, ...]] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def with_rules(self, **overrides: tuple[str, ...]) -> "ShardingEnv":
        r = dict(self.rules)
        r.update(overrides)
        return replace(self, rules=r)


_tls = threading.local()


def active_env() -> ShardingEnv | None:
    return getattr(_tls, "env", None)


@contextlib.contextmanager
def activate(env: ShardingEnv):
    prev = active_env()
    _tls.env = env
    try:
        yield env
    finally:
        _tls.env = prev


def axis_size(name: str, env: ShardingEnv | None = None) -> int:
    """Size of a mesh axis (1 if absent / no env)."""
    env = env or active_env()
    if env is None or name not in env.mesh.axis_names:
        return 1
    return env.mesh.shape[name]


def _mesh_axis_prod(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def resolve_spec(env: ShardingEnv, logical_axes: tuple[str | None, ...],
                 shape: tuple[int, ...]) -> P:
    """Map logical axes -> PartitionSpec, dropping non-dividing / reused axes.

    Multi-axis rules (e.g. batch -> (pod, data)) degrade gracefully: axes are
    dropped from the front until the product divides the dimension.
    """
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    mesh = env.mesh
    used: set[str] = set()
    entries = []
    for logical, dim in zip(logical_axes, shape):
        if logical is None:
            entries.append(None)
            continue
        cands = tuple(a for a in env.rules.get(logical, ())
                      if a in mesh.axis_names and a not in used)
        while cands and dim % _mesh_axis_prod(mesh, cands) != 0:
            cands = cands[1:]
        if not cands:
            entries.append(None)
        else:
            used.update(cands)
            entries.append(cands if len(cands) > 1 else cands[0])
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def logical_sharding(logical_axes: tuple[str | None, ...], shape: tuple[int, ...],
                     env: ShardingEnv | None = None) -> NamedSharding | None:
    env = env or active_env()
    if env is None:
        return None
    return NamedSharding(env.mesh, resolve_spec(env, logical_axes, shape))


def logical_constraint(x: jax.Array, logical_axes: tuple[str | None, ...]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without an active env."""
    env = active_env()
    if env is None:
        return x
    s = logical_sharding(logical_axes, x.shape, env)
    return jax.lax.with_sharding_constraint(x, s)


def fsdp_spec(env: ShardingEnv, logical_axes: tuple[str | None, ...],
              shape: tuple[int, ...], *, skip_leading: int = 0) -> P:
    """Add the fsdp ('data') axis to the first eligible dim of a parameter
    spec (ZeRO-3 / FSDP parameter sharding).  ``skip_leading`` protects the
    scan-stacked layer dim."""
    base = resolve_spec(env, logical_axes, shape)
    entries = list(base) + [None] * (len(shape) - len(base))
    used = {a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    fsdp_axes = tuple(a for a in env.rules.get("fsdp", ()) if a in env.mesh.axis_names)
    if not fsdp_axes or any(a in used for a in fsdp_axes):
        return base
    size = _mesh_axis_prod(env.mesh, fsdp_axes)
    for i in range(skip_leading, len(shape)):
        if entries[i] is None and shape[i] % size == 0:
            entries[i] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
            break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)

from repro.serving.engine import Request, ServingEngine
from repro.serving.sim import (
    SLO, ServingReport, ServingScenario, ServingSimulator, StepOracle,
    VirtualClock, Workload, synthesize,
)
from repro.serving.sp_planner import (
    BatchPlan, SPChoice, attention_latency_us, plan_batch, plan_request,
)

__all__ = ["Request", "ServingEngine", "BatchPlan", "SPChoice",
           "attention_latency_us", "plan_batch", "plan_request",
           "SLO", "ServingReport", "ServingScenario", "ServingSimulator",
           "StepOracle", "VirtualClock", "Workload", "synthesize"]

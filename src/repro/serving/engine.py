"""Serving engine: continuous batching over slot-based KV caches.

``ServingEngine`` keeps B cache slots; requests are admitted into free slots
(prefill populates the slot via the model's prefill path at batch=1, then the
KV rows are scattered into the slot), and every engine step decodes one token
for all active slots.  Per-slot positions make mixed-depth batches exact.
SLO accounting (TTFT/TPOT per request) feeds the explorer's Pareto search.

All timestamps flow through one injected ``clock`` (default: wall clock).
Trace replay passes a :class:`~repro.serving.sim.workload.VirtualClock`
driven in simulated seconds, so caller-supplied ``arrival_s`` values —
including ``0.0`` — are honored exactly and TTFT/finish times stay on the
trace's timebase instead of mixing in ``perf_counter`` readings.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model, zero_cache


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    arrival_s: float | None = None   # None: stamped by the engine's clock
    # outputs
    tokens: list[int] = field(default_factory=list)
    ttft_s: float | None = None
    finished_s: float | None = None
    slot: int | None = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 cache_len: int = 512, greedy: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.cfg = cfg
        self.clock = clock
        self.model = Model(cfg)
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.cache = zero_cache(cfg, slots, cache_len)
        self.cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self.active: dict[int, Request] = {}     # slot -> request
        self.queue: list[Request] = []
        self.greedy = greedy
        self._decode = jax.jit(self.model.decode_step)
        self._last_tok = jnp.zeros((slots, 1), jnp.int32)
        self.finished: list[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        if req.arrival_s is None:    # explicit 0.0 (trace replay) is kept
            req.arrival_s = self.clock()
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            req.slot = slot
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, pc = self.model.prefill(self.params, {"tokens": prompt},
                                            cache_len=self.cache_len)
            tok = int(jnp.argmax(logits[0, -1]))
            req.tokens.append(tok)
            req.ttft_s = self.clock() - req.arrival_s
            # scatter the single-request (batch=1) cache into this slot
            # (cycle leaves are layer-stacked: batch is dim 1; tail: dim 0)
            self.cache["blocks"]["cycle"] = jax.tree.map(
                lambda c, o: c.at[:, slot].set(o[:, 0]) if c.ndim >= 2 else c,
                self.cache["blocks"]["cycle"], pc["blocks"]["cycle"])
            self.cache["blocks"]["tail"] = jax.tree.map(
                lambda c, o: c.at[slot].set(o[0]) if c.ndim >= 1 else c,
                self.cache["blocks"]["tail"], pc["blocks"]["tail"])
            self.cache["pos"] = self.cache["pos"].at[slot].set(len(req.prompt))
            self._last_tok = self._last_tok.at[slot, 0].set(tok)
            self.active[slot] = req

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one decode step for all active slots.  Returns #active."""
        self._admit()
        if not self.active:
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          {"tokens": self._last_tok})
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        self._last_tok = next_tok[:, None]
        done = []
        for slot, req in self.active.items():
            req.tokens.append(int(next_tok[slot]))
            if len(req.tokens) >= req.max_new_tokens:
                req.finished_s = self.clock()
                done.append(slot)
        for slot in done:
            self.finished.append(self.active.pop(slot))
        return len(self.active) + len(done)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

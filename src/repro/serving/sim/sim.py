"""Discrete-event serving simulator: continuous batching over predicted steps.

This is the request-level layer the paper's deployment case study needs:
instead of executing a model, every engine iteration is *priced* by the core
:class:`~repro.core.simulator.Simulator` (through the memoized
:class:`~repro.serving.sim.oracle.StepOracle`) and a discrete-event loop
advances simulated time, so a 500-request trace replays in seconds of wall
time while producing the TTFT/TPOT/goodput distributions a real deployment
would measure.

Event loop invariants:

* A pool (one engine instance) runs at most one iteration at a time; when a
  ``STEP_DONE`` fires, token accounting happens first, then every idle pool
  gets a chance to plan its next step.
* Requests finish exactly once: the first token is emitted by the step that
  completes the prompt (prefill counts the first output token, the standard
  TTFT convention), the remaining ``output_len - 1`` tokens by decode steps.
* Disaggregated prefill/decode expands into two pools; completing a prefill
  on a ``role="prefill"`` pool schedules a delayed ``ARRIVAL`` (KV transfer)
  at the decode pool.
* All times come from the seeded workload and the deterministic oracle, and
  event ties break on insertion order — identical runs are bit-identical.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core.passes.base import ParallelConfig
from repro.core.simulator import Simulator
from repro.obs.recorder import CNAMES, NULL_RECORDER
from repro.serving.sim.events import (
    ARRIVAL, AUTOSCALE, FAILURE, RECOVER, STEP_DONE, EventQueue,
)
from repro.serving.sim.oracle import StepOracle
from repro.serving.sim.policies import (
    ContinuousBatching, DecodeOnly, DisaggregatedPD, PrefillOnly, StepPlan,
    make_policy,
)
from repro.serving.sim.report import SLO, FleetReport, ServingReport
from repro.serving.sim.router import Autoscaler, LeastLoadedRouter, make_router
from repro.serving.sim.workload import SimRequest, Workload, synthesize


@dataclass
class Pool:
    """One engine instance: a queue, a running batch, busy-time accounting."""
    name: str
    policy: object
    role: str = "both"                  # both | prefill | decode
    queue: deque = field(default_factory=deque)
    running: list = field(default_factory=list)
    prefilling: list = field(default_factory=list)
    pending_arrivals: int = 0
    busy: bool = False
    busy_s: float = 0.0
    phase_s: dict = field(default_factory=dict)       # step kind -> seconds
    steps_by_kind: dict = field(default_factory=dict)  # step kind -> count
    n_steps: int = 0


def make_pools(policy) -> tuple[list[Pool], float]:
    """Policy -> the pool(s) one engine replica runs: a DisaggregatedPD
    descriptor expands into a prefill/decode pair (plus its KV-transfer
    latency), anything else is a single ``engine`` pool.  Shared by the
    single-replica and fleet simulators so per-replica pool names — and
    therefore utilization keys — match between the two."""
    if isinstance(policy, DisaggregatedPD):
        return [Pool("prefill", PrefillOnly(policy.prefill_batch),
                     role="prefill"),
                Pool("decode", DecodeOnly(policy.decode_batch),
                     role="decode")], policy.transfer_s
    return [Pool("engine", policy)], 0.0


def record_request_lanes(rec, reqs, *, pid: str = "requests",
                         metrics=None) -> None:
    """Emit per-request trace lanes: queued → prefill → decode spans, one
    ``tid`` per request.  Lanes beyond ``rec.max_request_lanes`` (a 100k
    request trace would mean 100k Perfetto tracks) are dropped *loudly*: a
    metadata instant carries the dropped count and a
    ``trace.dropped_request_lanes`` counter is bumped when ``metrics`` is
    given."""
    if not rec.enabled:
        return
    cap = rec.max_request_lanes
    shown = sorted(reqs, key=lambda r: r.rid)
    dropped = max(len(shown) - cap, 0)
    for r in shown[:cap]:
        tid = f"req{r.rid}"
        q0 = r.enqueue_s if r.enqueue_s is not None else r.arrival_s
        if r.start_s is not None:
            if r.start_s > q0:
                rec.span(pid, tid, "queued", q0, r.start_s - q0,
                         cat="request", cname="grey")
            if r.first_token_s is not None:
                rec.span(pid, tid, "prefill", r.start_s,
                         r.first_token_s - r.start_s, cat="request",
                         args={"prompt_len": r.prompt_len})
                if r.finished_s is not None and r.output_len > 1:
                    rec.span(pid, tid, "decode", r.first_token_s,
                             r.finished_s - r.first_token_s, cat="request",
                             args={"output_len": r.output_len})
    if dropped > 0:
        last = max((r.finished_s or r.arrival_s for r in shown),
                   default=0.0)
        rec.instant(pid, "meta", "charon:request_lanes_truncated", last,
                    args={"dropped_requests": dropped,
                          "max_request_lanes": cap,
                          "total_requests": len(shown)})
        if metrics is not None:
            metrics.inc("trace.dropped_request_lanes", dropped)


def price_step_s(oracle: StepOracle, plan: StepPlan) -> float:
    """Price one planned engine iteration through the shared step oracle —
    the single pricing convention both simulators use."""
    if plan.kind == "decode":
        ctx = max(r.prompt_len + r.decoded for r in plan.decode)
        return oracle.decode_step_s(len(plan.decode), ctx)
    if plan.kind == "prefill":
        seq = max(chunk for _, chunk in plan.prefill)
        return oracle.prefill_s(len(plan.prefill), seq)
    ctx = max((r.prompt_len + r.decoded for r in plan.decode), default=0)
    chunk = sum(c for _, c in plan.prefill)
    return oracle.mixed_step_s(len(plan.decode), ctx, chunk)


class ServingSimulator:
    """Replay a :class:`Workload` through a batching policy, pricing every
    engine iteration with the step oracle."""

    def __init__(self, sim: Simulator, cfg: ModelConfig | None = None, *,
                 par: ParallelConfig | None = None, policy=None,
                 oracle: StepOracle | None = None, ctx_floor: int = 256):
        self.sim = sim
        self.cfg = cfg
        self.par = par or ParallelConfig()
        self.policy = policy or ContinuousBatching()
        # spec-driven use (``ServingSimulator(sim).run(spec)``) defers the
        # oracle until the spec supplies model/parallelism
        self.oracle = oracle if cfg is None else (
            oracle or StepOracle(sim, cfg, self.par, ctx_floor=ctx_floor))

    # ------------------------------------------------------------------
    def _pools(self) -> tuple[list[Pool], float]:
        return make_pools(self.policy)

    def _price_s(self, plan: StepPlan) -> float:
        return price_step_s(self.oracle, plan)

    def _finish_step(self, pool: Pool, plan: StepPlan, now: float,
                     evq: EventQueue, pools: list[Pool], transfer_s: float,
                     finished: list[SimRequest]) -> None:
        pool.busy = False
        for r, chunk in plan.prefill:
            r.prefilled += chunk
            if r.prefilled >= r.prompt_len:
                pool.prefilling.remove(r)
                r.first_token_s = now       # prefill emits the first token
                r.decoded = 1
                if r.decoded >= r.output_len:
                    r.finished_s = now
                    finished.append(r)
                elif pool.role == "prefill":
                    evq.push(now + transfer_s, ARRIVAL, (pools[1], r))
                else:
                    pool.running.append(r)
        for r in plan.decode:
            r.decoded += 1
            if r.decoded >= r.output_len:
                r.finished_s = now
                pool.running.remove(r)
                finished.append(r)

    # ------------------------------------------------------------------
    def run(self, workload, *, slo: SLO | None = None,
            max_steps: int = 2_000_000, recorder=None,
            metrics=None) -> ServingReport:
        """Replay a request trace and aggregate a :class:`ServingReport`.

        Accepts either a legacy :class:`Workload` (with the policy/model
        fixed at construction) or a :class:`~repro.api.spec.SimSpec` whose
        workload is a :class:`~repro.api.spec.ServingWorkload` — the spec
        then supplies the model, parallelism, policy, trace and SLO.  A
        spec whose workload carries a non-trivial
        :class:`~repro.api.spec.FleetSpec` is delegated to
        :class:`FleetSimulator` and returns a :class:`FleetReport`.

        ``recorder`` (a :class:`~repro.obs.TraceRecorder`) collects engine
        step spans and per-request lanes; ``metrics`` (a
        :class:`~repro.obs.MetricsRegistry`) accumulates step/request
        counters and the oracle hit/miss delta.  Both default to off and
        cost nothing when off — the report is bit-identical either way.
        """
        from repro.api.spec import SimSpec
        if isinstance(workload, SimSpec):
            spec = workload
            w = spec.workload
            if getattr(w, "mode", None) != "serving":
                raise TypeError(
                    "ServingSimulator.run(spec) needs a ServingWorkload; "
                    f"got {type(w).__name__} (use Simulator.run for "
                    "steady-state workloads)")
            if spec.cluster.hardware != self.sim.hw.name:
                raise ValueError(
                    f"simulator built for {self.sim.hw.name!r} cannot run a "
                    f"spec for cluster hardware {spec.cluster.hardware!r}")
            if not w.fleet.trivial:
                return FleetSimulator(self.sim).run(spec, slo=slo,
                                                    max_steps=max_steps,
                                                    recorder=recorder,
                                                    metrics=metrics)
            inner = ServingSimulator(self.sim, spec.model, par=spec.parallel,
                                     policy=w.make_policy(),
                                     ctx_floor=w.ctx_floor)
            return inner.run(w.build(), slo=slo if slo is not None else w.slo,
                             max_steps=max_steps, recorder=recorder,
                             metrics=metrics)
        if self.oracle is None:
            raise TypeError("ServingSimulator was built without a model "
                            "config; pass a SimSpec to run()")
        rec = recorder if recorder is not None else NULL_RECORDER
        reqs = sorted((r.reset_copy() for r in workload.requests),
                      key=lambda r: r.arrival_s)
        pools, transfer_s = self._pools()
        evq = EventQueue()
        for r in reqs:
            evq.push(r.arrival_s, ARRIVAL, (pools[0], r))
        # only the entry pool knows its arrival count up front; downstream
        # pools (disaggregated decode) receive an unknowable subset via
        # migration, so a wait-for-arrivals policy must not wait on them
        pools[0].pending_arrivals = len(reqs)
        finished: list[SimRequest] = []
        stats0 = self.oracle.stats()
        steps = 0
        while evq:
            ev = evq.pop()
            now = ev.time
            if ev.kind == ARRIVAL:
                pool, r = ev.payload
                pool.queue.append(r)
                pool.pending_arrivals = max(pool.pending_arrivals - 1, 0)
                if r.enqueue_s is None:
                    r.enqueue_s = now
            else:                                   # STEP_DONE
                pool, plan = ev.payload
                self._finish_step(pool, plan, now, evq, pools, transfer_s,
                                  finished)
            for pool in pools:
                if pool.busy:
                    continue
                plan = pool.policy.plan(pool, now)
                if plan is None:
                    continue
                steps += 1
                if steps > max_steps:
                    raise RuntimeError(
                        f"serving sim exceeded {max_steps} steps "
                        f"({len(finished)}/{len(reqs)} finished)")
                dt = self._price_s(plan)
                for r, _ in plan.prefill:
                    if r.start_s is None:
                        r.start_s = now
                for r in plan.decode:
                    if r.start_s is None:
                        r.start_s = now
                pool.busy = True
                pool.n_steps += 1
                pool.busy_s += dt
                pool.phase_s[plan.kind] = pool.phase_s.get(plan.kind, 0.0) + dt
                pool.steps_by_kind[plan.kind] = \
                    pool.steps_by_kind.get(plan.kind, 0) + 1
                if rec.enabled:
                    rec.span("serving", pool.name, plan.kind, now, dt,
                             cat="step",
                             args={"n_prefill": len(plan.prefill),
                                   "n_decode": len(plan.decode)})
                evq.push(now + dt, STEP_DONE, (pool, plan))
        if len(finished) != len(reqs):
            raise RuntimeError(
                f"serving sim deadlocked: {len(reqs) - len(finished)} of "
                f"{len(reqs)} requests unfinished under {self.policy.name}")
        stats1 = self.oracle.stats()
        delta = {k: stats1.get(k, 0) - stats0.get(k, 0)
                 for k in ("hits", "misses")}
        delta["hit_rate"] = round(
            delta["hits"] / max(delta["hits"] + delta["misses"], 1), 4)
        record_request_lanes(rec, finished, pid="serving/requests",
                             metrics=metrics)
        rep = ServingReport.build(finished, pools, slo, delta)
        if metrics is not None:
            metrics.inc("serving.requests", len(finished))
            metrics.inc("serving.steps", steps)
            for k, n in rep.steps_by_kind.items():
                metrics.inc(f"serving.steps.{k}", n)
            metrics.update_nested(delta, prefix="serving.oracle")
        return rep


# ----------------------------------------------------------------------
@dataclass
class ReplicaPool:
    """One replica of a fleet: an engine instance (a single pool, or a
    prefill/decode pool pair when the per-replica policy is
    :class:`DisaggregatedPD`), plus routability state.

    ``active`` gates routing only — a scaled-down replica keeps draining
    the requests it already holds, so request conservation never depends on
    autoscaler behaviour.  ``ready_at`` models provisioning: a freshly
    scaled-up replica takes traffic once the clock passes it.

    ``failed_until`` is the fault-injection analogue: a failed replica is
    unroutable (and never plans steps) until the clock passes it.  Each
    failure bumps ``epoch`` so the in-flight step's ``STEP_DONE`` — priced
    before the failure — is recognized as stale and dropped.
    """
    index: int
    pools: list
    transfer_s: float = 0.0
    role: str = "serve"                  # serve | prefill (fleet-level disagg)
    active: bool = True
    ready_at: float = 0.0
    failed_until: float = 0.0
    epoch: int = 0

    def up(self, now: float) -> bool:
        return now >= self.failed_until

    @property
    def entry(self) -> Pool:
        return self.pools[0]

    def load(self) -> int:
        """In-flight requests: queued + prefilling + decoding — the routing
        and autoscaling depth metric."""
        return sum(len(p.queue) + len(p.prefilling) + len(p.running)
                   for p in self.pools)


class FleetSimulator:
    """Fleet-scale serving: N replica engines behind a router, sharing one
    :class:`StepOracle`, on one deterministic event heap.

    Each replica is an independent :class:`ReplicaPool` (identical model /
    parallelism / policy — the fleet is homogeneous), so pricing goes
    through a single oracle and the marginal cost of a replica is queue
    bookkeeping, not JAX traces.  The router spreads fresh arrivals over
    routable replicas; with ``FleetSpec.prefill_replicas > 0`` the fleet is
    disaggregated — arrivals prefill on dedicated :class:`PrefillOnly`
    replicas, then migrate (paying ``transfer_s``) to the least-loaded
    decode replica.  An optional :class:`~repro.api.spec.AutoscalerSpec`
    grows/shrinks the serving set on ``AUTOSCALE`` ticks.  An optional
    :class:`~repro.api.spec.ReplicaFaultSpec` (``FleetSpec.faults``)
    injects seeded replica failures: the failed replica's in-flight step is
    killed (epoch guard), its requests reroute through the router and
    restart from scratch, the autoscaler skips down replicas, and the
    report carries the failure trace — SLO goodput under failures.

    Determinism matches the single-replica loop: seeded workloads, a
    deterministic oracle, heap ties broken by insertion order, and routers/
    autoscaler that are pure functions of fleet state.  Only the pools of
    the replica an event touches are replanned — except on the final fresh
    arrival, which replans every entry replica (the fleet-wide drain signal
    it flips can unblock gang-scheduling pools idling on a partial batch) —
    so fleet event-loop cost stays O(events), not O(events × replicas).
    """

    def __init__(self, sim: Simulator, cfg: ModelConfig | None = None, *,
                 par: ParallelConfig | None = None, policy=None, fleet=None,
                 oracle: StepOracle | None = None, ctx_floor: int = 256):
        from repro.api.spec import FleetSpec
        self.sim = sim
        self.cfg = cfg
        self.par = par or ParallelConfig()
        self.policy = policy or ContinuousBatching()
        self.fleet = fleet or FleetSpec()
        self.oracle = oracle if cfg is None else (
            oracle or StepOracle(sim, cfg, self.par, ctx_floor=ctx_floor))

    # ------------------------------------------------------------------
    def _replicas(self) -> tuple[list[ReplicaPool], list[ReplicaPool],
                                 list[ReplicaPool]]:
        """Build the fleet: (all, serve group, entry group).

        With an autoscaler, ``max_replicas`` serve replicas exist up front
        (construction is cheap — they share the oracle) and only the
        initial count is active; scale-ups activate standbys in index
        order, so replica identity is stable across the run.
        """
        import copy

        f = self.fleet
        scaler = f.autoscaler
        n_active = f.replicas
        n_total = f.replicas
        if scaler is not None:
            n_active = min(max(f.replicas, scaler.min_replicas),
                           scaler.max_replicas)
            n_total = max(n_total, scaler.max_replicas)
        reps: list[ReplicaPool] = []
        for i in range(n_total):
            pools, transfer = make_pools(copy.deepcopy(self.policy))
            reps.append(ReplicaPool(index=i, pools=pools, transfer_s=transfer,
                                    active=i < n_active))
        serve = list(reps)
        if f.prefill_replicas > 0:
            for _ in range(f.prefill_replicas):
                pool = Pool("prefill", PrefillOnly(f.prefill_batch),
                            role="prefill")
                reps.append(ReplicaPool(index=len(reps), pools=[pool],
                                        transfer_s=f.transfer_s,
                                        role="prefill"))
            # decode side of a disaggregated fleet: pure continuous decode,
            # capped by the per-replica policy's admission limit (a
            # DisaggregatedPD policy names its decode cap explicitly)
            if isinstance(self.policy, DisaggregatedPD):
                cap = self.policy.decode_batch
            else:
                cap = getattr(self.policy, "max_batch",
                              getattr(self.policy, "batch_size", 16))
            for rep in serve:
                rep.pools[:] = [Pool("decode", DecodeOnly(cap), role="decode")]
        entry = [rep for rep in reps if rep.role == "prefill"] or serve
        return reps, serve, entry

    def _routable(self, group: list[ReplicaPool],
                  now: float) -> list[ReplicaPool]:
        up = [rep for rep in group
              if rep.active and now >= rep.ready_at and rep.up(now)]
        # provisioning gap, a fleet-wide outage, or everything scaled down:
        # fall back rather than drop arrivals (a request queued on a down
        # replica is drained — or re-displaced — when it recovers)
        return (up
                or [rep for rep in group if rep.active and rep.up(now)]
                or [rep for rep in group if rep.active] or group)

    def _finish(self, rep: ReplicaPool, pool: Pool, plan: StepPlan,
                now: float, evq: EventQueue, serve: list[ReplicaPool],
                decode_router, finished_by: list[list],
                rec=NULL_RECORDER) -> None:
        pool.busy = False
        for r, chunk in plan.prefill:
            r.prefilled += chunk
            if r.prefilled >= r.prompt_len:
                pool.prefilling.remove(r)
                if r.first_token_s is None:
                    r.first_token_s = now   # prefill emits the first token
                    # (a request re-prefilling after a replica failure keeps
                    # its original TTFT — that token was already delivered)
                r.decoded = 1
                if r.decoded >= r.output_len:
                    r.finished_s = now
                    finished_by[rep.index].append(r)
                elif rep.role == "prefill":
                    # fleet-level disaggregation: migrate to a decode replica
                    target = decode_router.route(
                        r, self._routable(serve, now), now)
                    if rec.enabled:
                        rec.instant(f"replica{rep.index}", "kv_transfer",
                                    "kv_transfer", now, cat="migration",
                                    args={"rid": r.rid, "to": target.index,
                                          "transfer_s": rep.transfer_s})
                    evq.push(now + rep.transfer_s, ARRIVAL,
                             (target, target.entry, r))
                elif pool.role == "prefill":
                    # per-replica DisaggregatedPD: decode pool is a sibling
                    if rec.enabled:
                        rec.instant(f"replica{rep.index}", "kv_transfer",
                                    "kv_transfer", now, cat="migration",
                                    args={"rid": r.rid, "to": rep.index,
                                          "transfer_s": rep.transfer_s})
                    evq.push(now + rep.transfer_s, ARRIVAL,
                             (rep, rep.pools[1], r))
                else:
                    pool.running.append(r)
        for r in plan.decode:
            r.decoded += 1
            if r.decoded >= r.output_len:
                r.finished_s = now
                pool.running.remove(r)
                finished_by[rep.index].append(r)

    # ------------------------------------------------------------------
    def run(self, workload, *, slo: SLO | None = None,
            max_steps: int = 50_000_000, recorder=None,
            metrics=None) -> FleetReport:
        """Replay a trace through the fleet and aggregate a
        :class:`FleetReport`.

        Accepts a :class:`Workload` (fleet/policy fixed at construction) or
        a :class:`~repro.api.spec.SimSpec` whose
        :class:`~repro.api.spec.ServingWorkload` supplies model,
        parallelism, policy, trace, SLO and :class:`FleetSpec` — the spec
        form of "sweep disaggregation ratios × replica counts".

        ``recorder`` collects per-replica step-span lanes, per-request
        lanes, KV-transfer migration instants, autoscaler actions and
        FAILURE/RECOVER/reroute instants; ``metrics`` accumulates fleet
        counters.  Both default to off and cost nothing when off.
        """
        from repro.api.spec import SimSpec
        if isinstance(workload, SimSpec):
            spec = workload
            w = spec.workload
            if getattr(w, "mode", None) != "serving":
                raise TypeError(
                    "FleetSimulator.run(spec) needs a ServingWorkload; "
                    f"got {type(w).__name__}")
            if spec.cluster.hardware != self.sim.hw.name:
                raise ValueError(
                    f"simulator built for {self.sim.hw.name!r} cannot run a "
                    f"spec for cluster hardware {spec.cluster.hardware!r}")
            inner = FleetSimulator(self.sim, spec.model, par=spec.parallel,
                                   policy=w.make_policy(), fleet=w.fleet,
                                   ctx_floor=w.ctx_floor)
            return inner.run(w.build(), slo=slo if slo is not None else w.slo,
                             max_steps=max_steps, recorder=recorder,
                             metrics=metrics)
        if self.oracle is None:
            raise TypeError("FleetSimulator was built without a model "
                            "config; pass a SimSpec to run()")
        rec = recorder if recorder is not None else NULL_RECORDER
        f = self.fleet
        reqs = sorted((r.reset_copy() for r in workload.requests),
                      key=lambda r: r.arrival_s)
        replicas, serve, entry = self._replicas()
        router = make_router(f.router)
        decode_router = LeastLoadedRouter()
        scaler = Autoscaler(f.autoscaler) if f.autoscaler is not None else None
        evq = EventQueue()
        for r in reqs:
            evq.push(r.arrival_s, ARRIVAL, (None, None, r))
        if scaler is not None and reqs:
            evq.push(reqs[0].arrival_s + f.autoscaler.interval_s,
                     AUTOSCALE, ())
        # seeded replica fault injection: every replica (standbys included —
        # machines fail whether or not they take traffic) owns a lazy
        # renewal stream; the next failure is always one event ahead
        faults = f.faults if (f.faults is not None and f.faults.active) \
            else None
        fault_gap: dict[int, object] = {}
        if faults is not None and reqs:
            from repro.resilience.faults import replica_fault_stream
            for rep in replicas:
                fault_gap[rep.index] = replica_fault_stream(faults, rep.index)
                evq.push(reqs[0].arrival_s + fault_gap[rep.index](),
                         FAILURE, (rep,))
        failure_trace: list[dict] = []
        n_rerouted = 0
        remaining = len(reqs)
        finished_by: list[list[SimRequest]] = [[] for _ in replicas]
        n_finished = 0
        stats0 = self.oracle.stats()
        steps = 0
        while evq:
            ev = evq.pop()
            now = ev.time
            rep = None
            replan: list[ReplicaPool] = []
            if ev.kind == ARRIVAL:
                rep, pool, r = ev.payload
                if rep is None:             # fresh arrival: route it now
                    remaining -= 1
                    rep = router.route(r, self._routable(entry, now), now)
                    pool = rep.entry
                    # fleet-wide drain signal for wait-for-gang policies:
                    # per-replica arrival counts are unknowable under
                    # load-dependent routing, so every entry pool sees the
                    # fleet-wide undelivered count (conservative: a gang
                    # waits a little longer, never deadlocks)
                    for x in entry:
                        x.entry.pending_arrivals = remaining
                    if remaining == 0:
                        # the drain signal just flipped fleet-wide: an entry
                        # replica idling on a partial gang (static batching
                        # planned None while arrivals were pending) gets no
                        # further events, so the final arrival must replan
                        # every entry replica, not just the routed one
                        replan = [x for x in entry if x is not rep]
                pool.queue.append(r)
                if r.enqueue_s is None:
                    r.enqueue_s = now
            elif ev.kind == STEP_DONE:
                rep, pool, plan, epoch = ev.payload
                if epoch != rep.epoch:
                    continue                 # step killed by a failure
                before = len(finished_by[rep.index])
                self._finish(rep, pool, plan, now, evq, serve, decode_router,
                             finished_by, rec)
                n_finished += len(finished_by[rep.index]) - before
            elif ev.kind == FAILURE:
                (frep,) = ev.payload
                if n_finished >= len(reqs):
                    continue                 # trace done: stop the process
                failure_trace.append({"t": round(now, 4),
                                      "replica": frep.index})
                frep.failed_until = now + faults.restart_s
                frep.epoch += 1              # kills the in-flight STEP_DONE
                displaced: list[SimRequest] = []
                for pool in frep.pools:
                    pool.busy = False
                    displaced.extend(pool.queue)
                    pool.queue.clear()
                    displaced.extend(pool.prefilling)
                    pool.prefilling.clear()
                    displaced.extend(pool.running)
                    pool.running.clear()
                evq.push(frep.failed_until, RECOVER, (frep,))
                evq.push(frep.failed_until + fault_gap[frep.index](),
                         FAILURE, (frep,))
                # reroute what the replica held: KV state died with it, so
                # requests restart from scratch (keeping their original
                # enqueue/start/first-token stamps — latency is end-to-end)
                for r in displaced:
                    r.prefilled = 0
                    r.decoded = 0
                    n_rerouted += 1
                    target = router.route(r, self._routable(entry, now), now)
                    target.entry.queue.append(r)
                    if rec.enabled:
                        rec.instant("fleet", "faults", "reroute", now,
                                    cat="fault",
                                    args={"rid": r.rid, "from": frep.index,
                                          "to": target.index})
                    if target not in replan:
                        replan.append(target)
                if rec.enabled:
                    rec.instant("fleet", "faults", f"FAILURE r{frep.index}",
                                now, cat="fault",
                                args={"replica": frep.index,
                                      "displaced": len(displaced),
                                      "restart_s": faults.restart_s})
                    rec.span(f"replica{frep.index}", "downtime", "down", now,
                             faults.restart_s, cat="fault",
                             cname=CNAMES["downtime"])
            elif ev.kind == RECOVER:
                (rep,) = ev.payload          # replan it (gated if re-failed)
                if rec.enabled:
                    rec.instant("fleet", "faults", f"RECOVER r{rep.index}",
                                now, cat="fault", args={"replica": rep.index})
            else:                            # AUTOSCALE
                n_actions0 = len(scaler.trace)
                scaler.tick(now, serve)
                if rec.enabled:
                    for entry_row in scaler.trace[n_actions0:]:
                        rec.instant("fleet", "autoscaler",
                                    entry_row["action"], now, cat="autoscale",
                                    args=dict(entry_row))
                if remaining > 0 or n_finished < len(reqs):
                    evq.push(now + f.autoscaler.interval_s, AUTOSCALE, ())
            if rep is not None:
                replan.insert(0, rep)        # touched replica replans first
            for prep in replan:
                if not prep.up(now):
                    continue                 # down: drains at its RECOVER
                for pool in prep.pools:
                    if pool.busy:
                        continue
                    plan = pool.policy.plan(pool, now)
                    if plan is None:
                        continue
                    steps += 1
                    if steps > max_steps:
                        raise RuntimeError(
                            f"fleet sim exceeded {max_steps} steps "
                            f"({n_finished}/{len(reqs)} finished)")
                    dt = price_step_s(self.oracle, plan)
                    for r, _ in plan.prefill:
                        if r.start_s is None:
                            r.start_s = now
                    for r in plan.decode:
                        if r.start_s is None:
                            r.start_s = now
                    pool.busy = True
                    pool.n_steps += 1
                    pool.busy_s += dt
                    pool.phase_s[plan.kind] = \
                        pool.phase_s.get(plan.kind, 0.0) + dt
                    pool.steps_by_kind[plan.kind] = \
                        pool.steps_by_kind.get(plan.kind, 0) + 1
                    if rec.enabled:
                        rec.span(f"replica{prep.index}", pool.name,
                                 plan.kind, now, dt, cat="step",
                                 args={"n_prefill": len(plan.prefill),
                                       "n_decode": len(plan.decode)})
                    evq.push(now + dt, STEP_DONE, (prep, pool, plan,
                                                   prep.epoch))
        if n_finished != len(reqs):
            raise RuntimeError(
                f"fleet sim deadlocked: {len(reqs) - n_finished} of "
                f"{len(reqs)} requests unfinished across "
                f"{len(replicas)} replicas")
        stats1 = self.oracle.stats()
        delta = {k: stats1.get(k, 0) - stats0.get(k, 0)
                 for k in ("hits", "misses")}
        delta["hit_rate"] = round(
            delta["hits"] / max(delta["hits"] + delta["misses"], 1), 4)
        delta["distinct_steps"] = self.oracle.n_distinct_steps
        record_request_lanes(
            rec, [r for chunk in finished_by for r in chunk],
            pid="fleet/requests", metrics=metrics)
        frep = FleetReport.build(
            finished_by, replicas, slo, router.name,
            scaler.trace if scaler is not None else [], delta,
            failure_trace=failure_trace, n_rerouted=n_rerouted)
        if metrics is not None:
            metrics.inc("fleet.requests", n_finished)
            metrics.inc("fleet.steps", steps)
            for k, n in frep.steps_by_kind.items():
                metrics.inc(f"fleet.steps.{k}", n)
            metrics.inc("fleet.failures", len(failure_trace))
            metrics.inc("fleet.rerouted", n_rerouted)
            metrics.inc("fleet.autoscale_actions",
                        len(frep.autoscaler_trace))
            metrics.update_nested(delta, prefix="fleet.oracle")
        return frep


# ----------------------------------------------------------------------
@dataclass
class ServingScenario:
    """A request-level what-if the explorer can rank candidates by.

    ``evaluate`` turns an explorer candidate into a per-replica serving run:
    the workload is round-robin split over the candidate's ``dp * pods``
    replicas, the candidate's per-replica batch (``B_local``) becomes the
    policy's admission cap, and the reported goodput is scaled back to the
    system level — so a config with more replicas competes on aggregate
    SLO-attainment throughput, not per-step latency.
    """
    workload: Workload
    slo: SLO = field(default_factory=SLO)
    policy: str = "continuous"          # continuous | chunked | static
    token_budget: int = 256             # chunked-prefill budget
    ctx_floor: int = 256
    fleet: object | None = None         # FleetSpec -> fleet-level evaluation

    @staticmethod
    def default(seed: int = 0) -> "ServingScenario":
        """A small mixed workload: enough load that admission capacity (not
        per-step latency) decides SLO attainment — see docs/serving.md."""
        return ServingScenario(synthesize(
            200, arrival="poisson", rate_rps=16.0, seed=seed))

    def make_policy(self, max_batch: int):
        return make_policy(self.policy, max_batch,
                           token_budget=self.token_budget)

    def evaluate(self, sim: Simulator, cfg: ModelConfig, cand):
        if self.fleet is not None and not self.fleet.trivial:
            # fleet evaluation: the full workload hits the routed fleet, the
            # candidate's per-replica batch caps each engine, and the
            # resulting goodput is system-level already (no dp*pods scaling)
            fsim = FleetSimulator(sim, cfg, par=cand.par,
                                  policy=self.make_policy(cand.B_local()),
                                  fleet=self.fleet, ctx_floor=self.ctx_floor)
            return fsim.run(self.workload, slo=self.slo)
        replicas = max(cand.par.dp * cand.par.pods, 1)
        wl = self.workload.shard(replicas)
        ssim = ServingSimulator(sim, cfg, par=cand.par,
                                policy=self.make_policy(cand.B_local()),
                                ctx_floor=self.ctx_floor)
        rep = ssim.run(wl, slo=self.slo)
        return rep

"""Discrete-event serving simulator: continuous batching over predicted steps.

This is the request-level layer the paper's deployment case study needs:
instead of executing a model, every engine iteration is *priced* by the core
:class:`~repro.core.simulator.Simulator` (through the memoized
:class:`~repro.serving.sim.oracle.StepOracle`) and a discrete-event loop
advances simulated time, so a 500-request trace replays in seconds of wall
time while producing the TTFT/TPOT/goodput distributions a real deployment
would measure.

Event loop invariants:

* A pool (one engine instance) runs at most one iteration at a time; when a
  ``STEP_DONE`` fires, token accounting happens first, then every idle pool
  gets a chance to plan its next step.
* Requests finish exactly once: the first token is emitted by the step that
  completes the prompt (prefill counts the first output token, the standard
  TTFT convention), the remaining ``output_len - 1`` tokens by decode steps.
* Disaggregated prefill/decode expands into two pools; completing a prefill
  on a ``role="prefill"`` pool schedules a delayed ``ARRIVAL`` (KV transfer)
  at the decode pool.
* All times come from the seeded workload and the deterministic oracle, and
  event ties break on insertion order — identical runs are bit-identical.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core.passes.base import ParallelConfig
from repro.core.simulator import Simulator
from repro.serving.sim.events import ARRIVAL, STEP_DONE, EventQueue
from repro.serving.sim.oracle import StepOracle
from repro.serving.sim.policies import (
    ContinuousBatching, DecodeOnly, DisaggregatedPD, PrefillOnly, StepPlan,
    make_policy,
)
from repro.serving.sim.report import SLO, ServingReport
from repro.serving.sim.workload import SimRequest, Workload, synthesize


@dataclass
class Pool:
    """One engine instance: a queue, a running batch, busy-time accounting."""
    name: str
    policy: object
    role: str = "both"                  # both | prefill | decode
    queue: deque = field(default_factory=deque)
    running: list = field(default_factory=list)
    prefilling: list = field(default_factory=list)
    pending_arrivals: int = 0
    busy: bool = False
    busy_s: float = 0.0
    phase_s: dict = field(default_factory=dict)       # step kind -> seconds
    steps_by_kind: dict = field(default_factory=dict)  # step kind -> count
    n_steps: int = 0


class ServingSimulator:
    """Replay a :class:`Workload` through a batching policy, pricing every
    engine iteration with the step oracle."""

    def __init__(self, sim: Simulator, cfg: ModelConfig | None = None, *,
                 par: ParallelConfig | None = None, policy=None,
                 oracle: StepOracle | None = None, ctx_floor: int = 256):
        self.sim = sim
        self.cfg = cfg
        self.par = par or ParallelConfig()
        self.policy = policy or ContinuousBatching()
        # spec-driven use (``ServingSimulator(sim).run(spec)``) defers the
        # oracle until the spec supplies model/parallelism
        self.oracle = oracle if cfg is None else (
            oracle or StepOracle(sim, cfg, self.par, ctx_floor=ctx_floor))

    # ------------------------------------------------------------------
    def _pools(self) -> tuple[list[Pool], float]:
        p = self.policy
        if isinstance(p, DisaggregatedPD):
            return [Pool("prefill", PrefillOnly(p.prefill_batch), role="prefill"),
                    Pool("decode", DecodeOnly(p.decode_batch), role="decode")], \
                p.transfer_s
        return [Pool("engine", p)], 0.0

    def _price_s(self, plan: StepPlan) -> float:
        o = self.oracle
        if plan.kind == "decode":
            ctx = max(r.prompt_len + r.decoded for r in plan.decode)
            return o.decode_step_s(len(plan.decode), ctx)
        if plan.kind == "prefill":
            seq = max(chunk for _, chunk in plan.prefill)
            return o.prefill_s(len(plan.prefill), seq)
        ctx = max((r.prompt_len + r.decoded for r in plan.decode), default=0)
        chunk = sum(c for _, c in plan.prefill)
        return o.mixed_step_s(len(plan.decode), ctx, chunk)

    def _finish_step(self, pool: Pool, plan: StepPlan, now: float,
                     evq: EventQueue, pools: list[Pool], transfer_s: float,
                     finished: list[SimRequest]) -> None:
        pool.busy = False
        for r, chunk in plan.prefill:
            r.prefilled += chunk
            if r.prefilled >= r.prompt_len:
                pool.prefilling.remove(r)
                r.first_token_s = now       # prefill emits the first token
                r.decoded = 1
                if r.decoded >= r.output_len:
                    r.finished_s = now
                    finished.append(r)
                elif pool.role == "prefill":
                    evq.push(now + transfer_s, ARRIVAL, (pools[1], r))
                else:
                    pool.running.append(r)
        for r in plan.decode:
            r.decoded += 1
            if r.decoded >= r.output_len:
                r.finished_s = now
                pool.running.remove(r)
                finished.append(r)

    # ------------------------------------------------------------------
    def run(self, workload, *, slo: SLO | None = None,
            max_steps: int = 2_000_000) -> ServingReport:
        """Replay a request trace and aggregate a :class:`ServingReport`.

        Accepts either a legacy :class:`Workload` (with the policy/model
        fixed at construction) or a :class:`~repro.api.spec.SimSpec` whose
        workload is a :class:`~repro.api.spec.ServingWorkload` — the spec
        then supplies the model, parallelism, policy, trace and SLO.
        """
        from repro.api.spec import SimSpec
        if isinstance(workload, SimSpec):
            spec = workload
            w = spec.workload
            if getattr(w, "mode", None) != "serving":
                raise TypeError(
                    "ServingSimulator.run(spec) needs a ServingWorkload; "
                    f"got {type(w).__name__} (use Simulator.run for "
                    "steady-state workloads)")
            if spec.cluster.hardware != self.sim.hw.name:
                raise ValueError(
                    f"simulator built for {self.sim.hw.name!r} cannot run a "
                    f"spec for cluster hardware {spec.cluster.hardware!r}")
            inner = ServingSimulator(self.sim, spec.model, par=spec.parallel,
                                     policy=w.make_policy(),
                                     ctx_floor=w.ctx_floor)
            return inner.run(w.build(), slo=slo if slo is not None else w.slo,
                             max_steps=max_steps)
        if self.oracle is None:
            raise TypeError("ServingSimulator was built without a model "
                            "config; pass a SimSpec to run()")
        reqs = sorted((r.reset_copy() for r in workload.requests),
                      key=lambda r: r.arrival_s)
        pools, transfer_s = self._pools()
        evq = EventQueue()
        for r in reqs:
            evq.push(r.arrival_s, ARRIVAL, (pools[0], r))
        # only the entry pool knows its arrival count up front; downstream
        # pools (disaggregated decode) receive an unknowable subset via
        # migration, so a wait-for-arrivals policy must not wait on them
        pools[0].pending_arrivals = len(reqs)
        finished: list[SimRequest] = []
        stats0 = self.oracle.stats()
        steps = 0
        while evq:
            ev = evq.pop()
            now = ev.time
            if ev.kind == ARRIVAL:
                pool, r = ev.payload
                pool.queue.append(r)
                pool.pending_arrivals = max(pool.pending_arrivals - 1, 0)
                if r.enqueue_s is None:
                    r.enqueue_s = now
            else:                                   # STEP_DONE
                pool, plan = ev.payload
                self._finish_step(pool, plan, now, evq, pools, transfer_s,
                                  finished)
            for pool in pools:
                if pool.busy:
                    continue
                plan = pool.policy.plan(pool, now)
                if plan is None:
                    continue
                steps += 1
                if steps > max_steps:
                    raise RuntimeError(
                        f"serving sim exceeded {max_steps} steps "
                        f"({len(finished)}/{len(reqs)} finished)")
                dt = self._price_s(plan)
                for r, _ in plan.prefill:
                    if r.start_s is None:
                        r.start_s = now
                for r in plan.decode:
                    if r.start_s is None:
                        r.start_s = now
                pool.busy = True
                pool.n_steps += 1
                pool.busy_s += dt
                pool.phase_s[plan.kind] = pool.phase_s.get(plan.kind, 0.0) + dt
                pool.steps_by_kind[plan.kind] = \
                    pool.steps_by_kind.get(plan.kind, 0) + 1
                evq.push(now + dt, STEP_DONE, (pool, plan))
        if len(finished) != len(reqs):
            raise RuntimeError(
                f"serving sim deadlocked: {len(reqs) - len(finished)} of "
                f"{len(reqs)} requests unfinished under {self.policy.name}")
        stats1 = self.oracle.stats()
        delta = {k: stats1.get(k, 0) - stats0.get(k, 0)
                 for k in ("hits", "misses")}
        delta["hit_rate"] = round(
            delta["hits"] / max(delta["hits"] + delta["misses"], 1), 4)
        return ServingReport.build(finished, pools, slo, delta)


# ----------------------------------------------------------------------
@dataclass
class ServingScenario:
    """A request-level what-if the explorer can rank candidates by.

    ``evaluate`` turns an explorer candidate into a per-replica serving run:
    the workload is round-robin split over the candidate's ``dp * pods``
    replicas, the candidate's per-replica batch (``B_local``) becomes the
    policy's admission cap, and the reported goodput is scaled back to the
    system level — so a config with more replicas competes on aggregate
    SLO-attainment throughput, not per-step latency.
    """
    workload: Workload
    slo: SLO = field(default_factory=SLO)
    policy: str = "continuous"          # continuous | chunked | static
    token_budget: int = 256             # chunked-prefill budget
    ctx_floor: int = 256

    @staticmethod
    def default(seed: int = 0) -> "ServingScenario":
        """A small mixed workload: enough load that admission capacity (not
        per-step latency) decides SLO attainment — see docs/serving.md."""
        return ServingScenario(synthesize(
            200, arrival="poisson", rate_rps=16.0, seed=seed))

    def make_policy(self, max_batch: int):
        return make_policy(self.policy, max_batch,
                           token_budget=self.token_budget)

    def evaluate(self, sim: Simulator, cfg: ModelConfig, cand) -> ServingReport:
        replicas = max(cand.par.dp * cand.par.pods, 1)
        wl = self.workload.thin(replicas)
        ssim = ServingSimulator(sim, cfg, par=cand.par,
                                policy=self.make_policy(cand.B_local()),
                                ctx_floor=self.ctx_floor)
        rep = ssim.run(wl, slo=self.slo)
        return rep

"""Request workloads for the serving simulator (synthetic + trace replay).

Every stochastic choice flows through a single ``random.Random(seed)``
instance, so a (spec, seed) pair always synthesizes the same trace — the
property the conservation/memoization tests and A/B policy comparisons rely
on.  The module also provides the clocks shared with ``serving.engine``:
the real :class:`~repro.serving.engine.ServingEngine` timestamps requests
through an injected clock, and trace replay passes a :class:`VirtualClock`
driven in simulated seconds so a caller-supplied ``arrival_s`` of ``0.0``
is preserved exactly instead of being silently replaced by wall-clock time.
"""
from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, replace


class VirtualClock:
    """Monotone simulated-seconds clock, callable like ``time.perf_counter``."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        if t < self._now:
            raise ValueError(f"clock moving backwards: {t} < {self._now}")
        self._now = float(t)


def wall_clock() -> float:
    """Default real-time clock (so callers never reach for ``time`` directly)."""
    return time.perf_counter()


@dataclass
class SimRequest:
    """One request flowing through the discrete-event simulator.

    Progress fields are mutated by the event loop; ``ServingSimulator.run``
    operates on reset copies so a :class:`Workload` can be replayed through
    any number of policies/candidates.  ``session`` (-1 = sessionless)
    groups requests that share a conversation prefix — the fleet router's
    session-affinity policy keeps a session on one replica so its prefix
    stays in that replica's cache.
    """
    rid: int
    arrival_s: float
    prompt_len: int
    output_len: int
    session: int = -1
    # progress (mutated by the event loop)
    prefilled: int = 0
    decoded: int = 0
    # timestamps (simulated seconds)
    enqueue_s: float | None = None      # entered the current pool's queue
    start_s: float | None = None        # first scheduled into an engine step
    first_token_s: float | None = None
    finished_s: float | None = None

    @property
    def done(self) -> bool:
        return self.finished_s is not None

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def tpot_ms(self) -> float:
        if self.output_len <= 1:
            return 0.0
        return (self.finished_s - self.first_token_s) / (self.output_len - 1) * 1e3

    @property
    def e2e_s(self) -> float:
        return self.finished_s - self.arrival_s

    @property
    def queue_delay_s(self) -> float:
        return self.start_s - self.arrival_s

    def reset_copy(self) -> "SimRequest":
        return replace(self, prefilled=0, decoded=0, enqueue_s=None,
                       start_s=None, first_token_s=None, finished_s=None)


@dataclass(frozen=True)
class LengthDist:
    """Token-length distribution: ``fixed`` | ``uniform`` | ``lognormal``.

    ``lognormal`` is the production shape (heavy right tail of long prompts);
    ``median`` is the log-space location and ``sigma`` the log-space spread.
    Samples are clamped to ``[1, cap]``.
    """
    kind: str = "fixed"
    value: int = 512                # fixed
    lo: int = 1                     # uniform
    hi: int = 1024
    median: float = 512.0           # lognormal
    sigma: float = 0.6
    cap: int = 8192

    def sample(self, rng: random.Random) -> int:
        if self.kind == "fixed":
            n = self.value
        elif self.kind == "uniform":
            n = rng.randint(self.lo, self.hi)
        elif self.kind == "lognormal":
            n = int(round(self.median * math.exp(rng.gauss(0.0, self.sigma))))
        else:
            raise ValueError(f"unknown length distribution {self.kind!r}")
        return max(1, min(n, self.cap))


@dataclass
class Workload:
    """An arrival-ordered request trace (immutable by convention: the
    simulator runs on reset copies, never on these instances)."""
    requests: list[SimRequest]

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    @property
    def prompt_tokens(self) -> int:
        return sum(r.prompt_len for r in self.requests)

    @property
    def output_tokens(self) -> int:
        return sum(r.output_len for r in self.requests)

    @property
    def duration_s(self) -> float:
        if not self.requests:
            return 0.0
        return self.requests[-1].arrival_s - self.requests[0].arrival_s

    @staticmethod
    def from_trace(rows) -> "Workload":
        """Trace replay: ``rows`` is an iterable of
        ``(arrival_s, prompt_len, output_len)`` (any order; re-sorted)."""
        rows = sorted(rows, key=lambda r: float(r[0]))
        return Workload([
            SimRequest(rid=i, arrival_s=float(a), prompt_len=max(int(p), 1),
                       output_len=max(int(o), 1))
            for i, (a, p, o) in enumerate(rows)])

    def shard(self, k: int, offset: int = 0) -> "Workload":
        """Every ``k``-th request (deterministic round-robin split) — one
        replica's share of a round-robin split over ``k`` identical
        replicas.  This is exactly what a round-robin fleet router delivers
        to replica ``offset``, which is how the explorer's goodput objective
        turns a system-level workload into a per-replica one (and what the
        shim↔spec bit-identity tests assert)."""
        if k <= 1:
            return Workload([r.reset_copy() for r in self.requests])
        return Workload([r.reset_copy()
                         for r in self.requests[offset % k::k]])

    def thin(self, k: int, offset: int = 0) -> "Workload":
        """Deprecated replica-thinning knob: describe the replica split with
        :class:`~repro.api.spec.FleetSpec` (``ServingWorkload(fleet=
        FleetSpec(replicas=k))``) and let the fleet simulator route the
        stream, or call :meth:`shard` for the raw per-replica share."""
        import warnings

        from repro.api.spec import CharonDeprecationWarning
        warnings.warn(
            "Workload.thin(k) is deprecated; use FleetSpec(replicas=k) on a "
            "ServingWorkload (see docs/serving.md) or Workload.shard(k) for "
            "the raw round-robin share", CharonDeprecationWarning,
            stacklevel=2)
        return self.shard(k, offset)


def synthesize(n: int, *, arrival: str = "poisson", rate_rps: float = 8.0,
               burst_factor: float = 4.0, switch_prob: float = 0.1,
               period_s: float = 600.0, diurnal_amp: float = 0.8,
               flash_start_s: float = 60.0, flash_dur_s: float = 30.0,
               flash_mult: float = 8.0, sessions: int = 0,
               prompt: LengthDist = LengthDist("lognormal", median=512.0,
                                               sigma=0.7, cap=4096),
               output: LengthDist = LengthDist("lognormal", median=128.0,
                                               sigma=0.7, cap=1024),
               seed: int = 0, start_s: float = 0.0) -> Workload:
    """Synthesize a deterministic ``n``-request workload.

    ``arrival``:
      * ``poisson``      — exponential interarrivals at ``rate_rps``.
      * ``uniform``      — evenly spaced at ``1/rate_rps``.
      * ``bursty``       — two-regime modulated Poisson: the rate alternates
        between ``rate_rps * burst_factor`` (burst) and
        ``rate_rps / burst_factor`` (lull); the regime flips with
        probability ``switch_prob`` per arrival (sticky bursts).  The mean
        rate is of order ``rate_rps`` but not exactly it — this is a shape
        knob, not a calibrated trace.
      * ``diurnal``      — non-homogeneous Poisson with a sinusoidal rate
        ``rate_rps * (1 + diurnal_amp * sin(2πt / period_s))`` (Lewis-
        Shedler thinning against the peak rate): the traffic shape an
        autoscaler earns its keep on.
      * ``flash_crowd``  — base Poisson at ``rate_rps`` with a
        ``flash_mult``× spike during ``[flash_start_s, flash_start_s +
        flash_dur_s)`` (thinning again) — the scale-up stress case.

    ``sessions > 0`` tags every request with a session id drawn uniformly
    from ``range(sessions)`` (multi-turn users); ``sessions = 0`` leaves
    requests sessionless and the rng stream identical to earlier versions.
    """
    rng = random.Random(seed)
    t = float(start_s)
    in_burst = False

    if arrival == "diurnal":
        amp = min(max(float(diurnal_amp), 0.0), 1.0)
        peak = rate_rps * (1.0 + amp)
        two_pi = 2.0 * math.pi

        def rate_at(ts: float) -> float:
            return rate_rps * (1.0 + amp * math.sin(two_pi * ts / period_s))
    elif arrival == "flash_crowd":
        peak = rate_rps * max(float(flash_mult), 1.0)
        flash_end = flash_start_s + flash_dur_s

        def rate_at(ts: float) -> float:
            return peak if flash_start_s <= ts < flash_end else rate_rps
    else:
        peak = rate_at = None

    reqs = []
    for i in range(n):
        if arrival == "poisson":
            t += rng.expovariate(rate_rps)
        elif arrival == "uniform":
            t += 1.0 / rate_rps
        elif arrival == "bursty":
            if rng.random() < switch_prob:
                in_burst = not in_burst
            r = rate_rps * (burst_factor if in_burst else 1.0 / burst_factor)
            t += rng.expovariate(r)
        elif rate_at is not None:
            # thinning: candidate points at the peak rate, accepted with
            # probability rate(t)/peak — exact for any bounded rate function
            while True:
                t += rng.expovariate(peak)
                if rng.random() * peak <= rate_at(t):
                    break
        else:
            raise ValueError(f"unknown arrival process {arrival!r}")
        req = SimRequest(rid=i, arrival_s=t, prompt_len=prompt.sample(rng),
                         output_len=output.sample(rng))
        if sessions > 0:
            req.session = rng.randrange(sessions)
        reqs.append(req)
    return Workload(reqs)

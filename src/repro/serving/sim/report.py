"""Serving metrics: percentile summaries, SLO goodput, utilization.

``ServingReport`` is the request-level analogue of the core simulator's
``Report``: instead of one steady-state step time it carries the TTFT/TPOT/
end-to-end *distributions* a deployment decision actually hinges on, plus
SLO-attainment goodput — the objective the explorer can rank parallelism
configs by (``sweep(..., objective="goodput")``).  ``FleetReport`` is the
same thing one level up: per-replica ``ServingReport``s plus fleet-wide
distributions, replica utilization and the autoscaler trace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import ClassVar


@dataclass(frozen=True)
class SLO:
    """Per-request latency targets; a request "meets SLO" when both hold."""
    ttft_s: float = 2.0
    tpot_ms: float = 100.0

    def met(self, r) -> bool:
        return r.ttft_s <= self.ttft_s and r.tpot_ms <= self.tpot_ms


@dataclass(frozen=True)
class Percentiles:
    p50: float
    p90: float
    p99: float
    mean: float
    max: float

    @staticmethod
    def of(values) -> "Percentiles":
        s = sorted(values)
        if not s:
            return Percentiles(0.0, 0.0, 0.0, 0.0, 0.0)

        def q(p: float) -> float:
            i = (len(s) - 1) * p
            lo, hi = math.floor(i), math.ceil(i)
            return s[lo] + (s[hi] - s[lo]) * (i - lo)

        return Percentiles(q(0.50), q(0.90), q(0.99), sum(s) / len(s), s[-1])

    def as_dict(self, scale: float = 1.0, nd: int = 4) -> dict:
        return {k: round(getattr(self, k) * scale, nd)
                for k in ("p50", "p90", "p99", "mean", "max")}


@dataclass
class ServingReport:
    """Aggregate result of one workload replay through one policy."""
    n_requests: int
    makespan_s: float                   # first arrival -> last completion
    ttft_s: Percentiles                 # time to first token
    tpot_ms: Percentiles                # per-output-token latency after first
    e2e_s: Percentiles                  # arrival -> completion
    queue_delay_s: Percentiles          # arrival -> first scheduled
    prompt_tokens: int
    output_tokens: int
    tokens_per_s: float                 # (prompt + output) / makespan
    output_tokens_per_s: float
    requests_per_s: float
    slo: SLO | None
    slo_attainment: float               # fraction of requests meeting SLO
    goodput_rps: float                  # attainment * requests_per_s
    n_steps: int
    steps_by_kind: dict                 # step kind -> count
    utilization: dict                   # pool -> {busy_frac, <kind>_frac, steps}
    oracle_stats: dict = field(default_factory=dict)  # serving-bucket delta
    # finished SimRequests; a tuple because report objects are cache-shared
    # (charon-lint R1: cached values must be immutable or copied)
    requests: tuple = field(default_factory=tuple)

    @staticmethod
    def build(reqs, pools, slo: SLO | None,
              oracle_stats: dict) -> "ServingReport":
        t0 = min((r.arrival_s for r in reqs), default=0.0)
        t1 = max((r.finished_s for r in reqs), default=0.0)
        makespan = max(t1 - t0, 1e-12)
        prompt_toks = sum(r.prompt_len for r in reqs)
        out_toks = sum(r.output_len for r in reqs)
        attain = (sum(1 for r in reqs if slo.met(r)) / len(reqs)
                  if slo and reqs else 1.0)
        rps = len(reqs) / makespan
        steps_by_kind: dict[str, int] = {}
        util: dict[str, dict] = {}
        for p in pools:
            for k, n in p.steps_by_kind.items():
                steps_by_kind[k] = steps_by_kind.get(k, 0) + n
            u = {"busy_frac": round(p.busy_s / makespan, 4),
                 "steps": p.n_steps}
            for k, s in p.phase_s.items():
                u[f"{k}_frac"] = round(s / makespan, 4)
            util[p.name] = u
        return ServingReport(
            n_requests=len(reqs), makespan_s=makespan,
            ttft_s=Percentiles.of([r.ttft_s for r in reqs]),
            tpot_ms=Percentiles.of([r.tpot_ms for r in reqs]),
            e2e_s=Percentiles.of([r.e2e_s for r in reqs]),
            queue_delay_s=Percentiles.of([r.queue_delay_s for r in reqs]),
            prompt_tokens=prompt_toks, output_tokens=out_toks,
            tokens_per_s=(prompt_toks + out_toks) / makespan,
            output_tokens_per_s=out_toks / makespan,
            requests_per_s=rps, slo=slo, slo_attainment=attain,
            goodput_rps=attain * rps,
            n_steps=sum(p.n_steps for p in pools),
            steps_by_kind=steps_by_kind, utilization=util,
            oracle_stats=oracle_stats, requests=tuple(reqs))

    # per-replica serving results are replica-level; FleetReport overrides
    system_level: ClassVar[bool] = False

    # ---- attribution (repro.obs.explain) ----
    def explain(self, top_k: int = 8) -> str:
        """Plain-text attribution: dominant SLO-violation cause (queueing vs
        prefill vs decode), queue-delay share of TTFT, step mix, busiest
        lanes."""
        from repro.obs.explain import render_serving
        return render_serving(self, top_k=top_k)

    def explain_dict(self, top_k: int = 8) -> dict:
        """Structured form of :meth:`explain` (what sweep manifests embed)."""
        from repro.obs.explain import explain_serving
        return explain_serving(self, top_k=top_k)

    def summary(self) -> dict:
        """Flat dict for benchmarks / examples."""
        return {
            "n_requests": self.n_requests,
            "makespan_s": round(self.makespan_s, 3),
            "ttft_p50_s": round(self.ttft_s.p50, 4),
            "ttft_p99_s": round(self.ttft_s.p99, 4),
            "tpot_p50_ms": round(self.tpot_ms.p50, 3),
            "tpot_p99_ms": round(self.tpot_ms.p99, 3),
            "queue_delay_p50_s": round(self.queue_delay_s.p50, 4),
            "tokens_per_s": round(self.tokens_per_s, 1),
            "output_tokens_per_s": round(self.output_tokens_per_s, 1),
            "requests_per_s": round(self.requests_per_s, 3),
            "slo_attainment": round(self.slo_attainment, 4),
            "goodput_rps": round(self.goodput_rps, 3),
            "n_steps": self.n_steps,
            "steps_by_kind": dict(self.steps_by_kind),
            "utilization": self.utilization,
            "oracle_stats": self.oracle_stats,
        }


# ----------------------------------------------------------------------
@dataclass
class FleetReport:
    """Aggregate result of one workload replay through a replica fleet.

    Fleet-wide percentiles/goodput are computed over the union of every
    replica's finished requests against the fleet makespan, so they equal a
    hand-merge of the per-replica :class:`ServingReport`s (asserted in
    tests/test_fleet_sim.py).  ``goodput_rps`` is therefore *system-level*
    already — the explorer must not multiply it by a replica count the way
    it scales per-replica serving results (``system_level`` flags that).
    """
    n_requests: int
    makespan_s: float
    ttft_s: Percentiles
    tpot_ms: Percentiles
    e2e_s: Percentiles
    queue_delay_s: Percentiles
    prompt_tokens: int
    output_tokens: int
    tokens_per_s: float
    output_tokens_per_s: float
    requests_per_s: float
    slo: SLO | None
    slo_attainment: float
    goodput_rps: float
    n_steps: int
    steps_by_kind: dict
    router: str
    n_replicas: int                      # replicas constructed (incl. standby)
    replicas: tuple = field(default_factory=tuple)  # per-replica ServingReports
    replica_requests: dict = field(default_factory=dict)  # r<idx> -> n finished
    replica_utilization: dict = field(default_factory=dict)  # r<idx>/<pool>
    autoscaler_trace: tuple = field(default_factory=tuple)
    oracle_stats: dict = field(default_factory=dict)
    requests: tuple = field(default_factory=tuple)
    # replica fault injection (FleetSpec.faults): the seeded failure trace,
    # and how many queued/in-flight requests were displaced and rerouted
    failure_trace: tuple = field(default_factory=tuple)  # {t, replica} rows
    n_rerouted: int = 0

    system_level: ClassVar[bool] = True

    @property
    def n_replica_failures(self) -> int:
        return len(self.failure_trace)

    @property
    def utilization(self) -> dict:
        """Alias so fleet and single-replica reports expose the same lane
        map to :func:`repro.obs.explain.explain_serving`."""
        return self.replica_utilization

    def explain(self, top_k: int = 8) -> str:
        """Plain-text attribution — see :meth:`ServingReport.explain`."""
        from repro.obs.explain import render_serving
        return render_serving(self, top_k=top_k)

    def explain_dict(self, top_k: int = 8) -> dict:
        from repro.obs.explain import explain_serving
        return explain_serving(self, top_k=top_k)

    @staticmethod
    def build(finished_by: list, replicas: list, slo: SLO | None, router: str,
              autoscaler_trace: list, oracle_stats: dict, *,
              failure_trace: list | None = None,
              n_rerouted: int = 0) -> "FleetReport":
        """Merge per-replica finished-request lists into the fleet view.

        ``finished_by[i]`` holds the requests that *finished* on
        ``replicas[i]`` (disaggregated fleets attribute a request to its
        decode replica).  The per-replica :class:`ServingReport`s are built
        exactly as a standalone single-replica run would build them — same
        pool names, own makespan — which is what makes the round-robin
        fleet bit-identical to per-shard single runs.
        """
        per = tuple(ServingReport.build(reqs, rep.pools, slo, {})
                    for rep, reqs in zip(replicas, finished_by))
        reqs = [r for chunk in finished_by for r in chunk]
        t0 = min((r.arrival_s for r in reqs), default=0.0)
        t1 = max((r.finished_s for r in reqs), default=0.0)
        makespan = max(t1 - t0, 1e-12)
        prompt_toks = sum(r.prompt_len for r in reqs)
        out_toks = sum(r.output_len for r in reqs)
        attain = (sum(1 for r in reqs if slo.met(r)) / len(reqs)
                  if slo and reqs else 1.0)
        rps = len(reqs) / makespan
        steps_by_kind: dict[str, int] = {}
        util: dict[str, dict] = {}
        for rep in replicas:
            for p in rep.pools:
                for k, n in p.steps_by_kind.items():
                    steps_by_kind[k] = steps_by_kind.get(k, 0) + n
                u = {"busy_frac": round(p.busy_s / makespan, 4),
                     "steps": p.n_steps}
                for k, s in p.phase_s.items():
                    u[f"{k}_frac"] = round(s / makespan, 4)
                util[f"r{rep.index}/{p.name}"] = u
        return FleetReport(
            n_requests=len(reqs), makespan_s=makespan,
            ttft_s=Percentiles.of([r.ttft_s for r in reqs]),
            tpot_ms=Percentiles.of([r.tpot_ms for r in reqs]),
            e2e_s=Percentiles.of([r.e2e_s for r in reqs]),
            queue_delay_s=Percentiles.of([r.queue_delay_s for r in reqs]),
            prompt_tokens=prompt_toks, output_tokens=out_toks,
            tokens_per_s=(prompt_toks + out_toks) / makespan,
            output_tokens_per_s=out_toks / makespan,
            requests_per_s=rps, slo=slo, slo_attainment=attain,
            goodput_rps=attain * rps,
            n_steps=sum(p.n_steps for rep in replicas for p in rep.pools),
            steps_by_kind=steps_by_kind, router=router,
            n_replicas=len(replicas), replicas=per,
            replica_requests={f"r{rep.index}": len(chunk)
                              for rep, chunk in zip(replicas, finished_by)},
            replica_utilization=util,
            autoscaler_trace=tuple(autoscaler_trace),
            oracle_stats=oracle_stats, requests=tuple(reqs),
            failure_trace=tuple(failure_trace or ()), n_rerouted=n_rerouted)

    def summary(self) -> dict:
        """Flat dict for benchmarks / examples."""
        return {
            "n_requests": self.n_requests,
            "n_replicas": self.n_replicas,
            "router": self.router,
            "makespan_s": round(self.makespan_s, 3),
            "ttft_p50_s": round(self.ttft_s.p50, 4),
            "ttft_p99_s": round(self.ttft_s.p99, 4),
            "tpot_p50_ms": round(self.tpot_ms.p50, 3),
            "tpot_p99_ms": round(self.tpot_ms.p99, 3),
            "queue_delay_p50_s": round(self.queue_delay_s.p50, 4),
            "tokens_per_s": round(self.tokens_per_s, 1),
            "output_tokens_per_s": round(self.output_tokens_per_s, 1),
            "requests_per_s": round(self.requests_per_s, 3),
            "slo_attainment": round(self.slo_attainment, 4),
            "goodput_rps": round(self.goodput_rps, 3),
            "n_steps": self.n_steps,
            "steps_by_kind": dict(self.steps_by_kind),
            "replica_requests": dict(self.replica_requests),
            "autoscaler_actions": len(self.autoscaler_trace),
            "n_replica_failures": self.n_replica_failures,
            "n_rerouted": self.n_rerouted,
            "oracle_stats": self.oracle_stats,
        }

"""Fleet control plane: request routers and the queue-depth autoscaler.

Routers pick which replica an arriving request lands on; the autoscaler
grows and shrinks the serving set on the same deterministic event heap the
engines run on.  Both are pure functions of fleet state — no wall clocks,
no salted hashes — so fleet runs stay bit-reproducible.

Router ducks implement ``route(request, replicas, now) -> ReplicaPool``
where ``replicas`` is the non-empty list of currently routable targets.
The spec-facing form is :class:`~repro.api.spec.RouterSpec`;
:func:`make_router` turns either a spec or a bare name into an instance.
"""
from __future__ import annotations

_M64 = (1 << 64) - 1


def _mix(x: int) -> int:
    """splitmix64 finalizer: a deterministic 64-bit hash.  Session-affinity
    scores must not depend on Python's per-process str-hash salt, or fleet
    runs would stop being reproducible across processes."""
    x &= _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


class RoundRobinRouter:
    """Cycle through the routable replicas in arrival order.  With a fixed
    fleet this delivers replica ``i`` exactly ``Workload.shard(k, i)`` — the
    bit-identity the thinning-shim tests assert."""

    name = "round_robin"

    def __init__(self):
        self._i = 0

    def route(self, r, replicas, now: float):
        rep = replicas[self._i % len(replicas)]
        self._i += 1
        return rep


class LeastLoadedRouter:
    """Send each arrival to the replica with the fewest in-flight requests
    (queued + prefilling + running); ties break on replica index."""

    name = "least_loaded"

    def route(self, r, replicas, now: float):
        return min(replicas, key=lambda rep: (rep.load(), rep.index))


class SessionAffinityRouter:
    """Prefix-cache-aware routing: requests of one session always land on
    the same replica (rendezvous hashing over the routable set, so a scale
    event only remaps the sessions of the replicas it touched), keeping the
    session's shared prompt prefix warm in that replica's cache.
    Sessionless requests fall back to another policy."""

    name = "session_affinity"

    def __init__(self, fallback=None):
        self.fallback = fallback or LeastLoadedRouter()

    def route(self, r, replicas, now: float):
        if r.session < 0:
            return self.fallback.route(r, replicas, now)
        sess = (r.session & 0xFFFFFFFF) << 32
        return max(replicas,
                   key=lambda rep: (_mix(sess | (rep.index & 0xFFFFFFFF)),
                                    -rep.index))


_ROUTERS = {"round_robin": RoundRobinRouter, "least_loaded": LeastLoadedRouter,
            "session_affinity": SessionAffinityRouter}


def make_router(spec):
    """RouterSpec (or bare name) -> router instance."""
    kind = spec if isinstance(spec, str) else spec.kind
    if kind not in _ROUTERS:
        raise ValueError(f"unknown router {kind!r}; have {sorted(_ROUTERS)}")
    if kind == "session_affinity":
        fb = "least_loaded" if isinstance(spec, str) else spec.fallback
        if fb == "session_affinity":
            raise ValueError("session_affinity cannot be its own fallback")
        return SessionAffinityRouter(make_router(fb))
    return _ROUTERS[kind]()


class Autoscaler:
    """Queue-depth autoscaler with hysteresis, driven by ``AUTOSCALE`` ticks
    on the fleet's event heap.

    Every ``interval_s`` it samples the mean in-flight depth over the active
    serving replicas.  Above ``scale_up_queue`` it activates one standby
    replica, which starts taking traffic after ``provision_s`` (model boot +
    weight load); below ``scale_down_queue`` it deactivates the least-loaded
    active replica, which stops receiving routes but drains what it holds
    (so request conservation is unconditional).  ``cooldown_s`` between
    actions plus the up/down threshold gap is the hysteresis that keeps a
    flat trace from scale-thrashing — asserted in tests/test_fleet_sim.py.
    """

    def __init__(self, spec):
        self.spec = spec
        self.trace: list[dict] = []
        self._last_action_s = -float("inf")

    def tick(self, now: float, serve: list) -> None:
        sp = self.spec
        # failed replicas (fault injection) hold no load and must not
        # dilute the depth metric; getattr keeps bare test doubles working
        def up(rep) -> bool:
            return getattr(rep, "failed_until", 0.0) <= now

        active = [rep for rep in serve if rep.active and up(rep)]
        depth = sum(rep.load() for rep in active) / max(len(active), 1)
        action = None
        if now - self._last_action_s >= sp.cooldown_s:
            if depth > sp.scale_up_queue and len(active) < sp.max_replicas:
                # never provision a replica that is currently down
                standby = [rep for rep in serve
                           if not rep.active and up(rep)]
                if standby:
                    rep = min(standby, key=lambda x: x.index)
                    rep.active = True
                    rep.ready_at = now + sp.provision_s
                    action = f"scale_up:r{rep.index}"
            elif depth < sp.scale_down_queue and len(active) > sp.min_replicas:
                rep = min(active, key=lambda x: (x.load(), -x.index))
                rep.active = False
                action = f"scale_down:r{rep.index}"
        if action is not None:
            self._last_action_s = now
            self.trace.append({
                "t": round(now, 4), "action": action,
                "active": sum(1 for r in serve if r.active),
                "avg_depth": round(depth, 3)})

    @property
    def n_actions(self) -> int:
        return len(self.trace)

"""Pluggable batching policies for the serving event loop.

A policy turns pool state into the next engine iteration (a
:class:`StepPlan`); the event loop prices the plan through the step oracle
and applies its effects at completion time.  Policies see a ``pool`` duck
(see :class:`~repro.serving.sim.sim.Pool`) with:

* ``queue``            — waiting requests (FIFO ``deque``)
* ``running``          — decode-phase requests (hold a KV slot)
* ``prefilling``       — admitted requests whose prompt is (partially)
                         unprocessed
* ``pending_arrivals`` — arrivals not yet delivered, so static batching can
                         distinguish "wait for a full batch" from "drain the
                         tail of the trace"

``plan`` may mutate the pool's queues (admission) but never timestamps —
those belong to the event loop.  Returning ``None`` means "idle until the
next event".
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StepPlan:
    """One engine iteration: prompt chunks to prefill + sequences to decode."""
    kind: str                                      # prefill | decode | mixed
    prefill: list = field(default_factory=list)    # (SimRequest, chunk_tokens)
    decode: list = field(default_factory=list)     # SimRequests, 1 token each


class StaticBatching:
    """Gang scheduling: admit a batch, prefill it, decode until every member
    finishes, then admit the next batch.  A partial batch is admitted only
    when no further arrivals can top it up (end-of-trace drain)."""

    name = "static"

    def __init__(self, batch_size: int = 8):
        self.batch_size = batch_size

    def plan(self, pool, now: float) -> StepPlan | None:
        if pool.running:
            return StepPlan("decode", decode=list(pool.running))
        if pool.prefilling:                 # cohort prefill already planned
            return None
        if len(pool.queue) < self.batch_size and pool.pending_arrivals > 0:
            return None                     # wait for a full gang
        if not pool.queue:
            return None
        n = min(self.batch_size, len(pool.queue))
        admit = [pool.queue.popleft() for _ in range(n)]
        pool.prefilling.extend(admit)
        return StepPlan("prefill", prefill=[(r, r.prompt_len) for r in admit])


class ContinuousBatching:
    """vLLM-v0-style continuous batching: free KV slots are refilled from the
    queue every iteration; newly admitted requests run one batched full
    prefill step (decode pauses for it), then join the decode batch.
    ``admit_cap`` bounds admissions per step, limiting prefill stalls."""

    name = "continuous"

    def __init__(self, max_batch: int = 16, admit_cap: int | None = None):
        self.max_batch = max_batch
        self.admit_cap = admit_cap

    def plan(self, pool, now: float) -> StepPlan | None:
        free = self.max_batch - len(pool.running) - len(pool.prefilling)
        n = min(free, len(pool.queue), self.admit_cap or free)
        if n > 0:
            admit = [pool.queue.popleft() for _ in range(n)]
            pool.prefilling.extend(admit)
            return StepPlan("prefill",
                            prefill=[(r, r.prompt_len) for r in admit])
        if pool.running:
            return StepPlan("decode", decode=list(pool.running))
        return None


class ChunkedPrefill:
    """Sarathi-style chunked prefill: every iteration carries a token budget;
    each decode sequence costs one token and the remainder goes to the
    head-of-line prompt, so long prompts never stall decode for a whole
    prefill.  One prompt chunks at a time (FCFS)."""

    name = "chunked"

    def __init__(self, max_batch: int = 16, token_budget: int = 256):
        self.max_batch = max_batch
        self.token_budget = token_budget

    def plan(self, pool, now: float) -> StepPlan | None:
        decode = list(pool.running)
        if (not pool.prefilling and pool.queue
                and len(pool.running) + 1 <= self.max_batch):
            pool.prefilling.append(pool.queue.popleft())
        prefill = []
        budget = self.token_budget - len(decode)
        if pool.prefilling and budget > 0:
            head = pool.prefilling[0]
            chunk = min(budget, head.prompt_len - head.prefilled)
            if chunk > 0:
                prefill.append((head, chunk))
        if not decode and not prefill:
            return None
        kind = ("mixed" if decode and prefill
                else "prefill" if prefill else "decode")
        return StepPlan(kind, prefill=prefill, decode=decode)


class PrefillOnly:
    """FCFS batched full prefill — the prefill side of disaggregation."""

    name = "prefill_only"

    def __init__(self, batch_size: int = 1):
        self.batch_size = batch_size

    def plan(self, pool, now: float) -> StepPlan | None:
        if not pool.queue:
            return None
        n = min(self.batch_size, len(pool.queue))
        admit = [pool.queue.popleft() for _ in range(n)]
        pool.prefilling.extend(admit)
        return StepPlan("prefill", prefill=[(r, r.prompt_len) for r in admit])


class DecodeOnly:
    """Pure continuous decode — the decode side of disaggregation.  Arriving
    requests are already prefilled, so admission is free: the queue drains
    straight into the running batch whenever slots are open."""

    name = "decode_only"

    def __init__(self, max_batch: int = 16):
        self.max_batch = max_batch

    def plan(self, pool, now: float) -> StepPlan | None:
        while pool.queue and len(pool.running) < self.max_batch:
            pool.running.append(pool.queue.popleft())
        if pool.running:
            return StepPlan("decode", decode=list(pool.running))
        return None


@dataclass
class DisaggregatedPD:
    """Prefill/decode disaggregation: arrivals prefill on a dedicated pool,
    then migrate (paying a KV-transfer latency) to a decode pool running
    pure continuous decode.  Removes prefill/decode interference at the
    price of the transfer and a second set of chips; the event loop expands
    this descriptor into two pools."""

    prefill_batch: int = 1
    decode_batch: int = 16
    transfer_s: float = 0.002

    name = "disaggregated"


def make_policy(name: str, max_batch: int, *, token_budget: int = 256):
    """Name -> policy instance: the one registry shared by
    :class:`~repro.api.spec.ServingWorkload` and
    :class:`~repro.serving.sim.sim.ServingScenario` (policies that need
    richer construction, e.g. :class:`DisaggregatedPD`, are passed as
    objects instead of names)."""
    if name == "continuous":
        return ContinuousBatching(max_batch)
    if name == "chunked":
        return ChunkedPrefill(max_batch, token_budget=token_budget)
    if name == "static":
        return StaticBatching(max_batch)
    raise ValueError(f"unknown serving policy {name!r}")

"""Discrete-event core: a time-ordered queue with deterministic ties.

Five event kinds drive the serving simulation: request ``ARRIVAL`` into a
pool's queue (from the workload, or from a prefill pool migrating a request
to its decode pool), ``STEP_DONE`` (an engine iteration priced by the
step oracle completes), and — fleet runs only — ``AUTOSCALE`` (the
autoscaler samples queue depths and may grow or shrink the serving set),
``FAILURE`` (a replica's seeded fault process fires: its in-flight work is
lost and its requests reroute) and ``RECOVER`` (a failed replica rejoins).
Ties at equal timestamps break by insertion order (a monotone sequence
number), so runs are bit-reproducible.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

ARRIVAL = "arrival"
STEP_DONE = "step_done"
AUTOSCALE = "autoscale"
FAILURE = "failure"
RECOVER = "recover"


@dataclass(frozen=True)
class Event:
    time: float
    seq: int
    kind: str
    payload: tuple = field(default=())


class EventQueue:
    def __init__(self):
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0

    def push(self, time: float, kind: str, payload: tuple = ()) -> Event:
        ev = Event(float(time), self._seq, kind, payload)
        self._seq += 1
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

"""Step-time oracle: the core ``Simulator`` as a (mode, batch, context) pricer.

The event loop asks "how long does ONE engine iteration take?" thousands of
times per trace.  Answers repeat heavily once batch size and context length
are bucketed (rounded up to the next power of two), so misses — a full
``Simulator.simulate`` call on one replica — are rare and everything else is
served from the simulator's :class:`~repro.core.simcache.SimCache`
``serving`` bucket, which makes oracle hit rates visible in
``Simulator.cache_stats()`` next to every other cache layer.

Replica pricing: the oracle forces ``dp = pods = 1`` on the candidate's
:class:`~repro.core.passes.base.ParallelConfig` — the event loop models a
single engine instance, and the explorer's goodput objective splits the
workload over (and multiplies goodput back by) the replica count.  TP/PP/
EP/SP stay, so sharding and pipeline-latency effects are still priced.

Bucketing rounds *up*, so prices are mildly conservative (a batch of 9 pays
the batch-16 step); ``ctx_floor`` bounds the number of distinct context
buckets, which bounds cold JAX traces per sweep.

When the owning simulator has a persistent tier attached
(``Simulator(persist=dir)`` / ``CHARON_CACHE_DIR``), the ``serving`` bucket
— bucketed spec keys and their priced ``Report``s — survives across
processes, so a repeated serving benchmark replays its whole trace without
a single JAX trace; oracle misses additionally land in the cross-run
``reports`` tier via ``Simulator.run``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.api.spec import Cluster, DecodeWorkload, PrefillWorkload, SimSpec
from repro.configs.base import ModelConfig
from repro.core.passes.base import ParallelConfig
from repro.core.simulator import Simulator


def pow2_bucket(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor)."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


@dataclass
class StepOracle:
    sim: Simulator
    cfg: ModelConfig
    par: ParallelConfig = field(default_factory=ParallelConfig)
    ctx_floor: int = 256        # min context bucket (bounds distinct keys)
    seq_floor: int = 16         # min prefill-length bucket
    lookups: int = 0

    def __post_init__(self):
        self._par1 = replace(self.par, dp=1, pods=1, microbatches=1)
        self._cluster = Cluster(self.sim.hw)
        self._specs: dict[tuple, SimSpec] = {}
        self._price: dict[tuple, float] = {}
        self._raw: dict[tuple, float] = {}
        self._memo_ver = None       # engine state version the memos are for
        # sanitize mode (CHARON_SANITIZE / Simulator(sanitize=True)):
        # every memo fast-path hit is re-verified against the authoritative
        # serving-bucket price; off path is this one attribute check
        self._sanitize = bool(getattr(self.sim, "sanitize", False))

    @classmethod
    def from_spec(cls, sim: Simulator, spec) -> "StepOracle":
        """The oracle a serving/fleet run of ``spec`` prices through — one
        instance per run, shared by every replica of a fleet (replicas are
        identical engines, so their step prices are one bucketed table)."""
        return cls(sim, spec.model, spec.parallel,
                   ctx_floor=spec.workload.ctx_floor)

    def _spec_for(self, mode: str, B: int, S: int, cache_len: int) -> SimSpec:
        """Bucket tuple -> SimSpec, memoized: spec construction + the nested
        hash are not free and this sits on the per-engine-step hot path."""
        k = (mode, B, S, cache_len)
        spec = self._specs.get(k)
        if spec is None:
            wcls = DecodeWorkload if mode == "decode" else PrefillWorkload
            spec = SimSpec(self.cfg, cluster=self._cluster,
                           parallel=self._par1,
                           workload=wcls(global_batch=B, seq_len=S,
                                         cache_len=cache_len))
            self._specs[k] = spec
        return spec

    # ------------------------------------------------------------------
    def _memos_live(self, ver=None) -> bool:
        """The ``_raw``/``_price`` front memos are valid only while the sim
        cache is enabled and the engine state version is unchanged: a
        profile-DB put or prediction retrain evicts both wholesale (rather
        than keying each entry on the version, which would leak dead entries
        across retrains in long-lived simulators)."""
        if not self.sim.cache.enabled:
            return False
        if ver is None:
            ver = self.sim.engine._state_version()
        if ver != self._memo_ver:
            self._raw.clear()
            self._price.clear()
            self._memo_ver = ver
        return True

    def _priced_s(self, mode: str, B: int, S: int, cache_len: int) -> float:
        self.lookups += 1
        # fast path: hashing a nested frozen SimSpec costs ~15 us and a fleet
        # trace prices millions of steps, so repeat lookups resolve through a
        # plain bucket-tuple memo (_memos_live keeps invalidation intact)
        ver = self.sim.engine._state_version()
        fast = (mode, B, S, cache_len)
        if self._memos_live(ver):
            price = self._price.get(fast)
            if price is not None:
                self.sim.cache.stats["serving"].hits += 1  # semantically a hit
                if self._sanitize:
                    self._verify_memo("_price", fast, price, ver)
                return price
        spec = self._spec_for(mode, B, S, cache_len)
        # the bucketed spec IS the cache key; the engine state version rides
        # along so a profile-DB put or prediction retrain can never serve a
        # stale priced Report (same invalidation as block_times)
        key = (spec, ver)
        rep = self.sim.cache.get("serving", key, lambda: self.sim.run(spec))
        price = rep.step_time_us / 1e6
        if self.sim.cache.enabled:
            self._price[fast] = price
        return price

    def _raw_hit(self, key: tuple) -> float | None:
        """Pre-bucketing memo on raw (mode, batch, ctx) keys: a fleet trace
        repeats raw shapes millions of times, and even the bucket arithmetic
        + bucketed-key lookup is measurable at that rate."""
        if not self._memos_live():
            return None
        price = self._raw.get(key)
        if price is not None:
            self.lookups += 1
            self.sim.cache.stats["serving"].hits += 1   # semantically a hit
        return price

    def _verify_memo(self, memo: str, key: tuple, price: float,
                     ver=None) -> None:
        """Sanitize-mode cross-check: recompute *key*'s price through the
        authoritative serving-bucket path and require an exact match with
        the memoized value (a mismatch means a memo survived state it
        should not have — the PR 6 oracle-leak class, at runtime)."""
        if memo == "_raw":
            mode, n, length = key
            B = pow2_bucket(n)
            if mode == "decode":
                C = pow2_bucket(length, self.ctx_floor)
                fresh = self._priced_s("decode", B, C, C)
            else:
                S = pow2_bucket(length, self.seq_floor)
                fresh = self._priced_s("prefill", B, S, 0)
        else:
            mode, B, S, cache_len = key
            if ver is None:
                ver = self.sim.engine._state_version()
            spec = self._spec_for(mode, B, S, cache_len)
            rep = self.sim.cache.get("serving", (spec, ver),
                                     lambda: self.sim.run(spec))
            fresh = rep.step_time_us / 1e6
        if fresh != price:
            from repro.analysis.sanitize import CacheSanitizerError
            raise CacheSanitizerError(f"oracle.{memo}", key,
                                      repr(price), repr(fresh))

    def decode_step_s(self, batch: int, ctx: int) -> float:
        """One decode iteration: ``batch`` sequences, deepest context ``ctx``."""
        key = ("decode", batch, ctx)
        price = self._raw_hit(key)
        if price is None:
            B = pow2_bucket(batch)
            C = pow2_bucket(ctx, self.ctx_floor)
            price = self._priced_s("decode", B, C, C)
            if self.sim.cache.enabled:
                self._raw[key] = price
        elif self._sanitize:
            self._verify_memo("_raw", key, price)
        return price

    def prefill_s(self, batch: int, seq: int) -> float:
        """One batched prefill of ``batch`` prompts padded to ``seq`` tokens."""
        key = ("prefill", batch, seq)
        price = self._raw_hit(key)
        if price is None:
            B = pow2_bucket(batch)
            S = pow2_bucket(seq, self.seq_floor)
            price = self._priced_s("prefill", B, S, 0)
            if self.sim.cache.enabled:
                self._raw[key] = price
        elif self._sanitize:
            self._verify_memo("_raw", key, price)
        return price

    def mixed_step_s(self, n_decode: int, ctx: int, chunk_tokens: int) -> float:
        """Chunked-prefill iteration: a prompt chunk plus a decode batch.

        Priced as chunk-prefill + decode serialized within the iteration —
        an upper bound (a fused mixed kernel would overlap some of the two),
        conservative in the same direction as the bucket rounding."""
        t = self.prefill_s(1, chunk_tokens)
        if n_decode > 0:
            t += self.decode_step_s(n_decode, ctx)
        return t

    # ------------------------------------------------------------------
    @property
    def n_distinct_steps(self) -> int:
        """Distinct bucketed step specs priced so far — the number of
        potentially-cold full simulations a whole trace boils down to."""
        return len(self._specs)

    def stats(self) -> dict:
        """Cumulative serving-bucket hit/miss counters of the owning sim."""
        return dict(self.sim.cache_stats().get("serving", {}))

"""Request-level serving simulator (discrete-event continuous batching).

Layers a discrete-event request/queueing model on top of the core
:class:`~repro.core.simulator.Simulator`: workloads (synthetic or
trace-driven) flow through pluggable batching policies; every engine
iteration is priced by the step-time oracle and the event loop aggregates
TTFT/TPOT/goodput into a :class:`ServingReport`.  The fleet layer
(:class:`FleetSimulator`) runs N replica engines behind a router with
optional autoscaling and aggregates a :class:`FleetReport`.  See
``docs/serving.md``.
"""
from repro.serving.sim.events import (
    ARRIVAL, AUTOSCALE, STEP_DONE, Event, EventQueue,
)
from repro.serving.sim.oracle import StepOracle, pow2_bucket
from repro.serving.sim.policies import (
    ChunkedPrefill, ContinuousBatching, DecodeOnly, DisaggregatedPD,
    PrefillOnly, StaticBatching, StepPlan,
)
from repro.serving.sim.report import (
    SLO, FleetReport, Percentiles, ServingReport,
)
from repro.serving.sim.router import (
    Autoscaler, LeastLoadedRouter, RoundRobinRouter, SessionAffinityRouter,
    make_router,
)
from repro.serving.sim.sim import (
    FleetSimulator, Pool, ReplicaPool, ServingScenario, ServingSimulator,
    make_pools, price_step_s,
)
from repro.serving.sim.workload import (
    LengthDist, SimRequest, VirtualClock, Workload, synthesize, wall_clock,
)

__all__ = [
    "ARRIVAL", "AUTOSCALE", "STEP_DONE", "Event", "EventQueue",
    "StepOracle", "pow2_bucket",
    "ChunkedPrefill", "ContinuousBatching", "DecodeOnly", "DisaggregatedPD",
    "PrefillOnly", "StaticBatching", "StepPlan",
    "SLO", "FleetReport", "Percentiles", "ServingReport",
    "Autoscaler", "LeastLoadedRouter", "RoundRobinRouter",
    "SessionAffinityRouter", "make_router",
    "FleetSimulator", "Pool", "ReplicaPool", "ServingScenario",
    "ServingSimulator", "make_pools", "price_step_s",
    "LengthDist", "SimRequest", "VirtualClock", "Workload", "synthesize",
    "wall_clock",
]

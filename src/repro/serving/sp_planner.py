"""Dynamic sequence-parallel planning (paper §5.1 case study).

Zigzag attention splits a sequence into 2*SP chunks assigned pairwise
(chunk i and 2*SP-1-i to rank i) so causal work balances.  For short
requests, wide SP over-partitions: the all-gather overhead outweighs the
compute saving.  The planner assigns a *per-request* SP configuration inside
a batch by minimising simulated per-rank attention latency (compute from the
causal-flops share + the collective model for the gathers) — reproducing the
paper's ~15 % attention-latency win over static zigzag.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.backend.collectives import collective_time_us
from repro.core.backend.hardware import HardwareSpec, TPU_V5E


@dataclass
class SPChoice:
    sp: int
    zigzag: bool
    latency_us: float


def zigzag_rank_flops(S: int, sp: int, d_head: int, n_heads: int) -> float:
    """Per-rank causal attention flops under zigzag partitioning: each rank
    owns chunks (i, 2sp-1-i) of length S/(2sp) -> balanced ~ total/sp."""
    total = 2.0 * 2.0 * (S * S / 2) * d_head * n_heads  # qk + pv over causal half
    return total / sp


def naive_rank_flops(S: int, sp: int, d_head: int, n_heads: int) -> float:
    """Contiguous partitioning: the LAST rank does the most causal work."""
    chunk = S / sp
    # rank r attends rows (r*chunk, (r+1)*chunk) over cols <= row
    worst_rows = (S * S - (S - chunk) * (S - chunk)) / 2
    return 2.0 * 2.0 * worst_rows * d_head * n_heads


def attention_latency_us(S: int, sp: int, *, zigzag: bool, d_head: int,
                         n_heads: int, hw: HardwareSpec = TPU_V5E,
                         dtype_bytes: int = 2, eff: float = 0.5) -> float:
    """Per-request prefill attention latency at the given SP width."""
    flops = (zigzag_rank_flops if zigzag else naive_rank_flops)(
        S, sp, d_head, n_heads)
    t_comp = flops / (hw.flops_for("bf16") * eff) * 1e6
    t_comm = 0.0
    if sp > 1:
        kv_bytes = 2 * S * n_heads * d_head * dtype_bytes  # K and V
        t_comm = collective_time_us("all_gather", kv_bytes, sp, hw.intra)
    return t_comp + t_comm


def plan_request(S: int, *, d_head: int, n_heads: int, max_sp: int = 8,
                 hw: HardwareSpec = TPU_V5E) -> SPChoice:
    """Best (sp, zigzag) for one request."""
    best: SPChoice | None = None
    sp = 1
    while sp <= max_sp:
        for zz in ((False,) if sp == 1 else (False, True)):
            t = attention_latency_us(S, sp, zigzag=zz, d_head=d_head,
                                     n_heads=n_heads, hw=hw)
            if best is None or t < best.latency_us:
                best = SPChoice(sp, zz, t)
        sp *= 2
    return best


@dataclass
class BatchPlan:
    choices: list[SPChoice]
    makespan_us: float


def plan_batch(seq_lens: list[int], *, d_head: int, n_heads: int,
               sp_world: int = 8, hw: HardwareSpec = TPU_V5E,
               dynamic: bool = True) -> BatchPlan:
    """Assign per-request SP configs and pack onto ``sp_world`` ranks.

    Static zigzag baseline: every request at sp_world with zigzag.  Dynamic:
    per-request best choice, then LPT packing of the per-request rank-work
    onto ranks (requests with sp < world run concurrently side by side)."""
    if not dynamic:
        choices = [SPChoice(sp_world, True,
                            attention_latency_us(S, sp_world, zigzag=True,
                                                 d_head=d_head, n_heads=n_heads, hw=hw))
                   for S in seq_lens]
        # all requests serialise across the full SP group
        return BatchPlan(choices, sum(c.latency_us for c in choices))
    choices = [plan_request(S, d_head=d_head, n_heads=n_heads,
                            max_sp=sp_world, hw=hw) for S in seq_lens]
    # LPT bin-pack: each request occupies `sp` ranks for `latency` time
    rank_free = [0.0] * sp_world
    for c in sorted(choices, key=lambda c: -c.latency_us):
        # choose the sp-sized window of ranks with the earliest availability
        best_start, best_t = 0, math.inf
        for start in range(0, sp_world - c.sp + 1):
            t = max(rank_free[start:start + c.sp])
            if t < best_t:
                best_t, best_start = t, start
        for r in range(best_start, best_start + c.sp):
            rank_free[r] = best_t + c.latency_us
    return BatchPlan(choices, max(rank_free))

"""repro — Charon-JAX: unified fine-grained LLM training/inference simulator
plus the JAX/TPU substrate it simulates (model zoo, distributed training,
serving, Pallas kernels, multi-pod launcher)."""

__version__ = "1.0.0"

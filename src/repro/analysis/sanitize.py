"""Runtime cache-poisoning detector and determinism harness.

Layer 2 of the correctness tooling (layer 1 is :mod:`repro.analysis.lint`).
Two pieces:

* :class:`SanitizingSimCache` — a drop-in :class:`~repro.core.simcache.
  SimCache` that fingerprints every cached value with a deep structural
  hash at insert and re-verifies the fingerprint on every hit.  Any
  in-place mutation of a cached value — the aliasing class charon-lint R1
  hunts statically — raises :class:`CacheSanitizerError` naming the
  offending bucket and key.  Enabled via ``CHARON_SANITIZE=1`` or
  ``Simulator(sanitize=True)``; the off path stays exactly one attribute
  check (the default ``SimCache`` has no fingerprinting code at all).

* :func:`check_determinism` — runs a spec cold, warm (cached vs cold),
  cache-disabled, and through a pickle round-trip, and diffs the four
  reports field-by-field with exact float equality.  Catches
  nondeterminism the linter cannot see (set-order leaks through data,
  process-salted hashes in persisted state — the PR 5 class).

This module imports the simulation stack lazily so ``repro.analysis``
stays importable in a bare CI job.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
from typing import Any

from repro.core.simcache import SimCache

__all__ = ["CacheSanitizerError", "DeterminismError", "DeterminismReport",
           "SanitizingSimCache", "check_determinism", "sanitize_enabled",
           "structural_fingerprint"]


def sanitize_enabled() -> bool:
    """True when the CHARON_SANITIZE env knob requests sanitizing."""
    return os.environ.get("CHARON_SANITIZE", "") not in ("", "0")


# ------------------------------------------------------------ fingerprint

def structural_fingerprint(value: Any) -> str:
    """Deep structural hash of *value* — dataclasses, dicts, sequences,
    sets, numpy arrays and scalars all contribute typed tokens, so any
    in-place mutation anywhere in the object graph changes the digest.

    Shared substructure is fine; genuinely cyclic graphs fall back to a
    stable per-path marker rather than recursing forever.
    """
    h = hashlib.blake2b(digest_size=16)
    _feed(h, value, seen=set())
    return h.hexdigest()


def _feed(h, value: Any, seen: set) -> None:
    # cycle guard: mark revisits of an object already on the current path
    if isinstance(value, (dict, list, set, tuple)) \
            or dataclasses.is_dataclass(value):
        vid = id(value)
        if vid in seen:
            h.update(b"<cycle>")
            return
        seen = seen | {vid}

    if value is None or isinstance(value, (bool, int, str, bytes)):
        h.update(f"{type(value).__name__}:{value!r};".encode())
    elif isinstance(value, float):
        # exact bit pattern (repr round-trips doubles; nan/inf included)
        h.update(f"f:{value!r};".encode())
    elif dataclasses.is_dataclass(value) and not isinstance(value, type):
        h.update(f"dc:{type(value).__name__}(".encode())
        for f in dataclasses.fields(value):
            h.update(f.name.encode() + b"=")
            _feed(h, getattr(value, f.name, None), seen)
        h.update(b");")
    elif isinstance(value, dict):
        h.update(b"dict(")
        # entry fingerprints sorted so dicts differing only in insertion
        # order (still equal) fingerprint identically
        entries = []
        for k, v in value.items():
            eh = hashlib.blake2b(digest_size=16)
            _feed(eh, k, seen)
            eh.update(b"->")
            _feed(eh, v, seen)
            entries.append(eh.digest())
        for d in sorted(entries):
            h.update(d)
        h.update(b");")
    elif isinstance(value, (list, tuple)):
        h.update(f"{type(value).__name__}(".encode())
        for v in value:
            _feed(h, v, seen)
        h.update(b");")
    elif isinstance(value, (set, frozenset)):
        h.update(f"{type(value).__name__}(".encode())
        entries = []
        for v in value:
            eh = hashlib.blake2b(digest_size=16)
            _feed(eh, v, seen)
            entries.append(eh.digest())
        for d in sorted(entries):
            h.update(d)
        h.update(b");")
    elif type(value).__module__ == "numpy":
        import numpy as np
        arr = np.asarray(value)
        h.update(f"np:{arr.dtype}:{arr.shape}:".encode())
        h.update(np.ascontiguousarray(arr).tobytes())
        h.update(b";")
    else:
        # opaque object: repr is the best stable surface available
        h.update(f"obj:{type(value).__name__}:{value!r};".encode())


# ------------------------------------------------------------ sanitizer

class CacheSanitizerError(RuntimeError):
    """A cached value's structural fingerprint changed between insert and a
    later hit — someone mutated a cache-fetched value in place."""

    def __init__(self, bucket: str, key: Any, stored: str, now: str):
        self.bucket = bucket
        self.key = key
        self.stored_fingerprint = stored
        self.current_fingerprint = now
        super().__init__(
            f"cache poisoning detected in bucket {bucket!r}, key {key!r}: "
            f"value fingerprint changed {stored} -> {now} since insert; a "
            "consumer mutated a cached value in place (see charon-lint R1 "
            "and docs/static-analysis.md)")


class SanitizingSimCache(SimCache):
    """SimCache that verifies cached values were never mutated in place.

    Fingerprints are recorded at miss (insert) and at the first hit of an
    entry merged from the persistent tier, then re-verified on every
    subsequent hit.  The fingerprint table lives beside the data buckets
    and never pickles into the persistent tier.
    """

    def __init__(self, enabled: bool = True):
        super().__init__(enabled)
        self._fps: dict[str, dict] = {b: {} for b in self.BUCKETS}

    def get(self, bucket: str, key: Any, build):
        if not self.enabled:
            return build()
        d = self._data[bucket]
        st = self.stats[bucket]
        try:
            hit = key in d
        except TypeError:           # unhashable key component: skip caching
            return build()
        fps = self._fps[bucket]
        if hit:
            st.hits += 1
            v = d[key]
            now = structural_fingerprint(v)
            stored = fps.get(key)
            if stored is None:
                # first sighting of a persisted-tier entry
                fps[key] = now
            elif now != stored:
                raise CacheSanitizerError(bucket, key, stored, now)
            return v
        st.misses += 1
        v = build()
        d[key] = v
        fps[key] = structural_fingerprint(v)
        return v

    def clear(self) -> None:
        super().clear()
        self._fps = {b: {} for b in self.BUCKETS}


# ------------------------------------------------------------ determinism

class DeterminismError(AssertionError):
    """check_determinism(..., raise_on_mismatch=True) found a diff."""


@dataclasses.dataclass(frozen=True)
class DeterminismReport:
    """Outcome of :func:`check_determinism`: per-variant field diffs
    against the cold baseline run."""
    ok: bool
    variants: tuple                       # variant names compared
    mismatches: tuple                     # (variant, field_path, a, b)
    ignored_fields: tuple

    def render(self) -> str:
        if self.ok:
            return ("determinism check ok: " + ", ".join(self.variants)
                    + " all bit-identical to the cold run")
        lines = [f"determinism check FAILED "
                 f"({len(self.mismatches)} field diff(s)):"]
        for variant, path, a, b in self.mismatches:
            lines.append(f"  [{variant}] {path}: {a!r} != {b!r}")
        return "\n".join(lines)


# counter-like surfaces legitimately differing between warm and cold runs
_TELEMETRY_FIELDS = frozenset({"oracle_stats"})


def diff_values(a: Any, b: Any, path: str = "report",
                ignore: frozenset = _TELEMETRY_FIELDS) -> list:
    """Recursive field-by-field diff with exact float equality (nan==nan).
    Returns (path, a, b) rows; empty means bit-identical."""
    out: list = []
    if dataclasses.is_dataclass(a) and not isinstance(a, type) \
            and type(a) is type(b):
        for f in dataclasses.fields(a):
            if f.name in ignore:
                continue
            out.extend(diff_values(getattr(a, f.name), getattr(b, f.name),
                                   f"{path}.{f.name}", ignore))
    elif isinstance(a, dict) and isinstance(b, dict):
        for k in a.keys() | b.keys():
            if k in ignore:
                continue
            ka, kb = a.get(k, "<missing>"), b.get(k, "<missing>")
            out.extend(diff_values(ka, kb, f"{path}[{k!r}]", ignore))
    elif isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        if len(a) != len(b):
            out.append((path, f"len={len(a)}", f"len={len(b)}"))
        else:
            for i, (va, vb) in enumerate(zip(a, b)):
                out.extend(diff_values(va, vb, f"{path}[{i}]", ignore))
    elif isinstance(a, float) and isinstance(b, float):
        same = (a == b) or (a != a and b != b)   # exact; nan == nan
        if not same:
            out.append((path, a, b))
    elif a != b:
        out.append((path, a, b))
    return out


def _run_spec(spec, *, cache: bool, engine: str, sim=None):
    """Price *spec* on the right simulator for its workload mode."""
    from repro.core.simulator import Simulator
    if sim is None:
        sim = Simulator(spec.cluster.resolve(), engine=engine, cache=cache)
    if getattr(spec.workload, "mode", None) == "serving":
        from repro.serving.sim import ServingSimulator
        return ServingSimulator(sim).run(spec), sim
    if getattr(spec, "resilience", None) is not None:
        from repro.resilience import ResilienceSimulator
        return ResilienceSimulator(sim).run(spec), sim
    return sim.run(spec), sim


def check_determinism(spec, *, engine: str = "analytical",
                      raise_on_mismatch: bool = False) -> DeterminismReport:
    """Run *spec* four ways and require bit-identical reports:

    * ``cold``      — fresh simulator, empty caches (the baseline)
    * ``warm``      — the same simulator again, everything cache-hit
    * ``uncached``  — fresh simulator with ``cache=False``
    * ``pickled``   — fresh simulator fed ``pickle.loads(pickle.dumps(
      spec))``, catching process-salted state leaking into the spec
      (the PR 5 ``__getstate__`` class)

    Telemetry counters (``oracle_stats``) are excluded: they legitimately
    differ between warm and cold runs.
    """
    base, sim = _run_spec(spec, cache=True, engine=engine)
    variants = {
        "warm": _run_spec(spec, cache=True, engine=engine, sim=sim)[0],
        "uncached": _run_spec(spec, cache=False, engine=engine)[0],
        "pickled": _run_spec(pickle.loads(pickle.dumps(spec)),
                             cache=True, engine=engine)[0],
    }
    mismatches: list = []
    for name, rep in variants.items():
        for path, a, b in diff_values(base, rep):
            mismatches.append((name, path, a, b))
    report = DeterminismReport(ok=not mismatches,
                               variants=tuple(variants),
                               mismatches=tuple(mismatches),
                               ignored_fields=tuple(sorted(
                                   _TELEMETRY_FIELDS)))
    if raise_on_mismatch and not report.ok:
        raise DeterminismError(report.render())
    return report

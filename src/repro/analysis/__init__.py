"""Correctness tooling for the Charon repro.

Two layers:

* :mod:`repro.analysis.lint` — charon-lint, an AST-based static analyzer
  (stdlib ``ast`` only) encoding the repo-specific invariants R1-R5; run it
  as ``python -m repro.analysis.lint src/``.
* :mod:`repro.analysis.sanitize` — runtime cache-poisoning detector
  (``CHARON_SANITIZE=1`` / ``Simulator(sanitize=True)``) and the
  :func:`check_determinism` harness.

This package must stay importable without jax: the lint CLI runs in a bare
CI job.  Keep heavy imports inside :mod:`repro.analysis.sanitize`.
"""
from __future__ import annotations

__all__ = ["lint", "sanitize"]

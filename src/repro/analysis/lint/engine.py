"""charon-lint driver: parse files, run rules, apply disable comments.

The engine is deliberately tiny — rules do the real work.  It owns three
jobs:

* walking the requested paths and parsing each ``.py`` file once into a
  :class:`ParsedModule` (AST + raw lines + parent links),
* normalizing paths so rule *scopes* ("core/", "serving/sim/", ...) match
  both the real tree (``src/repro/core/overlap.py``) and test fixtures laid
  out under a temp dir (``/tmp/x/core/bad.py``),
* honoring inline ``# charon-lint: disable=R2`` / ``disable=R1,R4``
  comments: a finding whose line (or whose statement's first line) carries a
  matching disable marker is demoted to *disabled* — reported and counted,
  never failing the run.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path

from .report import Finding, LintReport

_DISABLE_RE = re.compile(r"#\s*charon-lint:\s*disable=([A-Z0-9,\s]+)")

# path components stripped from the left so rule scopes are package-relative
_STRIP_PREFIXES = ("src", "repro")


def _normalize_rel(path: Path, root: Path) -> str:
    try:
        rel = path.resolve().relative_to(root.resolve())
    except ValueError:
        rel = Path(path.name)
    parts = list(rel.parts)
    while parts and parts[0] in _STRIP_PREFIXES:
        parts.pop(0)
    return "/".join(parts)


def parse_disables(lines: list) -> dict:
    """Map 1-based line number -> set of rule IDs disabled on that line."""
    out: dict[int, set] = {}
    for i, line in enumerate(lines, start=1):
        m = _DISABLE_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out[i] = rules
    return out


@dataclass
class ParsedModule:
    """One parsed source file handed to every rule."""
    path: Path                  # real filesystem path
    rel: str                    # scope-normalized posix-ish relative path
    tree: ast.AST
    lines: list
    disables: dict = field(default_factory=dict)

    def __post_init__(self):
        # parent links let rules look outward from a node (e.g. "is this
        # id() call inside a subscript key?") without threading state.
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._charon_parent = node  # type: ignore[attr-defined]

    def in_scope(self, scopes) -> bool:
        """True if this module falls under any of the given scope prefixes.

        A scope ending in ``/`` is a directory prefix; otherwise an exact
        file match.  ``()`` means all files.
        """
        if not scopes:
            return True
        for s in scopes:
            if s.endswith("/"):
                if self.rel.startswith(s):
                    return True
            elif self.rel == s:
                return True
        return False

    def disabled_at(self, line: int, rule: str) -> bool:
        rules = self.disables.get(line)
        return bool(rules) and rule in rules


def parent(node: ast.AST):
    return getattr(node, "_charon_parent", None)


def iter_py_files(paths):
    for p in paths:
        p = Path(p)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def run_lint(paths, rules=None, root: Path | None = None) -> LintReport:
    """Lint every ``.py`` under *paths* with *rules* (default: all).

    *root* anchors path normalization; defaults to the common parent so
    fixture trees behave like the real one.
    """
    from .rules import ALL_RULES
    rules = list(rules) if rules is not None else [cls() for cls in ALL_RULES]

    files = list(iter_py_files(paths))
    if root is None:
        root = Path(paths[0]) if files else Path(".")
        if root.is_file():
            root = root.parent
    findings: list[Finding] = []
    errors: list = []
    for path in files:
        try:
            text = path.read_text()
            tree = ast.parse(text, filename=str(path))
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            errors.append((str(path), str(e)))
            continue
        lines = text.splitlines()
        mod = ParsedModule(path=path, rel=_normalize_rel(path, root),
                           tree=tree, lines=lines,
                           disables=parse_disables(lines))
        for rule in rules:
            if not mod.in_scope(rule.scopes):
                continue
            for f in rule.check(mod):
                if mod.disabled_at(f.line, f.rule):
                    f = Finding(**{**f.as_dict(), "disabled": True})
                findings.append(f)
    return LintReport(findings=tuple(findings), n_files=len(files),
                      errors=tuple(errors))

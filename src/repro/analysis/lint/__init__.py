"""charon-lint: AST-based static analysis for Charon-specific invariants."""
from __future__ import annotations

from .engine import ParsedModule, run_lint
from .report import Finding, LintReport
from .rules import ALL_RULES, RULES_BY_ID

__all__ = ["ParsedModule", "run_lint", "Finding", "LintReport",
           "ALL_RULES", "RULES_BY_ID", "main"]


def main(argv=None) -> int:
    from .__main__ import main as _main
    return _main(argv)

"""CLI entry point: ``python -m repro.analysis.lint src/``.

Exit status 0 iff there are no *active* findings and every file parsed.
Disabled findings (``# charon-lint: disable=RN``) never fail the run but
are counted loudly in the summary.
"""
from __future__ import annotations

import argparse
import sys

from .engine import run_lint
from .rules import ALL_RULES, RULES_BY_ID


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="charon-lint: enforce Charon repro invariants R1-R6")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to scan (e.g. src/)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rule IDs (default all)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the report as JSON")
    args = ap.parse_args(argv)

    if args.rules:
        ids = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in ids if r not in RULES_BY_ID]
        if unknown:
            ap.error(f"unknown rule id(s): {', '.join(unknown)} "
                     f"(known: {', '.join(sorted(RULES_BY_ID))})")
        rules = [RULES_BY_ID[r]() for r in ids]
    else:
        rules = [cls() for cls in ALL_RULES]

    report = run_lint(args.paths, rules=rules)
    print(report.to_json() if args.as_json else report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())

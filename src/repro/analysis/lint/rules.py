"""charon-lint rules R1-R6.

Each rule encodes one invariant this repo keeps re-fixing by hand (see
docs/static-analysis.md for the catalog with the real past bug behind each
rule).  Rules are AST-only — stdlib ``ast``, no imports of the code under
scan — so the linter runs on any tree, including broken ones, and in CI
without jax installed.

Scope strings are package-relative paths (``core/`` matches
``src/repro/core/...`` and a fixture tree's ``core/...`` alike — see
``engine._normalize_rel``).
"""
from __future__ import annotations

import ast

from .engine import ParsedModule, parent
from .report import Finding

# ---------------------------------------------------------------- helpers

_FUNC_SCOPES = (ast.FunctionDef, ast.AsyncFunctionDef)


def import_aliases(tree: ast.AST) -> dict:
    """Map local binding name -> dotted origin ("np" -> "numpy")."""
    amap: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    amap[a.asname] = a.name
                else:
                    top = a.name.split(".")[0]
                    amap[top] = top
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                amap[a.asname or a.name] = f"{node.module}.{a.name}"
    return amap


def dotted(node: ast.AST, amap: dict) -> str | None:
    """Resolve a Name/Attribute chain to a dotted origin name, or None."""
    parts: list[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(amap.get(cur.id, cur.id))
        return ".".join(reversed(parts))
    return None


def scope_children(scope: ast.AST):
    """Yield nodes belonging to *scope* without descending into nested
    function/class/lambda scopes."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _FUNC_SCOPES + (ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def iter_scopes(tree: ast.AST):
    """Yield every lexical scope root: the module and each function."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, _FUNC_SCOPES):
            yield node


class Rule:
    id = "R?"
    title = ""
    fixit = ""
    scopes: tuple = ()

    def finding(self, mod: ParsedModule, node: ast.AST, message: str,
                fixit: str | None = None) -> Finding:
        return Finding(rule=self.id, title=self.title, path=mod.rel,
                       line=getattr(node, "lineno", 1), message=message,
                       fixit=self.fixit if fixit is None else fixit)

    def check(self, mod: ParsedModule):  # pragma: no cover - interface
        raise NotImplementedError


# ---------------------------------------------------------------- R1

def _is_cache_get(node: ast.AST) -> bool:
    """A SimCache-style ``<obj>.get(bucket, key, build)`` 3-arg call with a
    string-literal bucket.  ``dict.get(key, default)`` never has 3 args, so
    this shape is a reliable discriminator."""
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "get"
            and len(node.args) == 3
            and isinstance(node.args[0], ast.Constant)
            and isinstance(node.args[0].value, str))


def _mutable_ctor(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set,
                         ast.ListComp, ast.DictComp, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("list", "dict", "set", "bytearray",
                                 "defaultdict"))


_MUTATORS = {"append", "extend", "update", "pop", "popitem", "clear",
             "setdefault", "add", "remove", "discard", "insert", "sort",
             "reverse"}


def _chain_root(node: ast.AST) -> ast.Name | None:
    """Root Name of an attribute/subscript access chain
    (``rep.kind_us["matmul"]`` -> ``rep``), or None."""
    cur = node
    while isinstance(cur, (ast.Attribute, ast.Subscript)):
        cur = cur.value
    return cur if isinstance(cur, ast.Name) else None


class CacheAliasRule(Rule):
    """R1: values fetched from a cache bucket must not be returned as
    aliased mutable containers, and must never be mutated in place."""
    id = "R1"
    title = "cache-alias"
    fixit = ("return an immutable value (tuple/frozen dataclass) from the "
             "cache build fn, or copy before returning; never mutate a "
             "cache-fetched value in place")
    scopes = ()  # everywhere

    def check(self, mod: ParsedModule):
        # module-level map of function name -> def node, for resolving
        # build callbacks passed by name
        defs = {n.name: n for n in ast.walk(mod.tree)
                if isinstance(n, _FUNC_SCOPES)}

        def build_is_mutable(call: ast.Call) -> bool:
            build = call.args[2]
            if isinstance(build, ast.Lambda):
                return _mutable_ctor(build.body)
            if isinstance(build, ast.Name) and build.id in defs:
                fn = defs[build.id]
                return any(_mutable_ctor(r.value)
                           for r in ast.walk(fn)
                           if isinstance(r, ast.Return) and r.value)
            return False

        for scope in iter_scopes(mod.tree):
            # names bound directly to a cache get() result in this scope
            cached: dict[str, ast.Call] = {}
            for node in scope_children(scope):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and _is_cache_get(node.value)):
                    cached[node.targets[0].id] = node.value

            for node in scope_children(scope):
                if isinstance(node, ast.Return) and node.value is not None:
                    v = node.value
                    call = None
                    if _is_cache_get(v):
                        call = v
                    elif isinstance(v, ast.Name) and v.id in cached:
                        call = cached[v.id]
                    if call is not None and build_is_mutable(call):
                        yield self.finding(
                            mod, node,
                            "returns a cache-fetched mutable container; "
                            "callers can mutate the cached value in place "
                            "(the PR 8 MemoryReport.timeline aliasing bug)")
                # in-place mutation of a cache-fetched name
                tgt = None
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    tgts = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in tgts:
                        if isinstance(t, (ast.Subscript, ast.Attribute)):
                            root = _chain_root(t)
                            if root is not None and root.id in cached:
                                tgt = t
                elif isinstance(node, ast.Delete):
                    for t in node.targets:
                        if isinstance(t, ast.Subscript):
                            root = _chain_root(t)
                            if root is not None and root.id in cached:
                                tgt = t
                elif (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in _MUTATORS):
                    root = _chain_root(node.func.value)
                    if root is not None and root.id in cached:
                        tgt = node
                if tgt is not None:
                    yield self.finding(
                        mod, node,
                        "mutates a cache-fetched value in place; the "
                        "mutation poisons the shared cache entry")


# ---------------------------------------------------------------- R2

_EPOCH_CALLS = {
    "time.time", "time.time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.now", "datetime.utcnow",
    "os.urandom", "uuid.uuid1", "uuid.uuid4",
}
_PERF_CALLS = {"time.perf_counter", "time.perf_counter_ns",
               "time.monotonic", "time.monotonic_ns"}
# measurement engines: the only files allowed to touch a wall clock inside
# the deterministic scopes (they time real hardware, not simulated time)
_PERF_EXEMPT = {"core/backend/profiling.py", "serving/sim/workload.py"}
_NP_RANDOM_FNS = {"rand", "randn", "randint", "random", "normal", "uniform",
                  "choice", "shuffle", "permutation", "seed",
                  "random_sample", "standard_normal", "exponential",
                  "poisson"}


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


class NondeterminismRule(Rule):
    """R2: no wall clocks, global/unseeded RNGs, ``id()``-derived keys, or
    set-order-dependent iteration inside the deterministic simulation
    scopes.  Reports must be a pure function of (spec, profile DB)."""
    id = "R2"
    title = "nondeterminism"
    fixit = ("use repro.obs.clock.wall_s() for telemetry timing, a seeded "
             "random.Random(seed)/np.random.default_rng(seed) stream for "
             "randomness, stable keys instead of id(), and sorted(...) "
             "before iterating a set into ordered results")
    scopes = ("core/", "serving/sim/", "resilience/", "api/sweep.py")

    def check(self, mod: ParsedModule):
        amap = import_aliases(mod.tree)

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = dotted(node.func, amap)
                if name is None:
                    continue
                if name in _EPOCH_CALLS:
                    yield self.finding(
                        mod, node,
                        f"wall-clock/nondeterministic call {name}() in a "
                        "deterministic simulation scope")
                elif name in _PERF_CALLS and mod.rel not in _PERF_EXEMPT:
                    yield self.finding(
                        mod, node,
                        f"{name}() outside the measurement engines "
                        f"({', '.join(sorted(_PERF_EXEMPT))}); simulated "
                        "time must come from the event loop, telemetry "
                        "time from repro.obs.clock")
                elif name.startswith("random."):
                    attr = name.split(".", 1)[1]
                    if attr == "SystemRandom":
                        yield self.finding(
                            mod, node, "random.SystemRandom is entropy-"
                            "seeded and never reproducible")
                    elif attr == "Random":
                        if not node.args:
                            yield self.finding(
                                mod, node,
                                "unseeded random.Random(); pass an explicit "
                                "seed derived from the spec")
                    elif "." not in attr and attr[:1].islower():
                        yield self.finding(
                            mod, node,
                            f"module-level random.{attr}() uses the global "
                            "interpreter-wide RNG state")
                elif name == "numpy.random.default_rng" and not node.args:
                    yield self.finding(
                        mod, node,
                        "unseeded np.random.default_rng(); pass an explicit "
                        "seed derived from the spec")
                elif (name.startswith("numpy.random.")
                        and name.split(".")[-1] in _NP_RANDOM_FNS):
                    yield self.finding(
                        mod, node,
                        f"legacy global-state {name}(); use a seeded "
                        "np.random.default_rng(seed) generator")
                elif (name == "id" and node.args
                        and self._in_key_position(node)):
                    yield self.finding(
                        mod, node,
                        "id() used as a key: object addresses vary run to "
                        "run and across processes, so any ordering or "
                        "persistence derived from them is nondeterministic")

            # set iteration feeding ordered results
            iters = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters = [node.iter]
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                iters = [g.iter for g in node.generators]
            for it in iters:
                if _is_set_expr(it):
                    yield self.finding(
                        mod, it,
                        "iterating directly over a set; wrap in sorted() "
                        "before feeding ordered results")

        # names bound only to set expressions, then iterated
        for scope in iter_scopes(mod.tree):
            bound: dict[str, bool] = {}
            for node in scope_children(scope):
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            is_set = _is_set_expr(node.value)
                            if t.id in bound:
                                bound[t.id] = bound[t.id] and is_set
                            else:
                                bound[t.id] = is_set
            set_names = {n for n, ok in bound.items() if ok}
            if not set_names:
                continue
            for node in scope_children(scope):
                iters = []
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    iters = [node.iter]
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.DictComp, ast.GeneratorExp)):
                    iters = [g.iter for g in node.generators]
                for it in iters:
                    if isinstance(it, ast.Name) and it.id in set_names:
                        yield self.finding(
                            mod, it,
                            f"iterating over set-typed name '{it.id}'; "
                            "wrap in sorted() before feeding ordered "
                            "results")

    @staticmethod
    def _in_key_position(node: ast.Call) -> bool:
        """True if this id() call feeds a subscript slice, dict key,
        hash()/dict-method argument, or an ``in`` test."""
        cur: ast.AST = node
        p = parent(cur)
        while p is not None:
            if isinstance(p, ast.Subscript) and cur is p.slice:
                return True
            if isinstance(p, ast.Dict) and cur in p.keys:
                return True
            if isinstance(p, ast.Compare) and any(
                    isinstance(op, (ast.In, ast.NotIn)) for op in p.ops):
                return True
            if isinstance(p, ast.Call):
                if isinstance(p.func, ast.Name) and p.func.id == "hash":
                    return True
                if isinstance(p.func, ast.Attribute) and p.func.attr in (
                        "get", "setdefault", "pop", "add", "remove",
                        "discard"):
                    return True
                return False  # id() consumed by an unrelated call
            if isinstance(p, (ast.stmt,)):
                return False
            cur, p = p, parent(p)
        return False


# ---------------------------------------------------------------- R3

class SpecDriftRule(Rule):
    """R3: every field of a frozen spec dataclass must survive the
    to_json/from_dict round-trip and participate in hashing."""
    id = "R3"
    title = "spec-drift"
    fixit = ("wire the new field through from_dict (string-literal key), "
             "keep compare=True so it participates in __eq__/__hash__, and "
             "reference it in any manual __hash__")
    scopes = ("api/spec.py",)

    def check(self, mod: ParsedModule):
        classes = [n for n in mod.tree.body if isinstance(n, ast.ClassDef)]
        frozen = {c.name: c for c in classes if self._is_frozen(c)}
        literals = {n.value for n in ast.walk(mod.tree)
                    if isinstance(n, ast.Constant)
                    and isinstance(n.value, str)}

        for cls in frozen.values():
            fields = self._fields(cls)
            for fname, ann, kws, node in fields:
                if fname.startswith("_"):
                    continue  # private plumbing (e.g. memoized _hash)
                # (a) compare=False silently drops the field from __eq__
                # and __hash__ -> two unequal specs collide in caches
                cmp = kws.get("compare")
                if isinstance(cmp, ast.Constant) and cmp.value is False:
                    yield self.finding(
                        mod, node,
                        f"{cls.name}.{fname}: compare=False on a public "
                        "spec field drops it from __eq__/__hash__; unequal "
                        "specs would share cache entries")
                # (b) nested spec fields must show up as a string-literal
                # key somewhere in the module (from_dict reconstruction)
                if self._is_nested_spec(ann, kws, frozen) \
                        and fname not in literals:
                    yield self.finding(
                        mod, node,
                        f"{cls.name}.{fname}: nested spec field has no "
                        "string-literal key in this module — from_dict "
                        "cannot be reconstructing it, so JSON round-trip "
                        "drops the field")
            # (c) a manual __hash__ must reference every public field
            hash_fn = next((n for n in cls.body
                            if isinstance(n, _FUNC_SCOPES)
                            and n.name == "__hash__"), None)
            if hash_fn is not None:
                seen = {n.attr for n in ast.walk(hash_fn)
                        if isinstance(n, ast.Attribute)
                        and isinstance(n.value, ast.Name)
                        and n.value.id == "self"}
                for fname, ann, kws, node in fields:
                    cmp = kws.get("compare")
                    off = isinstance(cmp, ast.Constant) and cmp.value is False
                    if fname.startswith("_") or off:
                        continue
                    if fname not in seen:
                        yield self.finding(
                            mod, hash_fn,
                            f"{cls.name}.__hash__ does not reference field "
                            f"'{fname}'; specs differing only in it would "
                            "collide as cache keys")

    @staticmethod
    def _is_frozen(cls: ast.ClassDef) -> bool:
        for dec in cls.decorator_list:
            if isinstance(dec, ast.Call):
                name = dec.func.attr if isinstance(dec.func, ast.Attribute) \
                    else getattr(dec.func, "id", "")
                if name == "dataclass":
                    for kw in dec.keywords:
                        if kw.arg == "frozen" and isinstance(
                                kw.value, ast.Constant) and kw.value.value:
                            return True
        return False

    @staticmethod
    def _fields(cls: ast.ClassDef):
        out = []
        for node in cls.body:
            if isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                ann_src = ast.unparse(node.annotation) \
                    if node.annotation is not None else ""
                if "ClassVar" in ann_src:
                    continue
                kws = {}
                if isinstance(node.value, ast.Call):
                    fn = node.value.func
                    fname = fn.attr if isinstance(fn, ast.Attribute) \
                        else getattr(fn, "id", "")
                    if fname == "field":
                        kws = {kw.arg: kw.value
                               for kw in node.value.keywords}
                out.append((node.target.id, ann_src, kws, node))
        return out

    @staticmethod
    def _is_nested_spec(ann_src: str, kws: dict, frozen: dict) -> bool:
        if any(name in ann_src for name in frozen):
            return True
        df = kws.get("default_factory")
        return isinstance(df, ast.Name) and df.id in frozen


# ---------------------------------------------------------------- R4

_PRICING_HINTS = ("price", "run", "latency", "simulate", "schedule")


class MemoGuardRule(Rule):
    """R4: memo dicts on state-versioned engine objects must be cleared by
    the state-version guard (the PR 6 oracle-leak class)."""
    id = "R4"
    title = "memo-guard"
    fixit = ("clear the memo (self.X.clear() or self.X = {}) inside the "
             "method that detects a _state_version change, so priced "
             "results cannot survive an engine reconfiguration")
    scopes = ("core/", "serving/sim/", "resilience/")

    def check(self, mod: ParsedModule):
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            refs_version = any(
                (isinstance(n, ast.Attribute) and "_state_version" in n.attr)
                or (isinstance(n, ast.Name) and "_state_version" in n.id)
                for n in ast.walk(cls))
            if not refs_version:
                continue
            memos = self._memo_attrs(cls)
            if not memos:
                continue
            cleared = self._cleared_attrs(cls)
            priced = self._priced_write_attrs(cls)
            for attr, node in memos.items():
                if attr in priced and attr not in cleared:
                    yield self.finding(
                        mod, node,
                        f"memo dict self.{attr} caches priced results but "
                        "is never cleared outside __init__; it will serve "
                        "stale values after a _state_version change")

    @staticmethod
    def _memo_attrs(cls: ast.ClassDef) -> dict:
        """self.X attrs assigned a dict in __init__/__post_init__."""
        out: dict = {}
        for fn in cls.body:
            if not (isinstance(fn, _FUNC_SCOPES)
                    and fn.name in ("__init__", "__post_init__")):
                continue
            for node in ast.walk(fn):
                targets = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                    value = node.value
                elif isinstance(node, ast.AnnAssign) and node.value:
                    targets = [node.target]
                    value = node.value
                else:
                    continue
                has_dict = any(
                    isinstance(v, (ast.Dict, ast.DictComp))
                    or (isinstance(v, ast.Call)
                        and isinstance(v.func, ast.Name)
                        and v.func.id == "dict")
                    for v in ast.walk(value))
                if not has_dict:
                    continue
                for t in targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id == "self":
                        out[t.attr] = node
        return out

    @staticmethod
    def _cleared_attrs(cls: ast.ClassDef) -> set:
        """attrs cleared or reassigned outside __init__/__post_init__."""
        out: set = set()
        for fn in cls.body:
            if not isinstance(fn, _FUNC_SCOPES) \
                    or fn.name in ("__init__", "__post_init__"):
                continue
            for node in ast.walk(fn):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "clear"
                        and isinstance(node.func.value, ast.Attribute)
                        and isinstance(node.func.value.value, ast.Name)
                        and node.func.value.value.id == "self"):
                    out.add(node.func.value.attr)
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Attribute) \
                                and isinstance(t.value, ast.Name) \
                                and t.value.id == "self":
                            out.add(t.attr)
        return out

    @staticmethod
    def _priced_write_attrs(cls: ast.ClassDef) -> set:
        """attrs written by subscript/setdefault inside a method that also
        calls something pricing-shaped (price/run/latency/simulate/
        schedule).  Pure key->spec tables (no pricing involved) are exempt:
        their entries cannot go stale."""
        out: set = set()
        for fn in cls.body:
            if not isinstance(fn, _FUNC_SCOPES) \
                    or fn.name in ("__init__", "__post_init__"):
                continue
            calls_pricing = False
            writes: set = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    name = None
                    if isinstance(node.func, ast.Attribute):
                        name = node.func.attr
                    elif isinstance(node.func, ast.Name):
                        name = node.func.id
                    if name and any(h in name.lower()
                                    for h in _PRICING_HINTS):
                        calls_pricing = True
                    if (isinstance(node.func, ast.Attribute)
                            and node.func.attr == "setdefault"
                            and isinstance(node.func.value, ast.Attribute)
                            and isinstance(node.func.value.value, ast.Name)
                            and node.func.value.value.id == "self"):
                        writes.add(node.func.value.attr)
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Subscript) \
                                and isinstance(t.value, ast.Attribute) \
                                and isinstance(t.value.value, ast.Name) \
                                and t.value.value.id == "self":
                            writes.add(t.value.attr)
            if calls_pricing:
                out |= writes
        return out


# ---------------------------------------------------------------- R5

class RecorderThreadingRule(Rule):
    """R5: simulator entry points accept and forward recorder=/metrics= so
    observability reaches every nested event loop."""
    id = "R5"
    title = "recorder-threading"
    fixit = ("add recorder=None and metrics=None keyword params to the run "
             "method and forward them on delegated .run(...) calls "
             "(pricing calls on the owned self.sim core simulator are "
             "exempt: priced sub-runs are cache-shared and must not "
             "record)")
    scopes = ("core/simulator.py", "serving/sim/", "resilience/")

    def check(self, mod: ParsedModule):
        for cls in ast.walk(mod.tree):
            if not isinstance(cls, ast.ClassDef) \
                    or not cls.name.endswith("Simulator"):
                continue
            run = next((n for n in cls.body if isinstance(n, _FUNC_SCOPES)
                        and n.name == "run"), None)
            if run is None:
                continue
            params = {a.arg for a in run.args.args} \
                | {a.arg for a in run.args.kwonlyargs}
            for missing in ("recorder", "metrics"):
                if missing not in params:
                    yield self.finding(
                        mod, run,
                        f"{cls.name}.run() does not accept {missing}=; "
                        "observability cannot be threaded through this "
                        "entry point")
            # delegated .run(...) calls must forward recorder=
            for node in ast.walk(run):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "run"):
                    continue
                recv = node.func.value
                if isinstance(recv, ast.Name) and recv.id == "self":
                    continue
                # self.sim is the owned core pricing simulator: its runs
                # are memoized step prices, deliberately not recorded
                if isinstance(recv, ast.Attribute) \
                        and isinstance(recv.value, ast.Name) \
                        and recv.value.id == "self" and recv.attr == "sim":
                    continue
                kwargs = {kw.arg for kw in node.keywords}
                if "recorder" not in kwargs:
                    yield self.finding(
                        mod, node,
                        f"{cls.name}.run() delegates to a nested .run() "
                        "without forwarding recorder=; trace lanes from "
                        "the inner loop are silently dropped")


# ---------------------------------------------------------------- R6

# exceptions that carry control flow (shutdown, Ctrl-C, generator close):
# swallowing one inside retry/cleanup logic turns "user pressed Ctrl-C"
# into "retry the candidate", making a sweep unkillable
_CONTROL_EXCS = {"BaseException", "KeyboardInterrupt", "SystemExit",
                 "GeneratorExit"}


def _caught_names(node: ast.expr | None) -> set:
    """Exception names named by an ``except`` clause (tuples flattened;
    ``mp.ProcessError``-style attributes reduce to their tail name)."""
    if node is None:
        return set()
    if isinstance(node, ast.Tuple):
        out: set = set()
        for e in node.elts:
            out |= _caught_names(e)
        return out
    if isinstance(node, ast.Name):
        return {node.id}
    if isinstance(node, ast.Attribute):
        return {node.attr}
    return set()


class ExceptionHygieneRule(Rule):
    """R6: the crash-recovery scopes (worker pool, sweep retry loop, chaos
    harness, atomic cache writes) must not swallow control-flow exceptions.
    A bare ``except:`` — or a handler naming BaseException / KeyboardInterrupt
    / SystemExit / GeneratorExit without a bare ``raise`` in its body — eats
    Ctrl-C and pool shutdown, leaving orphaned workers and half-written
    cache files.  Retry logic catches ``Exception``; anything wider must
    clean up and re-raise (see ``WorkerPool.run`` and ``atomic_pickle`` for
    the compliant shape)."""
    id = "R6"
    title = "exception-hygiene"
    fixit = ("catch Exception for retryable candidate errors; if a wider "
             "handler is needed for cleanup, end it with a bare `raise` so "
             "KeyboardInterrupt/SystemExit still propagate")
    scopes = ("api/pool.py", "api/sweep.py", "analysis/chaos.py",
              "core/simcache.py")

    def check(self, mod: ParsedModule):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                yield self.finding(
                    mod, node,
                    "bare `except:` in a crash-recovery scope catches "
                    "KeyboardInterrupt/SystemExit; retries would swallow "
                    "Ctrl-C and make the sweep unkillable")
                continue
            control = _caught_names(node.type) & _CONTROL_EXCS
            if not control:
                continue
            reraises = any(isinstance(n, ast.Raise) and n.exc is None
                           for n in ast.walk(node))
            if not reraises:
                yield self.finding(
                    mod, node,
                    f"handler catches {'/'.join(sorted(control))} without a "
                    "bare `raise`; control-flow exceptions must propagate "
                    "after cleanup or workers/cache writes leak")


ALL_RULES = (CacheAliasRule, NondeterminismRule, SpecDriftRule,
             MemoGuardRule, RecorderThreadingRule, ExceptionHygieneRule)

RULES_BY_ID = {r.id: r for r in ALL_RULES}

"""Finding and report types for charon-lint.

A :class:`Finding` is one rule violation at one source location; a
:class:`LintReport` aggregates findings across a run, splitting them into
*active* findings (fail the build) and *disabled* findings (suppressed by an
inline ``# charon-lint: disable=RN`` comment).  Disabled findings never fail
the run but are counted loudly: every suppression is a standing claim that a
nondeterminism/aliasing pattern is safe, and the report surfaces the full
list so reviews re-litigate them instead of forgetting them.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``fixit`` is the rule's standing advice for repairing this class of
    finding (not a machine-applicable patch); ``disabled`` marks findings
    suppressed by an inline disable comment.
    """
    rule: str                   # "R1".."R5"
    title: str                  # rule short name
    path: str                   # path as scanned (normalized, posix)
    line: int
    message: str
    fixit: str = ""
    disabled: bool = False

    def render(self) -> str:
        mark = " [disabled]" if self.disabled else ""
        out = f"{self.path}:{self.line}: {self.rule}{mark}: {self.message}"
        if self.fixit and not self.disabled:
            out += f"\n    fix: {self.fixit}"
        return out

    def as_dict(self) -> dict:
        return {"rule": self.rule, "title": self.title, "path": self.path,
                "line": self.line, "message": self.message,
                "fixit": self.fixit, "disabled": self.disabled}


@dataclass
class LintReport:
    """All findings of one lint run plus scan bookkeeping."""
    findings: tuple = ()
    n_files: int = 0
    errors: tuple = ()          # (path, message) rows for unparseable files

    @property
    def active(self) -> tuple:
        return tuple(f for f in self.findings if not f.disabled)

    @property
    def disabled(self) -> tuple:
        return tuple(f for f in self.findings if f.disabled)

    @property
    def ok(self) -> bool:
        return not self.active and not self.errors

    def by_rule(self) -> dict:
        out: dict[str, int] = {}
        for f in self.active:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def render(self) -> str:
        lines: list[str] = []
        for path, msg in self.errors:
            lines.append(f"{path}: parse error: {msg}")
        for f in sorted(self.findings,
                        key=lambda f: (f.path, f.line, f.rule)):
            lines.append(f.render())
        counts = self.by_rule()
        summary = ", ".join(f"{r}:{n}" for r, n in sorted(counts.items())) \
            or "none"
        lines.append(
            f"charon-lint: {self.n_files} files, "
            f"{len(self.active)} finding(s) [{summary}], "
            f"{len(self.disabled)} disabled suppression(s)")
        if self.disabled:
            # loud: every suppression is listed in the summary line block
            for f in self.disabled:
                lines.append(f"  suppressed: {f.path}:{f.line} {f.rule} "
                             f"({f.title})")
        return "\n".join(lines)

    def as_dict(self) -> dict:
        return {"n_files": self.n_files,
                "n_active": len(self.active),
                "n_disabled": len(self.disabled),
                "by_rule": self.by_rule(),
                "errors": [list(e) for e in self.errors],
                "findings": [f.as_dict() for f in self.findings]}

    def to_json(self) -> str:
        return json.dumps(self.as_dict(), indent=1, sort_keys=True)

"""Deterministic chaos injection for the sweep execution layer.

The crash-safe worker pool (:mod:`repro.api.pool`) recovers from worker
crashes, hangs, poison candidates and corrupt cache shards — but recovery
paths that are never exercised rot.  A :class:`FaultPlan` injects exactly
those failures, *deterministically*: every decision is a pure function of
``(seed, kind, key, attempt)`` hashed through blake2b, so a fault schedule
is reproducible across runs, processes and machines (no ``hash()``
randomization, no RNG sequence coupling to execution order).

The headline contract (tests/test_pool_robustness.py, CI chaos smoke): a
sweep under any injected fault schedule that does not exhaust a candidate's
retries produces rankings, reports and pruned reasons **bit-identical** to
the fault-free serial sweep.  Faults touch only the execution layer; they
must never be able to change a simulated number.

Fault kinds (the ``CHARON_FAULTS`` grammar, comma-separated ``kind:rate``):

* ``worker_crash``    — the worker process ``os._exit(137)``s before
                        evaluating the candidate (simulated segfault);
* ``worker_hang``     — the worker sleeps ``hang_s`` mid-candidate, so the
                        pool's per-candidate timeout must fire;
* ``candidate_error`` — a :class:`ChaosError` is raised inside evaluation
                        (simulated poison candidate; the only kind also
                        honored by *serial* sweeps, which have no process
                        boundary to crash);
* ``cache_corrupt``   — the worker's persistent-cache shard is truncated
                        mid-file after writing, so the parent's shard merge
                        must quarantine it.

Extra knobs: ``seed:<int>`` reseeds every decision; ``repeat:1`` makes a
faulted candidate fault on *every* attempt (default: first attempt only, so
bounded retry always recovers — the bit-identity schedule).  Example::

    CHARON_FAULTS="worker_crash:0.05,worker_hang:0.01,cache_corrupt:0.02"

Programmatic use: ``sweep(space, workers=2, faults=FaultPlan(seed=7,
worker_crash=0.3))``.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass


class ChaosError(RuntimeError):
    """The injected poison-candidate failure (``candidate_error``)."""


_RATE_KINDS = ("worker_crash", "worker_hang", "candidate_error",
               "cache_corrupt")


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, hashable fault schedule (frozen: doubles as a pool key)."""
    worker_crash: float = 0.0
    worker_hang: float = 0.0
    candidate_error: float = 0.0
    cache_corrupt: float = 0.0
    seed: int = 0
    # fire on every attempt (exhausts retries -> quarantine paths) instead
    # of only the first (always-recoverable -> bit-identity paths)
    repeat: bool = False
    # how long an injected hang sleeps; the pool's per-candidate timeout is
    # expected to kill the worker long before this elapses
    hang_s: float = 3600.0

    def __post_init__(self):
        for kind in _RATE_KINDS:
            rate = getattr(self, kind)
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{kind} rate must be in [0, 1], "
                                 f"got {rate!r}")

    @property
    def enabled(self) -> bool:
        return any(getattr(self, k) > 0.0 for k in _RATE_KINDS)

    # ------------------------------------------------------------------
    def roll(self, kind: str, *key) -> bool:
        """Pure decision: blake2b((seed, kind, *key)) < rate.  Stable across
        processes and runs — never the interpreter ``hash()`` and never a
        sequential RNG stream (which would couple faults to dispatch
        order)."""
        rate = getattr(self, kind)
        if rate <= 0.0:
            return False
        if rate >= 1.0:
            return True
        blob = "|".join(str(p) for p in (self.seed, kind) + key)
        h = hashlib.blake2b(blob.encode(), digest_size=8).digest()
        return int.from_bytes(h, "big") / 2.0 ** 64 < rate

    def should(self, kind: str, key: tuple, attempt: int = 1) -> bool:
        """Does *kind* fire for *key* on this *attempt*?  Without
        ``repeat``, a faulted key faults only on its first attempt, so the
        pool's retry always recovers it."""
        if attempt > 1 and not self.repeat:
            return False
        return self.roll(kind, *key)

    def maybe_raise(self, candidate_hash: str, attempt: int = 1) -> None:
        """Serial-safe injection: only ``candidate_error`` (a process with
        no worker boundary cannot meaningfully crash or hang itself)."""
        if self.should("candidate_error", (candidate_hash,), attempt):
            raise ChaosError(
                f"injected candidate_error for {candidate_hash[:12]} "
                f"(attempt {attempt}, seed {self.seed})")

    # ------------------------------------------------------------------
    @staticmethod
    def from_env(environ=None) -> "FaultPlan | None":
        """Parse ``CHARON_FAULTS`` (None when unset/empty).  Grammar:
        comma-separated ``kind:value`` with kinds ``worker_crash`` /
        ``worker_hang`` / ``candidate_error`` / ``cache_corrupt`` (rates in
        [0,1]) plus ``seed:<int>``, ``repeat:<0|1>``, ``hang_s:<float>``."""
        env = os.environ if environ is None else environ
        raw = env.get("CHARON_FAULTS", "").strip()
        if not raw:
            return None
        kwargs: dict = {}
        for part in raw.split(","):
            kind, sep, value = part.partition(":")
            kind, value = kind.strip(), value.strip()
            if not sep or not value:
                raise ValueError(
                    f"CHARON_FAULTS entry {part!r} is not 'kind:value'")
            if kind in _RATE_KINDS:
                kwargs[kind] = float(value)
            elif kind == "seed":
                kwargs["seed"] = int(value)
            elif kind == "hang_s":
                kwargs["hang_s"] = float(value)
            elif kind == "repeat":
                kwargs["repeat"] = value.lower() in ("1", "true", "yes")
            else:
                raise ValueError(
                    f"unknown CHARON_FAULTS kind {kind!r} (known: "
                    f"{', '.join(_RATE_KINDS + ('seed', 'repeat', 'hang_s'))})")
        return FaultPlan(**kwargs)


def corrupt_shard(path: str) -> None:
    """Truncate a cache shard mid-file (the ``cache_corrupt`` injection):
    the resulting partial pickle must be quarantined — never loaded, never
    fatal — by :func:`repro.core.simulator.merge_cache_shards`."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(size // 2, 1))

"""Serving driver: continuous batching over a Poisson request stream with
SLO accounting.  CPU-runnable with tiny configs; full configs target the
production mesh (decode cells compile-proven by dryrun.py).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --tiny \
        --requests 12 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_tiny_config
from repro.models import Model
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b", choices=list(ARCH_IDS))
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ttft-slo-ms", type=float, default=None)
    args = ap.parse_args(argv)

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(cfg, params, slots=args.slots, cache_len=args.cache_len)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new))
    finished = engine.run_until_drained()
    wall = time.perf_counter() - t0

    toks = sum(len(r.tokens) for r in finished)
    ttfts = [r.ttft_s * 1e3 for r in finished if r.ttft_s is not None]
    print(f"served {len(finished)}/{args.requests} requests, {toks} tokens, "
          f"{wall*1e3:.0f} ms wall ({toks/wall:.1f} tok/s)")
    print(f"TTFT ms: p50={np.percentile(ttfts, 50):.1f} "
          f"p95={np.percentile(ttfts, 95):.1f} max={max(ttfts):.1f}")
    if args.ttft_slo_ms is not None:
        ok = sum(t <= args.ttft_slo_ms for t in ttfts)
        print(f"TTFT SLO {args.ttft_slo_ms} ms: {ok}/{len(ttfts)} met")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) fakes 512 host devices so the
# production meshes (16x16 single-pod, 2x16x16 multi-pod) can be built.

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    ARCH_IDS, SHAPES, get_config, get_shape, supports_shape,
)
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.distributed.sharding import ShardingEnv, activate, resolve_spec
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import Model, abstract_params, count_params
from repro.models.kvcache import build_cache
from repro.training.optimizer import make_optimizer
from repro.training.train_step import (
    batch_pspecs, make_train_step, param_pspecs, state_pspecs, to_named,
)

from repro.launch.hlo_analysis import analyze_module

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
# Run-config defaults per cell
# ---------------------------------------------------------------------------

def default_run(cfg: ModelConfig, shape: ShapeConfig, multi_pod: bool,
                overrides: dict | None = None) -> RunConfig:
    n = count_params(cfg)
    kw = dict(
        pod=2 if multi_pod else 1,
        data=16, model_axis=16,
        optimizer="adafactor" if n > 100e9 else "adamw",
        zero_stage=3 if n > 5e9 else 1,
        remat_policy="block" if shape.kind == "train" else "none",
        microbatches=1,
    )
    if overrides:
        kw.update(overrides)
    return RunConfig(model=cfg, shape=shape, **kw)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _cache_pspecs(cfg: ModelConfig, env: ShardingEnv, B: int, S: int):
    """Resolve decode-cache logical axes against the active mesh."""
    def creator(shp, logical, dtype):
        return resolve_spec(env, tuple(logical), shp)
    return build_cache(cfg, creator, B, S)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               run_overrides: dict | None = None,
               model_overrides: dict | None = None):
    """Lower + compile one (arch x shape x mesh) cell.

    Returns (record, lowered, compiled) — record carries cost/memory/collective
    numbers for EXPERIMENTS.md §Dry-run and §Roofline.
    """
    cfg = get_config(arch)
    if model_overrides:
        cfg = cfg.replace(**model_overrides)
    shape = get_shape(shape_name)
    if not supports_shape(cfg, shape):
        return ({"arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
                 "status": "skipped", "reason": "sub-quadratic-only shape on full-attention arch"},
                None, None)
    mesh = make_production_mesh(multi_pod=multi_pod)
    env = ShardingEnv(mesh)
    run = default_run(cfg, shape, multi_pod, run_overrides)
    B, S = shape.global_batch, shape.seq_len
    t0 = time.time()

    with activate(env), mesh:
        params_abs = abstract_params(cfg)
        p_ns = to_named(env, param_pspecs(cfg, env, run.zero_stage if shape.kind == "train" else 0))
        b_ns = to_named(env, batch_pspecs(cfg, env, B, kind=shape.kind))
        batch_abs = input_specs(cfg, shape)

        if shape.kind == "train":
            optimizer = make_optimizer(run.optimizer)
            step = make_train_step(cfg, run, optimizer)
            opt_abs = jax.eval_shape(optimizer.init, params_abs)
            state_abs = {"params": params_abs, "opt": opt_abs,
                         "step": jax.ShapeDtypeStruct((), jnp.int32)}
            s_ns = to_named(env, state_pspecs(cfg, env, run))
            jitted = jax.jit(step, in_shardings=(s_ns, b_ns), out_shardings=(s_ns, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            model = Model(cfg)

            def prefill_step(params, batch):
                return model.prefill(params, batch, cache_len=S)

            jitted = jax.jit(prefill_step, in_shardings=(p_ns, b_ns))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            model = Model(cfg)
            cache_abs = build_cache(cfg, lambda s, l, d: jax.ShapeDtypeStruct(s, d), B, S)
            c_ns = to_named(env, _cache_pspecs(cfg, env, B, S))

            def serve_step(params, cache, batch):
                return model.decode_step(params, cache, batch)

            jitted = jax.jit(serve_step, in_shardings=(p_ns, c_ns, b_ns),
                             out_shardings=(None, c_ns), donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, batch_abs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_rec = {"error": str(e)}

    hlo = compiled.as_text()
    hlo_stats = analyze_module(hlo)
    coll = hlo_stats["collectives"]
    n_dev = mesh.devices.size

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "params": count_params(cfg),
        "active_params": count_params(cfg, active_only=True),
        # raw XLA cost analysis (per-device; while bodies counted ONCE)
        "xla_flops": cost.get("flops"),
        "xla_bytes_accessed": cost.get("bytes accessed"),
        # trip-count-aware per-device numbers (launch/hlo_analysis.py)
        "flops_per_device": hlo_stats["flops"],
        "hbm_bytes_per_device": hlo_stats["hbm_bytes"],
        "while_loops": hlo_stats["while_loops"],
        "memory_analysis": mem_rec,
        "collectives": coll,
        "zero_stage": run.zero_stage,
        "optimizer": run.optimizer,
        "remat": run.remat_policy,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_bytes": len(hlo),
    }
    return record, lowered, compiled


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_cell_to_file(arch: str, shape_name: str, multi_pod: bool) -> dict:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
    out = RESULTS_DIR / f"{tag}.json"
    try:
        record, lowered, compiled = lower_cell(arch, shape_name, multi_pod)
    except Exception as e:
        record = {"arch": arch, "shape": shape_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    out.write_text(json.dumps(record, indent=1))
    return record


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every remaining cell")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    for arch in ([args.arch] if args.arch else ARCH_IDS):
        for shape_name in ([args.shape] if args.shape else SHAPES):
            for mp in meshes:
                cells.append((arch, shape_name, mp))
    if not args.all and not (args.arch and args.shape):
        ap.error("give --arch and --shape, or --all")

    for arch, shape_name, mp in cells:
        tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
        out = RESULTS_DIR / f"{tag}.json"
        if out.exists() and not args.force:
            rec = json.loads(out.read_text())
            print(f"[cached] {tag}: {rec.get('status')}", flush=True)
            continue
        t0 = time.time()
        rec = run_cell_to_file(arch, shape_name, mp)
        status = rec.get("status")
        extra = "" if status != "error" else " :: " + rec.get("error", "")[:160]
        print(f"[{time.time()-t0:7.1f}s] {tag}: {status}{extra}", flush=True)
        if status == "ok":
            ma = rec.get("memory_analysis", {})
            print(f"    flops/dev={rec.get('flops_per_device'):.3e} "
                  f"hbm/dev={rec.get('hbm_bytes_per_device'):.3e} "
                  f"coll_traffic/dev={rec['collectives']['traffic_bytes']:.3e} "
                  f"(n={rec['collectives']['count']}) mem={ma}", flush=True)


if __name__ == "__main__":
    main()

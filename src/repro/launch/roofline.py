"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch x shape) single-pod cell:
    compute term    = HLO_FLOPs_per_dev / 197e12          [s]
    memory term     = HLO_bytes_per_dev / 819e9           [s]
    collective term = ring-traffic_bytes_per_dev / 50e9   [s]
(the dry-run records are already per-device — see launch/hlo_analysis.py),
plus MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·B (decode), the
useful-compute ratio, the dominant term, and a what-would-move-it note.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, get_config

PEAK_FLOPS = 197e12      # TPU v5e bf16
HBM_BW = 819e9
LINK_BW = 50e9           # per ICI link

RESULTS = Path(__file__).resolve().parents[3] / "results"


def cell_terms(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    shape = SHAPES[rec["shape"]]
    cfg = get_config(rec["arch"])
    n_active = rec["active_params"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    n_dev = rec["n_devices"]
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = rec["hbm_bytes_per_device"] / HBM_BW
    t_x = rec["collectives"]["traffic_bytes"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = model_flops / n_dev / PEAK_FLOPS     # ideal per-device seconds
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": shape.kind,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": model_flops,
        "hlo_to_model_flops": rec["flops_per_device"] * n_dev / model_flops
        if model_flops else float("inf"),
        "roofline_fraction": useful / bound if bound > 0 else 0.0,
        "mem_args_gb": (rec["memory_analysis"].get("argument_bytes") or 0) / 1e9,
        "mem_temp_gb": (rec["memory_analysis"].get("temp_bytes") or 0) / 1e9,
    }


_NOTES = {
    "compute": "cut redundant FLOPs: causal-block skipping, remat policy "
               "(dots), drop MoE capacity padding",
    "memory": "reduce bytes: weight/KV quantization, larger fusion regions, "
              "wider batch to amortise weight streaming",
    "collective": "reduce traffic: ZeRO stage, collective dtype, capacity "
                  "factor, comm/compute overlap schedule",
}


def load_all(mesh: str = "single") -> list[dict]:
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            f = RESULTS / "dryrun" / f"{arch}__{shape}__{mesh}.json"
            if not f.exists():
                continue
            t = cell_terms(json.loads(f.read_text()))
            if t:
                rows.append(t)
    return rows


def to_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO flops | roofline frac | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{1.0 / r['hlo_to_model_flops']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{_NOTES[r['dominant']][:46]} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = load_all(args.mesh)
    rows.sort(key=lambda r: r["roofline_fraction"])
    if args.json:
        print(json.dumps(rows, indent=1))
    else:
        print(to_markdown(rows))
        print(f"\n{len(rows)} cells; worst fraction: {rows[0]['arch']}/{rows[0]['shape']}"
              f" = {rows[0]['roofline_fraction']:.4f}")
        coll = max(rows, key=lambda r: r["collective_s"] /
                   max(r["compute_s"], r["memory_s"], 1e-12))
        print(f"most collective-bound: {coll['arch']}/{coll['shape']} "
              f"(coll {coll['collective_s']:.3f}s vs max-other "
              f"{max(coll['compute_s'], coll['memory_s']):.3f}s)")
    (RESULTS / f"roofline_{args.mesh}.json").write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()

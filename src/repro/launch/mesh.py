"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (TPU v5e pod,
(data, model)).  Multi-pod: 2x16x16 = 512 chips with a leading "pod" axis —
data parallelism crosses pods over DCN; "data"/"model" stay intra-pod on ICI.
"""
from __future__ import annotations

import jax


def _auto(n: int):
    # Explicit Auto axis types: GSPMD propagation semantics, stable across
    # the jax 0.9 default flip.
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / exploration)."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))

"""End-to-end training driver.

CPU-runnable with tiny configs (``--tiny``); full configs target the
production mesh (compile-proven by dryrun.py).  Wires the data pipeline,
sharded train step, checkpoint/restart, and straggler monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b --tiny \
        --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_tiny_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.training.checkpoint import CheckpointManager
from repro.training.data import SyntheticTokenPipeline
from repro.training.fault_tolerance import StepMonitor, run_with_restarts
from repro.training.optimizer import make_optimizer
from repro.training.train_step import make_train_step
from repro.models import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b", choices=list(ARCH_IDS))
    ap.add_argument("--tiny", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "block", "dots"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape, optimizer=args.optimizer,
                    microbatches=args.microbatches, remat_policy=args.remat)
    optimizer = make_optimizer(args.optimizer)
    step_fn = jax.jit(make_train_step(cfg, run, optimizer), donate_argnums=(0,))

    model = Model(cfg, remat_policy=args.remat)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    monitor = StepMonitor()

    def train_loop(start_step: int) -> int:
        params = model.init(jax.random.PRNGKey(args.seed))
        state = {"params": params, "opt": optimizer.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        pipe_start = 0
        if start_step > 0:
            state, extra = ckpt.restore(state)
            pipe_start = extra.get("data_step", start_step)
            print(f"[restore] resumed at step {start_step}")
        pipe = SyntheticTokenPipeline(cfg, global_batch=args.batch,
                                      seq_len=args.seq, seed=args.seed,
                                      start_step=pipe_start)
        last_loss = float("nan")
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            monitor.start()
            state, metrics = step_fn(state, batch)
            last_loss = float(metrics["loss"])
            dt = monitor.stop()
            print(f"step {step:5d} loss {last_loss:8.4f} "
                  f"grad_norm {float(metrics['grad_norm']):8.3f} {dt*1e3:7.1f} ms",
                  flush=True)
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                ckpt.save(step, state, extra={"data_step": pipe.state()["step"],
                                              "loss": last_loss})
        pipe.close()
        print(f"done. mean step {monitor.mean_step_s*1e3:.1f} ms; "
              f"stragglers: {len(monitor.stragglers)}")
        return args.steps

    run_with_restarts(train_loop, ckpt,
                      on_restart=lambda n, e: print(f"[restart {n}] {e}"))


if __name__ == "__main__":
    main()

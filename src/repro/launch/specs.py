"""``input_specs()`` — ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation.  Modality frontends are
stubs per the assignment: whisper gets precomputed frame embeddings,
qwen2-vl gets precomputed patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

N_PATCH_STUB = 256  # vision stub: one image worth of patch embeddings


def batch_inputs(cfg: ModelConfig, B: int, S: int, *, kind: str) -> dict:
    """Abstract batch for train (tokens+labels) / prefill (tokens) /
    decode (single token)."""
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        if cfg.rope_style == "mrope":
            specs["positions"] = jax.ShapeDtypeStruct((B, 1, 3), i32)
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    if cfg.rope_style == "mrope":
        specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
    if cfg.encoder_layers > 0:
        specs["frame_embeds"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.frontend == "vision_patches":
        specs["patch_embeds"] = jax.ShapeDtypeStruct((B, N_PATCH_STUB, cfg.d_model), dt)
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    return batch_inputs(cfg, shape.global_batch, shape.seq_len, kind=shape.kind)


def concrete_batch(cfg: ModelConfig, B: int, S: int, *, kind: str, seed: int = 0) -> dict:
    """Small concrete batch for smoke tests / examples (mirrors input_specs)."""
    rng = jax.random.PRNGKey(seed)
    specs = batch_inputs(cfg, B, S, kind=kind)
    out = {}
    for k, s in specs.items():
        rng, sub = jax.random.split(rng)
        if s.dtype == jnp.int32:
            if k == "positions":
                base = jnp.arange(s.shape[1])[None, :, None] if s.ndim == 3 else None
                out[k] = jnp.broadcast_to(base, s.shape).astype(jnp.int32) if base is not None \
                    else jax.random.randint(sub, s.shape, 0, cfg.vocab_size)
            else:
                out[k] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size)
        else:
            out[k] = (jax.random.normal(sub, s.shape, jnp.float32) * 0.02).astype(s.dtype)
    return out

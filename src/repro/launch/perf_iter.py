import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (§Perf): lower a cell with a named change,
extract the three roofline terms, and log hypothesis -> before -> after.

    PYTHONPATH=src python -m repro.launch.perf_iter <experiment>
"""
import json
import sys
import time
from pathlib import Path

from repro.launch.dryrun import lower_cell
from repro.launch.roofline import cell_terms

RESULTS = Path(__file__).resolve().parents[3] / "results" / "perf"

# experiment := (arch, shape, run_overrides, model_overrides)
EXPERIMENTS = {
    # ---- cell A: qwen2.5-32b train_4k (worst big-model fraction) ----
    "qwen_train.baseline": ("qwen2.5-32b", "train_4k", {}, {}),
    "qwen_train.bf16_scores": ("qwen2.5-32b", "train_4k", {},
                               {"attn_score_dtype": "bfloat16"}),
    "qwen_train.remat_dots": ("qwen2.5-32b", "train_4k",
                              {"remat_policy": "dots"}, {}),
    "qwen_train.bf16+dots": ("qwen2.5-32b", "train_4k",
                             {"remat_policy": "dots"},
                             {"attn_score_dtype": "bfloat16"}),
    "qwen_train.kv_block2048": ("qwen2.5-32b", "train_4k", {},
                                {"attn_kv_block": 2048}),
    "qwen_train.kv_block4096": ("qwen2.5-32b", "train_4k", {},
                                {"attn_kv_block": 4096}),
    # ---- cell B: recurrentgemma-9b train_4k (most collective-bound) ----
    "rg_train.baseline": ("recurrentgemma-9b", "train_4k", {}, {}),
    "rg_train.blockdiag_gates": ("recurrentgemma-9b", "train_4k", {},
                                 {"lru_gate_blocks": 16}),
    "rg_train.blockdiag+zero1": ("recurrentgemma-9b", "train_4k",
                                 {"zero_stage": 1},
                                 {"lru_gate_blocks": 16}),
    "rg_train.blockdiag+bf16s": ("recurrentgemma-9b", "train_4k", {},
                                 {"lru_gate_blocks": 16,
                                  "attn_score_dtype": "bfloat16"}),
    # ---- cell C: qwen2.5-32b decode_32k (serving; paper's DSE theme) ----
    "qwen_decode.baseline": ("qwen2.5-32b", "decode_32k", {}, {}),
    # olmoe collective experiment (EP + FSDP interaction)
    "olmoe_train.baseline": ("olmoe-1b-7b", "train_4k", {}, {}),
    "olmoe_train.zero1": ("olmoe-1b-7b", "train_4k", {"zero_stage": 1}, {}),
    "olmoe_train.cap1.0": ("olmoe-1b-7b", "train_4k", {},
                           {"capacity_factor": 1.0}),
}


def run_experiment(name: str) -> dict:
    arch, shape, run_ov, model_ov = EXPERIMENTS[name]
    t0 = time.time()
    record, lowered, compiled = lower_cell(arch, shape, False,
                                           run_overrides=run_ov,
                                           model_overrides=model_ov)
    terms = cell_terms(record)
    out = {"experiment": name, "arch": arch, "shape": shape,
           "run_overrides": run_ov, "model_overrides": model_ov,
           "terms": terms,
           "memory_analysis": record.get("memory_analysis"),
           "collectives_by_kind": record["collectives"]["by_kind"],
           "wall_s": round(time.time() - t0, 1)}
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(out, indent=1))
    return out


def main():
    names = sys.argv[1:] or list(EXPERIMENTS)
    for name in names:
        if name not in EXPERIMENTS:
            print(f"unknown experiment {name}")
            continue
        out = (RESULTS / f"{name}.json")
        if out.exists():
            r = json.loads(out.read_text())
            print(f"[cached] {name}")
        else:
            r = run_experiment(name)
        t = r["terms"]
        print(f"{name:30s} compute={t['compute_s']:8.3f}s memory={t['memory_s']:8.3f}s "
              f"collective={t['collective_s']:7.3f}s dom={t['dominant']} "
              f"frac={t['roofline_fraction']:.4f}", flush=True)


if __name__ == "__main__":
    main()

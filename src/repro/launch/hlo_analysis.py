"""Optimized-HLO analysis: trip-count-aware FLOPs / HBM bytes / collectives.

``compiled.cost_analysis()`` counts every while-loop (scan) body ONCE, which
under-reports depth-scanned models by ~num_layers x.  This module re-derives
the roofline inputs directly from the per-device optimized HLO text:

  * execution counts per computation (while bodies scaled by
    ``backend_config.known_trip_count``; nested loops multiply),
  * dot FLOPs (2 * prod(out dims) * prod(contracting dims)),
  * HBM traffic model: every materialising top-level instruction reads its
    operands and writes its output once (XLA fuses elementwise chains, so
    `fusion` nodes approximate real buffer traffic),
  * collective inventory with ring-algorithm per-device link traffic:
      all-gather          (n-1) * operand      (operand = local shard)
      reduce-scatter      (n-1)/n * operand    (operand = full local buffer)
      all-reduce          2 (n-1)/n * operand
      all-to-all          (n-1)/n * operand
      collective-permute  operand

All shapes in the post-SPMD module are per-device, so every number reported
here is per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPCODE = re.compile(r"^(?:\([^=]*\)|\S+)\s+([\w\-]+)\(")
_OPND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+(\d+)')
_CALLED = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_NO_TRAFFIC_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota",
    # loop-carry copies alias on the TPU target (CPU-backend artifact)
    "copy", "copy-start", "copy-done",
    # the CPU backend computes in f32 and materialises bf16<->f32 converts
    # around every op; on TPU converts fuse into producers/consumers
    "convert",
}


def _shape_dims(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shapes_bytes(text: str, *, all_parts: bool) -> float:
    total = 0.0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        total += _shape_dims(dims) * _DTYPE_BYTES[dt]
        if not all_parts:
            break
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    rhs: str
    out_bytes: float
    out_dims: tuple[int, ...]
    out_dtype: str
    operands: list[str]


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    is_entry: bool = False

    def dus_update_bytes(self, shape_of) -> float | None:
        """If this (fused) computation performs dynamic-update-slice, return
        the update-slice traffic: on the TPU target the buffer updates in
        place, so pricing the full output is wrong (the CPU backend's
        materialisation is a backend artifact)."""
        total = None
        for ins in self.instrs:
            if ins.opcode == "dynamic-update-slice" and len(ins.operands) >= 2:
                dims, dt = shape_of.get(ins.operands[1], ((), ""))
                if dt:
                    n = 1
                    for d in dims:
                        n *= d
                    total = (total or 0.0) + n * _DTYPE_BYTES.get(dt, 4)
        return total


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        hdr = _COMP_HDR.match(raw)
        if hdr:
            cur = Computation(hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            continue
        if raw.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(raw)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # split off the leading (possibly tuple) result type via paren depth
        if rhs.startswith("("):
            depth, i = 0, 0
            for i, ch in enumerate(rhs):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            lead, rest = rhs[:i + 1], rhs[i + 1:].lstrip()
        else:
            parts = rhs.split(None, 1)
            lead, rest = parts[0], parts[1] if len(parts) > 1 else ""
        opcode = rest.split("(")[0].strip() if "(" in rest else rest.split()[0] if rest else ""
        sm = _SHAPE.search(lead)
        out_dims: tuple[int, ...] = ()
        out_dtype = ""
        if sm:
            out_dtype = sm.group(1)
            out_dims = tuple(int(d) for d in sm.group(2).split(",") if d)
        out_bytes = _shapes_bytes(lead, all_parts=rhs.startswith("("))
        # operands: names in the paren group right after the opcode
        operands: list[str] = []
        if "(" in rest:
            operands = _OPND.findall(rest.split("(", 1)[1].split(")")[0])
        cur.instrs.append(Instr(name, opcode, rhs, out_bytes, out_dims, out_dtype, operands))
    return comps


def execution_counts(comps: dict[str, Computation]) -> dict[str, float]:
    """Propagate execution multipliers from ENTRY through call sites."""
    counts = {name: 0.0 for name in comps}
    for name, c in comps.items():
        if c.is_entry:
            counts[name] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(len(comps) + 2):
        changed = False
        for name, c in comps.items():
            base = counts[name]
            if base <= 0:
                continue
            for ins in c.instrs:
                called = _CALLED.findall(ins.rhs)
                if not called:
                    continue
                mult = base
                if ins.opcode == "while":
                    tm = _TRIP.search(ins.rhs)
                    mult = base * (int(tm.group(1)) if tm else 1)
                for cal in called:
                    if cal in counts and counts[cal] < mult:
                        counts[cal] = mult
                        changed = True
        if not changed:
            break
    return counts


def _dot_flops(ins: Instr, shape_of: dict[str, tuple[tuple[int, ...], str]]) -> float:
    out_n = 1
    for d in ins.out_dims:
        out_n *= d
    cm = _CONTRACT.search(ins.rhs)
    contract = 1
    if cm and ins.operands:
        lhs = shape_of.get(ins.operands[0])
        if lhs:
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(lhs[0]):
                    contract *= lhs[0][int(idx)]
    return 2.0 * out_n * contract


@dataclass
class Collective:
    kind: str
    name: str
    comp: str
    operand_bytes: float
    output_bytes: float
    group_size: int
    mult: float = 1.0

    @property
    def traffic_bytes(self) -> float:
        n = max(self.group_size, 1)
        b = self.operand_bytes
        if self.kind == "all-gather":
            t = (n - 1) * b
        elif self.kind == "all-reduce":
            t = 2.0 * (n - 1) / n * b
        elif self.kind in ("reduce-scatter", "all-to-all"):
            t = (n - 1) / n * b
        else:
            t = b
        return t * self.mult


def analyze_module(hlo_text: str) -> dict:
    comps = parse_module(hlo_text)
    counts = execution_counts(comps)

    shape_of: dict[str, tuple[tuple[int, ...], str]] = {}
    for c in comps.values():
        for ins in c.instrs:
            shape_of[ins.name] = (ins.out_dims, ins.out_dtype)

    def op_bytes(name: str) -> float:
        if name not in shape_of:
            return 0.0
        dims, dt = shape_of[name]
        if not dt:
            return 0.0
        n = 1
        for d in dims:
            n *= d
        return n * _DTYPE_BYTES.get(dt, 4)

    flops = 0.0
    hbm_bytes = 0.0
    colls: list[Collective] = []
    while_info: list[dict] = []

    for cname, c in comps.items():
        mult = counts.get(cname, 0.0)
        if mult <= 0:
            continue
        for ins in c.instrs:
            if ins.opcode == "while":
                tm = _TRIP.search(ins.rhs)
                while_info.append({"name": ins.name, "comp": cname,
                                   "trip_count": int(tm.group(1)) if tm else None})
            if ins.opcode == "dot":
                flops += _dot_flops(ins, shape_of) * mult
            coll_kind = next((k for k in _COLL_KINDS
                              if re.match(rf"{k}(-start)?$", ins.opcode)), None)
            if coll_kind:
                ob = sum(op_bytes(o) for o in ins.operands)
                gm = _GROUPS_IOTA.search(ins.rhs)
                if gm:
                    gsize = int(gm.group(2))
                else:
                    gl = _GROUPS_LIST.search(ins.rhs)
                    gsize = len(gl.group(1).split(",")) if gl else 1
                colls.append(Collective(coll_kind, ins.name, cname, ob,
                                        ins.out_bytes, gsize, mult))
            if ins.opcode in _NO_TRAFFIC_OPS or ins.opcode.endswith("-done"):
                continue
            if ins.opcode in ("dynamic-slice", "slice", "gather"):
                # a slice READS only the slice (plus indices), not the operand
                # (e.g. per-layer weight slices from the scan-stacked params)
                hbm_bytes += 2.0 * ins.out_bytes * mult
                continue
            if ins.opcode in ("fusion", "dynamic-update-slice"):
                # in-place accumulator updates: price the slice, not the buffer
                if ins.opcode == "dynamic-update-slice":
                    # update operand = smallest non-scalar operand (operand
                    # order can be permuted by fusion parameter rewriting)
                    cand = [op_bytes(o) for o in ins.operands[1:]]
                    cand = [b for b in cand if b > 8]
                    upd = min(cand) if cand else None
                else:
                    called = _CALLED.findall(ins.rhs)
                    upd = None
                    for cal in called:
                        if cal in comps:
                            upd = comps[cal].dus_update_bytes(shape_of)
                            break
                if upd is not None:
                    # exclude the aliased accumulator operand; keep the rest
                    alias = next((o for o in ins.operands
                                  if abs(op_bytes(o) - ins.out_bytes) < 1.0), None)
                    rest = sum(op_bytes(o) for o in ins.operands if o != alias)
                    hbm_bytes += (rest + 2.0 * upd) * mult
                    continue
            opb = sum(op_bytes(o) for o in ins.operands)
            hbm_bytes += (ins.out_bytes + opb) * mult

    by_kind: dict[str, dict] = {}
    for cl in colls:
        d = by_kind.setdefault(cl.kind, {"count": 0, "operand_bytes": 0.0,
                                         "traffic_bytes": 0.0})
        d["count"] += int(cl.mult) if cl.mult >= 1 else 1
        d["operand_bytes"] += cl.operand_bytes * cl.mult
        d["traffic_bytes"] += cl.traffic_bytes

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collectives": {
            "by_kind": by_kind,
            "count": sum(d["count"] for d in by_kind.values()),
            "operand_bytes": sum(d["operand_bytes"] for d in by_kind.values()),
            "traffic_bytes": sum(d["traffic_bytes"] for d in by_kind.values()),
        },
        "while_loops": while_info,
        "n_computations": len(comps),
    }


def collective_summary(hlo_text: str) -> dict:
    return analyze_module(hlo_text)["collectives"]

"""Simulator facade: end-to-end LLM training/inference performance prediction.

Composition (paper Fig. 3): native ingestion (model_ingest/tracer) ->
parallelism & optimization passes -> multi-engine operator pricing ->
dependency-aware scheduling + overlap modeling -> multi-granularity reports
(end-to-end time, MFU, memory, per-op breakdown, chrome traces, PP timeline).
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import pickle
from dataclasses import dataclass, field

import jax

from repro.configs.base import ModelConfig
from repro.core.backend.analytical import AnalyticalEngine
from repro.core.backend.collectives import (
    GroupSpec, collective_memo_clear, collective_memo_stats,
    hierarchical_collective_time_us,
)
from repro.core.backend.engine import FusedEngine
from repro.core.backend.hardware import HARDWARE, HardwareSpec
from repro.core.backend.prediction import PredictionEngine
from repro.core.backend.profiling import ProfileDB, ProfilingEngine
from repro.core.ir import Graph
from repro.core.memory import MemoryReport, block_liveness, simulate_memory
from repro.core.model_ingest import ModelGraphs, ingest_graphs, ingest_key
from repro.core.overlap import apply_bandwidth_aware, apply_ratio_overlap
from repro.core.passes.base import ParallelConfig, PassContext, PassManager
from repro.core.simcache import SimCache
from repro.core.passes.data_parallel import optimizer_step_cost
from repro.core.passes.fusion import FusionPass
from repro.core.passes.parallelism import (
    ContextParallelPass, ExpertParallelPass, SequenceParallelPass,
    TensorParallelPass,
)
from repro.core.passes.pipeline import PPSchedule, make_schedule
from repro.core.passes.quantize import QuantizePass
from repro.core.passes.recompute import RecomputePass
from repro.core.scheduler import Timeline, schedule, schedule_times
from repro.models.kvcache import cache_bytes
from repro.models.params import count_params


@dataclass
class Report:
    mode: str
    step_time_us: float
    chips: int
    tokens_per_step: float
    tokens_per_s: float
    tps_per_chip: float
    mfu: float
    model_flops: float
    breakdown_us: dict = field(default_factory=dict)     # phase -> us
    kind_us: dict = field(default_factory=dict)          # op kind -> us
    memory: MemoryReport | None = None
    pp: PPSchedule | None = None
    block_timelines: dict = field(default_factory=dict)  # kind -> Timeline
    detail: dict = field(default_factory=dict)

    # serving metrics
    @property
    def tpot_ms(self) -> float:
        return self.step_time_us / 1e3 if self.mode == "decode" else float("nan")

    @property
    def ttft_ms(self) -> float:
        return self.step_time_us / 1e3 if self.mode == "prefill" else float("nan")

    # ---- attribution (repro.obs.explain) ----
    def explain(self, top_k: int = 8) -> str:
        """Plain-text attribution: phase breakdown, top-k op kinds,
        compute-vs-comm split; with ``keep_timelines=True`` reports also
        the critical path, per-op comm bytes and exposed-comm overlap."""
        from repro.obs.explain import render_report
        return render_report(self, top_k=top_k)

    def explain_dict(self, top_k: int = 8) -> dict:
        """Structured form of :meth:`explain` (what sweep manifests embed)."""
        from repro.obs.explain import explain_report
        return explain_report(self, top_k=top_k)


def shard_memory_floor(cfg: ModelConfig, par: ParallelConfig, B_local: int,
                       mode: str, cache_len: int) -> tuple[float, float]:
    """(per-device parameter bytes, per-device KV-cache bytes) after sharding.

    Single source of truth shared by ``simulate()``'s memory report and the
    explorer's ``rule_memory_fit`` pre-filter — the pre-filter's lower-bound
    guarantee only holds while both sides use the same formulas.
    """
    param_dev = 2 * count_params(cfg) / max(par.tp * par.pp, 1)
    if par.zero_stage >= 3:
        param_dev /= max(par.dp * par.pods, 1)
    # KV cache shards over the model axis (heads when divisible, else the
    # KV sequence — see models/kvcache.py)
    kvb = cache_bytes(cfg, B_local, cache_len) / max(par.tp, 1) \
        if mode == "decode" else 0.0
    return param_dev, kvb


@dataclass
class _BlockStage:
    """Priced per-block sub-results shared by sweep candidates with equal
    (model, B_local, S, mode, cache_len, shard_key, pipeline) keys."""
    graphs: ModelGraphs
    t_fwd: dict
    t_bwd: dict
    kind_us: dict
    first_fwd: Graph                 # post-pass first decoder block (memory)
    first_joint: Graph | None
    timelines: dict
    livekey: tuple = ()              # memory-liveness cache key (no engine ver)


class Simulator:
    def __init__(self, hw: str | HardwareSpec = "tpu_v5e",
                 engine: str = "analytical", db: ProfileDB | None = None,
                 *, overlap: str = "ratio", measure_on_miss: bool = False,
                 cache: bool = True, persist: str | None = None,
                 sanitize: bool | None = None):
        self.hw = HARDWARE[hw] if isinstance(hw, str) else hw
        self.db = db or ProfileDB()
        self.overlap = overlap
        # sanitize=None defers to the CHARON_SANITIZE env knob; when on,
        # the cache fingerprints values at insert and re-verifies at hit
        # (cache-poisoning detector — see repro.analysis.sanitize).  The
        # default path constructs a plain SimCache with no fingerprinting
        # code anywhere near the hot get().
        if sanitize is None:
            sanitize = os.environ.get("CHARON_SANITIZE", "") not in ("", "0")
        self.sanitize = bool(sanitize)
        if self.sanitize:
            from repro.analysis.sanitize import SanitizingSimCache
            self.cache = SanitizingSimCache(enabled=cache)
        else:
            self.cache = SimCache(enabled=cache)
        engines = []
        if engine in ("fused", "profiling"):
            engines.append(ProfilingEngine(self.hw, self.db,
                                           measure_on_miss=measure_on_miss))
        if engine in ("fused", "prediction"):
            engines.append(PredictionEngine(self.hw, self.db))
        engines.append(AnalyticalEngine(self.hw))
        if engine == "analytical":
            engines = [AnalyticalEngine(self.hw)]
        elif engine == "profiling":
            engines = [engines[0], engines[-1]]
        elif engine == "prediction":
            engines = [e for e in engines if e.name in ("prediction", "analytical")]
        self.engine = FusedEngine(engines, cache=cache)
        # persistent cross-run tier: explicit ``persist=`` dir, else the
        # CHARON_CACHE_DIR environment knob (loads are automatic; writes
        # only happen on an explicit save_cache() call)
        persist = persist or os.environ.get("CHARON_CACHE_DIR")
        if persist and cache:
            path = (f"simcache-{self.hw.name}-"
                    f"{'+'.join(e.name for e in self.engine.engines)}"
                    f"-{overlap}.pkl")
            pricing = self.cache.attach_persistent(
                os.path.join(os.path.expanduser(persist), path),
                self._persist_meta())
            if pricing and self.engine._cache is not None:
                self.engine._cache.update(pricing)

    def _persist_meta(self) -> dict:
        """Versioned identity of everything the persisted entries depend on.
        Computed fresh at attach AND at save time: a profile-DB mutated
        after construction must be described by its *mutated* digest, so a
        process with the original DB can never load entries priced under
        the new state (and vice versa)."""
        import repro
        from repro.core.simcache import CACHE_FORMAT
        digest = "|".join(f"{e.name}:{int(getattr(e, 'state_version', 0))}"
                          for e in self.engine.engines)
        if self.db.data:
            digest += "|db:" + hashlib.sha1(json.dumps(
                self.db.data, sort_keys=True, default=str)
                .encode()).hexdigest()
        return {"format": CACHE_FORMAT, "repro": repro.__version__,
                "jax": jax.__version__, "hw": self.hw.name,
                "overlap": self.overlap, "engines": digest}

    def save_cache(self):
        """Write the persistent tier to disk (no-op without ``persist=`` /
        ``CHARON_CACHE_DIR``).  Returns the written path or None."""
        return self.cache.save_persistent(
            self.engine._cache if self.engine._cache else None,
            meta=self._persist_meta())

    def save_cache_shard(self, tag: str):
        """Write this process's cache as a per-worker *shard* next to the
        attached persistent file (``<main>.<tag>.<pid>.shard``) instead of
        racing other workers on the main path.  The sweep parent unions
        shards back via :func:`merge_cache_shards` once workers are done.
        No-op (None) without an attached persistent tier."""
        if self.cache.persist_path is None:
            return None
        shard = self.cache.persist_path.with_name(
            f"{self.cache.persist_path.name}.{tag}.{os.getpid()}.shard")
        return self.cache.save_persistent(
            self.engine._cache if self.engine._cache else None,
            meta=self._persist_meta(), path=shard)

    def cache_stats(self) -> dict:
        """Hit/miss counters for every cache layer (benchmark telemetry)."""
        out = self.cache.stats_dict()
        out["pricing"] = self.engine.stats.as_dict()
        # module-level memo: counters aggregate over all simulators
        out["collectives"] = collective_memo_stats().as_dict()
        return out

    def metrics_registry(self, registry=None):
        """Fill a :class:`~repro.obs.MetricsRegistry` (created when None)
        with every stats surface this simulator exposes — the one-call form
        of the scattered ``cache_stats()`` / extrapolation dicts.  Snapshot
        before and after a run and ``MetricsRegistry.diff`` the two to cost
        just that run."""
        from repro.obs import MetricsRegistry
        if registry is None:
            registry = MetricsRegistry()
        registry.update_from_simulator(self)
        return registry

    def cache_clear(self) -> None:
        self.cache.clear()
        self.engine.cache_clear()
        collective_memo_clear()

    # ------------------------------------------------------------------
    def _passes(self, cfg: ModelConfig, par: ParallelConfig, *,
                fusion: bool, quantize: str | None, remat: str,
                train: bool) -> PassManager:
        pm = PassManager()
        pm.add(TensorParallelPass())
        if cfg.num_kv_heads % max(par.tp, 1) != 0:
            # heads unshardable -> Ulysses-style context parallelism on the
            # same chips (mirrors the substrate's divisibility fallback)
            pm.add(ContextParallelPass(cp=par.tp))
        if par.sp > 1:
            pm.add(SequenceParallelPass())
        if cfg.num_experts:
            pm.add(ExpertParallelPass(cfg.num_experts))
        if fusion:
            pm.add(FusionPass())
        if quantize:
            pm.add(QuantizePass(quantize))
        if train and remat != "none":
            pm.add(RecomputePass(remat))
        return pm

    def _time(self, g: Graph) -> tuple[float, Timeline]:
        tl = schedule(g, self.engine)
        tl = (apply_bandwidth_aware if self.overlap == "bandwidth"
              else apply_ratio_overlap)(tl, self.hw)
        return tl.total_time, tl

    # ------------------------------------------------------------------
    def _block_stage(self, cfg: ModelConfig, mode: str, B_local: int, S: int,
                     cache_len: int, par: ParallelConfig, *, fusion: bool,
                     quantize: str | None, remat: str,
                     keep_timelines: bool) -> _BlockStage:
        """Trace, transform and price all block graphs — the dominant cost of
        one ``simulate`` call, memoized across candidates that share shapes.

        Three cache layers compose: ``ingest`` (traced graphs), ``passes``
        (post-``PassManager`` graphs), ``block_times`` (the whole priced
        stage).  ``keep_timelines=True`` bypasses the ``block_times`` layer
        (timelines are per-call artifacts) but still reuses the lower two.
        """
        train = mode == "train"
        # fast path: totals via running scalars, no per-node Interval
        # allocation — the bandwidth-aware model joins via its
        # flow-compressed schedule_times variant; traces need timelines
        use_fast = not keep_timelines
        ikey = ingest_key(cfg, B_local, S, mode, cache_len)
        pm = self._passes(cfg, par, fusion=fusion, quantize=quantize,
                          remat=remat, train=train)
        pm_sig = pm.signature()
        shard = par.shard_key()

        def build() -> _BlockStage:
            mg = self.cache.get("ingest", ikey, lambda: ingest_graphs(
                cfg, B_local, S, mode, cache_len=cache_len))
            ctx = PassContext(parallel=par, model=cfg)

            def passed(g: Graph, kind: str, which: str) -> Graph:
                return self.cache.get(
                    "passes", (ikey, kind, which, pm_sig, shard),
                    lambda: pm.run(g.clone(), ctx))

            t_fwd: dict[str, float] = {}
            t_bwd: dict[str, float] = {}
            kind_us: dict[str, float] = {}
            timelines: dict[str, Timeline] = {}
            first_kind = mg.blocks[0].kind
            first_fwd = first_joint = None
            for bg in mg.all_blocks():
                fwd = passed(bg.fwd, bg.kind, "fwd")
                if use_fast:
                    tf, bk = schedule_times(fwd, self.engine, self.hw,
                                            overlap=self.overlap)
                else:
                    tf, tlf = self._time(fwd)
                    bk = tlf.by_kind()
                    if keep_timelines:
                        timelines[bg.kind] = tlf
                t_fwd[bg.kind] = tf
                for k, v in bk.items():
                    kind_us[k] = kind_us.get(k, 0.0) + v * bg.repeat
                if bg.kind == first_kind:
                    first_fwd = fwd
                if train and bg.joint is not None:
                    joint = passed(bg.joint, bg.kind, "joint")
                    tj = schedule_times(joint, self.engine, self.hw,
                                          overlap=self.overlap)[0] \
                        if use_fast else self._time(joint)[0]
                    t_bwd[bg.kind] = max(tj - tf, tf)  # bwd >= fwd in practice
                    if bg.kind == first_kind:
                        first_joint = joint
                else:
                    t_bwd[bg.kind] = 0.0
            return _BlockStage(mg, t_fwd, t_bwd, kind_us,
                               first_fwd, first_joint, timelines,
                               livekey=(ikey, pm_sig, shard))

        if keep_timelines:
            return build()
        # engine state version: profiling-DB/prediction-model mutation must
        # not serve stale priced stages (matches the FusedEngine price memo)
        skey = (ikey, pm_sig, shard, self.engine._state_version())
        return self.cache.get("block_times", skey, build)

    # ------------------------------------------------------------------
    def run(self, spec, *, keep_timelines: bool = False,
            recorder=None, metrics=None) -> Report:
        """Simulate one :class:`repro.api.spec.SimSpec` — the primary entry
        point.  The spec's cluster must name this simulator's hardware;
        serving workloads belong to ``ServingSimulator.run``.

        ``recorder`` (a :class:`~repro.obs.TraceRecorder`) captures the
        priced block timelines and pipeline schedule as trace lanes; it
        forces ``keep_timelines=True`` internally (there is nothing to
        record without them) but the returned report is numerically
        identical to the fast path either way.  ``metrics`` (a
        :class:`~repro.obs.MetricsRegistry`) adopts this simulator's cache
        and extrapolation counters after the run; both default to off and
        cost one ``is None`` check on the fast path."""
        if spec.cluster.hardware != self.hw.name:
            raise ValueError(
                f"simulator built for {self.hw.name!r} cannot run a spec for "
                f"cluster hardware {spec.cluster.hardware!r}")
        w = spec.workload
        if getattr(w, "mode", None) == "serving":
            raise TypeError("serving workloads are request-level: use "
                            "ServingSimulator(sim).run(spec)")
        if recorder is not None and recorder.enabled:
            from repro.core.timeline import record_report
            rep = self._simulate(spec.model, par=spec.parallel,
                                 keep_timelines=True, **w.sim_kwargs())
            record_report(recorder, rep)
        elif keep_timelines or not self.cache.persistent:
            rep = self._simulate(spec.model, par=spec.parallel,
                                 keep_timelines=keep_timelines,
                                 **w.sim_kwargs())
        else:
            # cross-run memo (persistent tier attached): the stable spec
            # JSON hash is the on-disk key, the engine state version rides
            # along so a profile-DB put / prediction retrain can never
            # serve a stale Report
            key = (spec.json_hash(), self.engine._state_version())
            rep = self.cache.get(
                "reports", key,
                lambda: self._simulate(spec.model, par=spec.parallel,
                                       **w.sim_kwargs()))
        if metrics is not None:
            metrics.inc("sim.runs")
            metrics.update_from_simulator(self)
        return rep

    def simulate(self, cfg: ModelConfig, *, mode: str = "train",
                 global_batch: int = 8, seq_len: int = 2048,
                 par: ParallelConfig | None = None, remat: str = "block",
                 optimizer: str = "adamw", fusion: bool = False,
                 quantize: str | None = None, cache_len: int = 0,
                 keep_timelines: bool = False) -> Report:
        """Deprecated kwargs shim for external callers: builds the
        equivalent :class:`~repro.api.spec.SimSpec` and delegates to
        :meth:`run` (bit-identical by construction)."""
        import warnings

        from repro.api.spec import CharonDeprecationWarning, SimSpec
        warnings.warn(
            "Simulator.simulate(**kwargs) is deprecated; build a SimSpec "
            "and call Simulator.run(spec) (see docs/api.md)",
            CharonDeprecationWarning, stacklevel=2)
        spec = SimSpec.from_legacy(
            cfg, self.hw, mode=mode, global_batch=global_batch,
            seq_len=seq_len, par=par, remat=remat, optimizer=optimizer,
            fusion=fusion, quantize=quantize, cache_len=cache_len)
        return self.run(spec, keep_timelines=keep_timelines)

    def _simulate(self, cfg: ModelConfig, *, mode: str = "train",
                  global_batch: int = 8, seq_len: int = 2048,
                  par: ParallelConfig | None = None, remat: str = "block",
                  optimizer: str = "adamw", fusion: bool = False,
                  quantize: str | None = None, cache_len: int = 0,
                  keep_timelines: bool = False) -> Report:
        par = par or ParallelConfig()
        dp_total = max(par.dp * par.pods, 1)
        B_local = max(global_batch // dp_total, 1)
        train = mode == "train"

        stage = self._block_stage(
            cfg, mode, B_local, seq_len if mode != "decode" else 1,
            cache_len or seq_len, par, fusion=fusion, quantize=quantize,
            remat=remat, keep_timelines=keep_timelines)
        mg = stage.graphs
        t_fwd = stage.t_fwd
        t_bwd = stage.t_bwd
        kind_us = dict(stage.kind_us)   # copy: stage may be cache-shared
        timelines = dict(stage.timelines)

        # ---- stack totals ----
        dec_blocks = [b for b in mg.blocks]
        total_layers = sum(b.repeat for b in dec_blocks)
        t_f_layers = sum(t_fwd[b.kind] * b.repeat for b in dec_blocks)
        t_b_layers = sum(t_bwd[b.kind] * b.repeat for b in dec_blocks)
        t_f_head = t_fwd.get("head", 0.0)
        t_b_head = t_bwd.get("head", 0.0)
        t_f_enc = t_fwd.get("enc", 0.0) * (mg.encoder.repeat if mg.encoder else 0)
        t_b_enc = t_bwd.get("enc", 0.0) * (mg.encoder.repeat if mg.encoder else 0)

        pp, m = par.pp, max(par.microbatches, 1)
        # inter-stage p2p payload per microbatch
        act_bytes = B_local * (seq_len if mode != "decode" else 1) * cfg.d_model * 2 / m
        t_p2p = hierarchical_collective_time_us(
            "send", act_bytes, GroupSpec(intra_size=2), self.hw)

        if train:
            t_f_stage = (t_f_layers / pp + (t_f_enc + t_f_head) / pp) / m
            t_b_stage = (t_b_layers / pp + (t_b_enc + t_b_head) / pp) / m
            sched = make_schedule(par.pp_schedule, pp, m, t_f_stage, t_b_stage, t_p2p)
            t_compute = sched.total_time
            # DP gradient sync (overlappable with backward) + optimizer
            n_params = count_params(cfg)
            shard = par.tp * pp * (max(par.ep, 1) if cfg.num_experts else 1)
            grad_bytes = 2 * n_params / max(shard, 1)
            t_dp = hierarchical_collective_time_us(
                "all_reduce" if par.zero_stage == 0 else "reduce_scatter",
                grad_bytes, GroupSpec(par.dp, par.pods), self.hw)
            if par.zero_stage >= 1:
                t_dp += hierarchical_collective_time_us(
                    "all_gather", grad_bytes, GroupSpec(par.dp, par.pods), self.hw)
            bwd_window = sched.total_time * (t_b_stage / max(t_f_stage + t_b_stage, 1e-9))
            exposed_dp = max(0.0, t_dp - 0.8 * bwd_window) + 0.2 * t_dp
            o_flops, o_bytes = optimizer_step_cost(
                n_params / max(shard, 1), optimizer=optimizer,
                zero_stage=par.zero_stage, dp=dp_total)
            from repro.models.params import param_logical_axes
            n_leaves = len(jax.tree.leaves(
                param_logical_axes(cfg), is_leaf=lambda x: isinstance(x, tuple)))
            t_opt = max(o_flops / self.hw.flops_for("f32"),
                        o_bytes / self.hw.hbm_bw) * 1e6 \
                + 3 * n_leaves * self.hw.dispatch_us  # m/v/p update dispatches
            total = t_compute + exposed_dp + t_opt
            breakdown = {"fwd": t_f_layers + t_f_enc + t_f_head,
                         "bwd": t_b_layers + t_b_enc + t_b_head,
                         "pp_bubble": sched.total_time - (t_f_layers + t_b_layers
                                                          + t_f_enc + t_b_enc
                                                          + t_f_head + t_b_head) / pp,
                         "dp_sync_exposed": exposed_dp, "optimizer": t_opt}
        else:
            sched = None
            total = t_f_layers + t_f_enc + t_f_head + (pp - 1) * t_p2p
            breakdown = {"fwd": t_f_layers + t_f_enc + t_f_head,
                         "pp_latency": (pp - 1) * t_p2p}

        # ---- metrics ----
        chips = par.chips
        n_active = count_params(cfg, active_only=True)
        tokens = global_batch * (seq_len if mode != "decode" else 1)
        model_flops = (6 if train else 2) * n_active * tokens
        peak = self.hw.flops_for("bf16")
        mfu = model_flops / (chips * peak * total / 1e6) if total else 0.0

        # ---- memory ----
        # expert shard already inside the tp*pp approximation for MoE
        param_dev, kvb = shard_memory_floor(cfg, par, B_local, mode,
                                            cache_len or seq_len)
        # the liveness walk re-reads only the transformed first block, so it
        # is keyed like the block stage minus the engine version (pricing
        # mutations cannot change activation bytes)
        mem_mode = "train" if train else mode
        block_joint = stage.first_joint if train else None
        liveness = self.cache.get(
            "memory", stage.livekey,
            lambda: block_liveness(stage.first_fwd, block_joint, mem_mode))
        mem = simulate_memory(
            stage.first_fwd, n_layers=total_layers // pp,
            param_bytes=param_dev,
            boundary_bytes=B_local * (seq_len if mode != "decode" else 1)
            * cfg.d_model * 2 / max(par.sp, 1),
            mode=mem_mode, optimizer=optimizer,
            zero_stage=par.zero_stage, dp=dp_total, tp=par.tp, remat=remat,
            kv_cache_bytes=kvb,
            block_joint=block_joint, liveness=liveness)

        return Report(
            mode=mode, step_time_us=total, chips=chips,
            tokens_per_step=tokens,
            tokens_per_s=tokens / (total / 1e6) if total else 0.0,
            tps_per_chip=tokens / (total / 1e6) / chips if total else 0.0,
            mfu=mfu, model_flops=model_flops,
            breakdown_us=breakdown, kind_us=kind_us, memory=mem, pp=sched,
            block_timelines=timelines,
            detail={"t_fwd": dict(t_fwd), "t_bwd": dict(t_bwd),
                    "B_local": B_local, "par": par},
        )


def merge_cache_shards(main_path, shard_paths, *, metrics=None) -> dict:
    """Union per-worker cache shards into the main persistent file.

    Robustness contract (tests/test_pool_robustness.py):

    * a corrupt or partially-written shard (killed worker, injected
      ``cache_corrupt``) is **quarantined** — renamed ``<shard>.corrupt``,
      counted as ``pool.cache_shards_quarantined`` — and the sweep degrades
      to cold pricing for those entries instead of raising;
    * a shard whose metadata disagrees with the main file / its siblings
      (stale worker from an older engine state) is skipped, never merged;
    * the main file is rewritten atomically (tmp + ``os.replace``) and
      merged shards are deleted, so a crash mid-merge leaves either the old
      main or the new one — never a partial file.

    Returns ``{"merged": n, "quarantined": n, "skipped": n, "path": ...}``.
    """
    from pathlib import Path

    from repro.core.simcache import SimCache, atomic_pickle

    main_path = Path(main_path)
    summary = {"merged": 0, "quarantined": 0, "skipped": 0,
               "path": str(main_path)}

    def _load(path: Path) -> dict | None:
        try:
            with open(path, "rb") as f:
                blob = pickle.load(f)
            # shallow shape check: a truncated pickle usually raises above,
            # but guard the layout too before trusting .get() results
            if not isinstance(blob, dict) or "meta" not in blob:
                raise ValueError("unexpected shard layout")
            return blob
        except FileNotFoundError:
            return None
        except Exception:
            corrupt = path.with_name(path.name + ".corrupt")
            try:
                os.replace(path, corrupt)
            except OSError:
                pass
            summary["quarantined"] += 1
            if metrics is not None:
                metrics.inc("pool.cache_shards_quarantined")
            return None

    base = _load(main_path) if main_path.exists() else None
    meta = base["meta"] if base else None
    buckets: dict[str, dict] = {b: {} for b in SimCache.PERSISTED}
    pricing: dict = {}
    if base:
        for b in SimCache.PERSISTED:
            buckets[b].update(base.get("buckets", {}).get(b) or {})
        pricing.update(base.get("pricing") or {})

    merged_paths = []
    for path in sorted(Path(p) for p in shard_paths):
        blob = _load(path)
        if blob is None:
            continue
        if meta is None:
            meta = blob["meta"]          # first good shard defines identity
        if blob["meta"] != meta:
            summary["skipped"] += 1      # stale worker: never merge
            if metrics is not None:
                metrics.inc("pool.cache_shards_skipped")
            continue
        for b in SimCache.PERSISTED:
            buckets[b].update(blob.get("buckets", {}).get(b) or {})
        pricing.update(blob.get("pricing") or {})
        summary["merged"] += 1
        merged_paths.append(path)

    if summary["merged"]:
        atomic_pickle(main_path, {"meta": meta, "buckets": buckets,
                                  "pricing": pricing})
        if metrics is not None:
            metrics.inc("pool.cache_shards_merged", summary["merged"])
    for path in merged_paths:
        try:
            os.unlink(path)
        except OSError:
            pass
    return summary

"""Backend engine protocol (paper §3.3).

An engine prices a single operator: ``latency_us(node) -> float | None``
(None = unsupported, the fused engine falls through to the next priority).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

from repro.core.backend.hardware import HardwareSpec
from repro.core.ir import OpNode


@runtime_checkable
class Engine(Protocol):
    name: str
    priority: int  # higher = preferred by the fused engine

    def supports(self, node: OpNode) -> bool: ...

    def latency_us(self, node: OpNode) -> float | None: ...


class FusedEngine:
    """Priority-fallback over a registry of engines (paper §3.3d).

    Each engine keeps its own supported-operator registry; the fused engine
    dynamically selects the highest-priority engine for every operator and
    falls back when an engine declines (returns None)."""

    name = "fused"

    def __init__(self, engines):
        self.engines = sorted(engines, key=lambda e: -e.priority)

    def supports(self, node: OpNode) -> bool:
        return any(e.supports(node) for e in self.engines)

    def latency_us(self, node: OpNode) -> float | None:
        for e in self.engines:
            if e.supports(node):
                t = e.latency_us(node)
                if t is not None:
                    return t
        return None

    def engine_for(self, node: OpNode) -> str:
        for e in self.engines:
            if e.supports(node) and e.latency_us(node) is not None:
                return e.name
        return "none"

"""Backend engine protocol (paper §3.3).

An engine prices a single operator: ``latency_us(node) -> float | None``
(None = unsupported, the fused engine falls through to the next priority).
The fused engine memoizes prices on a canonical operator signature — sweep
candidates re-price the same (kind, shapes, dtype, comm) tuples thousands of
times, and every registered engine is a pure function of those fields.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

from repro.core.backend.hardware import HardwareSpec
from repro.core.ir import OpNode
from repro.core.simcache import CacheStats


@runtime_checkable
class Engine(Protocol):
    name: str
    priority: int  # higher = preferred by the fused engine

    def supports(self, node: OpNode) -> bool: ...

    def latency_us(self, node: OpNode) -> float | None: ...


def node_signature(node: OpNode) -> tuple:
    """Canonical pricing signature: every node field any engine consumes.

    Analytical: kind/dtype/flops/bytes/comm + mm_dims + operand_bytes.
    Profiling:  kind/dtype + mm_dims | attn_dims | out_shape (+ vocab).
    Prediction: kind/dtype + dims + flops + total_bytes.
    ``repeat`` and ``phase`` are deliberately excluded — engines price one
    execution; the scheduler applies the repeat multiplier.
    """
    a = node.attrs
    mm = a.get("mm_dims")
    at = a.get("attn_dims")
    return (node.kind, node.dtype, node.flops, node.bytes_in, node.bytes_out,
            node.comm_bytes, node.comm_group, node.comm_size,
            tuple(node.out_shape) if node.out_shape else (),
            tuple(mm) if mm else None, tuple(at) if at else None,
            a.get("operand_bytes"), a.get("vocab"))


class FusedEngine:
    """Priority-fallback over a registry of engines (paper §3.3d).

    Each engine keeps its own supported-operator registry; the fused engine
    dynamically selects the highest-priority engine for every operator and
    falls back when an engine declines (returns None).  Prices are memoized
    per :func:`node_signature` with hit/miss counters for the benchmarks."""

    name = "fused"

    def __init__(self, engines, *, cache: bool = True):
        self.engines = sorted(engines, key=lambda e: -e.priority)
        self._cache: dict | None = {} if cache else None
        self.stats = CacheStats()
        self._version = self._state_version()

    def _state_version(self) -> int:
        """Combined version of mutable engine state (profiling DB contents,
        prediction-model retrains).  A change invalidates the price memo —
        engines are pure functions of (signature, state version)."""
        return sum(int(getattr(e, "state_version", 0)) for e in self.engines)

    def supports(self, node: OpNode) -> bool:
        return any(e.supports(node) for e in self.engines)

    def _price(self, node: OpNode) -> tuple[float | None, str]:
        for e in self.engines:
            if e.supports(node):
                t = e.latency_us(node)
                if t is not None:
                    return t, e.name
        return None, "none"

    def _priced(self, node: OpNode) -> tuple[float | None, str]:
        if self._cache is None:
            return self._price(node)
        v = self._state_version()
        if v != self._version:
            self._cache.clear()
            self._version = v
        try:
            sig = node_signature(node)
            ent = self._cache.get(sig)
        except TypeError:            # exotic attrs: price uncached
            return self._price(node)
        if ent is not None:
            self.stats.hits += 1
            return ent
        self.stats.misses += 1
        ent = self._price(node)
        self._cache[sig] = ent
        return ent

    def latency_us(self, node: OpNode) -> float | None:
        return self._priced(node)[0]

    def price_batch(self, nodes) -> list:
        """Vectorized pricing for a node batch (the scheduler's pre-pass).

        Cache hits resolve per signature exactly like :meth:`latency_us`;
        misses are grouped and pushed through the highest-priority engine's
        ``price_batch`` when one exists (the analytical roofline vectorizes),
        falling back to the scalar priority chain per node whenever a
        profile-DB-backed engine could claim the node — batch results are
        bit-identical to the scalar path by construction.  Duplicate
        signatures within one batch count one miss then hits, matching the
        scalar call sequence."""
        if self._cache is None:
            return [self._price(n)[0] for n in nodes]
        v = self._state_version()
        if v != self._version:
            self._cache.clear()
            self._version = v
        out: list = [None] * len(nodes)
        last = self.engines[-1] if self.engines else None
        vec_engine = last if hasattr(last, "price_batch") else None
        pending: dict[tuple, list[int]] = {}
        sig_of: list = [None] * len(nodes)
        for i, node in enumerate(nodes):
            try:
                sig = node_signature(node)
            except TypeError:            # exotic attrs: price uncached
                out[i] = self._price(node)[0]
                continue
            ent = self._cache.get(sig)
            if ent is not None:
                self.stats.hits += 1
                out[i] = ent[0]
            elif sig in pending:
                self.stats.hits += 1     # scalar path: earlier miss primed it
                pending[sig].append(i)
            else:
                self.stats.misses += 1
                pending[sig] = [i]
                sig_of[i] = sig
        if not pending:
            return out
        vec_nodes: list[OpNode] = []
        vec_sigs: list[tuple] = []
        for i, sig in enumerate(sig_of):
            if sig is None:
                continue
            node = nodes[i]
            # a node any higher-priority engine claims keeps the scalar
            # fallback chain (profile DBs may still decline with None)
            if vec_engine is not None and vec_engine.supports(node) and not any(
                    e.supports(node) for e in self.engines[:-1]):
                vec_nodes.append(node)
                vec_sigs.append(sig)
            else:
                ent = self._price(node)
                self._cache[sig] = ent
                for j in pending[sig]:
                    out[j] = ent[0]
        if vec_nodes:
            prices = vec_engine.price_batch(vec_nodes)
            for sig, t in zip(vec_sigs, prices):
                ent = (t, vec_engine.name) if t is not None else (None, "none")
                self._cache[sig] = ent
                for j in pending[sig]:
                    out[j] = ent[0]
        return out

    def engine_for(self, node: OpNode) -> str:
        return self._priced(node)[1]

    def cache_clear(self) -> None:
        if self._cache is not None:
            self._cache.clear()
        self.stats = CacheStats()

    def cache_info(self) -> CacheStats:
        return self.stats

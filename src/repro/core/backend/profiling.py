"""Profiling engine + profiling database (paper §3.3a).

Operators are synthesised from their IR description, executed under jit on
the locally available hardware (XLA-CPU in this container; the design is
identical for a GPU/TPU fleet — only the dispatch target changes), and the
measured latency is cached in a JSON database keyed by
(hardware, kind, dims, dtype).  The same database is the training set for the
prediction engine.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend.hardware import HardwareSpec
from repro.core.ir import OpNode

DB_PATH = Path(__file__).resolve().parents[4] / "results" / "profile_db.json"

_DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32, "f16": jnp.float16,
           "int8": jnp.int8, "f8": jnp.bfloat16}


def node_key(node: OpNode, hw_name: str) -> str:
    dims = node.attrs.get("mm_dims") or node.attrs.get("attn_dims") or node.out_shape
    return f"{hw_name}|{node.kind}|{','.join(map(str, dims))}|{node.dtype}"


class ProfileDB:
    def __init__(self, path: Path | str = DB_PATH):
        self.path = Path(path)
        self.data: dict[str, dict] = {}
        self.version = 0     # bumped on every put; price caches key on it
        if self.path.exists():
            try:
                self.data = json.loads(self.path.read_text())
            except Exception:
                self.data = {}

    def get(self, key: str):
        e = self.data.get(key)
        return e["us"] if e else None

    def put(self, key: str, us: float, meta: dict):
        self.version += 1
        self.data[key] = {"us": us, **meta}

    def save(self):
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.path.write_text(json.dumps(self.data, indent=0))

    def entries(self):
        return self.data.items()


_DISPATCH_US: list[float] = []


def dispatch_overhead_us() -> float:
    """Measured jit-dispatch floor on this host.  Profiled operator times
    subtract it: inside a fused step the dispatch is paid once per step, not
    per operator (calibrated like the paper's slowdown factors)."""
    if not _DISPATCH_US:
        # a minimal COMPUTE op (not identity): captures thread-pool wakeup +
        # buffer allocation, which every standalone op measurement pays
        x = jnp.zeros((8,), jnp.float32)
        f = jax.jit(lambda x: x + 1.0)
        jax.block_until_ready(f(x))
        ts = []
        for _ in range(80):
            t0 = time.perf_counter()
            jax.block_until_ready(f(x))
            ts.append(time.perf_counter() - t0)
        _DISPATCH_US.append(float(np.median(ts) * 1e6))
    return _DISPATCH_US[0]


def _time_fn(fn, *args, min_time_s: float = 0.05, max_iters: int = 200) -> float:
    """Median wall time per call (us) of a jitted fn, dispatch-corrected."""
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    # warm
    jax.block_until_ready(jfn(*args))
    times = []
    total = 0.0
    while total < min_time_s and len(times) < max_iters:
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
    raw = float(np.min(times) * 1e6)   # min: least contention noise
    return max(raw - dispatch_overhead_us(), 0.02 * raw)


def synthesize_and_measure(node: OpNode) -> float | None:
    """Build the operator from its IR description and time it on local XLA."""
    dt = _DTYPES.get(node.dtype, jnp.bfloat16)
    rng = jax.random.PRNGKey(0)
    k = node.kind
    try:
        if k == "matmul":
            dims = node.attrs.get("mm_dims")
            if not dims:
                return None
            m, n, kk = (int(x) for x in dims)
            a = jax.random.normal(rng, (m, kk), jnp.float32).astype(dt)
            b = jax.random.normal(rng, (kk, n), jnp.float32).astype(dt)
            return _time_fn(lambda x, y: x @ y, a, b)
        if k == "attention":
            bsz, h, sq, skv, d = (int(x) for x in node.attrs["attn_dims"])
            q = jax.random.normal(rng, (bsz, h, sq, d), jnp.float32).astype(dt)
            kv = jax.random.normal(rng, (bsz, h, skv, d), jnp.float32).astype(dt)

            def attn(q, kv):
                s = jnp.einsum("bhsd,bhtd->bhst", q, kv) / jnp.sqrt(float(d))
                p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
                return jnp.einsum("bhst,bhtd->bhsd", p, kv)

            return _time_fn(attn, q, kv)
        if k in ("norm", "softmax", "elementwise", "reduce", "copy", "transpose"):
            shape = tuple(int(x) for x in node.out_shape) or (1024,)
            x = jax.random.normal(rng, shape, jnp.float32).astype(dt)
            if k == "norm":
                w = jnp.ones(shape[-1:], dt)
                return _time_fn(
                    lambda x, w: (x * jax.lax.rsqrt(
                        jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True) + 1e-6
                    ).astype(x.dtype)) * w, x, w)
            if k == "softmax":
                return _time_fn(lambda x: jax.nn.softmax(x.astype(jnp.float32), -1).astype(x.dtype), x)
            if k == "reduce":
                return _time_fn(lambda x: jnp.sum(x.astype(jnp.float32)), x)
            if k == "transpose":
                if x.ndim < 2:
                    return _time_fn(lambda x: x + 1, x)
                perm = tuple(range(x.ndim - 2)) + (x.ndim - 1, x.ndim - 2)
                return _time_fn(lambda x: jnp.transpose(x, perm) + 0, x)
            return _time_fn(lambda x: jax.nn.silu(x) * x + 1.0, x)
        if k in ("embed", "gather"):
            v = int(node.attrs.get("vocab", 32768))
            d = int(node.out_shape[-1]) if node.out_shape else 512
            t = int(np.prod(node.out_shape[:-1])) if len(node.out_shape) > 1 else 1024
            tbl = jax.random.normal(rng, (v, d), jnp.float32).astype(dt)
            idx = jax.random.randint(rng, (t,), 0, v)
            return _time_fn(lambda tbl, idx: jnp.take(tbl, idx, axis=0), tbl, idx)
        return None
    except Exception:
        return None


class ProfilingEngine:
    """Highest-priority engine: exact measured latencies from the DB, with
    optional on-demand measurement on the local backend."""

    name = "profiling"
    priority = 30

    SUPPORTED = {"matmul", "attention", "norm", "softmax", "elementwise",
                 "reduce", "embed", "gather", "copy", "transpose"}

    def __init__(self, hw: HardwareSpec, db: ProfileDB | None = None,
                 *, measure_on_miss: bool = False):
        self.hw = hw
        self.db = db or ProfileDB()
        self.measure_on_miss = measure_on_miss and hw.name == "xla_cpu"
        self._self_puts = 0

    @property
    def state_version(self) -> int:
        """Changes when *external* DB mutation could alter an already-given
        answer (fused-engine price caches invalidate on it).  Own
        measure-on-miss puts are excluded: the value cached for that
        signature IS the measurement, so nothing previously answered
        changes."""
        return self.db.version - self._self_puts

    def supports(self, node: OpNode) -> bool:
        return node.kind in self.SUPPORTED

    def latency_us(self, node: OpNode) -> float | None:
        key = node_key(node, self.hw.name)
        us = self.db.get(key)
        if us is not None:
            return us
        if not self.measure_on_miss:
            return None
        us = synthesize_and_measure(node)
        if us is not None:
            self._self_puts += 1
            self.db.put(key, us, {"kind": node.kind,
                                  "dims": list(node.attrs.get("mm_dims")
                                               or node.attrs.get("attn_dims")
                                               or node.out_shape),
                                  "dtype": node.dtype,
                                  "flops": node.flops,
                                  "bytes": node.total_bytes})
        return us

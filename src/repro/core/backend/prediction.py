"""Prediction engine: per-operator random-forest latency regressors
(paper §3.3b), implemented from scratch in numpy (no sklearn offline).

Features are log-scaled shape/flops/bytes descriptors; targets are log
latency.  One compact forest per operator kind, trained from the profiling
database, generalises to unseen shapes without hardware execution.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.backend.hardware import HardwareSpec
from repro.core.backend.profiling import ProfileDB
from repro.core.ir import OpNode


# --------------------------------------------------------------------------
# CART regression tree + random forest (from scratch)
# --------------------------------------------------------------------------

class _Tree:
    def __init__(self, max_depth=8, min_leaf=2, n_feature_frac=0.8, rng=None):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.n_feature_frac = n_feature_frac
        self.rng = rng or np.random.default_rng(0)
        self.nodes: list[tuple] = []  # (feat, thresh, left, right) or ('leaf', value)

    def fit(self, X: np.ndarray, y: np.ndarray):
        self.nodes = []
        self._build(X, y, 0)
        return self

    def _build(self, X, y, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(None)
        if depth >= self.max_depth or len(y) <= self.min_leaf or np.ptp(y) < 1e-9:
            self.nodes[idx] = ("leaf", float(np.mean(y)))
            return idx
        nf = X.shape[1]
        feats = self.rng.choice(nf, max(1, int(nf * self.n_feature_frac)), replace=False)
        best = None  # (sse, feat, thresh)
        for f in feats:
            xs = X[:, f]
            order = np.argsort(xs)
            xs_s, ys_s = xs[order], y[order]
            # candidate splits between distinct values
            distinct = np.nonzero(np.diff(xs_s) > 1e-12)[0]
            if len(distinct) == 0:
                continue
            cands = distinct[np.linspace(0, len(distinct) - 1,
                                         min(16, len(distinct))).astype(int)]
            csum = np.cumsum(ys_s)
            csum2 = np.cumsum(ys_s ** 2)
            n = len(ys_s)
            for c in cands:
                nl = c + 1
                nr = n - nl
                if nl < self.min_leaf or nr < self.min_leaf:
                    continue
                sl, sl2 = csum[c], csum2[c]
                sr, sr2 = csum[-1] - sl, csum2[-1] - sl2
                sse = (sl2 - sl * sl / nl) + (sr2 - sr * sr / nr)
                if best is None or sse < best[0]:
                    best = (sse, f, (xs_s[c] + xs_s[c + 1]) / 2.0)
        if best is None:
            self.nodes[idx] = ("leaf", float(np.mean(y)))
            return idx
        _, f, t = best
        mask = X[:, f] <= t
        left = self._build(X[mask], y[mask], depth + 1)
        right = self._build(X[~mask], y[~mask], depth + 1)
        self.nodes[idx] = (f, t, left, right)
        return idx

    def predict_one(self, x: np.ndarray) -> float:
        i = 0
        while True:
            node = self.nodes[i]
            if node[0] == "leaf":
                return node[1]
            f, t, l, r = node
            i = l if x[f] <= t else r


class RandomForest:
    def __init__(self, n_trees=24, max_depth=9, seed=0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.seed = seed
        self.trees: list[_Tree] = []

    def fit(self, X: np.ndarray, y: np.ndarray):
        rng = np.random.default_rng(self.seed)
        self.trees = []
        n = len(y)
        for t in range(self.n_trees):
            rows = rng.integers(0, n, n)  # bootstrap
            tree = _Tree(max_depth=self.max_depth,
                         rng=np.random.default_rng(self.seed * 1000 + t))
            tree.fit(X[rows], y[rows])
            self.trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        out = np.zeros(len(X))
        for i, x in enumerate(X):
            out[i] = float(np.mean([t.predict_one(x) for t in self.trees]))
        return out


# --------------------------------------------------------------------------
# Feature extraction
# --------------------------------------------------------------------------

def node_features(node: OpNode) -> np.ndarray:
    dims = list(node.attrs.get("mm_dims") or node.attrs.get("attn_dims")
                or node.out_shape or (1,))
    dims = (dims + [1, 1, 1, 1, 1])[:5]
    flops = max(node.flops, 1.0)
    byts = max(node.total_bytes, 1.0)
    return np.array([
        *[math.log1p(d) for d in dims],
        math.log1p(flops),
        math.log1p(byts),
        math.log1p(flops / byts),
        1.0 if node.dtype in ("bf16", "f16") else 0.0,
    ])


def entry_features(entry: dict) -> np.ndarray:
    dims = list(entry.get("dims", (1,)))
    dims = (dims + [1, 1, 1, 1, 1])[:5]
    flops = max(entry.get("flops", 1.0), 1.0)
    byts = max(entry.get("bytes", 1.0), 1.0)
    return np.array([
        *[math.log1p(float(d)) for d in dims],
        math.log1p(flops),
        math.log1p(byts),
        math.log1p(flops / byts),
        1.0 if entry.get("dtype") in ("bf16", "f16") else 0.0,
    ])


class PredictionEngine:
    """Per-kind random forests trained from the profiling DB."""

    name = "prediction"
    priority = 20

    def __init__(self, hw: HardwareSpec, db: ProfileDB | None = None):
        self.hw = hw
        self.db = db or ProfileDB()
        self.models: dict[str, RandomForest] = {}
        self._trained = False
        self.state_version = 0   # bumped per (re)train; invalidates price caches

    def train(self, *, exclude_keys: set[str] | None = None, min_samples: int = 8):
        self.state_version += 1
        by_kind: dict[str, list[tuple[np.ndarray, float]]] = {}
        for key, entry in self.db.entries():
            if exclude_keys and key in exclude_keys:
                continue
            hwname, kind = key.split("|")[:2]
            if hwname != self.hw.name:
                continue
            by_kind.setdefault(kind, []).append(
                (entry_features(entry), math.log(max(entry["us"], 1e-3))))
        for kind, rows in by_kind.items():
            if len(rows) < min_samples:
                continue
            X = np.stack([r[0] for r in rows])
            y = np.array([r[1] for r in rows])
            self.models[kind] = RandomForest().fit(X, y)
        self._trained = True
        return self

    def supports(self, node: OpNode) -> bool:
        if not self._trained:
            self.train()
        return node.kind in self.models

    def latency_us(self, node: OpNode) -> float | None:
        if not self.supports(node):
            return None
        x = node_features(node)[None, :]
        return float(math.exp(self.models[node.kind].predict(x)[0]))

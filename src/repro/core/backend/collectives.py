"""Hierarchical link-centric collective model (paper §3.3c).

Collectives decompose into physical link-level transfers: per hop the cost is
calibrated handshake latency + payload / effective bandwidth.  Ring and tree
algorithms over the chosen link domain; cross-pod ('dp across DCN') groups pay
the hierarchical price: intra-pod reduce-scatter + inter-pod exchange +
intra-pod all-gather.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.backend.hardware import HardwareSpec, LinkDomain
from repro.core.simcache import CacheStats


def _ring_steps(kind: str, n: int) -> tuple[float, float]:
    """(#hops, per-hop payload fraction of the FULL buffer) for ring algos."""
    if n <= 1:
        return 0.0, 0.0
    if kind == "all_reduce":
        return 2.0 * (n - 1), 1.0 / n
    if kind in ("all_gather", "reduce_scatter"):
        return float(n - 1), 1.0 / n
    if kind == "all_to_all":
        return float(n - 1), 1.0 / n
    if kind in ("send", "recv", "collective_permute"):
        return 1.0, 1.0
    raise ValueError(kind)


def _tree_steps(kind: str, n: int) -> tuple[float, float]:
    if n <= 1:
        return 0.0, 0.0
    levels = math.ceil(math.log2(n))
    if kind == "all_reduce":
        return 2.0 * levels, 1.0          # reduce + broadcast, full payload/hop
    return float(levels), 1.0


def collective_time_us(kind: str, payload_bytes: float, group_size: int,
                       link: LinkDomain, *, algorithm: str = "ring",
                       congestion: float = 1.0) -> float:
    """Time for one collective over a single link domain.

    ``payload_bytes``: full per-device buffer size.  ``congestion`` > 1 divides
    the effective bandwidth (bandwidth-aware overlap model, paper §3.4).
    """
    if group_size <= 1 or payload_bytes <= 0:
        return 0.0
    steps, frac = (_tree_steps if algorithm == "tree" else _ring_steps)(kind, group_size)
    bw = link.bandwidth * max(link.links_per_chip, 1) / max(congestion, 1.0)
    per_hop = link.latency_us + (payload_bytes * frac) / bw * 1e6
    return steps * per_hop


@dataclass(frozen=True)
class GroupSpec:
    """A communication group: participants within a pod and across pods."""
    intra_size: int = 1     # group participants inside one pod (ICI)
    inter_size: int = 1     # pods spanned (DCN)


# Memo for hierarchical collective times.  The p2p/DP-sync terms in
# ``Simulator.simulate`` recompute the same handful of (kind, payload, group)
# tuples for every sweep candidate; the result is a pure function of its
# arguments, so a flat dict suffices.  The key carries the ``LinkDomain``
# field values themselves (frozen, hashable) rather than the HardwareSpec
# identity — a different spec, or a recalibrated link, hashes to a different
# key, which gives the same staleness guarantee the pricing cache gets from
# its engine state version, without any explicit versioning.
_MEMO: dict[tuple, float] = {}
_MEMO_MAX = 200_000          # runaway-sweep backstop, not a tuning knob
_MEMO_STATS = CacheStats()


def collective_memo_stats() -> CacheStats:
    return _MEMO_STATS


def collective_memo_clear() -> None:
    _MEMO.clear()
    _MEMO_STATS.hits = _MEMO_STATS.misses = 0


def hierarchical_collective_time_us(kind: str, payload_bytes: float,
                                    group: GroupSpec, hw: HardwareSpec,
                                    *, algorithm: str = "ring",
                                    congestion: float = 1.0) -> float:
    """Cross-pod collectives decompose hierarchically:
    intra-pod reduce-scatter -> inter-pod stage on the shard -> intra-pod
    all-gather (standard hierarchical all-reduce).  Memoized (module level,
    shared across simulators): see ``_MEMO`` above."""
    key = (kind, payload_bytes, group.intra_size, group.inter_size,
           hw.intra, hw.inter, algorithm, congestion)
    t = _MEMO.get(key)
    if t is not None:
        _MEMO_STATS.hits += 1
        return t
    _MEMO_STATS.misses += 1
    t = _hierarchical_uncached(kind, payload_bytes, group, hw,
                               algorithm=algorithm, congestion=congestion)
    if len(_MEMO) >= _MEMO_MAX:
        _MEMO.clear()
    _MEMO[key] = t
    return t


def _hierarchical_uncached(kind: str, payload_bytes: float, group: GroupSpec,
                           hw: HardwareSpec, *, algorithm: str = "ring",
                           congestion: float = 1.0) -> float:
    ni, ne = group.intra_size, group.inter_size
    if ne <= 1:
        return collective_time_us(kind, payload_bytes, ni, hw.intra,
                                  algorithm=algorithm, congestion=congestion)
    if ni <= 1:
        return collective_time_us(kind, payload_bytes, ne, hw.inter,
                                  algorithm=algorithm, congestion=congestion)
    if kind == "all_reduce":
        t = collective_time_us("reduce_scatter", payload_bytes, ni, hw.intra,
                               congestion=congestion)
        t += collective_time_us("all_reduce", payload_bytes / ni, ne, hw.inter,
                                congestion=congestion)
        t += collective_time_us("all_gather", payload_bytes, ni, hw.intra,
                                congestion=congestion)
        return t
    # gather/scatter style: do the intra stage then the inter stage on shards
    t = collective_time_us(kind, payload_bytes, ni, hw.intra, congestion=congestion)
    t += collective_time_us(kind, payload_bytes / ni, ne, hw.inter,
                            congestion=congestion)
    return t


def link_traffic_bytes(kind: str, payload_bytes: float, group_size: int) -> float:
    """Per-device link traffic (used for the roofline collective term)."""
    n = max(group_size, 1)
    if n == 1:
        return 0.0
    if kind == "all_reduce":
        return 2.0 * (n - 1) / n * payload_bytes
    if kind == "all_gather":
        return (n - 1) * payload_bytes / n
    if kind in ("reduce_scatter", "all_to_all"):
        return (n - 1) / n * payload_bytes
    return payload_bytes

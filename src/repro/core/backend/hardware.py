"""Hardware specifications and interconnect topologies.

Constants follow public spec sheets; the assignment's TPU v5e numbers
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI) are the default target.
The simulator treats the fleet as hierarchical link domains: ICI torus links
inside a pod, DCN between pods — the paper's "hierarchical link-centric"
communication model with calibrated per-hop latency + effective bandwidth.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class LinkDomain:
    name: str                 # 'ici' | 'dcn' | 'nvlink' | 'ib' | 'host'
    bandwidth: float          # effective GB-per-second per direction per link
    latency_us: float         # per-hop handshake latency
    links_per_chip: int = 1
    topology: str = "ring"    # ring | switch | mesh2d


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: dict[str, float]       # dtype -> FLOP/s
    hbm_bw: float                      # bytes/s
    hbm_bytes: float
    vmem_bytes: float                  # on-chip (VMEM / SMEM+L2)
    intra: LinkDomain                  # intra-pod / intra-node fabric
    inter: LinkDomain                  # cross-pod / cross-node fabric
    mxu_dim: int = 128                 # systolic array tile (alignment grain)
    sub_dim: int = 8
    # calibrated effective-utilization knobs (paper: "calibrated ... from profiling")
    matmul_eff: float = 0.85           # large aligned matmul efficiency
    mem_eff: float = 0.80              # HBM streaming efficiency
    dispatch_us: float = 0.3           # per-dispatch overhead (opt leaves etc.)
    scatter_inplace: bool = True       # XLA aliases in-place updates through
                                       # loop carries (TPU/GPU yes; CPU no)
    overlap_slowdown_compute: float = 1.12   # ratio-based overlap model defaults
    overlap_slowdown_comm: float = 1.25
    overlap_slowdown_comm_comm: float = 1.9

    def flops_for(self, dtype: str) -> float:
        return self.peak_flops.get(dtype, self.peak_flops.get("bf16", 1e12))


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops={"bf16": 197e12, "f32": 98.5e12, "int8": 394e12, "f8": 394e12},
    hbm_bw=819e9,
    hbm_bytes=16e9,
    vmem_bytes=128e6,
    intra=LinkDomain("ici", 50e9, 1.0, links_per_chip=4, topology="mesh2d"),
    inter=LinkDomain("dcn", 25e9, 10.0, links_per_chip=1, topology="switch"),
)

TPU_V5P = HardwareSpec(
    name="tpu_v5p",
    peak_flops={"bf16": 459e12, "f32": 229e12, "int8": 918e12, "f8": 918e12},
    hbm_bw=2765e9,
    hbm_bytes=95e9,
    vmem_bytes=128e6,
    intra=LinkDomain("ici", 100e9, 1.0, links_per_chip=6, topology="mesh2d"),
    inter=LinkDomain("dcn", 25e9, 10.0, links_per_chip=1, topology="switch"),
)

A100_80G = HardwareSpec(
    name="a100_80g",
    peak_flops={"bf16": 312e12, "f32": 19.5e12, "int8": 624e12, "f8": 312e12},
    hbm_bw=2039e9,
    hbm_bytes=80e9,
    vmem_bytes=40e6 + 20e6,
    intra=LinkDomain("nvlink", 300e9, 0.7, links_per_chip=12, topology="switch"),
    inter=LinkDomain("ib", 25e9, 5.0, links_per_chip=1, topology="switch"),
    mxu_dim=16, sub_dim=8,
)

H100_SXM = HardwareSpec(
    name="h100_sxm",
    peak_flops={"bf16": 989e12, "f32": 67e12, "int8": 1979e12, "f8": 1979e12},
    hbm_bw=3350e9,
    hbm_bytes=80e9,
    vmem_bytes=50e6 + 25e6,
    intra=LinkDomain("nvlink", 450e9, 0.7, links_per_chip=18, topology="switch"),
    inter=LinkDomain("ib", 50e9, 5.0, links_per_chip=1, topology="switch"),
    mxu_dim=16, sub_dim=8,
)

XLA_CPU = HardwareSpec(
    # measured on this container (single-core XLA CPU): 107/135 GFLOP/s
    # bf16/f32 matmul, ~3.3-4.3 GB/s effective stream bandwidth.  Used as the
    # accuracy ground-truth target in benchmarks (the paper validates on real
    # GPUs; we validate on the hardware we actually have).
    name="xla_cpu",
    peak_flops={"bf16": 1.07e11, "f32": 1.35e11},
    hbm_bw=3.6e9,
    hbm_bytes=32e9,
    vmem_bytes=32e6,
    intra=LinkDomain("host", 1e10, 1.0),
    inter=LinkDomain("host", 1e10, 1.0),
    mxu_dim=16, sub_dim=4,
    matmul_eff=0.8, mem_eff=1.0,
    dispatch_us=25.0,
    scatter_inplace=False,
)

HARDWARE = {h.name: h for h in (TPU_V5E, TPU_V5P, A100_80G, H100_SXM, XLA_CPU)}


def get_hardware(name: str) -> HardwareSpec:
    return HARDWARE[name]

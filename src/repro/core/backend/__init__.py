from repro.core.backend.analytical import AnalyticalEngine
from repro.core.backend.engine import FusedEngine
from repro.core.backend.hardware import HARDWARE, HardwareSpec, get_hardware
from repro.core.backend.prediction import PredictionEngine, RandomForest
from repro.core.backend.profiling import ProfileDB, ProfilingEngine

__all__ = [
    "AnalyticalEngine", "FusedEngine", "HARDWARE", "HardwareSpec",
    "get_hardware", "PredictionEngine", "RandomForest", "ProfileDB",
    "ProfilingEngine",
]

"""Analytical engine: roofline for compute, link-centric model for comm.

Compute ops (paper §3.3c): t = max(flops / (peak * eff), bytes / (bw * eff)).
TPU adaptation: MXU efficiency degrades when matmul dims misalign with the
128x128 systolic tile / 8-row subtile, and when the working set exceeds VMEM
(double-buffering stalls).  This replaces CUDA occupancy heuristics — the
paper's analytical engine is hardware-agnostic by design.
"""
from __future__ import annotations

import math

from repro.core.backend.collectives import GroupSpec, hierarchical_collective_time_us
from repro.core.backend.hardware import HardwareSpec
from repro.core.ir import OpNode

_DTYPE_KEY = {"bf16": "bf16", "f16": "bf16", "f32": "f32", "fp32": "f32",
              "int8": "int8", "f8": "f8", "fp8": "f8"}


def mxu_efficiency(node: OpNode, hw: HardwareSpec) -> float:
    """Alignment-based MXU utilisation for matmul-class ops."""
    eff = hw.matmul_eff
    dims = node.attrs.get("mm_dims")  # (M, N, K) when the tracer knows them
    if not dims:
        return eff
    m, n, k = dims
    for d in (n, k):
        if d % hw.mxu_dim != 0:
            eff *= max(0.35, (d % hw.mxu_dim) / hw.mxu_dim if d < hw.mxu_dim
                       else 1.0 - 0.5 * (hw.mxu_dim - d % hw.mxu_dim) / hw.mxu_dim)
    if m % hw.sub_dim != 0 and m < hw.sub_dim:
        eff *= max(0.2, m / hw.sub_dim)
    # skinny matmuls can't fill the systolic pipeline
    if min(m, n, k) < hw.mxu_dim // 4:
        eff *= 0.7
    return max(eff, 0.05)


class AnalyticalEngine:
    name = "analytical"
    priority = 10

    def __init__(self, hw: HardwareSpec, *, algorithm: str = "ring"):
        self.hw = hw
        self.algorithm = algorithm

    def supports(self, node: OpNode) -> bool:
        return True  # the universal fallback

    def latency_us(self, node: OpNode) -> float | None:
        hw = self.hw
        if node.is_comm:
            group = GroupSpec(
                intra_size=node.comm_size if node.comm_group != "pod" else 1,
                inter_size=node.comm_size if node.comm_group == "pod" else 1,
            )
            return hierarchical_collective_time_us(
                node.kind, node.comm_bytes, group, hw, algorithm=self.algorithm)
        dtype = _DTYPE_KEY.get(node.dtype, "bf16")
        peak = hw.flops_for(dtype)
        eff = mxu_efficiency(node, hw) if node.kind in ("matmul", "attention", "conv", "fused") \
            else 1.0
        t_compute = node.flops / (peak * eff) if node.flops else 0.0
        total_bytes = node.total_bytes
        if node.kind == "scatter" and not hw.scatter_inplace:
            # non-aliasing backend copies the whole buffer on functional update
            total_bytes += 2.0 * node.attrs.get("operand_bytes", 0.0)
        t_memory = total_bytes / (hw.hbm_bw * hw.mem_eff) if total_bytes else 0.0
        t = max(t_compute, t_memory)
        # fixed per-op dispatch overhead (XLA fusion boundary cost)
        return t * 1e6 + 0.3

"""Analytical engine: roofline for compute, link-centric model for comm.

Compute ops (paper §3.3c): t = max(flops / (peak * eff), bytes / (bw * eff)).
TPU adaptation: MXU efficiency degrades when matmul dims misalign with the
128x128 systolic tile / 8-row subtile, and when the working set exceeds VMEM
(double-buffering stalls).  This replaces CUDA occupancy heuristics — the
paper's analytical engine is hardware-agnostic by design.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.backend.collectives import GroupSpec, hierarchical_collective_time_us
from repro.core.backend.hardware import HardwareSpec
from repro.core.ir import OpNode

_DTYPE_KEY = {"bf16": "bf16", "f16": "bf16", "f32": "f32", "fp32": "f32",
              "int8": "int8", "f8": "f8", "fp8": "f8"}


def mxu_efficiency(node: OpNode, hw: HardwareSpec) -> float:
    """Alignment-based MXU utilisation for matmul-class ops."""
    eff = hw.matmul_eff
    dims = node.attrs.get("mm_dims")  # (M, N, K) when the tracer knows them
    if not dims:
        return eff
    m, n, k = dims
    for d in (n, k):
        if d % hw.mxu_dim != 0:
            eff *= max(0.35, (d % hw.mxu_dim) / hw.mxu_dim if d < hw.mxu_dim
                       else 1.0 - 0.5 * (hw.mxu_dim - d % hw.mxu_dim) / hw.mxu_dim)
    if m % hw.sub_dim != 0 and m < hw.sub_dim:
        eff *= max(0.2, m / hw.sub_dim)
    # skinny matmuls can't fill the systolic pipeline
    if min(m, n, k) < hw.mxu_dim // 4:
        eff *= 0.7
    return max(eff, 0.05)


_MXU_KINDS = ("matmul", "attention", "conv", "fused")


class AnalyticalEngine:
    name = "analytical"
    priority = 10

    def __init__(self, hw: HardwareSpec, *, algorithm: str = "ring"):
        self.hw = hw
        self.algorithm = algorithm
        # eff is a pure function of (mm_dims, hw); sweeps re-derive it for
        # the same few dozen dim tuples thousands of times
        self._effs: dict = {}

    def supports(self, node: OpNode) -> bool:
        return True  # the universal fallback

    def _mxu_eff(self, node: OpNode) -> float:
        dims = node.attrs.get("mm_dims")
        key = tuple(dims) if dims else None
        e = self._effs.get(key)
        if e is None:
            e = self._effs[key] = mxu_efficiency(node, self.hw)
        return e

    def _comm_us(self, node: OpNode) -> float:
        group = GroupSpec(
            intra_size=node.comm_size if node.comm_group != "pod" else 1,
            inter_size=node.comm_size if node.comm_group == "pod" else 1,
        )
        return hierarchical_collective_time_us(
            node.kind, node.comm_bytes, group, self.hw, algorithm=self.algorithm)

    def _roofline_inputs(self, node: OpNode) -> tuple[float, float, float]:
        """(flops, total_bytes, peak*eff) — the roofline columns for one
        compute node, shared verbatim by the scalar and batch paths."""
        hw = self.hw
        peak = hw.flops_for(_DTYPE_KEY.get(node.dtype, "bf16"))
        eff = self._mxu_eff(node) if node.kind in _MXU_KINDS else 1.0
        total_bytes = node.total_bytes
        if node.kind == "scatter" and not hw.scatter_inplace:
            # non-aliasing backend copies the whole buffer on functional update
            total_bytes += 2.0 * node.attrs.get("operand_bytes", 0.0)
        return node.flops, total_bytes, peak * eff

    def latency_us(self, node: OpNode) -> float | None:
        if node.is_comm:
            return self._comm_us(node)
        flops, total_bytes, denom = self._roofline_inputs(node)
        t_compute = flops / denom if flops else 0.0
        t_memory = total_bytes / (self.hw.hbm_bw * self.hw.mem_eff) \
            if total_bytes else 0.0
        t = max(t_compute, t_memory)
        # fixed per-op dispatch overhead (XLA fusion boundary cost)
        return t * 1e6 + 0.3

    def price_batch(self, nodes) -> list:
        """Vectorized roofline over a node batch: the FLOPs/bytes/peak*eff
        columns go through numpy float64 element-wise ops — the same IEEE
        operations in the same per-element order as :meth:`latency_us`, so
        results are bit-identical to the scalar path (asserted in
        tests/test_sweep_parallel.py).  Comm nodes keep the per-node
        hierarchical-collective model (already memoized)."""
        out: list = [0.0] * len(nodes)
        idx: list[int] = []
        flops: list[float] = []
        bts: list[float] = []
        denom: list[float] = []
        for i, node in enumerate(nodes):
            if node.is_comm:
                out[i] = self._comm_us(node)
                continue
            f, tb, d = self._roofline_inputs(node)
            idx.append(i)
            flops.append(f)
            bts.append(tb)
            denom.append(d)
        if idx:
            f = np.asarray(flops, dtype=np.float64)
            t_c = f / np.asarray(denom, dtype=np.float64)   # 0/x == 0.0 exactly
            t_m = np.asarray(bts, dtype=np.float64) / (self.hw.hbm_bw * self.hw.mem_eff)
            t = np.maximum(t_c, t_m) * 1e6 + 0.3
            for j, i in enumerate(idx):
                out[i] = float(t[j])
        return out

"""Operator-graph IR for the Charon simulator.

A ``Graph`` is a DAG of ``OpNode``s at PyTorch-profiler granularity (matmul,
attention, norm, elementwise fusion, collective, ...).  Parallelism and
optimization passes rewrite graphs; backend engines price individual nodes;
the scheduler turns a priced graph into a per-rank timeline.

Charon's single-block trick (paper §3.2a): a node may carry ``repeat=n`` —
the scheduler expands it n times; tracing cost stays O(1) in depth.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

COMPUTE_KINDS = {
    "matmul", "attention", "conv", "elementwise", "norm", "reduce", "softmax",
    "embed", "gather", "scatter", "sort", "transpose", "copy", "scan_cell",
    "fused", "optimizer", "quant",
}
COMM_KINDS = {"all_reduce", "all_gather", "reduce_scatter", "all_to_all",
              "send", "recv", "collective_permute"}


@dataclass
class OpNode:
    name: str
    kind: str
    deps: list[str] = field(default_factory=list)
    out_shape: tuple = ()
    dtype: str = "bf16"
    flops: float = 0.0
    bytes_in: float = 0.0
    bytes_out: float = 0.0
    # communication
    comm_bytes: float = 0.0          # payload per participating device
    comm_group: str = ""             # mesh axis: 'tp' | 'dp' | 'ep' | 'pp' | 'pod'
    comm_size: int = 1               # participants
    overlappable: bool = False       # may run on a comm stream alongside compute
    stream: str = "compute"
    repeat: int = 1                  # single-block extrapolation multiplier
    phase: str = "fwd"               # fwd | bwd | opt | comm
    attrs: dict[str, Any] = field(default_factory=dict)

    @property
    def is_comm(self) -> bool:
        return self.kind in COMM_KINDS

    @property
    def total_bytes(self) -> float:
        return self.bytes_in + self.bytes_out

    def clone(self, **kw) -> "OpNode":
        # hot path (pass pipelines clone every node of every graph): a direct
        # __dict__ copy is ~6x faster than dataclasses.replace
        n = object.__new__(OpNode)
        n.__dict__.update(self.__dict__)
        n.deps = list(self.deps)
        n.attrs = dict(self.attrs)
        for k, v in kw.items():
            setattr(n, k, v)
        return n


class Graph:
    """Ordered operator DAG (insertion order is a valid topological order)."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.nodes: dict[str, OpNode] = {}
        self._ctr = 0
        self._topo: list[OpNode] | None = None   # cached toposort order

    # ---- construction ----
    def add(self, node: OpNode) -> OpNode:
        if node.name in self.nodes:
            self._ctr += 1
            node.name = f"{node.name}.{self._ctr}"
        self.nodes[node.name] = node
        self._topo = None
        return node

    def op(self, kind: str, name: str | None = None, deps: Iterable[str] = (),
           **kw) -> OpNode:
        self._ctr += 1
        return self.add(OpNode(name or f"{kind}.{self._ctr}", kind,
                               deps=list(deps), **kw))

    def remove(self, name: str):
        self._topo = None
        node = self.nodes.pop(name)
        for other in self.nodes.values():
            other.deps = [node.deps[0] if d == name and node.deps else d
                          for d in other.deps if d != name or node.deps]

    # ---- queries ----
    def __iter__(self):
        return iter(self.nodes.values())

    def __len__(self):
        return len(self.nodes)

    def toposort(self) -> list[OpNode]:
        if self._topo is not None:
            return self._topo
        order: list[OpNode] = []
        seen: set[str] = set()
        state: dict[str, int] = {}

        def visit(name: str):
            stack = [(name, iter(self.nodes[name].deps))]
            state[name] = 1
            while stack:
                cur, it = stack[-1]
                advanced = False
                for d in it:
                    if d not in self.nodes or d in seen:
                        continue
                    if state.get(d) == 1:
                        continue  # ignore back-edges defensively
                    state[d] = 1
                    stack.append((d, iter(self.nodes[d].deps)))
                    advanced = True
                    break
                if not advanced:
                    stack.pop()
                    seen.add(cur)
                    order.append(self.nodes[cur])

        for n in self.nodes:
            if n not in seen:
                visit(n)
        self._topo = order
        return order

    def successors(self) -> dict[str, list[str]]:
        succ: dict[str, list[str]] = {n: [] for n in self.nodes}
        for node in self.nodes.values():
            for d in node.deps:
                if d in succ:
                    succ[d].append(node.name)
        return succ

    # ---- aggregate metrics ----
    def total(self, attr: str, *, phase: str | None = None,
              pred: Callable[[OpNode], bool] | None = None) -> float:
        tot = 0.0
        for n in self.nodes.values():
            if phase is not None and n.phase != phase:
                continue
            if pred is not None and not pred(n):
                continue
            tot += getattr(n, attr) * n.repeat
        return tot

    def by_kind(self, attr: str = "flops") -> dict[str, float]:
        out: dict[str, float] = {}
        for n in self.nodes.values():
            out[n.kind] = out.get(n.kind, 0.0) + getattr(n, attr) * n.repeat
        return out

    def clone(self) -> "Graph":
        g = Graph(self.name)
        g._ctr = self._ctr
        for n in self.nodes.values():
            g.nodes[n.name] = n.clone()
        return g

    def __repr__(self):
        return f"Graph({self.name}, {len(self.nodes)} ops)"

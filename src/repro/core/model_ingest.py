"""Model ingestion: ModelConfig -> per-block operator graphs (paper §3.2a).

Charon extracts and simulates a single transformer block per distinct block
kind and extrapolates over depth; asymmetric stacks (whisper enc/dec,
recurrentgemma hybrid cycle) trace each kind separately.  Attention is traced
as a single abstract operator via core/stubs.py.

All graphs are traced at the *per-data-shard* batch (B_local); the
parallelism passes then rewrite for TP/SP/EP/CP.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import tracer
from repro.core.ir import Graph
from repro.core.stubs import ingest_attention
from repro.models import abstract_params, block_cycle
from repro.models.kvcache import build_cache
from repro.models.model import Model, apply_block_decode, apply_block_full


@dataclass
class BlockGraphs:
    kind: str
    repeat: int                      # how many times this block occurs
    fwd: Graph
    joint: Graph | None = None       # fwd+bwd (train)


@dataclass
class ModelGraphs:
    cfg: ModelConfig
    mode: str
    blocks: list[BlockGraphs]
    head: BlockGraphs | None = None  # embed + final norm + logits (+ loss bwd)
    encoder: BlockGraphs | None = None

    def all_blocks(self):
        out = list(self.blocks)
        if self.encoder:
            out.append(self.encoder)
        if self.head:
            out.append(self.head)
        return out


def ingest_key(cfg: ModelConfig, B_local: int, S: int, mode: str,
               cache_len: int = 0) -> tuple:
    """Memoization key for :func:`block_graphs`.

    ``ModelConfig`` is a frozen dataclass of hashable fields, so the config
    itself is the model fingerprint.  Two calls with equal keys trace
    identical graphs; callers must clone before mutating (the simulator's
    pass pipeline already does)."""
    return (cfg, B_local, S, mode, cache_len)


# --------------------------------------------------------------------------
# Batch extrapolation: Charon's single-block trick applied to the batch axis.
#
# Within one ingest *family* (cfg, S, mode, cache_len) every traced quantity
# is affine in B_local: tensor shapes carry at most one batch factor, so
# every dim, byte count and FLOP count is a + c*B with non-negative dyadic
# coefficients.  Two anchor traces at batch b1, b2 (|b2-b1| a power of two,
# so the coefficient division is exact in binary floating point) determine
# the whole family; the first ``_VERIFY_POINTS`` non-anchor requests are
# still traced directly and compared field-by-field against the
# interpolation — only after those prove bit-exact does the family skip JAX
# tracing.  Any structural or numeric mismatch permanently disables
# extrapolation for the family (silent, correct fallback).
# --------------------------------------------------------------------------

_NODE_NUM_FIELDS = ("flops", "bytes_in", "bytes_out", "comm_bytes")
_NODE_CONST_FIELDS = ("name", "kind", "dtype", "comm_group", "comm_size",
                      "overlappable", "stream", "repeat", "phase")
_VERIFY_POINTS = 2
_FAMILY_MAX = 64                 # runaway backstop, not a tuning knob


@dataclass
class _Family:
    traced: dict = field(default_factory=dict)   # B -> ModelGraphs (direct)
    pair: tuple | None = None                    # anchor (b1, b2)
    verified: int = 0
    disabled: bool = False


_FAMILIES: dict = {}
_EXTRAP_STATS = {"extrapolated": 0, "traced": 0}


def ingest_extrapolation_stats() -> dict:
    return dict(_EXTRAP_STATS)


def ingest_extrapolation_clear() -> None:
    _FAMILIES.clear()
    _EXTRAP_STATS.update(extrapolated=0, traced=0)


def _affine(v1, v2, b1: int, b2: int, B: int):
    """Exact affine reconstruction v(B) from (b1, v1), (b2, v2); None if the
    fit is not an exact non-negative affine function."""
    if isinstance(v1, bool) or isinstance(v2, bool):
        return v1 if v1 == v2 else None
    if isinstance(v1, int) and isinstance(v2, int):
        d = b2 - b1
        if (v2 - v1) % d:
            return None
        c = (v2 - v1) // d
        a = v1 - c * b1
        if c < 0 or a < 0:
            return None
        return a + c * B
    if isinstance(v1, float) and isinstance(v2, float):
        # b2-b1 is a power of two and traced values are dyadic rationals
        # well inside the 53-bit mantissa: every step below is exact
        c = (v2 - v1) / (b2 - b1)
        a = v1 - c * b1
        if c < 0.0 or a < 0.0:
            return None
        return a + c * B
    return v1 if v1 == v2 else None


def _affine_seq(s1, s2, b1, b2, B):
    if len(s1) != len(s2):
        return None
    out = []
    for v1, v2 in zip(s1, s2):
        v = _affine(v1, v2, b1, b2, B)
        if v is None:
            return None
        out.append(v)
    return tuple(out)


def _interp_graph(g1: Graph, g2: Graph, b1: int, b2: int, B: int) -> Graph | None:
    if len(g1) != len(g2):
        return None
    out = Graph(g1.name)
    out._ctr = g1._ctr
    for n1, n2 in zip(g1.nodes.values(), g2.nodes.values()):
        for f in _NODE_CONST_FIELDS:
            if getattr(n1, f) != getattr(n2, f):
                return None
        if n1.deps != n2.deps:
            return None
        n = n1.clone()
        for f in _NODE_NUM_FIELDS:
            v = _affine(getattr(n1, f), getattr(n2, f), b1, b2, B)
            if v is None:
                return None
            setattr(n, f, v)
        shape = _affine_seq(n1.out_shape, n2.out_shape, b1, b2, B)
        if shape is None:
            return None
        n.out_shape = shape
        if set(n1.attrs) != set(n2.attrs):
            return None
        for k, v1 in n1.attrs.items():
            v2 = n2.attrs[k]
            if isinstance(v1, tuple) and isinstance(v2, tuple):
                v = _affine_seq(v1, v2, b1, b2, B)
            elif isinstance(v1, (int, float)) and isinstance(v2, (int, float)):
                v = _affine(v1, v2, b1, b2, B)
            else:
                v = v1 if v1 == v2 else None
            if v is None:
                return None
            n.attrs[k] = v
        out.nodes[n.name] = n
    return out


def _interp_block(bg1: BlockGraphs, bg2: BlockGraphs, b1, b2, B):
    if bg1.kind != bg2.kind or bg1.repeat != bg2.repeat \
            or (bg1.joint is None) != (bg2.joint is None):
        return None
    fwd = _interp_graph(bg1.fwd, bg2.fwd, b1, b2, B)
    if fwd is None:
        return None
    joint = None
    if bg1.joint is not None:
        joint = _interp_graph(bg1.joint, bg2.joint, b1, b2, B)
        if joint is None:
            return None
    return BlockGraphs(bg1.kind, bg1.repeat, fwd, joint)


def _interp_model(mg1: ModelGraphs, mg2: ModelGraphs, b1, b2, B):
    if len(mg1.blocks) != len(mg2.blocks) \
            or (mg1.head is None) != (mg2.head is None) \
            or (mg1.encoder is None) != (mg2.encoder is None):
        return None
    blocks = []
    for bg1, bg2 in zip(mg1.blocks, mg2.blocks):
        bg = _interp_block(bg1, bg2, b1, b2, B)
        if bg is None:
            return None
        blocks.append(bg)
    head = encoder = None
    if mg1.head is not None:
        head = _interp_block(mg1.head, mg2.head, b1, b2, B)
        if head is None:
            return None
    if mg1.encoder is not None:
        encoder = _interp_block(mg1.encoder, mg2.encoder, b1, b2, B)
        if encoder is None:
            return None
    return ModelGraphs(mg1.cfg, mg1.mode, blocks, head, encoder)


def _graphs_match(a: ModelGraphs, b: ModelGraphs) -> bool:
    def sig(mg):
        out = []
        for bg in mg.all_blocks():
            for g in (bg.fwd, bg.joint):
                if g is None:
                    continue
                out.append((bg.kind, bg.repeat,
                            [(n.name, n.kind, n.dtype, n.flops, n.bytes_in,
                              n.bytes_out, n.comm_bytes, n.comm_group,
                              n.comm_size, n.overlappable, n.stream,
                              n.repeat, n.phase, tuple(n.out_shape),
                              tuple(sorted(n.attrs.items())), tuple(n.deps))
                             for n in g.nodes.values()]))
        return out
    return sig(a) == sig(b)


def ingest_graphs(cfg: ModelConfig, B_local: int, S: int, mode: str,
                  *, cache_len: int = 0) -> ModelGraphs:
    """:func:`block_graphs` with verified batch extrapolation (the
    simulator's ingest builder).  Callers must treat results as immutable —
    the same contract the per-simulator ingest cache already imposes."""
    key = (cfg, S, mode, cache_len)
    fam = _FAMILIES.get(key)
    if fam is None:
        if len(_FAMILIES) >= _FAMILY_MAX:
            _FAMILIES.clear()
        fam = _FAMILIES[key] = _Family()
    mg = fam.traced.get(B_local)
    if mg is not None:
        return mg
    interp = None
    # B_local == 1 is never anchored or interpolated: degenerate batch dims
    # genuinely change trace structure (e.g. the train head's loss backward
    # collapses its batch reduction), so batch 1 always traces directly
    if B_local > 1 and not fam.disabled and fam.pair is not None:
        b1, b2 = fam.pair
        interp = _interp_model(fam.traced[b1], fam.traced[b2], b1, b2, B_local)
        if interp is None:
            fam.disabled = True
        elif fam.verified >= _VERIFY_POINTS:
            _EXTRAP_STATS["extrapolated"] += 1
            return interp
    _EXTRAP_STATS["traced"] += 1
    mg = block_graphs(cfg, B_local, S, mode, cache_len=cache_len)
    if not fam.disabled:
        if interp is not None:
            if _graphs_match(interp, mg):
                fam.verified += 1
            else:
                fam.disabled = True
        elif fam.pair is None and B_local > 1:
            for b in sorted(fam.traced):
                d = B_local - b
                if b > 1 and d > 0 and (d & (d - 1)) == 0:  # 2^k spacing
                    fam.pair = (b, B_local)
                    break
        fam.traced[B_local] = mg
    return mg


def _cycle_param_slice(cfg: ModelConfig, pos: int):
    """Abstract params of one layer at cycle position ``pos``."""
    pa = abstract_params(cfg)
    stacked = pa["blocks"]["cycle"][pos]
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), stacked)


def _tag_moe(g: Graph, cfg: ModelConfig) -> Graph:
    if cfg.num_experts:
        for n in g:
            if n.kind == "matmul" and n.out_shape and n.out_shape[0] == cfg.num_experts:
                n.attrs["moe_expert"] = True
    return g


def block_graphs(cfg: ModelConfig, B_local: int, S: int, mode: str,
                 *, cache_len: int = 0) -> ModelGraphs:
    """Trace one graph per distinct block kind (+ embed/head)."""
    cycle, n_cycles, tail = block_cycle(cfg)
    counts: dict[int, int] = {}
    kinds: dict[int, str] = {}
    for j, k in enumerate(cycle):
        counts[j] = n_cycles + (1 if j < len(tail) else 0)
        kinds[j] = k
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    x_abs = jax.ShapeDtypeStruct((B_local, S, D), dt)
    pos_abs = jax.ShapeDtypeStruct((B_local, S, 3) if cfg.rope_style == "mrope"
                                   else (B_local, S), jnp.int32)
    enc_abs = jax.ShapeDtypeStruct((B_local, cfg.encoder_seq, D), dt) \
        if cfg.cross_attention else None

    blocks: list[BlockGraphs] = []
    with ingest_attention():
        seen_kinds: dict[str, BlockGraphs] = {}
        for j, kind in kinds.items():
            if kind in seen_kinds:
                seen_kinds[kind].repeat += counts[j]
                continue
            p_abs = _cycle_param_slice(cfg, j)
            if mode == "decode":
                cache_stacked = build_cache(
                    cfg, lambda s, l, d: jax.ShapeDtypeStruct(s, d), B_local,
                    cache_len or S)["blocks"]["cycle"][j]
                cache_abs = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), cache_stacked)
                x1 = jax.ShapeDtypeStruct((B_local, 1, D), dt)
                posv = jax.ShapeDtypeStruct((B_local,), jnp.int32)

                def dec_fn(p, x, cache, pos, _kind=kind):
                    aux = {"pos": pos, "decode_positions": pos[:, None]}
                    h, c = apply_block_decode(cfg, _kind, p, x, cache, aux)
                    return h

                fwd = _tag_moe(tracer.trace(dec_fn, p_abs, x1, cache_abs, posv,
                                            name=f"{kind}.decode"), cfg)
                bg = BlockGraphs(kind, counts[j], fwd)
            else:
                def fwd_fn(p, x, positions, enc=None, _kind=kind):
                    aux = {"positions": positions, "cache_len": 0}
                    if enc is not None:
                        aux["enc_out"] = enc
                    h, _, aux_l = apply_block_full(cfg, _kind, p, x, aux, False)
                    return h if mode != "train" else (h, aux_l)

                args = (p_abs, x_abs, pos_abs) + ((enc_abs,) if enc_abs is not None else ())
                if mode == "train":
                    fwd = _tag_moe(tracer.trace(
                        lambda *a: fwd_fn(*a)[0], *args, name=f"{kind}.fwd"), cfg)
                    joint = _tag_moe(tracer.trace_grad(
                        lambda *a: fwd_fn(*a)[0], *args, name=f"{kind}.joint"), cfg)
                    bg = BlockGraphs(kind, counts[j], fwd, joint)
                else:
                    fwd = _tag_moe(tracer.trace(fwd_fn, *args, name=f"{kind}.fwd"), cfg)
                    bg = BlockGraphs(kind, counts[j], fwd)
            seen_kinds[kind] = bg
            blocks.append(bg)

        # encoder (whisper)
        encoder = None
        if cfg.encoder_layers > 0 and mode != "decode":
            p_enc = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                abstract_params(cfg)["encoder"]["blocks"]["cycle"][0])
            xe = jax.ShapeDtypeStruct((B_local, cfg.encoder_seq, D), dt)
            pe = jax.ShapeDtypeStruct((B_local, cfg.encoder_seq), jnp.int32)

            def enc_fn(p, x, positions):
                h, _, _ = apply_block_full(cfg, "enc", p, x,
                                           {"positions": positions}, False)
                return h

            efwd = tracer.trace(enc_fn, p_enc, xe, pe, name="enc.fwd")
            ejoint = tracer.trace_grad(enc_fn, p_enc, xe, pe, name="enc.joint") \
                if mode == "train" else None
            encoder = BlockGraphs("enc", cfg.encoder_layers, efwd, ejoint)

        # embed + head (+ CE loss for train)
        model = Model(cfg)
        S_head = 1 if mode == "decode" else S
        tok_abs = jax.ShapeDtypeStruct((B_local, S_head), jnp.int32)
        emb_abs = jax.ShapeDtypeStruct((cfg.vocab_size, D), jnp.dtype(cfg.param_dtype))
        nrm_abs = {"w": jax.ShapeDtypeStruct((D,), jnp.dtype(cfg.param_dtype))}
        if cfg.norm == "layernorm":
            nrm_abs["b"] = jax.ShapeDtypeStruct((D,), jnp.dtype(cfg.param_dtype))
        h_abs = jax.ShapeDtypeStruct((B_local, S_head, D), dt)

        def head_fn(emb_w, nrm, h, tokens):
            from repro.models import layers as L
            params = {"embed": {"w": emb_w}, "final_norm": nrm}
            x = jnp.take(emb_w, tokens, axis=0).astype(dt)
            hh = h + x * 0  # keep both paths alive
            hh = L.apply_norm(cfg, nrm, hh)
            logits = jnp.einsum("bsd,dv->bsv", hh, emb_w.T.astype(dt)).astype(jnp.float32)
            if mode == "train":
                logp = jax.nn.log_softmax(logits, axis=-1)
                return -jnp.mean(jnp.take_along_axis(
                    logp, jnp.maximum(tokens, 0)[..., None], axis=-1))
            return logits

        hf = tracer.trace(head_fn, emb_abs, nrm_abs, h_abs, tok_abs, name="head.fwd")
        hj = tracer.trace_grad(head_fn, emb_abs, nrm_abs, h_abs, tok_abs,
                               name="head.joint") if mode == "train" else None
        head = BlockGraphs("head", 1, hf, hj)

    return ModelGraphs(cfg, mode, blocks, head, encoder)

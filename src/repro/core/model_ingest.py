"""Model ingestion: ModelConfig -> per-block operator graphs (paper §3.2a).

Charon extracts and simulates a single transformer block per distinct block
kind and extrapolates over depth; asymmetric stacks (whisper enc/dec,
recurrentgemma hybrid cycle) trace each kind separately.  Attention is traced
as a single abstract operator via core/stubs.py.

All graphs are traced at the *per-data-shard* batch (B_local); the
parallelism passes then rewrite for TP/SP/EP/CP.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import tracer
from repro.core.ir import Graph
from repro.core.stubs import ingest_attention
from repro.models import abstract_params, block_cycle
from repro.models.kvcache import build_cache
from repro.models.model import Model, apply_block_decode, apply_block_full


@dataclass
class BlockGraphs:
    kind: str
    repeat: int                      # how many times this block occurs
    fwd: Graph
    joint: Graph | None = None       # fwd+bwd (train)


@dataclass
class ModelGraphs:
    cfg: ModelConfig
    mode: str
    blocks: list[BlockGraphs]
    head: BlockGraphs | None = None  # embed + final norm + logits (+ loss bwd)
    encoder: BlockGraphs | None = None

    def all_blocks(self):
        out = list(self.blocks)
        if self.encoder:
            out.append(self.encoder)
        if self.head:
            out.append(self.head)
        return out


def ingest_key(cfg: ModelConfig, B_local: int, S: int, mode: str,
               cache_len: int = 0) -> tuple:
    """Memoization key for :func:`block_graphs`.

    ``ModelConfig`` is a frozen dataclass of hashable fields, so the config
    itself is the model fingerprint.  Two calls with equal keys trace
    identical graphs; callers must clone before mutating (the simulator's
    pass pipeline already does)."""
    return (cfg, B_local, S, mode, cache_len)


def _cycle_param_slice(cfg: ModelConfig, pos: int):
    """Abstract params of one layer at cycle position ``pos``."""
    pa = abstract_params(cfg)
    stacked = pa["blocks"]["cycle"][pos]
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), stacked)


def _tag_moe(g: Graph, cfg: ModelConfig) -> Graph:
    if cfg.num_experts:
        for n in g:
            if n.kind == "matmul" and n.out_shape and n.out_shape[0] == cfg.num_experts:
                n.attrs["moe_expert"] = True
    return g


def block_graphs(cfg: ModelConfig, B_local: int, S: int, mode: str,
                 *, cache_len: int = 0) -> ModelGraphs:
    """Trace one graph per distinct block kind (+ embed/head)."""
    cycle, n_cycles, tail = block_cycle(cfg)
    counts: dict[int, int] = {}
    kinds: dict[int, str] = {}
    for j, k in enumerate(cycle):
        counts[j] = n_cycles + (1 if j < len(tail) else 0)
        kinds[j] = k
    dt = jnp.dtype(cfg.dtype)
    D = cfg.d_model
    x_abs = jax.ShapeDtypeStruct((B_local, S, D), dt)
    pos_abs = jax.ShapeDtypeStruct((B_local, S, 3) if cfg.rope_style == "mrope"
                                   else (B_local, S), jnp.int32)
    enc_abs = jax.ShapeDtypeStruct((B_local, cfg.encoder_seq, D), dt) \
        if cfg.cross_attention else None

    blocks: list[BlockGraphs] = []
    with ingest_attention():
        seen_kinds: dict[str, BlockGraphs] = {}
        for j, kind in kinds.items():
            if kind in seen_kinds:
                seen_kinds[kind].repeat += counts[j]
                continue
            p_abs = _cycle_param_slice(cfg, j)
            if mode == "decode":
                cache_stacked = build_cache(
                    cfg, lambda s, l, d: jax.ShapeDtypeStruct(s, d), B_local,
                    cache_len or S)["blocks"]["cycle"][j]
                cache_abs = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), cache_stacked)
                x1 = jax.ShapeDtypeStruct((B_local, 1, D), dt)
                posv = jax.ShapeDtypeStruct((B_local,), jnp.int32)

                def dec_fn(p, x, cache, pos, _kind=kind):
                    aux = {"pos": pos, "decode_positions": pos[:, None]}
                    h, c = apply_block_decode(cfg, _kind, p, x, cache, aux)
                    return h

                fwd = _tag_moe(tracer.trace(dec_fn, p_abs, x1, cache_abs, posv,
                                            name=f"{kind}.decode"), cfg)
                bg = BlockGraphs(kind, counts[j], fwd)
            else:
                def fwd_fn(p, x, positions, enc=None, _kind=kind):
                    aux = {"positions": positions, "cache_len": 0}
                    if enc is not None:
                        aux["enc_out"] = enc
                    h, _, aux_l = apply_block_full(cfg, _kind, p, x, aux, False)
                    return h if mode != "train" else (h, aux_l)

                args = (p_abs, x_abs, pos_abs) + ((enc_abs,) if enc_abs is not None else ())
                if mode == "train":
                    fwd = _tag_moe(tracer.trace(
                        lambda *a: fwd_fn(*a)[0], *args, name=f"{kind}.fwd"), cfg)
                    joint = _tag_moe(tracer.trace_grad(
                        lambda *a: fwd_fn(*a)[0], *args, name=f"{kind}.joint"), cfg)
                    bg = BlockGraphs(kind, counts[j], fwd, joint)
                else:
                    fwd = _tag_moe(tracer.trace(fwd_fn, *args, name=f"{kind}.fwd"), cfg)
                    bg = BlockGraphs(kind, counts[j], fwd)
            seen_kinds[kind] = bg
            blocks.append(bg)

        # encoder (whisper)
        encoder = None
        if cfg.encoder_layers > 0 and mode != "decode":
            p_enc = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
                abstract_params(cfg)["encoder"]["blocks"]["cycle"][0])
            xe = jax.ShapeDtypeStruct((B_local, cfg.encoder_seq, D), dt)
            pe = jax.ShapeDtypeStruct((B_local, cfg.encoder_seq), jnp.int32)

            def enc_fn(p, x, positions):
                h, _, _ = apply_block_full(cfg, "enc", p, x,
                                           {"positions": positions}, False)
                return h

            efwd = tracer.trace(enc_fn, p_enc, xe, pe, name="enc.fwd")
            ejoint = tracer.trace_grad(enc_fn, p_enc, xe, pe, name="enc.joint") \
                if mode == "train" else None
            encoder = BlockGraphs("enc", cfg.encoder_layers, efwd, ejoint)

        # embed + head (+ CE loss for train)
        model = Model(cfg)
        S_head = 1 if mode == "decode" else S
        tok_abs = jax.ShapeDtypeStruct((B_local, S_head), jnp.int32)
        emb_abs = jax.ShapeDtypeStruct((cfg.vocab_size, D), jnp.dtype(cfg.param_dtype))
        nrm_abs = {"w": jax.ShapeDtypeStruct((D,), jnp.dtype(cfg.param_dtype))}
        if cfg.norm == "layernorm":
            nrm_abs["b"] = jax.ShapeDtypeStruct((D,), jnp.dtype(cfg.param_dtype))
        h_abs = jax.ShapeDtypeStruct((B_local, S_head, D), dt)

        def head_fn(emb_w, nrm, h, tokens):
            from repro.models import layers as L
            params = {"embed": {"w": emb_w}, "final_norm": nrm}
            x = jnp.take(emb_w, tokens, axis=0).astype(dt)
            hh = h + x * 0  # keep both paths alive
            hh = L.apply_norm(cfg, nrm, hh)
            logits = jnp.einsum("bsd,dv->bsv", hh, emb_w.T.astype(dt)).astype(jnp.float32)
            if mode == "train":
                logp = jax.nn.log_softmax(logits, axis=-1)
                return -jnp.mean(jnp.take_along_axis(
                    logp, jnp.maximum(tokens, 0)[..., None], axis=-1))
            return logits

        hf = tracer.trace(head_fn, emb_abs, nrm_abs, h_abs, tok_abs, name="head.fwd")
        hj = tracer.trace_grad(head_fn, emb_abs, nrm_abs, h_abs, tok_abs,
                               name="head.joint") if mode == "train" else None
        head = BlockGraphs("head", 1, hf, hj)

    return ModelGraphs(cfg, mode, blocks, head, encoder)

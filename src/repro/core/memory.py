"""Liveness-based peak-memory simulation (paper §3.2c memory analysis).

Graph-level liveness: an activation is allocated at its producer and freed
after its last consumer *in the joint fwd+bwd order* — peak memory is reached
during backward, which layer-level (static-tensor) estimators cannot see.
Static components (weights, grads, optimizer states per ZeRO stage, KV cache)
are added analytically, plus calibrated collective-buffer overhead and a
fragmentation factor (paper §4.3 calibrations).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ir import Graph

COLLECTIVE_BUFFER_BYTES = 256 * 1024 * 1024 * 0.12   # calibrated NCCL/ICI staging
FRAGMENTATION = 1.03                                  # calibrated allocator slack


@dataclass
class MemoryReport:
    weights: float = 0.0
    grads: float = 0.0
    opt_state: float = 0.0
    activations_peak: float = 0.0
    saved_activations: float = 0.0
    kv_cache: float = 0.0
    collective_buffers: float = 0.0
    total: float = 0.0
    # (op_idx, live_bytes) liveness curve.  Immutable on purpose: the walk
    # is cached (SimCache "memory" bucket) and shared across reports, so a
    # mutable list here would let one consumer poison every sibling report.
    timeline: tuple[tuple[float, float], ...] = ()

    def summary(self) -> dict:
        return {k: getattr(self, k) for k in
                ("weights", "grads", "opt_state", "activations_peak",
                 "saved_activations", "kv_cache", "collective_buffers", "total")}


def graph_liveness_peak(g: Graph, *, record_timeline: bool = False):
    """Peak live activation bytes over a single execution of ``g`` (repeat
    multipliers do not stack activations — a scanned block reuses buffers)."""
    order = g.toposort()
    last_use: dict[str, int] = {}
    for i, node in enumerate(order):
        for d in node.deps:
            last_use[d] = i
        last_use.setdefault(node.name, i)
    live = 0.0
    peak = 0.0
    timeline = []
    frees: dict[int, list[float]] = {}
    for i, node in enumerate(order):
        live += node.bytes_out
        frees.setdefault(last_use[node.name], []).append(node.bytes_out)
        peak = max(peak, live)
        if record_timeline:
            timeline.append((float(i), live))
        for b in frees.pop(i, ()):  # free tensors whose last use is this op
            live -= b
    return peak, timeline


def block_liveness(block_fwd: Graph, block_joint: Graph | None,
                   mode: str) -> tuple[float, list, float]:
    """The graph-walk stage of :func:`simulate_memory`: (peak live bytes,
    liveness timeline, interior fwd activation bytes).

    This is the only part of the memory report that touches the block graph
    — everything else is closed-form arithmetic — so it is what the
    simulator memoizes (SimCache ``memory`` bucket) across sweep candidates
    that share a transformed first block.  The timeline is returned as a
    tuple so the shared cached value is immutable by construction (a
    consumer mutating its report cannot poison the cache bucket).
    """
    g = block_joint if (mode == "train" and block_joint is not None) \
        else block_fwd
    peak, timeline = graph_liveness_peak(g, record_timeline=True)
    interior = block_fwd.total("bytes_out", phase="fwd")
    return peak, tuple(timeline), interior


def simulate_memory(block_fwd: Graph, *, n_layers: int, param_bytes: float,
                    boundary_bytes: float, mode: str = "train",
                    optimizer: str = "adamw", zero_stage: int = 0,
                    dp: int = 1, tp: int = 1, remat: str = "block",
                    kv_cache_bytes: float = 0.0,
                    block_joint: Graph | None = None,
                    liveness: tuple[float, list, float] | None = None
                    ) -> MemoryReport:
    """Per-device peak memory for an n_layers stack of ``block_fwd``.

    ``param_bytes``: per-device parameter bytes (post TP/EP/FSDP sharding).
    ``boundary_bytes``: per-layer residual-stream activation saved for bwd.
    ``liveness``: a precomputed (possibly cached) :func:`block_liveness`
    result; when None the graphs are walked here.
    """
    if liveness is None:
        liveness = block_liveness(block_fwd, block_joint, mode)
    peak_block, tl, interior = liveness
    r = MemoryReport()
    r.weights = param_bytes
    if mode == "train":
        r.grads = param_bytes * (2 / 2)  # grads at param dtype
        if zero_stage >= 2:
            r.grads /= max(dp, 1)
        n_params = param_bytes / 2
        if optimizer == "adamw":
            opt = n_params * 8  # fp32 m + v
        else:
            opt = n_params * 0.1  # adafactor factored moments
        if zero_stage >= 1:
            opt /= max(dp, 1)
        r.opt_state = opt
        # live activations inside one block's fwd+bwd (peak during backward)
        r.timeline = tuple(tl)
        if remat == "none":
            # every layer's interior activations are saved
            r.saved_activations = interior * n_layers
        else:
            r.saved_activations = boundary_bytes * n_layers
        r.activations_peak = peak_block
    else:
        r.timeline = tuple(tl)
        r.activations_peak = peak_block
        r.kv_cache = kv_cache_bytes
    r.collective_buffers = COLLECTIVE_BUFFER_BYTES
    r.total = (r.weights + r.grads + r.opt_state + r.activations_peak +
               r.saved_activations + r.kv_cache + r.collective_buffers) * FRAGMENTATION
    return r

"""Chrome-trace export: PyTorch-profiler-style timelines (paper §3.2c).

``to_chrome_trace`` emits a single-rank timeline; ``pp_trace`` emits the 3D
multi-GPU view (pid = "dp{i}|pp{j}", tid = stream) from a PPSchedule plus
per-rank op timelines.  Load the JSON in chrome://tracing or Perfetto.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.passes.pipeline import PPSchedule
from repro.core.scheduler import Timeline

_CAT = {"matmul": "compute", "attention": "compute", "fused": "compute",
        "norm": "compute", "elementwise": "compute", "softmax": "compute",
        "reduce": "compute", "all_reduce": "comm", "all_gather": "comm",
        "reduce_scatter": "comm", "all_to_all": "comm", "send": "comm",
        "recv": "comm", "collective_permute": "comm"}


def to_chrome_trace(tl: Timeline, *, pid: str = "rank0",
                    expand_limit: int = 20000) -> list[dict]:
    events = []
    for iv in tl.intervals[:expand_limit]:
        events.append({
            "name": iv.name, "cat": _CAT.get(iv.kind, "other"), "ph": "X",
            "ts": iv.start, "dur": iv.dur, "pid": pid, "tid": iv.stream,
            "args": {"kind": iv.kind, "phase": iv.phase, "engine": iv.engine,
                     "repeat": iv.repeat, "comm_bytes": iv.comm_bytes},
        })
    return events


def pp_trace(sched: PPSchedule, *, dp_rank: int = 0) -> list[dict]:
    events = []
    for e in sched.events:
        events.append({
            "name": f"{e.kind}{e.microbatch}", "cat": "pp", "ph": "X",
            "ts": e.start, "dur": e.end - e.start,
            "pid": f"dp{dp_rank}|pp{e.rank}", "tid": "pipeline",
            "args": {"microbatch": e.microbatch, "kind": e.kind},
        })
    return events


def write_trace(events: list[dict], path: str | Path):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"traceEvents": events,
                                "displayTimeUnit": "ms"}))
    return path

"""Chrome-trace export: PyTorch-profiler-style timelines (paper §3.2c).

``to_chrome_trace`` emits a single-rank timeline; ``pp_trace`` emits the 3D
multi-GPU view (pid = "dp{i}|pp{j}", tid = stream) from a PPSchedule plus
per-rank op timelines; ``record_report`` pushes a whole core
:class:`~repro.core.simulator.Report` (every block timeline + the pipeline
schedule) into a :class:`~repro.obs.TraceRecorder`, which is how core-step
runs join the unified observability trace.  Load the JSON in
chrome://tracing or Perfetto.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.passes.pipeline import PPSchedule
from repro.core.scheduler import Timeline

_CAT = {"matmul": "compute", "attention": "compute", "fused": "compute",
        "norm": "compute", "elementwise": "compute", "softmax": "compute",
        "reduce": "compute", "all_reduce": "comm", "all_gather": "comm",
        "reduce_scatter": "comm", "all_to_all": "comm", "send": "comm",
        "recv": "comm", "collective_permute": "comm"}


def to_chrome_trace(tl: Timeline, *, pid: str = "rank0",
                    expand_limit: int = 20000, metrics=None) -> list[dict]:
    """Timeline -> chrome events.  Timelines beyond ``expand_limit``
    intervals are truncated, *loudly*: a trailing metadata instant carries
    the dropped count (and a ``trace.dropped_intervals`` counter is bumped
    on ``metrics`` when one is given) — no silent caps."""
    events = []
    for iv in tl.intervals[:expand_limit]:
        events.append({
            "name": iv.name, "cat": _CAT.get(iv.kind, "other"), "ph": "X",
            "ts": iv.start, "dur": iv.dur, "pid": pid, "tid": iv.stream,
            "args": {"kind": iv.kind, "phase": iv.phase, "engine": iv.engine,
                     "repeat": iv.repeat, "comm_bytes": iv.comm_bytes},
        })
    dropped = len(tl.intervals) - expand_limit
    if dropped > 0:
        events.append({
            "name": "charon:trace_truncated", "cat": "meta", "ph": "i",
            "s": "p", "ts": events[-1]["ts"] + events[-1]["dur"],
            "pid": pid, "tid": "meta",
            "args": {"dropped_intervals": dropped,
                     "expand_limit": expand_limit,
                     "total_intervals": len(tl.intervals)},
        })
        if metrics is not None:
            metrics.inc("trace.dropped_intervals", dropped)
    return events


def pp_trace(sched: PPSchedule, *, dp_rank: int = 0) -> list[dict]:
    events = []
    for e in sched.events:
        events.append({
            "name": f"{e.kind}{e.microbatch}", "cat": "pp", "ph": "X",
            "ts": e.start, "dur": e.end - e.start,
            "pid": f"dp{dp_rank}|pp{e.rank}", "tid": "pipeline",
            "args": {"microbatch": e.microbatch, "kind": e.kind},
        })
    return events


def record_report(recorder, report, *, pid: str = "core",
                  expand_limit: int = 20000, metrics=None) -> None:
    """Push a core step report's timelines into a recorder: one lane group
    per block kind (``pid/<kind>``) plus the pipeline schedule when the
    report has one.  Requires a report produced with
    ``keep_timelines=True`` — without timelines there is nothing to record
    (``Simulator.run(spec, recorder=...)`` arranges this automatically)."""
    if not recorder.enabled:
        return
    for kind, tl in report.block_timelines.items():
        recorder.extend(to_chrome_trace(tl, pid=f"{pid}/{kind}",
                                        expand_limit=expand_limit,
                                        metrics=metrics))
    if report.pp is not None:
        recorder.extend(pp_trace(report.pp))


def merge_traces(*event_lists: list[dict]) -> list[dict]:
    """Merge chrome event lists into one, sorted by timestamp (stable, so
    equal timestamps keep their per-source order)."""
    out = [e for evs in event_lists for e in evs]
    out.sort(key=lambda e: e.get("ts", 0.0))
    return out


def write_trace(events: list[dict], path: str | Path):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"traceEvents": events,
                                "displayTimeUnit": "ms"}))
    return path

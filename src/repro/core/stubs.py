"""Abstract attention primitive for simulator tracing.

When the simulator ingests a model it wants attention as ONE operator (the
paper traces at torch-op granularity where sdpa/flash-attention is a single
node), not as the score/softmax/value decomposition.  ``charon_attention``
is a JAX primitive with abstract evaluation only — simulation never executes
it; ``jax.make_jaxpr`` is enough.  A custom_vjp routes backward tracing to a
``charon_attention_bwd`` primitive.

``attention_stub(...)`` is installed into ``repro.models.layers`` by the
:func:`ingest_attention` context manager during tracing.
"""
from __future__ import annotations

import contextlib
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.extend import core as jex_core
from jax.interpreters import ad

attention_p = jex_core.Primitive("charon_attention")
attention_bwd_p = jex_core.Primitive("charon_attention_bwd")
attention_bwd_p.multiple_results = True


@attention_p.def_abstract_eval
def _attn_abs(q, k, v, *, causal, window):
    # q: (B, Sq, Hkv, G, Dq); v: (B, T, Hkv, Dv) -> (B, Sq, Hkv, G, Dv)
    return jax.core.ShapedArray((*q.shape[:-1], v.shape[-1]), q.dtype)


@attention_bwd_p.def_abstract_eval
def _attn_bwd_abs(q, k, v, ct, *, causal, window):
    return (jax.core.ShapedArray(q.shape, q.dtype),
            jax.core.ShapedArray(k.shape, k.dtype),
            jax.core.ShapedArray(v.shape, v.dtype))


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attn(q, k, v, causal, window):
    return attention_p.bind(q, k, v, causal=causal, window=window)


def _attn_fwd(q, k, v, causal, window):
    return _attn(q, k, v, causal, window), (q, k, v)


def _attn_bwd(causal, window, res, ct):
    q, k, v = res
    return tuple(attention_bwd_p.bind(q, k, v, ct, causal=causal, window=window))


_attn.defvjp(_attn_fwd, _attn_bwd)


def attention_stub(q, k, v, *, q_offset=0, causal=True, window=0,
                   kv_valid_len=None, soft_cap=0.0, strategy="auto",
                   scale=None, q_block=2048, kv_block=512, score_dtype=None):
    """Signature-compatible replacement for layers.attention."""
    return _attn(q, k, v, causal, int(window))


@contextlib.contextmanager
def ingest_attention():
    """Swap layers.attention for the abstract stub while tracing."""
    from repro.models import layers as L
    orig = L.attention
    L.attention = attention_stub
    try:
        yield
    finally:
        L.attention = orig


def attention_flops(q_shape, v_shape, *, causal: bool, window: int) -> float:
    """2 matmuls over the (possibly windowed / causal) score matrix."""
    b, sq, hkv, g, dq = q_shape
    t, dv = v_shape[1], v_shape[-1]
    eff_t = min(t, window) if window else t
    frac = 0.5 if (causal and sq == t and not window) else 1.0
    return 2.0 * b * hkv * g * sq * eff_t * (dq + dv) * frac

"""Design-space exploration primitives and results (paper §3.5, §5.2).

The enumeration itself lives in :mod:`repro.api.sweep`: a declarative
:class:`~repro.api.sweep.SweepSpace` over :class:`~repro.api.spec.SimSpec`
fields replaces the old hardcoded (tp, pp, batch, micro) grid, with
:func:`explore` kept as a deprecation shim for external callers.  This
module keeps the pieces both surfaces share: pruning rules
(user-extensible), :class:`Candidate`/:class:`EvalResult`, and
:class:`ExplorationResult` — the Pareto frontier over (system throughput
TPS/chip vs user-facing TPS/user), best-under-SLO queries and
step-time/goodput rankings of the paper's Fig. 13 workflow.

Throughput is first-class: candidates are grouped by the sub-results they
share (same tp/ep and per-shard batch ⇒ same traced, transformed and priced
block graphs), so a sweep pays the expensive stages once per group and the
simulator's :class:`~repro.core.simcache.SimCache` serves the rest.
``ExplorationResult`` carries configs/sec and per-layer cache hit rates so
benchmarks can track the sweep-throughput trajectory.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Iterable

from repro.configs.base import ModelConfig
from repro.core.memory import COLLECTIVE_BUFFER_BYTES
from repro.core.passes.base import ParallelConfig
from repro.core.simulator import Report, Simulator, shard_memory_floor


@dataclass
class Candidate:
    par: ParallelConfig
    global_batch: int
    extra: dict = field(default_factory=dict)

    def key(self) -> tuple:
        p = self.par
        return (p.tp, p.pp, p.dp, p.pods, p.microbatches, self.global_batch)

    def B_local(self) -> int:
        return max(self.global_batch // max(self.par.dp * self.par.pods, 1), 1)

    def reuse_key(self) -> tuple:
        """Candidates with equal reuse keys share priced block graphs (the
        simulator's block-stage cache key, minus the sweep-constant parts)."""
        return (self.par.shard_key(), self.B_local())


@dataclass(frozen=True)
class FailedCandidate:
    """A quarantined candidate: it exhausted its execution contract
    (``max_retries`` worker deaths/timeouts, or raised inside evaluation)
    and was recorded instead of aborting the sweep.  A third outcome
    category next to evaluated/pruned — downstream tooling must never
    silently drop candidates (manifest rows carry ``status: failed``)."""
    cand: Candidate
    spec: object                 # the full SimSpec (json_hash for manifests)
    attempts: int
    reason: str
    traceback: str = ""          # compact summary, last frames only


@dataclass
class EvalResult:
    cand: Candidate
    report: Report
    pruned: bool = False
    reason: str = ""
    # request-level result when the sweep ran a serving scenario for this
    # candidate (per-replica workload share; see
    # repro.serving.sim.ServingScenario)
    serving: object | None = None
    # resilience result when the sweep priced the candidate under failures
    # (repro.resilience.ResilienceReport; objective="goodput_under_failures")
    resilience: object | None = None
    # the full SimSpec this candidate evaluated (set by repro.api.sweep)
    spec: object | None = None

    @property
    def tps_per_chip(self) -> float:
        return self.report.tps_per_chip

    @property
    def tps_per_user(self) -> float:
        # decode: tokens per second seen by one request
        return 1e6 / self.report.step_time_us if self.report.mode == "decode" else 0.0

    @property
    def goodput_rps(self) -> float:
        """System-level SLO-attainment goodput.  A per-replica serving
        result is scaled by the candidate's replica count; a fleet result
        (``system_level`` reports, e.g. ``FleetReport``) already aggregates
        over its replicas and is passed through unscaled."""
        if self.serving is None:
            return 0.0
        if getattr(type(self.serving), "system_level", False):
            return self.serving.goodput_rps
        replicas = max(self.cand.par.dp * self.cand.par.pods, 1)
        return self.serving.goodput_rps * replicas

    @property
    def slo_attainment(self) -> float:
        return self.serving.slo_attainment if self.serving is not None else 0.0


# -------------------------- pruning rules ---------------------------------

def rule_divisibility(cfg: ModelConfig, c: Candidate) -> str | None:
    p = c.par
    if c.global_batch % (p.dp * p.pods) and c.global_batch >= p.dp * p.pods:
        return "batch not divisible by dp"
    if p.microbatches > max(c.global_batch // (p.dp * p.pods), 1):
        return "microbatches exceed local batch"
    return None


def rule_tp_too_wide(cfg: ModelConfig, c: Candidate) -> str | None:
    if c.par.tp > cfg.d_model // 64:
        return "tp wider than head granularity"
    return None


def rule_pp_layers(cfg: ModelConfig, c: Candidate) -> str | None:
    if c.par.pp > cfg.num_layers:
        return "more stages than layers"
    return None


def rule_memory_fit(hw_bytes: float, *, mode: str = "decode",
                    seq_len: int = 4096, cache_len: int = 0):
    """Closed-form memory-infeasibility pruning (pre-simulation).

    Estimates the per-device floor: sharded parameters + KV cache (decode)
    + collective staging buffers.  Every term is a component the full memory
    simulation also counts (before its >=1 fragmentation factor), so the
    estimate is a lower bound — a candidate pruned here could never have
    passed the post-simulation ``memory_limit`` filter, while feasible
    candidates are never pruned early.  The post-filter remains as the
    fallback for the activation/optimizer terms this estimate omits.
    """
    def rule(cfg: ModelConfig, c: Candidate, report: Report | None = None) -> str | None:
        param_dev, kv = shard_memory_floor(cfg, c.par, c.B_local(), mode,
                                           cache_len or seq_len)
        est = param_dev + kv + COLLECTIVE_BUFFER_BYTES
        if est > hw_bytes:
            return (f"memory-fit: params+KV >= {est / 1e9:.1f}GB "
                    f"> limit {hw_bytes / 1e9:.1f}GB")
        return None
    return rule


DEFAULT_RULES: list[Callable] = [rule_divisibility, rule_tp_too_wide, rule_pp_layers]


# -------------------------- exploration -----------------------------------

@dataclass
class ExplorationResult:
    # tuples: sweep results are shared (manifest writers, notebooks, the
    # legacy explore() shim) — immutability keeps them consistent
    evaluated: tuple
    pruned: tuple
    wall_time_s: float
    n_groups: int = 0                               # distinct reuse groups
    configs_per_sec: float = 0.0
    cache_stats: dict = field(default_factory=dict)  # per-layer hits/misses
    objective: str = "step_time"
    workers: int = 1                                # sweep evaluation processes
    # MetricsRegistry snapshot of the sweep (counters/histograms); filled by
    # sweep(), empty for the legacy explore() path
    metrics: dict = field(default_factory=dict)
    # quarantined candidates (FailedCandidate): exhausted retries or raised
    # during evaluation under sweep(strict=False) — a category distinct from
    # pruned (pruning is a *verdict*, failure is an execution outcome)
    failed: tuple = ()

    def pareto(self, x=lambda r: r.tps_per_user, y=lambda r: r.tps_per_chip
               ) -> list[EvalResult]:
        """Upper-right Pareto frontier (maximize both)."""
        pts = sorted(self.evaluated, key=lambda r: (-x(r), -y(r)))
        front, best_y = [], -math.inf
        for r in pts:
            if y(r) > best_y:
                front.append(r)
                best_y = y(r)
        return front

    def best_under_slo(self, *, tpot_ms: float | None = None,
                       min_tps_user: float | None = None) -> EvalResult | None:
        ok = self.evaluated
        if tpot_ms is not None:
            ok = [r for r in ok if r.report.step_time_us / 1e3 <= tpot_ms]
        if min_tps_user is not None:
            ok = [r for r in ok if r.tps_per_user >= min_tps_user]
        if not ok:
            return None
        return max(ok, key=lambda r: r.tps_per_chip)

    def ranked(self, objective: str | None = None) -> list[EvalResult]:
        """Candidates best-first under an objective.

        ``step_time`` ranks by steady-state per-step latency (the pre-PR-3
        behaviour); ``goodput`` ranks by system-level SLO-attainment
        throughput from the request-level serving simulation and requires
        ``sweep(..., objective="goodput")``.  The two orders genuinely
        differ under load: small batches win on step time while starving
        admission capacity — see docs/serving.md for a documented scenario.
        ``goodput_under_failures`` ranks by useful tokens per wall second
        from the resilience replay (then goodput fraction) and requires
        ``sweep(..., objective="goodput_under_failures")`` — fast-but-
        fragile configurations genuinely reorder under failures; see
        docs/resilience.md.
        """
        objective = objective or self.objective
        if objective == "goodput":
            if any(r.serving is None for r in self.evaluated):
                raise ValueError(
                    "goodput ranking needs sweep(objective='goodput')")
            return sorted(self.evaluated,
                          key=lambda r: (-r.goodput_rps,
                                         r.report.step_time_us
                                         if r.report else 0.0))
        if objective == "goodput_under_failures":
            if any(r.resilience is None for r in self.evaluated):
                raise ValueError(
                    "goodput_under_failures ranking needs "
                    "sweep(objective='goodput_under_failures')")
            # useful tokens per wall second is the deployment-facing number;
            # goodput fraction breaks ties between equal-throughput meshes
            return sorted(self.evaluated,
                          key=lambda r: (-r.resilience.tokens_per_s,
                                         -r.resilience.goodput,
                                         r.report.step_time_us
                                         if r.report else 0.0))
        if objective == "step_time":
            return sorted(self.evaluated,
                          key=lambda r: (r.report.step_time_us,
                                         -r.tps_per_chip))
        raise ValueError(f"unknown objective {objective!r}")


def _stats_delta(after: dict, before: dict) -> dict:
    return {layer: {k: after[layer][k] - before.get(layer, {}).get(k, 0)
                    for k in ("hits", "misses")}
            for layer in after}


def explore(sim: Simulator, cfg: ModelConfig, *, mode: str = "decode",
            seq_len: int = 4096, chips: int = 256,
            tp_choices: Iterable[int] = (1, 2, 4, 8, 16),
            pp_choices: Iterable[int] = (1, 2, 4),
            batch_choices: Iterable[int] = (8, 16, 32, 64, 128, 256),
            micro_choices: Iterable[int] = (1,),
            rules: list[Callable] | None = None,
            memory_limit: float | None = None,
            max_evals: int = 10_000, objective: str = "step_time",
            scenario=None) -> ExplorationResult:
    """Deprecated kwargs shim for external callers: the hardcoded
    (tp, pp, batch, micro) grid expressed as a declarative
    :class:`~repro.api.sweep.SweepSpace` over :class:`~repro.api.spec.SimSpec`
    fields — bit-identical candidates, pruning, grouping and rankings by
    construction.  Intra-repo code calls :func:`repro.api.sweep.sweep`.
    """
    import warnings

    from repro.api.spec import (
        Cluster, CharonDeprecationWarning, STEP_WORKLOADS, SimSpec,
    )
    from repro.api.sweep import SweepSpace, sweep
    warnings.warn(
        "explore(sim, cfg, tp_choices=...) is deprecated; build a "
        "SweepSpace over SimSpec fields and call repro.api.sweep (see "
        "docs/api.md)", CharonDeprecationWarning, stacklevel=2)
    if memory_limit is not None and memory_limit <= 0:
        # legacy 0.0 degenerately pruned everything; the spec surface uses
        # 0 for "unlimited", so refuse the ambiguous value outright
        raise ValueError("memory_limit must be positive; pass None (or "
                         "omit) for no limit")
    base = SimSpec(
        model=cfg,
        cluster=Cluster(sim.hw, chips=chips,
                        memory_limit=memory_limit or 0.0),
        workload=STEP_WORKLOADS[mode](seq_len=seq_len))
    space = SweepSpace(base, {
        "parallel.tp": tuple(tp_choices), "parallel.pp": tuple(pp_choices),
        "workload.global_batch": tuple(batch_choices),
        "parallel.microbatches": tuple(micro_choices)})
    return sweep(space, sim=sim, rules=rules, max_evals=max_evals,
                 objective=objective, scenario=scenario)

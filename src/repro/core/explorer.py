"""Design-space exploration with rule-based pruning (paper §3.5, §5.2).

Enumerates (chips, tp, pp, dp, batch, microbatches, ...) configurations,
prunes known-inefficient subspaces *before* simulating (user-extensible
rules), simulates the rest, and reports the Pareto frontier over
(system throughput TPS/chip vs user-facing TPS/user) plus best-under-SLO
queries — the paper's Fig. 13 workflow.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable

from repro.configs.base import ModelConfig
from repro.core.passes.base import ParallelConfig
from repro.core.simulator import Report, Simulator


@dataclass
class Candidate:
    par: ParallelConfig
    global_batch: int
    extra: dict = field(default_factory=dict)

    def key(self) -> tuple:
        p = self.par
        return (p.tp, p.pp, p.dp, p.pods, p.microbatches, self.global_batch)


@dataclass
class EvalResult:
    cand: Candidate
    report: Report
    pruned: bool = False
    reason: str = ""

    @property
    def tps_per_chip(self) -> float:
        return self.report.tps_per_chip

    @property
    def tps_per_user(self) -> float:
        # decode: tokens per second seen by one request
        return 1e6 / self.report.step_time_us if self.report.mode == "decode" else 0.0


# -------------------------- pruning rules ---------------------------------

def rule_divisibility(cfg: ModelConfig, c: Candidate) -> str | None:
    p = c.par
    if c.global_batch % (p.dp * p.pods) and c.global_batch >= p.dp * p.pods:
        return "batch not divisible by dp"
    if p.microbatches > max(c.global_batch // (p.dp * p.pods), 1):
        return "microbatches exceed local batch"
    return None


def rule_tp_too_wide(cfg: ModelConfig, c: Candidate) -> str | None:
    if c.par.tp > cfg.d_model // 64:
        return "tp wider than head granularity"
    return None


def rule_pp_layers(cfg: ModelConfig, c: Candidate) -> str | None:
    if c.par.pp > cfg.num_layers:
        return "more stages than layers"
    return None


def rule_memory_fit(hw_bytes: float):
    def rule(cfg: ModelConfig, c: Candidate, report: Report | None = None) -> str | None:
        return None
    return rule


DEFAULT_RULES: list[Callable] = [rule_divisibility, rule_tp_too_wide, rule_pp_layers]


# -------------------------- exploration -----------------------------------

@dataclass
class ExplorationResult:
    evaluated: list[EvalResult]
    pruned: list[EvalResult]
    wall_time_s: float

    def pareto(self, x=lambda r: r.tps_per_user, y=lambda r: r.tps_per_chip
               ) -> list[EvalResult]:
        """Upper-right Pareto frontier (maximize both)."""
        pts = sorted(self.evaluated, key=lambda r: (-x(r), -y(r)))
        front, best_y = [], -math.inf
        for r in pts:
            if y(r) > best_y:
                front.append(r)
                best_y = y(r)
        return front

    def best_under_slo(self, *, tpot_ms: float | None = None,
                       min_tps_user: float | None = None) -> EvalResult | None:
        ok = self.evaluated
        if tpot_ms is not None:
            ok = [r for r in ok if r.report.step_time_us / 1e3 <= tpot_ms]
        if min_tps_user is not None:
            ok = [r for r in ok if r.tps_per_user >= min_tps_user]
        if not ok:
            return None
        return max(ok, key=lambda r: r.tps_per_chip)


def explore(sim: Simulator, cfg: ModelConfig, *, mode: str = "decode",
            seq_len: int = 4096, chips: int = 256,
            tp_choices: Iterable[int] = (1, 2, 4, 8, 16),
            pp_choices: Iterable[int] = (1, 2, 4),
            batch_choices: Iterable[int] = (8, 16, 32, 64, 128, 256),
            micro_choices: Iterable[int] = (1,),
            rules: list[Callable] | None = None,
            memory_limit: float | None = None,
            max_evals: int = 10_000) -> ExplorationResult:
    rules = DEFAULT_RULES if rules is None else rules
    t0 = time.time()
    evaluated: list[EvalResult] = []
    pruned: list[EvalResult] = []
    n = 0
    for tp, pp, gb, m in itertools.product(tp_choices, pp_choices,
                                           batch_choices, micro_choices):
        if chips % (tp * pp):
            continue
        dp = chips // (tp * pp)
        par = ParallelConfig(tp=tp, pp=pp, dp=dp, microbatches=m,
                             ep=tp if cfg.num_experts else 1)
        cand = Candidate(par, gb)
        reason = next((r for rule in rules if (r := rule(cfg, cand))), None)
        if reason:
            pruned.append(EvalResult(cand, None, pruned=True, reason=reason))
            continue
        n += 1
        if n > max_evals:
            break
        rep = sim.simulate(cfg, mode=mode, global_batch=gb, seq_len=seq_len,
                           par=par, remat="none" if mode != "train" else "block")
        res = EvalResult(cand, rep)
        if memory_limit is not None and rep.memory and rep.memory.total > memory_limit:
            res.pruned = True
            res.reason = f"memory {rep.memory.total/1e9:.1f}GB > limit"
            pruned.append(res)
            continue
        evaluated.append(res)
    return ExplorationResult(evaluated, pruned, time.time() - t0)

"""Design-space exploration with rule-based pruning (paper §3.5, §5.2).

Enumerates (chips, tp, pp, dp, batch, microbatches, ...) configurations,
prunes known-inefficient subspaces *before* simulating (user-extensible
rules), simulates the rest, and reports the Pareto frontier over
(system throughput TPS/chip vs user-facing TPS/user) plus best-under-SLO
queries — the paper's Fig. 13 workflow.

Throughput is first-class: candidates are grouped by the sub-results they
share (same tp/ep and per-shard batch ⇒ same traced, transformed and priced
block graphs), so a sweep pays the expensive stages once per group and the
simulator's :class:`~repro.core.simcache.SimCache` serves the rest.
``ExplorationResult`` carries configs/sec and per-layer cache hit rates so
benchmarks can track the sweep-throughput trajectory.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Iterable

from repro.configs.base import ModelConfig
from repro.core.memory import COLLECTIVE_BUFFER_BYTES
from repro.core.passes.base import ParallelConfig
from repro.core.simulator import Report, Simulator, shard_memory_floor


@dataclass
class Candidate:
    par: ParallelConfig
    global_batch: int
    extra: dict = field(default_factory=dict)

    def key(self) -> tuple:
        p = self.par
        return (p.tp, p.pp, p.dp, p.pods, p.microbatches, self.global_batch)

    def B_local(self) -> int:
        return max(self.global_batch // max(self.par.dp * self.par.pods, 1), 1)

    def reuse_key(self) -> tuple:
        """Candidates with equal reuse keys share priced block graphs (the
        simulator's block-stage cache key, minus the sweep-constant parts)."""
        return (self.par.shard_key(), self.B_local())


@dataclass
class EvalResult:
    cand: Candidate
    report: Report
    pruned: bool = False
    reason: str = ""
    # request-level result when explore(objective="goodput") ran a serving
    # scenario for this candidate (per-replica workload share; see
    # repro.serving.sim.ServingScenario)
    serving: object | None = None

    @property
    def tps_per_chip(self) -> float:
        return self.report.tps_per_chip

    @property
    def tps_per_user(self) -> float:
        # decode: tokens per second seen by one request
        return 1e6 / self.report.step_time_us if self.report.mode == "decode" else 0.0

    @property
    def goodput_rps(self) -> float:
        """System-level SLO-attainment goodput: the per-replica serving
        result scaled by the candidate's replica count."""
        if self.serving is None:
            return 0.0
        replicas = max(self.cand.par.dp * self.cand.par.pods, 1)
        return self.serving.goodput_rps * replicas

    @property
    def slo_attainment(self) -> float:
        return self.serving.slo_attainment if self.serving is not None else 0.0


# -------------------------- pruning rules ---------------------------------

def rule_divisibility(cfg: ModelConfig, c: Candidate) -> str | None:
    p = c.par
    if c.global_batch % (p.dp * p.pods) and c.global_batch >= p.dp * p.pods:
        return "batch not divisible by dp"
    if p.microbatches > max(c.global_batch // (p.dp * p.pods), 1):
        return "microbatches exceed local batch"
    return None


def rule_tp_too_wide(cfg: ModelConfig, c: Candidate) -> str | None:
    if c.par.tp > cfg.d_model // 64:
        return "tp wider than head granularity"
    return None


def rule_pp_layers(cfg: ModelConfig, c: Candidate) -> str | None:
    if c.par.pp > cfg.num_layers:
        return "more stages than layers"
    return None


def rule_memory_fit(hw_bytes: float, *, mode: str = "decode",
                    seq_len: int = 4096, cache_len: int = 0):
    """Closed-form memory-infeasibility pruning (pre-simulation).

    Estimates the per-device floor: sharded parameters + KV cache (decode)
    + collective staging buffers.  Every term is a component the full memory
    simulation also counts (before its >=1 fragmentation factor), so the
    estimate is a lower bound — a candidate pruned here could never have
    passed the post-simulation ``memory_limit`` filter, while feasible
    candidates are never pruned early.  The post-filter remains as the
    fallback for the activation/optimizer terms this estimate omits.
    """
    def rule(cfg: ModelConfig, c: Candidate, report: Report | None = None) -> str | None:
        param_dev, kv = shard_memory_floor(cfg, c.par, c.B_local(), mode,
                                           cache_len or seq_len)
        est = param_dev + kv + COLLECTIVE_BUFFER_BYTES
        if est > hw_bytes:
            return (f"memory-fit: params+KV >= {est / 1e9:.1f}GB "
                    f"> limit {hw_bytes / 1e9:.1f}GB")
        return None
    return rule


DEFAULT_RULES: list[Callable] = [rule_divisibility, rule_tp_too_wide, rule_pp_layers]


# -------------------------- exploration -----------------------------------

@dataclass
class ExplorationResult:
    evaluated: list[EvalResult]
    pruned: list[EvalResult]
    wall_time_s: float
    n_groups: int = 0                               # distinct reuse groups
    configs_per_sec: float = 0.0
    cache_stats: dict = field(default_factory=dict)  # per-layer hits/misses
    objective: str = "step_time"

    def pareto(self, x=lambda r: r.tps_per_user, y=lambda r: r.tps_per_chip
               ) -> list[EvalResult]:
        """Upper-right Pareto frontier (maximize both)."""
        pts = sorted(self.evaluated, key=lambda r: (-x(r), -y(r)))
        front, best_y = [], -math.inf
        for r in pts:
            if y(r) > best_y:
                front.append(r)
                best_y = y(r)
        return front

    def best_under_slo(self, *, tpot_ms: float | None = None,
                       min_tps_user: float | None = None) -> EvalResult | None:
        ok = self.evaluated
        if tpot_ms is not None:
            ok = [r for r in ok if r.report.step_time_us / 1e3 <= tpot_ms]
        if min_tps_user is not None:
            ok = [r for r in ok if r.tps_per_user >= min_tps_user]
        if not ok:
            return None
        return max(ok, key=lambda r: r.tps_per_chip)

    def ranked(self, objective: str | None = None) -> list[EvalResult]:
        """Candidates best-first under an objective.

        ``step_time`` ranks by steady-state per-step latency (the pre-PR-3
        behaviour); ``goodput`` ranks by system-level SLO-attainment
        throughput from the request-level serving simulation and requires
        ``explore(..., objective="goodput")``.  The two orders genuinely
        differ under load: small batches win on step time while starving
        admission capacity — see docs/serving.md for a documented scenario.
        """
        objective = objective or self.objective
        if objective == "goodput":
            if any(r.serving is None for r in self.evaluated):
                raise ValueError(
                    "goodput ranking needs explore(objective='goodput')")
            return sorted(self.evaluated,
                          key=lambda r: (-r.goodput_rps,
                                         r.report.step_time_us))
        if objective == "step_time":
            return sorted(self.evaluated,
                          key=lambda r: (r.report.step_time_us,
                                         -r.tps_per_chip))
        raise ValueError(f"unknown objective {objective!r}")


def _stats_delta(after: dict, before: dict) -> dict:
    return {layer: {k: after[layer][k] - before.get(layer, {}).get(k, 0)
                    for k in ("hits", "misses")}
            for layer in after}


def explore(sim: Simulator, cfg: ModelConfig, *, mode: str = "decode",
            seq_len: int = 4096, chips: int = 256,
            tp_choices: Iterable[int] = (1, 2, 4, 8, 16),
            pp_choices: Iterable[int] = (1, 2, 4),
            batch_choices: Iterable[int] = (8, 16, 32, 64, 128, 256),
            micro_choices: Iterable[int] = (1,),
            rules: list[Callable] | None = None,
            memory_limit: float | None = None,
            max_evals: int = 10_000, objective: str = "step_time",
            scenario=None) -> ExplorationResult:
    """Enumerate, prune, simulate and rank candidate configurations.

    ``objective="step_time"`` (default) keeps the classic behaviour: every
    candidate gets one steady-state ``simulate`` call.  ``"goodput"``
    additionally replays a request-level serving scenario
    (:class:`repro.serving.sim.ServingScenario`, default workload if
    ``scenario`` is None) on every surviving candidate and ranks by system
    SLO-attainment goodput via :meth:`ExplorationResult.ranked`.
    """
    if objective not in ("step_time", "goodput"):
        raise ValueError(f"unknown objective {objective!r}")
    rules = list(DEFAULT_RULES if rules is None else rules)
    if memory_limit is not None:
        # cheap closed-form pre-filter; the post-simulation check stays below
        rules.append(rule_memory_fit(memory_limit, mode=mode, seq_len=seq_len))
    t0 = time.time()
    pruned: list[EvalResult] = []
    cands: list[Candidate] = []
    for tp, pp, gb, m in itertools.product(tp_choices, pp_choices,
                                           batch_choices, micro_choices):
        if chips % (tp * pp):
            continue
        dp = chips // (tp * pp)
        par = ParallelConfig(tp=tp, pp=pp, dp=dp, microbatches=m,
                             ep=tp if cfg.num_experts else 1)
        cand = Candidate(par, gb)
        reason = next((r for rule in rules if (r := rule(cfg, cand))), None)
        if reason:
            pruned.append(EvalResult(cand, None, pruned=True, reason=reason))
            continue
        cands.append(cand)

    # evaluate group-by-group so every candidate after the first in a group
    # hits the simulator's block-stage cache while it is warm
    cands.sort(key=lambda c: (c.reuse_key(), c.key()))
    n_groups = len({c.reuse_key() for c in cands})
    stats0 = sim.cache_stats()

    evaluated: list[EvalResult] = []
    for cand in cands[:max_evals]:
        rep = sim.simulate(cfg, mode=mode, global_batch=cand.global_batch,
                           seq_len=seq_len, par=cand.par,
                           remat="none" if mode != "train" else "block")
        res = EvalResult(cand, rep)
        if memory_limit is not None and rep.memory and rep.memory.total > memory_limit:
            res.pruned = True
            res.reason = f"memory {rep.memory.total/1e9:.1f}GB > limit"
            pruned.append(res)
            continue
        evaluated.append(res)

    if objective == "goodput":
        # deferred import: repro.serving pulls the real-model serving stack,
        # which the step-time-only path never needs
        from repro.serving.sim import ServingScenario
        scenario = scenario or ServingScenario.default()
        for res in evaluated:
            res.serving = scenario.evaluate(sim, cfg, res.cand)

    wall = time.time() - t0
    return ExplorationResult(
        evaluated, pruned, wall, n_groups=n_groups,
        configs_per_sec=(len(cands[:max_evals]) / wall) if wall > 0 else 0.0,
        cache_stats=_stats_delta(sim.cache_stats(), stats0),
        objective=objective)

"""Layered memoization for the simulation stack.

Design-space sweeps evaluate thousands of near-identical candidates; most of
the per-candidate cost (JAX tracing in ``block_graphs``, pass pipelines,
per-node engine pricing) repeats verbatim whenever two candidates share the
relevant key.  ``SimCache`` holds the three sweep-level buckets:

* ``ingest``       — ``block_graphs`` results, keyed on
                     (model config, B_local, S, mode, cache_len)
* ``passes``       — post-``PassManager`` graphs, keyed on
                     (ingest key, block kind, fwd/joint, pipeline signature,
                     parallel signature)
* ``block_times``  — the whole priced block stage (t_fwd / t_bwd / kind_us
                     plus the transformed first-block graphs the memory
                     analyzer needs), keyed on the union of the above
* ``memory``       — the memory analyzer's block-graph liveness walk
                     (``core.memory.block_liveness``), keyed like the block
                     stage minus the engine version (liveness reads bytes,
                     not prices)
* ``serving``      — whole ``Report``s priced for the request-level serving
                     simulator's step oracle, keyed directly on the
                     bucketed :class:`repro.api.spec.SimSpec` (specs are
                     frozen and hashable — the spec *is* the cache key)
                     plus the engine state version
* ``reports``      — whole ``Report``s per simulated spec, keyed on
                     (``SimSpec.json_hash()``, engine state version).  Only
                     consulted when a persistent tier is attached: it is the
                     cross-run memo that lets a repeated CLI/benchmark run
                     skip JAX tracing entirely.

Operator-pricing memoization lives on ``FusedEngine`` (see
``backend/engine.py``) but reports through the same ``CacheStats`` type so
benchmarks can track hit rates uniformly.  All cached values are treated as
immutable by their consumers; correctness bar: bit-identical ``Report``s with
caching on vs off (see tests/test_perf_cache.py).

Persistence: :meth:`SimCache.attach_persistent` loads a versioned pickle of
the cacheable buckets (+ the fused engine's pricing table) written by
:meth:`SimCache.save_persistent`.  The file is keyed by a metadata dict —
cache format version, package version, jax version, hardware name, engine
stack, overlap model and an engine-state digest — and is ignored wholesale on
any mismatch, so a package upgrade or a profile-DB change can never serve
stale entries (tests/test_sweep_parallel.py).
"""
from __future__ import annotations

import os
import pickle
import tempfile
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

# bump when the pickled layout of any cached value changes incompatibly
CACHE_FORMAT = 1


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0

    @property
    def total(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.total if self.total else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": round(self.hit_rate, 4)}


class SimCache:
    """Sweep-scoped cache of expensive simulation sub-results.

    ``enabled=False`` turns every ``get`` into a pass-through build (the cold
    path), which keeps cached and uncached runs on the same code path — the
    property the bit-identical tests rely on.
    """

    BUCKETS = ("ingest", "passes", "block_times", "memory", "serving",
               "reports")
    # buckets whose keys/values survive pickling across processes ("passes"
    # rides along with "ingest": both hold plain Graphs keyed by hashable
    # tuples of frozen dataclasses; "serving" keys are frozen SimSpecs)
    PERSISTED = ("ingest", "passes", "block_times", "memory", "serving",
                 "reports")

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._data: dict[str, dict] = {b: {} for b in self.BUCKETS}
        self.stats: dict[str, CacheStats] = {b: CacheStats() for b in self.BUCKETS}
        self.persist_path: Path | None = None
        self._persist_meta: dict | None = None
        self.loaded_sizes: dict[str, int] = {}

    def get(self, bucket: str, key: Any, build: Callable[[], Any]) -> Any:
        if not self.enabled:
            return build()
        d = self._data[bucket]
        st = self.stats[bucket]
        try:
            hit = key in d
        except TypeError:           # unhashable key component: skip caching
            return build()
        if hit:
            st.hits += 1
            return d[key]
        st.misses += 1
        v = build()
        d[key] = v
        return v

    def clear(self) -> None:
        for d in self._data.values():
            d.clear()
        self.stats = {b: CacheStats() for b in self.BUCKETS}

    def sizes(self) -> dict[str, int]:
        return {b: len(d) for b, d in self._data.items()}

    def stats_dict(self) -> dict[str, dict]:
        return {b: st.as_dict() for b, st in self.stats.items()}

    # ---------------- persistent tier -------------------------------------
    @property
    def persistent(self) -> bool:
        return self.persist_path is not None

    def attach_persistent(self, path: str | Path, meta: dict) -> dict:
        """Attach an on-disk tier: load ``path`` if it exists and its stored
        metadata equals ``meta`` (any mismatch — package version, engine
        state digest, hardware, cache format — invalidates the whole file),
        merge its buckets, and return the persisted engine pricing table for
        the caller to splice into its ``FusedEngine``.  Corrupt or
        unreadable files are treated as a cold start."""
        self.persist_path = Path(path)
        self._persist_meta = dict(meta)
        self.loaded_sizes = {}
        if not self.enabled or not self.persist_path.exists():
            return {}
        try:
            with open(self.persist_path, "rb") as f:
                blob = pickle.load(f)
        except Exception:
            return {}
        if blob.get("meta") != self._persist_meta:
            return {}                     # versioned key mismatch: invalidate
        for b in self.PERSISTED:
            entries = blob.get("buckets", {}).get(b)
            if entries:
                self._data[b].update(entries)
                self.loaded_sizes[b] = len(entries)
        pricing = blob.get("pricing", {})
        if pricing:
            self.loaded_sizes["pricing"] = len(pricing)
        return pricing

    def save_persistent(self, pricing: dict | None = None, *,
                        meta: dict | None = None,
                        path: str | Path | None = None) -> Path | None:
        """Atomically write the persisted buckets (+ engine pricing table)
        to the attached path.  No-op without :meth:`attach_persistent`.

        ``meta`` lets the caller stamp the file with the *current* engine
        state (recomputed at save time): entries priced after a profile-DB
        mutation must never be described by the attach-time digest.
        ``path`` overrides the destination without re-attaching — how sweep
        worker processes write per-worker *shards* next to the main file
        (merged by :func:`repro.core.simulator.merge_cache_shards`) instead
        of racing each other on it."""
        if self.persist_path is None:
            return None
        if meta is not None:
            self._persist_meta = dict(meta)
        blob = {
            "meta": self._persist_meta,
            "buckets": {b: self._data[b] for b in self.PERSISTED},
            "pricing": pricing or {},
        }
        return atomic_pickle(Path(path) if path is not None
                             else self.persist_path, blob)


def atomic_pickle(path: Path, blob) -> Path:
    """Pickle *blob* to *path* via tmp-file + ``os.replace`` so a concurrent
    reader (or a crash mid-write) can never observe a partial file at the
    final name.  Shared by the persistent tier and the shard merge."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(blob, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)                # atomic vs concurrent runs
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path

"""Layered memoization for the simulation stack.

Design-space sweeps evaluate thousands of near-identical candidates; most of
the per-candidate cost (JAX tracing in ``block_graphs``, pass pipelines,
per-node engine pricing) repeats verbatim whenever two candidates share the
relevant key.  ``SimCache`` holds the three sweep-level buckets:

* ``ingest``       — ``block_graphs`` results, keyed on
                     (model config, B_local, S, mode, cache_len)
* ``passes``       — post-``PassManager`` graphs, keyed on
                     (ingest key, block kind, fwd/joint, pipeline signature,
                     parallel signature)
* ``block_times``  — the whole priced block stage (t_fwd / t_bwd / kind_us
                     plus the transformed first-block graphs the memory
                     analyzer needs), keyed on the union of the above
* ``memory``       — the memory analyzer's block-graph liveness walk
                     (``core.memory.block_liveness``), keyed like the block
                     stage minus the engine version (liveness reads bytes,
                     not prices)
* ``serving``      — whole ``Report``s priced for the request-level serving
                     simulator's step oracle, keyed directly on the
                     bucketed :class:`repro.api.spec.SimSpec` (specs are
                     frozen and hashable — the spec *is* the cache key)
                     plus the engine state version

Operator-pricing memoization lives on ``FusedEngine`` (see
``backend/engine.py``) but reports through the same ``CacheStats`` type so
benchmarks can track hit rates uniformly.  All cached values are treated as
immutable by their consumers; correctness bar: bit-identical ``Report``s with
caching on vs off (see tests/test_perf_cache.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0

    @property
    def total(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.total if self.total else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": round(self.hit_rate, 4)}


class SimCache:
    """Sweep-scoped cache of expensive simulation sub-results.

    ``enabled=False`` turns every ``get`` into a pass-through build (the cold
    path), which keeps cached and uncached runs on the same code path — the
    property the bit-identical tests rely on.
    """

    BUCKETS = ("ingest", "passes", "block_times", "memory", "serving")

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._data: dict[str, dict] = {b: {} for b in self.BUCKETS}
        self.stats: dict[str, CacheStats] = {b: CacheStats() for b in self.BUCKETS}

    def get(self, bucket: str, key: Any, build: Callable[[], Any]) -> Any:
        if not self.enabled:
            return build()
        d = self._data[bucket]
        st = self.stats[bucket]
        try:
            hit = key in d
        except TypeError:           # unhashable key component: skip caching
            return build()
        if hit:
            st.hits += 1
            return d[key]
        st.misses += 1
        v = build()
        d[key] = v
        return v

    def clear(self) -> None:
        for d in self._data.values():
            d.clear()
        self.stats = {b: CacheStats() for b in self.BUCKETS}

    def sizes(self) -> dict[str, int]:
        return {b: len(d) for b, d in self._data.items()}

    def stats_dict(self) -> dict[str, dict]:
        return {b: st.as_dict() for b, st in self.stats.items()}

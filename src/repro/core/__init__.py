"""Charon simulator core — the paper's primary contribution.

Compiler-style simulation pipeline: native JAX ingestion (tracer/stubs/
model_ingest) -> parallelism & optimization passes -> multi-engine backend
(profiling / prediction / analytical, fused fallback) -> scheduler + overlap
models -> multi-granularity analyses (time, MFU, memory, chrome traces) and
design-space exploration.
"""
from repro.core.ir import Graph, OpNode
from repro.core.passes.base import ParallelConfig
from repro.core.simulator import Report, Simulator

__all__ = ["Graph", "OpNode", "Report", "Simulator", "ParallelConfig"]

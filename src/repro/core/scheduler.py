"""Dependency-aware operator scheduler (paper §3.2c).

List-schedules a priced graph onto per-rank streams ('compute' plus comm
streams), honoring data dependencies; overlappable comm ops run on their own
stream concurrently with compute.  The result feeds the overlap processor
(core/overlap.py) and the chrome-trace exporter (core/timeline.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ir import Graph, OpNode


@dataclass
class Interval:
    name: str
    kind: str
    stream: str
    start: float            # us
    end: float
    phase: str = "fwd"
    comm_group: str = ""
    comm_bytes: float = 0.0
    repeat: int = 1
    engine: str = ""

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclass
class Timeline:
    intervals: list[Interval] = field(default_factory=list)

    @property
    def total_time(self) -> float:
        return max((i.end for i in self.intervals), default=0.0)

    def stream_time(self, stream: str) -> float:
        return sum(i.dur for i in self.intervals if i.stream == stream)

    def by_kind(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for i in self.intervals:
            out[i.kind] = out.get(i.kind, 0.0) + i.dur
        return out

    def by_phase(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for i in self.intervals:
            out[i.phase] = out.get(i.phase, 0.0) + i.dur
        return out


def schedule(graph: Graph, engine, *, expand_repeats: bool = False,
             max_expand: int = 4096) -> Timeline:
    """Price every node with ``engine`` and list-schedule.

    ``expand_repeats`` emits one interval per repetition (trace export);
    otherwise a node with repeat=n occupies n * latency sequentially.
    """
    tl = Timeline()
    stream_free: dict[str, float] = {}
    done: dict[str, float] = {}
    eng_name = getattr(engine, "engine_for", None)

    for node in graph.toposort():
        lat = engine.latency_us(node)
        if lat is None:
            lat = 0.0
        stream = node.stream if (node.overlappable or node.stream != "compute") \
            else "compute"
        dep_ready = max((done.get(d, 0.0) for d in node.deps), default=0.0)
        reps = node.repeat if expand_repeats and node.repeat <= max_expand else 1
        dur_total = lat * (node.repeat if reps == 1 else 1)
        t = max(stream_free.get(stream, 0.0), dep_ready)
        for r in range(reps):
            iv = Interval(
                name=node.name if reps == 1 else f"{node.name}#{r}",
                kind=node.kind, stream=stream, start=t, end=t + dur_total,
                phase=node.phase, comm_group=node.comm_group,
                comm_bytes=node.comm_bytes * (node.repeat if reps == 1 else 1),
                repeat=node.repeat,
                engine=eng_name(node) if eng_name else getattr(engine, "name", ""),
            )
            tl.intervals.append(iv)
            t = iv.end
        stream_free[stream] = t
        done[node.name] = t
    return tl


def graph_time_us(graph: Graph, engine) -> float:
    return schedule(graph, engine).total_time

"""Dependency-aware operator scheduler (paper §3.2c).

List-schedules a priced graph onto per-rank streams ('compute' plus comm
streams), honoring data dependencies; overlappable comm ops run on their own
stream concurrently with compute.  The result feeds the overlap processor
(core/overlap.py) and the chrome-trace exporter (core/timeline.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ir import Graph, OpNode


@dataclass
class Interval:
    name: str
    kind: str
    stream: str
    start: float            # us
    end: float
    phase: str = "fwd"
    comm_group: str = ""
    comm_bytes: float = 0.0
    repeat: int = 1
    engine: str = ""

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclass
class Timeline:
    intervals: list[Interval] = field(default_factory=list)

    @property
    def total_time(self) -> float:
        return max((i.end for i in self.intervals), default=0.0)

    def stream_time(self, stream: str) -> float:
        return sum(i.dur for i in self.intervals if i.stream == stream)

    def by_kind(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for i in self.intervals:
            out[i.kind] = out.get(i.kind, 0.0) + i.dur
        return out

    def by_phase(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for i in self.intervals:
            out[i.phase] = out.get(i.phase, 0.0) + i.dur
        return out


def schedule(graph: Graph, engine, *, expand_repeats: bool = False,
             max_expand: int = 4096) -> Timeline:
    """Price every node with ``engine`` and list-schedule.

    ``expand_repeats`` emits one interval per repetition (trace export);
    otherwise a node with repeat=n occupies n * latency sequentially.
    """
    tl = Timeline()
    stream_free: dict[str, float] = {}
    done: dict[str, float] = {}
    eng_name = getattr(engine, "engine_for", None)

    for node in graph.toposort():
        lat = engine.latency_us(node)
        if lat is None:
            lat = 0.0
        stream = node.stream
        dep_ready = max((done.get(d, 0.0) for d in node.deps), default=0.0)
        reps = node.repeat if expand_repeats and node.repeat <= max_expand else 1
        dur_total = lat * (node.repeat if reps == 1 else 1)
        t = max(stream_free.get(stream, 0.0), dep_ready)
        for r in range(reps):
            iv = Interval(
                name=node.name if reps == 1 else f"{node.name}#{r}",
                kind=node.kind, stream=stream, start=t, end=t + dur_total,
                phase=node.phase, comm_group=node.comm_group,
                comm_bytes=node.comm_bytes * (node.repeat if reps == 1 else 1),
                repeat=node.repeat,
                engine=eng_name(node) if eng_name else getattr(engine, "name", ""),
            )
            tl.intervals.append(iv)
            t = iv.end
        stream_free[stream] = t
        done[node.name] = t
    return tl


def schedule_times(graph: Graph, engine, hw=None) -> tuple[float, dict[str, float]]:
    """Interval-free fast path: ``(total_time, by_kind)`` via running scalars.

    Performs the same list-scheduling arithmetic as :func:`schedule` followed
    by the ratio overlap model (core/overlap.py) when ``hw`` is given, but
    keeps only flat per-op arrays — no ``Interval``/``Timeline`` allocation.
    Accumulation order matches the interval path exactly, so the results are
    bit-identical to ``apply_ratio_overlap(schedule(g, engine), hw)``.
    Used by ``Simulator._time`` whenever ``keep_timelines=False``; traces and
    the bandwidth-aware overlap model keep the interval-building path.
    """
    starts: list[float] = []
    ends: list[float] = []
    kinds: list[str] = []
    comp_idx: list[int] = []
    comm_idx: list[int] = []
    comm_stream: list[str] = []
    stream_free: dict[str, float] = {}
    done: dict[str, float] = {}

    for node in graph.toposort():
        lat = engine.latency_us(node)
        if lat is None:
            lat = 0.0
        stream = node.stream
        dep_ready = max((done.get(d, 0.0) for d in node.deps), default=0.0)
        t = max(stream_free.get(stream, 0.0), dep_ready)
        end = t + lat * node.repeat
        i = len(starts)
        starts.append(t)
        ends.append(end)
        kinds.append(node.kind)
        if stream == "compute":
            comp_idx.append(i)
        else:
            comm_idx.append(i)
            comm_stream.append(stream)
        stream_free[stream] = end
        done[node.name] = end

    extra: dict[int, float] = {}
    if hw is not None and comm_idx:
        sc = hw.overlap_slowdown_compute - 1.0
        sm = hw.overlap_slowdown_comm - 1.0
        smm = hw.overlap_slowdown_comm_comm - 1.0
        for c in comm_idx:
            cs, ce = starts[c], ends[c]
            for k in comp_idx:
                ov = min(ce, ends[k]) - max(cs, starts[k])
                if ov <= 0:
                    continue
                extra[k] = extra.get(k, 0.0) + ov * sc
                extra[c] = extra.get(c, 0.0) + ov * sm
        for a, c1 in enumerate(comm_idx):
            for b in range(a + 1, len(comm_idx)):
                if comm_stream[a] == comm_stream[b]:
                    continue
                c2 = comm_idx[b]
                ov = min(ends[c1], ends[c2]) - max(starts[c1], starts[c2])
                if ov <= 0:
                    continue
                extra[c1] = extra.get(c1, 0.0) + ov * smm
                extra[c2] = extra.get(c2, 0.0) + ov * smm

    total = 0.0
    by_kind: dict[str, float] = {}
    for i in range(len(starts)):
        end = ends[i] + extra.get(i, 0.0)
        if end > total:
            total = end
        by_kind[kinds[i]] = by_kind.get(kinds[i], 0.0) + (end - starts[i])
    return total, by_kind


def graph_time_us(graph: Graph, engine) -> float:
    return schedule(graph, engine).total_time

"""Dependency-aware operator scheduler (paper §3.2c).

List-schedules a priced graph onto per-rank streams ('compute' plus comm
streams), honoring data dependencies; overlappable comm ops run on their own
stream concurrently with compute.  The result feeds the overlap processor
(core/overlap.py) and the chrome-trace exporter (core/timeline.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ir import Graph, OpNode


@dataclass
class Interval:
    name: str
    kind: str
    stream: str
    start: float            # us
    end: float
    phase: str = "fwd"
    comm_group: str = ""
    comm_bytes: float = 0.0
    repeat: int = 1
    engine: str = ""

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclass
class Timeline:
    intervals: list[Interval] = field(default_factory=list)

    @property
    def total_time(self) -> float:
        return max((i.end for i in self.intervals), default=0.0)

    def stream_time(self, stream: str) -> float:
        return sum(i.dur for i in self.intervals if i.stream == stream)

    def by_kind(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for i in self.intervals:
            out[i.kind] = out.get(i.kind, 0.0) + i.dur
        return out

    def by_phase(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for i in self.intervals:
            out[i.phase] = out.get(i.phase, 0.0) + i.dur
        return out


def _prices(order: list[OpNode], engine) -> list[float]:
    """Latency per node, via the engine's vectorized ``price_batch`` when it
    has one (one numpy pass over the cache misses) else per-node calls."""
    batch = getattr(engine, "price_batch", None)
    lats = batch(order) if batch is not None \
        else [engine.latency_us(n) for n in order]
    return [0.0 if t is None else t for t in lats]


def schedule(graph: Graph, engine, *, expand_repeats: bool = False,
             max_expand: int = 4096) -> Timeline:
    """Price every node with ``engine`` and list-schedule.

    ``expand_repeats`` emits one interval per repetition (trace export);
    otherwise a node with repeat=n occupies n * latency sequentially.
    """
    tl = Timeline()
    stream_free: dict[str, float] = {}
    done: dict[str, float] = {}
    eng_name = getattr(engine, "engine_for", None)

    order = graph.toposort()
    lats = _prices(order, engine)
    for node, lat in zip(order, lats):
        stream = node.stream
        dep_ready = max((done.get(d, 0.0) for d in node.deps), default=0.0)
        reps = node.repeat if expand_repeats and node.repeat <= max_expand else 1
        dur_total = lat * (node.repeat if reps == 1 else 1)
        t = max(stream_free.get(stream, 0.0), dep_ready)
        for r in range(reps):
            iv = Interval(
                name=node.name if reps == 1 else f"{node.name}#{r}",
                kind=node.kind, stream=stream, start=t, end=t + dur_total,
                phase=node.phase, comm_group=node.comm_group,
                comm_bytes=node.comm_bytes * (node.repeat if reps == 1 else 1),
                repeat=node.repeat,
                engine=eng_name(node) if eng_name else getattr(engine, "name", ""),
            )
            tl.intervals.append(iv)
            t = iv.end
        stream_free[stream] = t
        done[node.name] = t
    return tl


def schedule_times(graph: Graph, engine, hw=None, *,
                   overlap: str = "ratio") -> tuple[float, dict[str, float]]:
    """Interval-free fast path: ``(total_time, by_kind)`` via running scalars.

    Performs the same list-scheduling arithmetic as :func:`schedule` followed
    by the overlap model (core/overlap.py) when ``hw`` is given, but keeps
    only flat per-op arrays — no per-node ``Interval``/``Timeline``
    allocation.  Accumulation order matches the interval path exactly, so
    ``overlap="ratio"`` is bit-identical to
    ``apply_ratio_overlap(schedule(g, engine), hw)`` and
    ``overlap="bandwidth"`` to ``apply_bandwidth_aware(...)`` — the latter is
    *flow-compressed*: only the (few) comm flows materialize as intervals for
    the progressive-filling fluid model; compute ops stay scalar columns.
    Used by ``Simulator._time`` whenever ``keep_timelines=False``; only trace
    export keeps the interval-building path.
    """
    starts: list[float] = []
    ends: list[float] = []
    kinds: list[str] = []
    comp_idx: list[int] = []
    comm_idx: list[int] = []
    comm_stream: list[str] = []
    comm_nodes: list[OpNode] = []
    stream_free: dict[str, float] = {}
    done: dict[str, float] = {}

    order = graph.toposort()
    lats = _prices(order, engine)
    for node, lat in zip(order, lats):
        stream = node.stream
        dep_ready = 0.0
        for d in node.deps:
            v = done.get(d, 0.0)
            if v > dep_ready:
                dep_ready = v
        t = max(stream_free.get(stream, 0.0), dep_ready)
        end = t + lat * node.repeat
        i = len(starts)
        starts.append(t)
        ends.append(end)
        kinds.append(node.kind)
        if stream == "compute":
            comp_idx.append(i)
        else:
            comm_idx.append(i)
            comm_stream.append(stream)
            comm_nodes.append(node)
        stream_free[stream] = end
        done[node.name] = end

    comm_streams = {i: s for i, s in zip(comm_idx, comm_stream)}
    if overlap == "bandwidth" and comm_idx:
        # fluid model first (mirrors apply_bandwidth_aware): adjusted comm
        # ends feed the ratio pass, whose comm iteration order becomes the
        # flows' start-sorted order — exactly the Timeline the interval path
        # would hand to apply_ratio_overlap
        from repro.core.overlap import bandwidth_aware_comm
        flows = [Interval(name=str(i), kind=kinds[i], stream=comm_stream[j],
                          start=starts[i], end=ends[i],
                          comm_bytes=comm_nodes[j].comm_bytes
                          * comm_nodes[j].repeat)
                 for j, i in enumerate(comm_idx)]
        adjusted = bandwidth_aware_comm(flows)       # start-order preserved
        for f in adjusted:
            ends[int(f.name)] = f.end
        comm_order = [int(f.name) for f in adjusted]
    else:
        comm_order = comm_idx

    extra: dict[int, float] = {}
    if hw is not None and comm_order:
        sc = hw.overlap_slowdown_compute - 1.0
        sm = hw.overlap_slowdown_comm - 1.0
        smm = hw.overlap_slowdown_comm_comm - 1.0
        for c in comm_order:
            cs, ce = starts[c], ends[c]
            for k in comp_idx:
                ov = min(ce, ends[k]) - max(cs, starts[k])
                if ov <= 0:
                    continue
                extra[k] = extra.get(k, 0.0) + ov * sc
                extra[c] = extra.get(c, 0.0) + ov * sm
        for a, c1 in enumerate(comm_order):
            for b in range(a + 1, len(comm_order)):
                c2 = comm_order[b]
                if comm_streams[c1] == comm_streams[c2]:
                    continue
                ov = min(ends[c1], ends[c2]) - max(starts[c1], starts[c2])
                if ov <= 0:
                    continue
                extra[c1] = extra.get(c1, 0.0) + ov * smm
                extra[c2] = extra.get(c2, 0.0) + ov * smm

    total = 0.0
    by_kind: dict[str, float] = {}
    if overlap == "bandwidth" and comm_idx:
        # match Timeline(rest + adjusted).by_kind() summation order:
        # compute ops in graph order, then comm flows in start-sorted order
        sum_order = comp_idx + comm_order
    else:
        sum_order = range(len(starts))
    for i in sum_order:
        end = ends[i] + extra.get(i, 0.0)
        if end > total:
            total = end
        by_kind[kinds[i]] = by_kind.get(kinds[i], 0.0) + (end - starts[i])
    return total, by_kind


def graph_time_us(graph: Graph, engine) -> float:
    return schedule(graph, engine).total_time

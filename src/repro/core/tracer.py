"""Graph tracer: native JAX model ingestion (paper §3.2a).

Charon ingests HuggingFace/vLLM/PyTorch models via torch.fx; the JAX-native
equivalent is the jaxpr.  ``trace(fn, *args)`` turns ANY jax-traceable
callable (our model zoo, a train step, a serving step, user code) into an
operator-level :class:`~repro.core.ir.Graph` — no hand-crafted workload
description.  Backward graphs come from ``jax.vjp`` (the aot_autograd
analogue).  ``lax.scan`` sub-jaxprs are traced once and emitted with a
``repeat`` multiplier — the paper's single-block extrapolation.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from jax.extend import core as jex_core

from repro.core.ir import Graph, OpNode

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
                "uint32": 4, "int8": 1, "uint8": 1, "bool": 1, "float64": 8,
                "int64": 8, "uint64": 8, "float8_e4m3fn": 1, "float8_e5m2": 1}
_DTYPE_SHORT = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
                "int8": "int8", "float8_e4m3fn": "f8", "float8_e5m2": "f8"}

ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "neg", "abs", "sign", "floor",
    "ceil", "round", "clamp", "select_n", "convert_element_type", "and", "or",
    "not", "xor", "eq", "ne", "lt", "le", "gt", "ge", "add_any", "rem",
    "stop_gradient", "copy", "real", "imag", "is_finite", "nextafter",
    "shift_left", "shift_right_logical", "shift_right_arithmetic", "erf_inv",
}
TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "pow",
                  "integer_pow", "sin", "cos", "erf", "cbrt", "log1p", "expm1",
                  "atan2", "exp2", "square"}
MOVEMENT = {"broadcast_in_dim": "copy", "reshape": "copy", "squeeze": "copy",
            "transpose": "transpose", "rev": "copy", "slice": "copy",
            "dynamic_slice": "copy", "concatenate": "copy", "pad": "copy",
            "dynamic_update_slice": "scatter", "gather": "gather",
            "scatter": "scatter", "scatter-add": "scatter", "scatter_add": "scatter",
            "sort": "sort", "argsort": "sort", "iota": "copy", "expand_dims": "copy"}
REDUCTION = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
             "reduce_and", "reduce_or", "argmax", "argmin", "reduce_precision",
             "cumsum", "cummax", "cumprod", "cumlogsumexp"}
COMM = {"psum": "all_reduce", "all_gather": "all_gather",
        "psum_scatter": "reduce_scatter", "reduce_scatter": "reduce_scatter",
        "all_to_all": "all_to_all", "ppermute": "collective_permute"}
INLINE = {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
          "custom_vjp_call_jaxpr", "remat", "checkpoint", "remat2",
          "custom_jvp_call_jaxpr", "core_call", "xla_call", "sharding_constraint",
          "mesh_cast", "shard_map", "device_put"}


def _aval_bytes(aval) -> float:
    # math.prod over the (small, int) shape tuple: ~30x cheaper than np.prod
    # on the thousands of per-eqn calls a block trace makes
    try:
        return float(math.prod(aval.shape)) * _DTYPE_BYTES.get(str(aval.dtype), 4)
    except Exception:
        return 0.0


def _aval_elems(aval) -> float:
    try:
        return float(math.prod(aval.shape))
    except Exception:
        return 0.0


def _short_dtype(aval) -> str:
    return _DTYPE_SHORT.get(str(getattr(aval, "dtype", "bfloat16")), "f32")


class _TraceCtx:
    def __init__(self, graph: Graph):
        self.graph = graph
        self.producer: dict[Any, str] = {}

    def dep_of(self, var) -> str | None:
        return self.producer.get(var)


def _dot_general_node(ctx: _TraceCtx, eqn, mult: float, phase: str):
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    contract = 1
    for d in lc:
        contract *= lhs.shape[d]
    out_elems = _aval_elems(out)
    flops = 2.0 * out_elems * contract
    # (M, N, K) for the MXU-alignment model
    n = 1
    for d in range(len(rhs.shape)):
        if d not in rc and d not in rb:
            n *= rhs.shape[d]
    m = out_elems / max(n, 1)
    node = ctx.graph.op(
        "matmul", deps=[d for v in eqn.invars if (d := ctx.dep_of(v))],
        out_shape=tuple(out.shape), dtype=_short_dtype(out),
        flops=flops,
        bytes_in=sum(_aval_bytes(v.aval) for v in eqn.invars),
        bytes_out=_aval_bytes(out),
        repeat=int(mult), phase=phase,
        attrs={"mm_dims": (int(m), int(n), int(contract)),
               "mm_bytes": (_aval_bytes(lhs), _aval_bytes(rhs))},
    )
    return node


def _trace_jaxpr(ctx: _TraceCtx, jaxpr, mult: float, phase: str):
    g = ctx.graph
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        # structural prims first: they recurse and never consume the per-eqn
        # byte accounting, so skip building it (pjit eqns dominate raw jaxprs)
        if prim == "scan":
            length = eqn.params.get("length", 1)
            inner = eqn.params["jaxpr"].jaxpr
            for v_outer, v_inner in zip(eqn.invars, inner.invars):
                if not isinstance(v_outer, jex_core.Literal) and ctx.dep_of(v_outer):
                    ctx.producer[v_inner] = ctx.dep_of(v_outer)
            _trace_jaxpr(ctx, inner, mult * length, phase)
            for v_outer, v_inner in zip(eqn.outvars, inner.outvars):
                if not isinstance(v_inner, jex_core.Literal) and ctx.dep_of(v_inner):
                    ctx.producer[v_outer] = ctx.dep_of(v_inner)
            continue
        if prim == "while":
            _trace_jaxpr(ctx, eqn.params["body_jaxpr"].jaxpr, mult, phase)
            continue
        if prim == "cond":
            _trace_jaxpr(ctx, eqn.params["branches"][0].jaxpr, mult, phase)
            continue
        if prim in INLINE:
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr") \
                or eqn.params.get("fun_jaxpr")
            if sub is None:
                continue
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            for v_outer, v_inner in zip(eqn.invars, inner.invars):
                if not isinstance(v_outer, jex_core.Literal) and ctx.dep_of(v_outer):
                    ctx.producer[v_inner] = ctx.dep_of(v_outer)
            _trace_jaxpr(ctx, inner, mult, phase)
            for v_outer, v_inner in zip(eqn.outvars, inner.outvars):
                if not isinstance(v_inner, jex_core.Literal) and ctx.dep_of(v_inner):
                    ctx.producer[v_outer] = ctx.dep_of(v_inner)
            continue
        deps = [d for v in eqn.invars
                if not isinstance(v, jex_core.Literal) and (d := ctx.dep_of(v))]
        out = eqn.outvars[0].aval if eqn.outvars else None
        common = dict(deps=deps,
                      out_shape=tuple(getattr(out, "shape", ()) or ()),
                      dtype=_short_dtype(out) if out is not None else "f32",
                      bytes_in=sum(_aval_bytes(v.aval) for v in eqn.invars
                                   if not isinstance(v, jex_core.Literal)),
                      bytes_out=sum(_aval_bytes(v.aval) for v in eqn.outvars),
                      repeat=int(mult), phase=phase)
        node = None
        if prim in ("charon_attention", "charon_attention_bwd"):
            from repro.core.stubs import attention_flops
            q, k, v = (eqn.invars[i].aval for i in range(3))
            causal = eqn.params.get("causal", True)
            window = eqn.params.get("window", 0)
            fl = attention_flops(q.shape, v.shape, causal=causal, window=window)
            if prim.endswith("bwd"):
                fl *= 2.5  # dq/dk/dv + score recompute
            b, sq, hkv, g_, dq = q.shape
            node = g.op("attention", flops=fl, **common)
            node.attrs["attn_dims"] = (int(b), int(hkv * g_), int(sq),
                                       int(v.shape[1]), int(dq))
            node.attrs["causal"], node.attrs["window"] = causal, window
        elif prim == "dot_general":
            node = _dot_general_node(ctx, eqn, mult, phase)
        elif prim in ("conv_general_dilated",):
            out_elems = _aval_elems(out)
            k = eqn.invars[1].aval
            kernel_elems = _aval_elems(k) / max(k.shape[-1], 1)
            node = g.op("conv", flops=2.0 * out_elems * kernel_elems, **common)
        elif prim in COMM:
            axis = eqn.params.get("axes") or eqn.params.get("axis_name") or ("?",)
            axis = axis[0] if isinstance(axis, tuple) and axis else axis
            node = g.op(COMM[prim], comm_bytes=common["bytes_out"],
                        comm_group=str(axis), **common)
        elif prim in REDUCTION:
            node = g.op("reduce", flops=sum(_aval_elems(v.aval) for v in eqn.invars
                                            if not isinstance(v, jex_core.Literal)),
                        **common)
        elif prim in MOVEMENT:
            kind = MOVEMENT[prim]
            if prim in ("slice", "dynamic_slice", "gather"):
                # slices/gathers read the extracted elements, not the operand
                # (embedding lookups must not be priced as full-table reads)
                common = dict(common, bytes_in=common["bytes_out"])
            if kind == "scatter" and len(eqn.invars) >= 2:
                # in-place update semantics (XLA donates/aliases the operand):
                # traffic = read+write of the UPDATE slice + indices, not the
                # full buffer.  The full operand size is kept in attrs so
                # engines on non-aliasing backends (XLA-CPU) can re-add the
                # copy cost (hw.scatter_inplace=False).
                operand_bytes = _aval_bytes(eqn.invars[0].aval)
                upd_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars[1:]
                                if not isinstance(v, jex_core.Literal))
                common = dict(common, bytes_in=upd_bytes, bytes_out=upd_bytes)
                node = g.op(kind, **common)
                node.attrs["operand_bytes"] = operand_bytes
                for v in eqn.outvars:
                    ctx.producer[v] = node.name
                continue
            node = g.op(kind, **common)
        elif prim in TRANSCENDENTAL:
            node = g.op("elementwise", flops=4.0 * _aval_elems(out), **common)
        elif prim in ELEMENTWISE or out is not None:
            node = g.op("elementwise", flops=_aval_elems(out), **common)
        else:
            continue
        for v in eqn.outvars:
            ctx.producer[v] = node.name
    return ctx


def trace(fn: Callable, *args, name: str = "traced", phase: str = "fwd",
          coalesce: bool = True, **kwargs) -> Graph:
    """Native ingestion: any JAX callable + example (abstract) args -> Graph."""
    closed = jax.make_jaxpr(partial(fn, **kwargs) if kwargs else fn)(*args)
    g = Graph(name)
    ctx = _TraceCtx(g)
    _trace_jaxpr(ctx, closed.jaxpr, 1.0, phase)
    if coalesce:
        g = coalesce_elementwise(g)
    return g


def trace_grad(fn: Callable, *args, name: str = "joint", **kwargs) -> Graph:
    """Joint forward+backward graph via jax.vjp (aot_autograd analogue).
    Backward-only cost = joint - forward (paper partitions the joint graph)."""

    def joint(*a):
        out, vjp = jax.vjp(partial(fn, **kwargs) if kwargs else fn, *a)
        cts = jax.tree.map(jnp.ones_like, out)
        return vjp(cts)

    return trace(joint, *args, name=name, phase="bwd")


# --------------------------------------------------------------------------
# PyTorch-profiler granularity: coalesce adjacent elementwise chains
# --------------------------------------------------------------------------

def coalesce_elementwise(g: Graph) -> Graph:
    """Fuse elementwise/copy chains into single nodes (matching what XLA's
    fuser — and the paper's operator granularity — would show)."""
    FUSABLE = {"elementwise", "copy"}
    succ_n = {k: len(v) for k, v in g.successors().items()}
    out = Graph(g.name)
    alias: dict[str, str] = {}
    orig_of: dict[str, str] = {}  # output-graph name -> last original fused in
    for node in g.toposort():
        deps = [alias.get(d, d) for d in node.deps]
        if node.kind in FUSABLE and deps:
            cand = deps[0]
            if (cand in out.nodes and out.nodes[cand].kind in FUSABLE
                    and out.nodes[cand].repeat == node.repeat
                    and succ_n.get(orig_of.get(cand, cand), 2) == 1):
                p = out.nodes[cand]
                p.flops += node.flops
                p.bytes_out = node.bytes_out        # chain output replaces
                p.out_shape = node.out_shape or p.out_shape
                for d in deps[1:]:
                    if d != p.name and d not in p.deps:
                        p.deps.append(d)
                alias[node.name] = p.name
                orig_of[p.name] = node.name
                continue
        nn = node.clone()
        nn.deps = [d for d in dict.fromkeys(deps) if d != nn.name]
        out.nodes[nn.name] = nn
        orig_of[nn.name] = node.name
    return out

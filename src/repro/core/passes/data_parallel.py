"""DP / ZeRO / FSDP passes (paper §3.2b-iii).

DDP: gradient all-reduce over the dp group, tagged overlappable (bucketed
sync overlaps the backward pass).  ZeRO-1/2: reduce-scatter gradients +
all-gather updated params.  ZeRO-3/FSDP: additionally all-gather parameters
in forward and backward (prefetch-overlappable).  Cross-pod DP pays the
hierarchical (ICI+DCN) price via intra/inter sizes on the node attrs.
"""
from __future__ import annotations

from repro.core.ir import Graph


class DataParallelPass:
    name = "dp"

    def __init__(self, *, grad_dtype_bytes: int = 2, compression: str = "none"):
        self.grad_bytes_per_param = {"none": grad_dtype_bytes, "int8": 1}.get(
            compression, grad_dtype_bytes)
        self.compression = compression

    def apply(self, g: Graph, ctx) -> Graph:
        p = ctx.parallel
        dp_total = p.dp * p.pods
        if dp_total <= 1 or ctx.param_bytes <= 0:
            return g
        n_params = ctx.param_bytes / 2  # params assumed bf16
        grad_bytes = n_params * self.grad_bytes_per_param / (p.tp * max(p.ep, 1) // max(p.ep, 1))
        grad_bytes = n_params * self.grad_bytes_per_param / p.tp
        zs = p.zero_stage
        hier = {"intra_size": p.dp, "inter_size": p.pods}

        last = None
        for node in g:
            last = node.name
        if zs >= 1:
            g.op("reduce_scatter", name="dp_grad_reduce_scatter",
                 deps=[last] if last else [],
                 comm_bytes=grad_bytes, comm_group="dp", comm_size=dp_total,
                 overlappable=True, stream="dp_comm", phase="opt",
                 attrs=dict(hier))
            g.op("all_gather", name="dp_param_all_gather",
                 deps=["dp_grad_reduce_scatter"],
                 comm_bytes=n_params * 2 / p.tp, comm_group="dp",
                 comm_size=dp_total, overlappable=True, stream="dp_comm",
                 phase="opt", attrs=dict(hier))
        else:
            g.op("all_reduce", name="dp_grad_all_reduce",
                 deps=[last] if last else [],
                 comm_bytes=grad_bytes, comm_group="dp", comm_size=dp_total,
                 overlappable=True, stream="dp_comm", phase="opt",
                 attrs=dict(hier))
        if zs >= 3:
            # FSDP parameter all-gathers in fwd and bwd (prefetchable)
            for phase in ("fwd", "bwd"):
                g.op("all_gather", name=f"fsdp_param_ag_{phase}",
                     comm_bytes=n_params * 2 / p.tp, comm_group="dp",
                     comm_size=dp_total, overlappable=True, stream="dp_comm",
                     phase=phase, attrs=dict(hier))
        return g


def optimizer_step_cost(n_params: float, *, optimizer: str = "adamw",
                        zero_stage: int = 0, dp: int = 1) -> tuple[float, float]:
    """(flops, bytes) of the optimizer update, post ZeRO sharding."""
    shard = dp if zero_stage >= 1 else 1
    n = n_params / shard
    if optimizer == "adamw":
        flops = 12 * n
        byts = n * (2 + 2 + 4 + 4) + n * (4 + 4)   # p, g, m, v read + m,v write
    else:  # adafactor
        flops = 14 * n
        byts = n * (2 + 2) + 4 * (n ** 0.5) * 4
    return flops, byts

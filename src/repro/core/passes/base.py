"""Compiler-style pass framework (paper §3.2b).

Optimizations, parallelisms and analyses are graph->graph passes; adding or
removing a pass toggles the corresponding feature in simulation; passes
compose freely (``PassManager``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Protocol

from repro.core.ir import Graph


@dataclass(unsafe_hash=True)
class ParallelConfig:
    """Parallelism sizes the passes shard the graph by.

    Hashable (``unsafe_hash``) so a :class:`repro.api.spec.SimSpec` can be a
    cache key; treat instances as frozen — build variants with
    ``dataclasses.replace``."""
    tp: int = 1
    dp: int = 1
    pp: int = 1
    ep: int = 1
    sp: int = 1           # Megatron sequence parallelism (within the TP group)
    pods: int = 1
    cp: int = 1           # context parallelism
    zero_stage: int = 0
    microbatches: int = 1
    pp_schedule: str = "1f1b"   # 1f1b | dualpipe | gpipe

    @property
    def chips(self) -> int:
        return self.tp * self.dp * self.pp * self.pods * self.cp

    def key(self) -> tuple:
        """Hashable identity over every field (cache keys, dedup)."""
        return (self.tp, self.dp, self.pp, self.ep, self.sp, self.pods,
                self.cp, self.zero_stage, self.microbatches, self.pp_schedule)

    def shard_key(self) -> tuple:
        """The fields the graph-rewriting passes consume (TP/SP/EP/CP).

        Replication axes (dp, pods, pp, microbatches, zero) only enter the
        stack/schedule math, not per-block graphs — candidates that differ
        only there share priced block graphs."""
        return (self.tp, self.sp, self.ep, self.cp)


@dataclass
class PassContext:
    parallel: ParallelConfig
    model: object | None = None          # ModelConfig when known
    param_bytes: float = 0.0             # per pipeline stage, pre-sharding
    extra: dict = field(default_factory=dict)


class Pass(Protocol):
    name: str

    def apply(self, g: Graph, ctx: PassContext) -> Graph: ...


def pass_cache_key(p) -> tuple:
    """Hashable identity of one pass instance.  Parameterized passes override
    ``cache_key()``; stateless passes are identified by name."""
    ck = getattr(p, "cache_key", None)
    if ck is not None:
        return ck()
    return (getattr(p, "name", type(p).__name__),)


class PassManager:
    def __init__(self, passes: list | None = None):
        self.passes = list(passes or [])

    def add(self, p) -> "PassManager":
        self.passes.append(p)
        return self

    def run(self, g: Graph, ctx: PassContext) -> Graph:
        for p in self.passes:
            g = p.apply(g, ctx)
        return g

    def signature(self) -> tuple:
        """Pipeline identity for post-pass graph caching: two managers with
        equal signatures rewrite a given graph identically (for equal
        ``ParallelConfig.shard_key()``)."""
        return tuple(pass_cache_key(p) for p in self.passes)

"""Match-and-replace operator rewriting (paper §3.2b operator fusion).

A ``FusionRule`` matches a linear chain of node kinds (connected through
single-consumer edges) and replaces it with one fused node whose cost model
is derived from the chain (sum of flops; boundary bytes only — the fusion
eliminates intermediate materialisation).  New rules are plain data.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core.ir import Graph, OpNode


@dataclass
class FusionRule:
    name: str
    pattern: tuple[str, ...]             # chain of node kinds
    fused_kind: str = "fused"
    extra_pred: Callable[[list[OpNode]], bool] | None = None


DEFAULT_RULES = [
    FusionRule("norm+matmul", ("norm", "matmul")),
    FusionRule("matmul+elementwise", ("matmul", "elementwise")),
    FusionRule("elementwise+matmul", ("elementwise", "matmul")),
    FusionRule("softmax+matmul", ("softmax", "matmul")),
]


class FusionPass:
    name = "fusion"

    def __init__(self, rules: list[FusionRule] | None = None):
        self.rules = rules if rules is not None else list(DEFAULT_RULES)
        self.applied: list[str] = []

    def cache_key(self) -> tuple:
        return (self.name,) + tuple(r.name for r in self.rules)

    def apply(self, g: Graph, ctx=None) -> Graph:
        for rule in self.rules:
            g = self._apply_rule(g, rule)
        return g

    def _apply_rule(self, g: Graph, rule: FusionRule) -> Graph:
        succ = g.successors()
        consumed: set[str] = set()
        out = Graph(g.name)
        rename: dict[str, str] = {}
        order = g.toposort()
        by_name = {n.name: n for n in order}

        def chain_from(start: OpNode):
            chain = [start]
            cur = start
            for want in rule.pattern[1:]:
                nxt = succ.get(cur.name, [])
                if len(nxt) != 1:
                    return None
                nn = by_name[nxt[0]]
                if nn.kind != want or nn.repeat != start.repeat or nn.phase != start.phase:
                    return None
                chain.append(nn)
                cur = nn
            if rule.extra_pred and not rule.extra_pred(chain):
                return None
            return chain

        for node in order:
            if node.name in consumed:
                continue
            if node.kind == rule.pattern[0]:
                chain = chain_from(node)
                if chain:
                    fused = OpNode(
                        name=f"{rule.name}.{node.name}",
                        kind=rule.fused_kind,
                        deps=[rename.get(d, d) for d in chain[0].deps],
                        out_shape=chain[-1].out_shape,
                        dtype=chain[-1].dtype,
                        flops=sum(c.flops for c in chain),
                        bytes_in=chain[0].bytes_in,
                        bytes_out=chain[-1].bytes_out,
                        repeat=node.repeat, phase=node.phase,
                        attrs={"fused_from": [c.kind for c in chain],
                               **{k: v for c in chain for k, v in c.attrs.items()}},
                    )
                    out.add(fused)
                    for c in chain:
                        consumed.add(c.name)
                        rename[c.name] = fused.name
                    self.applied.append(rule.name)
                    continue
            n = node.clone()
            n.deps = [rename.get(d, d) for d in n.deps]
            out.add(n)
        return out

"""Activation recomputation pass (paper §3.2c / §5 what-if analyses).

Under 'block' remat the backward pass re-executes the block forward; the
pass clones forward compute nodes into the backward phase.  The memory
analyzer (core/memory.py) correspondingly keeps only block-boundary
activations alive.  FLOPs analyses run before this pass (the paper notes
FLOPs must be measured pre-recompute)."""
from __future__ import annotations

from repro.core.ir import Graph


class RecomputePass:
    name = "recompute"

    def __init__(self, policy: str = "block"):
        self.policy = policy  # none | block | dots

    def cache_key(self) -> tuple:
        return (self.name, self.policy)

    def apply(self, g: Graph, ctx=None) -> Graph:
        if self.policy == "none":
            return g
        for node in list(g.toposort()):
            if node.phase != "fwd" or node.is_comm:
                continue
            if self.policy == "dots" and node.kind in ("matmul", "attention", "conv"):
                continue  # dots saved, everything else recomputed
            rc = node.clone()
            rc.name = f"{node.name}.rc"
            rc.phase = "bwd"
            rc.attrs = dict(rc.attrs, recompute=True)
            g.add(rc)
        return g

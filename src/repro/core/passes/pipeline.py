"""Pipeline-parallel schedule generation (paper §3.2b-ii).

Builds explicit per-rank event lists for GPipe, 1F1B and DualPipe and
returns both the makespan and the events (consumed by the 3D timeline).
Times are per-microbatch per-stage forward/backward durations plus the
inter-stage p2p transfer time.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class PPEvent:
    rank: int
    kind: str       # 'F' | 'B' | 'W' | 'send' | 'recv'
    microbatch: int
    start: float
    end: float


@dataclass
class PPSchedule:
    events: list[PPEvent]
    total_time: float
    bubble_fraction: float
    name: str = "1f1b"

    def rank_events(self, rank: int) -> list[PPEvent]:
        return [e for e in self.events if e.rank == rank]


def schedule_gpipe(p: int, m: int, t_f: float, t_b: float, t_comm: float) -> PPSchedule:
    """All forwards, then all backwards.  Bubble = (p-1)(tf+tb)."""
    events = []
    # forward wave
    for mb in range(m):
        for r in range(p):
            start = mb * t_f + r * (t_f + t_comm)
            events.append(PPEvent(r, "F", mb, start, start + t_f))
    t_fwd_done = (m - 1) * t_f + (p - 1) * (t_f + t_comm) + t_f
    for mb in range(m):
        for ri, r in enumerate(reversed(range(p))):
            start = t_fwd_done + mb * t_b + ri * (t_b + t_comm)
            events.append(PPEvent(r, "B", mb, start, start + t_b))
    total = t_fwd_done + (m - 1) * t_b + (p - 1) * (t_b + t_comm) + t_b
    ideal = m * (t_f + t_b)
    return PPSchedule(events, total, 1.0 - ideal / total, "gpipe")


def schedule_1f1b(p: int, m: int, t_f: float, t_b: float, t_comm: float) -> PPSchedule:
    """Classic 1F1B: warmup (p-rank) forwards, steady 1F1B, cooldown.

    Event-driven simulation honoring activation dependencies."""
    events: list[PPEvent] = []
    rank_free = [0.0] * p
    f_done = [[None] * m for _ in range(p)]   # completion time of F(mb) at rank r
    b_done = [[None] * m for _ in range(p)]

    # per-rank instruction streams (canonical 1F1B order)
    streams = []
    for r in range(p):
        warmup = min(p - r, m)
        order = [("F", i) for i in range(warmup)]
        nf, nb = warmup, 0
        while nf < m or nb < m:
            if nb < m and (nb < nf or nf == m):
                order.append(("B", nb)); nb += 1
            if nf < m:
                order.append(("F", nf)); nf += 1
        streams.append(order)

    idx = [0] * p
    progressed = True
    while progressed:
        progressed = False
        for r in range(p):
            while idx[r] < len(streams[r]):
                kind, mb = streams[r][idx[r]]
                if kind == "F":
                    dep = 0.0 if r == 0 else (
                        f_done[r - 1][mb] + t_comm if f_done[r - 1][mb] is not None else None)
                    dur = t_f
                else:
                    dep = f_done[r][mb] if r == p - 1 else (
                        b_done[r + 1][mb] + t_comm if b_done[r + 1][mb] is not None else None)
                    dur = t_b
                if dep is None:
                    break
                start = max(rank_free[r], dep)
                end = start + dur
                rank_free[r] = end
                (f_done if kind == "F" else b_done)[r][mb] = end
                events.append(PPEvent(r, kind, mb, start, end))
                idx[r] += 1
                progressed = True
    total = max(rank_free)
    ideal = m * (t_f + t_b)
    return PPSchedule(events, total, 1.0 - ideal / max(total, 1e-12), "1f1b")


def schedule_dualpipe(p: int, m: int, t_f: float, t_b: float, t_comm: float,
                      overlap_frac: float = 0.7) -> PPSchedule:
    """DualPipe (DeepSeek-V3): bidirectional schedule with mutual F/B
    overlap.  Modeled as 1F1B on half the microbatches from each end with
    ``overlap_frac`` of the steady-state F/B pairs co-scheduled — matching
    the paper's reported bubble ((p/2 - 1)(tF + tB - overlap))."""
    base = schedule_1f1b(p, m, t_f, t_b, t_comm)
    steady = m * (t_f + t_b)
    bubble_1f1b = base.total_time - steady
    bubble_dual = max(0.0, (p / 2 - 1) / max(p - 1, 1) * bubble_1f1b
                      * (1.0 - overlap_frac * 0.5))
    total = steady + bubble_dual
    # compress event times proportionally for the timeline view
    scale = total / max(base.total_time, 1e-12)
    events = [PPEvent(e.rank, e.kind, e.microbatch, e.start * scale, e.end * scale)
              for e in base.events]
    return PPSchedule(events, total, 1.0 - steady / max(total, 1e-12), "dualpipe")


def schedule_interleaved(p: int, m: int, t_f: float, t_b: float, t_comm: float,
                         v: int = 2) -> PPSchedule:
    """Interleaved 1F1B (Megatron virtual stages): each rank holds ``v``
    model chunks of 1/v the stage size; bubble shrinks ~1/v at the cost of
    v x p2p traffic.  Modeled by running 1F1B on v*m chunk-microbatches of
    1/v duration with v x communication events."""
    base = schedule_1f1b(p, m * v, t_f / v, t_b / v, t_comm)
    steady = m * (t_f + t_b)
    total = base.total_time + (v - 1) * (p - 1) * t_comm  # extra chunk hops
    events = [PPEvent(e.rank, e.kind, e.microbatch // v, e.start, e.end)
              for e in base.events]
    return PPSchedule(events, total, 1.0 - steady / max(total, 1e-12),
                      f"interleaved{v}")


def make_schedule(name: str, p: int, m: int, t_f: float, t_b: float,
                  t_comm: float) -> PPSchedule:
    if p <= 1:
        total = m * (t_f + t_b)
        ev = []
        t = 0.0
        for mb in range(m):
            ev.append(PPEvent(0, "F", mb, t, t + t_f)); t += t_f
            ev.append(PPEvent(0, "B", mb, t, t + t_b)); t += t_b
        return PPSchedule(ev, total, 0.0, "none")
    fn = {"gpipe": schedule_gpipe, "1f1b": schedule_1f1b,
          "dualpipe": schedule_dualpipe, "interleaved": schedule_interleaved}[name]
    return fn(p, m, t_f, t_b, t_comm)

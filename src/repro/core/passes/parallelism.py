"""Shard-based parallelism passes: TP / SP / EP / CP (paper §3.2b-i).

The passes adjust sharded operator shapes/costs and insert the collective
operators the parallelism implies (Megatron column->row TP with all-reduce,
SP's reduce-scatter + all-gather split, EP's all-to-all pair, CP's KV
all-gather).  They operate on any traced graph using shape heuristics plus
optional attribute tags set by the model-ingest layer.
"""
from __future__ import annotations

from repro.core.ir import Graph, OpNode


def _scale(node: OpNode, f: float, *, bytes_in=True, bytes_out=True, flops=True):
    if flops:
        node.flops /= f
    if bytes_in:
        node.bytes_in /= f
    if bytes_out:
        node.bytes_out /= f
    if node.attrs.get("mm_dims"):
        m, n, k = node.attrs["mm_dims"]
        node.attrs["mm_dims"] = (m, n, k)  # refined below by caller when known


class TensorParallelPass:
    """Megatron TP: column-parallel then row-parallel matmul pairs; the row
    output needs an all-reduce (or reduce-scatter + all-gather under SP).

    Column/row classification: a matmul whose input is feature-sharded
    (produced by a column-parallel ancestor through elementwise ops) is
    row-parallel; otherwise, if its N dim divides tp it starts a
    column-parallel region.
    """

    name = "tp"

    def apply(self, g: Graph, ctx) -> Graph:
        tp = ctx.parallel.tp
        if tp <= 1:
            return g
        sp = ctx.parallel.sp > 1
        out = Graph(g.name)
        sharded_feat: set[str] = set()   # nodes whose output is feature-sharded
        rename: dict[str, str] = {}
        for node in g.toposort():
            n = node.clone()
            n.deps = [rename.get(d, d) for d in n.deps]
            if n.kind == "matmul":
                m, nn, kk = n.attrs.get("mm_dims", (0, 0, 0))
                lhs_b, rhs_b = n.attrs.get("mm_bytes", (n.bytes_in / 2, n.bytes_in / 2))
                dep_sharded = any(d in sharded_feat for d in node.deps)
                if dep_sharded and kk % tp == 0:
                    # row-parallel: K sharded on both operands -> all-reduce
                    n.flops /= tp
                    n.bytes_in = (lhs_b + rhs_b) / tp
                    n.attrs["mm_bytes"] = (lhs_b / tp, rhs_b / tp)
                    n.attrs["mm_dims"] = (m, nn, kk // tp)
                    out.add(n)
                    cname = "reduce_scatter" if sp else "all_reduce"
                    c = out.op(cname, deps=[n.name],
                               comm_bytes=n.bytes_out / (tp if sp else 1),
                               comm_group="tp", comm_size=tp,
                               bytes_in=n.bytes_out, bytes_out=n.bytes_out,
                               repeat=n.repeat, phase=n.phase, dtype=n.dtype,
                               out_shape=n.out_shape)
                    rename[node.name] = c.name
                    continue
                if nn % tp == 0 and nn >= tp:
                    # column-parallel: N sharded -> weights (rhs) shard by tp
                    if sp:
                        ag = out.op("all_gather", deps=list(n.deps),
                                    comm_bytes=lhs_b / tp,
                                    comm_group="tp", comm_size=tp,
                                    bytes_in=lhs_b, bytes_out=lhs_b,
                                    repeat=n.repeat, phase=n.phase, dtype=n.dtype)
                        n.deps = [ag.name]
                    n.flops /= tp
                    n.bytes_out /= tp
                    n.bytes_in = lhs_b + rhs_b / tp
                    n.attrs["mm_bytes"] = (lhs_b, rhs_b / tp)
                    n.attrs["mm_dims"] = (m, nn // tp, kk)
                    out.add(n)
                    sharded_feat.add(n.name)
                    continue
                out.add(n)
                continue
            if n.kind == "attention" and n.attrs.get("attn_dims"):
                b, h, sq, skv, d = n.attrs["attn_dims"]
                if h % tp == 0:
                    n.flops /= tp
                    n.bytes_in /= tp
                    n.bytes_out /= tp
                    n.attrs["attn_dims"] = (b, h // tp, sq, skv, d)
                    sharded_feat.add(n.name)
                out.add(n)
                continue
            # elementwise/movement: propagate feature sharding + shrink if fed
            # only by sharded producers
            if node.deps and all(d in sharded_feat for d in node.deps):
                n.flops /= tp
                n.bytes_in /= tp
                n.bytes_out /= tp
                sharded_feat.add(n.name)
            out.add(n)
        return out


class SequenceParallelPass:
    """Megatron-SP: ops outside the TP regions (norms, residual elementwise)
    run on a sequence shard.  Applied after TP: unsharded compute nodes
    shrink by sp."""

    name = "sp"

    def apply(self, g: Graph, ctx) -> Graph:
        sp = ctx.parallel.sp
        if sp <= 1:
            return g
        for n in g:
            if n.kind in ("norm", "elementwise", "reduce", "copy", "softmax") \
                    and not n.attrs.get("tp_sharded"):
                n.flops /= sp
                n.bytes_in /= sp
                n.bytes_out /= sp
        return g


class ExpertParallelPass:
    """EP: expert GEMMs shard over ep; an all-to-all pair moves capacity rows
    to expert owners and back (Megatron/DeepSpeed-MoE dataflow)."""

    name = "ep"

    def __init__(self, num_experts: int):
        self.num_experts = num_experts

    def cache_key(self) -> tuple:
        return (self.name, self.num_experts)

    def apply(self, g: Graph, ctx) -> Graph:
        ep = ctx.parallel.ep
        if ep <= 1 or self.num_experts % ep != 0:
            return g
        out = Graph(g.name)
        rename: dict[str, str] = {}
        expert_nodes = []
        for node in g.toposort():
            n = node.clone()
            n.deps = [rename.get(d, d) for d in n.deps]
            is_expert = n.attrs.get("moe_expert") or (
                n.kind == "matmul" and n.out_shape
                and n.out_shape[0] == self.num_experts)
            if is_expert:
                if not expert_nodes:  # first expert GEMM: dispatch all-to-all
                    a2a = out.op("all_to_all", deps=list(n.deps),
                                 comm_bytes=n.bytes_in, comm_group="ep",
                                 comm_size=ep, bytes_in=n.bytes_in,
                                 bytes_out=n.bytes_in, repeat=n.repeat,
                                 phase=n.phase, dtype=n.dtype)
                    n.deps = [a2a.name]
                n.flops /= ep
                n.bytes_in /= ep
                n.bytes_out /= ep
                expert_nodes.append(n.name)
                out.add(n)
                last_expert = n
                continue
            if expert_nodes and any(d in expert_nodes for d in n.deps):
                # leaving the expert region: combine all-to-all
                a2a = out.op("all_to_all", deps=[expert_nodes[-1]],
                             comm_bytes=last_expert.bytes_out,
                             comm_group="ep", comm_size=ep,
                             bytes_in=last_expert.bytes_out,
                             bytes_out=last_expert.bytes_out,
                             repeat=n.repeat, phase=n.phase, dtype=n.dtype)
                n.deps = [a2a.name if d in expert_nodes else d for d in n.deps]
                expert_nodes = []
            out.add(n)
        return out


class ContextParallelPass:
    """CP (Ulysses/ring style): attention q-sequence shards over cp; KV is
    all-gathered per layer."""

    name = "cp"

    def __init__(self, cp: int | None = None):
        self.cp = cp   # explicit size (e.g. reuse of the tp axis); else ctx.cp

    def cache_key(self) -> tuple:
        return (self.name, self.cp)

    def apply(self, g: Graph, ctx) -> Graph:
        cp = self.cp or ctx.parallel.cp
        if cp <= 1:
            return g
        out = Graph(g.name)
        rename: dict[str, str] = {}
        for node in g.toposort():
            n = node.clone()
            n.deps = [rename.get(d, d) for d in n.deps]
            if n.kind == "attention" and n.attrs.get("attn_dims"):
                b, h, sq, skv, d = n.attrs["attn_dims"]
                if sq == 1:
                    # decode: flash-decode style KV-sequence sharding — each
                    # shard scans its KV slice; combine partial softmax with a
                    # small all-reduce of (m, l, o)
                    n.flops /= cp
                    n.bytes_in /= cp
                    n.attrs["attn_dims"] = (b, h, sq, skv // cp, d)
                    out.add(n)
                    ar = out.op("all_reduce", deps=[n.name],
                                comm_bytes=b * h * (d + 2) * 4,
                                comm_group="cp", comm_size=cp,
                                repeat=n.repeat, phase=n.phase, dtype="f32",
                                out_shape=n.out_shape)
                    rename[node.name] = ar.name
                    continue
                # prefill/train: q-sequence sharding, KV all-gathered
                ag = out.op("all_gather", deps=list(n.deps),
                            comm_bytes=2 * b * skv * d * h * 2 / cp,
                            comm_group="cp", comm_size=cp,
                            repeat=n.repeat, phase=n.phase, dtype=n.dtype)
                n.deps = [ag.name]
                n.flops /= cp
                n.bytes_out /= cp
                n.attrs["attn_dims"] = (b, h, sq // cp, skv, d)
            out.add(n)
        return out

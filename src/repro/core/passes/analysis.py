"""Analysis passes (paper §3.2c): dependency-independent metrics computed by
graph traversal, composable with optimization passes in one flow.

The paper stresses ordering: FLOPs analysis runs BEFORE the recompute pass
(model-level compute cost), memory liveness AFTER (real allocation timing).
``AnalysisPipeline`` enforces that.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ir import Graph
from repro.core.memory import graph_liveness_peak


@dataclass
class GraphMetrics:
    flops: float = 0.0
    bytes: float = 0.0
    comm_bytes: float = 0.0
    arithmetic_intensity: float = 0.0
    by_kind_flops: dict = field(default_factory=dict)
    by_phase_flops: dict = field(default_factory=dict)
    activation_peak: float = 0.0
    n_ops: int = 0


class FlopsAnalysis:
    """Dependency-independent: totals + per-kind/per-phase aggregation."""

    name = "flops_analysis"

    def run(self, g: Graph) -> GraphMetrics:
        m = GraphMetrics()
        for n in g:
            m.flops += n.flops * n.repeat
            m.bytes += n.total_bytes * n.repeat
            m.comm_bytes += n.comm_bytes * n.repeat
            m.by_kind_flops[n.kind] = m.by_kind_flops.get(n.kind, 0.0) + n.flops * n.repeat
            m.by_phase_flops[n.phase] = m.by_phase_flops.get(n.phase, 0.0) + n.flops * n.repeat
            m.n_ops += 1
        m.arithmetic_intensity = m.flops / max(m.bytes, 1.0)
        return m


class MemoryAnalysis:
    """Dependency-aware: liveness peak over the (possibly remat-rewritten)
    graph — must run AFTER RecomputePass."""

    name = "memory_analysis"

    def run(self, g: Graph) -> float:
        peak, _ = graph_liveness_peak(g)
        return peak


def mfu(model_flops: float, wall_us: float, chips: int, peak_flops: float) -> float:
    """Model-FLOPs utilisation (paper's headline summary metric)."""
    if wall_us <= 0:
        return 0.0
    return model_flops / (chips * peak_flops * wall_us / 1e6)


@dataclass
class AnalysisPipeline:
    """Interleave analyses with optimization passes at the right stages
    (paper: 'natively supports interleaving them within the same flow')."""

    pre_passes: list = field(default_factory=list)    # e.g. TP/SP/EP
    post_passes: list = field(default_factory=list)   # e.g. Recompute

    def run(self, g: Graph, ctx) -> dict:
        for p in self.pre_passes:
            g = p.apply(g, ctx)
        pre = FlopsAnalysis().run(g)          # model-level flops: pre-recompute
        for p in self.post_passes:
            g = p.apply(g, ctx)
        post = FlopsAnalysis().run(g)
        return {
            "model_flops": pre.flops,
            "executed_flops": post.flops,     # includes recompute
            "recompute_overhead": post.flops / max(pre.flops, 1.0) - 1.0,
            "activation_peak": MemoryAnalysis().run(g),
            "pre": pre, "post": post, "graph": g,
        }

"""Precision pass (paper §3.2b quantization).

Rewrites node dtypes; bytes scale by the dtype-width ratio and compute time
scales through the hardware's precision-specific peak (the analytical engine
reads node.dtype).  Matmul-only quantization (weight-only W8A16-style) is the
default; full activation quantization is opt-in."""
from __future__ import annotations

from repro.core.ir import Graph

_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "int8": 1, "f8": 1}


class QuantizePass:
    name = "quantize"

    def __init__(self, dtype: str = "int8", *, matmul_only: bool = True):
        self.dtype = dtype
        self.matmul_only = matmul_only

    def cache_key(self) -> tuple:
        return (self.name, self.dtype, self.matmul_only)

    def apply(self, g: Graph, ctx=None) -> Graph:
        new_b = _BYTES[self.dtype]
        for n in g:
            if self.matmul_only and n.kind not in ("matmul", "fused", "attention", "conv"):
                continue
            old_b = _BYTES.get(n.dtype, 2)
            scale = new_b / old_b
            n.bytes_in *= scale
            n.bytes_out *= scale
            if n.is_comm:
                n.comm_bytes *= scale
            n.dtype = self.dtype
        return g

from repro.core.passes.base import ParallelConfig, PassContext, PassManager
from repro.core.passes.data_parallel import DataParallelPass, optimizer_step_cost
from repro.core.passes.fusion import FusionPass, FusionRule
from repro.core.passes.parallelism import (
    ContextParallelPass, ExpertParallelPass, SequenceParallelPass,
    TensorParallelPass,
)
from repro.core.passes.pipeline import PPSchedule, make_schedule
from repro.core.passes.quantize import QuantizePass
from repro.core.passes.recompute import RecomputePass

__all__ = [
    "ParallelConfig", "PassContext", "PassManager", "DataParallelPass",
    "optimizer_step_cost", "FusionPass", "FusionRule", "ContextParallelPass",
    "ExpertParallelPass", "SequenceParallelPass", "TensorParallelPass",
    "PPSchedule", "make_schedule", "QuantizePass", "RecomputePass",
]

"""Operator-overlap modeling (paper §3.4).

Two models:

* **ratio-based** — overlapped portions of compute and comm ops are slowed by
  calibrated per-hardware factors (separate factors for the compute and comm
  sides of compute<->comm overlap; a shared factor for comm<->comm).

* **bandwidth-aware** (fine-grained, comm<->comm under the analytical
  engine) — a progressive-filling fluid model: flows sharing a link domain
  split its effective bandwidth; per overlapped segment each flow advances at
  bw/n_active, reproducing packet-level congestion behaviour (paper Fig. 6).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.backend.hardware import HardwareSpec
from repro.core.scheduler import Interval, Timeline


def _overlap(a: Interval, b: Interval) -> float:
    return max(0.0, min(a.end, b.end) - max(a.start, b.start))


def apply_ratio_overlap(tl: Timeline, hw: HardwareSpec) -> Timeline:
    """Ratio-based slowdown: only the overlapped fraction of an op is slowed
    (paper: 'the slowdown factor only applies to the portion overlapped')."""
    # Intervals are mutable (unhashable) objects, so slowdown accumulators
    # are keyed by timeline position — stable across runs and processes,
    # unlike the id()-keyed dicts this replaced.
    ivs = tl.intervals
    comp_idx = [i for i, iv in enumerate(ivs) if iv.stream == "compute"]
    comm_idx = [i for i, iv in enumerate(ivs) if iv.stream != "compute"]
    extra = [0.0] * len(ivs)
    for ci in comm_idx:
        c = ivs[ci]
        for ki in comp_idx:
            ov = _overlap(c, ivs[ki])
            if ov <= 0:
                continue
            extra[ki] += ov * (hw.overlap_slowdown_compute - 1.0)
            extra[ci] += ov * (hw.overlap_slowdown_comm - 1.0)
    for a, ci in enumerate(comm_idx):
        c1 = ivs[ci]
        for cj in comm_idx[a + 1:]:
            c2 = ivs[cj]
            if c1.stream == c2.stream:
                continue
            ov = _overlap(c1, c2)
            if ov <= 0:
                continue
            s = hw.overlap_slowdown_comm_comm - 1.0
            extra[ci] += ov * s
            extra[cj] += ov * s
    for i, iv in enumerate(ivs):
        iv.end += extra[i]
    return tl


def bandwidth_aware_comm(comm_intervals: list[Interval]) -> list[Interval]:
    """Progressive-filling fluid model for concurrent comm flows sharing one
    link domain.  Each flow carries ``comm_bytes`` and a standalone duration;
    rate alone = bytes/duration; with n concurrent flows every flow runs at
    rate/n (fair bandwidth competition).  Returns intervals with adjusted end
    times, preserving start order."""
    flows = sorted(comm_intervals, key=lambda i: i.start)
    if not flows:
        return []
    # flows are tracked by sorted position, not id(): indices are stable
    # across runs, so the fluid model is replayable bit-for-bit
    remaining = [max(f.comm_bytes, 1e-9) for f in flows]
    rate1 = [max(f.comm_bytes, 1e-9) / max(f.dur, 1e-9) for f in flows]
    finished: dict[int, float] = {}
    t = flows[0].start
    active: list[int] = []
    pending = list(range(len(flows)))
    while pending or active:
        while pending and flows[pending[0]].start <= t + 1e-12:
            active.append(pending.pop(0))
        if not active:
            t = flows[pending[0]].start
            continue
        n = len(active)
        # next event: a flow finishing or a new arrival
        t_finish = min(t + remaining[i] / (rate1[i] / n) for i in active)
        t_next = min(t_finish, flows[pending[0]].start) if pending \
            else t_finish
        dt = t_next - t
        if dt <= 0.0:
            # numerical stall: remaining/rate underflowed against t, so no
            # event advances the clock — finish the flow closest to done to
            # guarantee forward progress
            i = min(active, key=lambda i: remaining[i] / rate1[i])
            finished[i] = t
            active.remove(i)
            continue
        for i in list(active):
            remaining[i] -= rate1[i] / n * dt
            if remaining[i] <= 1e-9:
                finished[i] = t_next
                active.remove(i)
        t = t_next
    out = []
    for i, f in enumerate(flows):
        nf = Interval(f.name, f.kind, f.stream, f.start,
                      finished.get(i, f.end), f.phase, f.comm_group,
                      f.comm_bytes, f.repeat, f.engine)
        out.append(nf)
    return out


def apply_bandwidth_aware(tl: Timeline, hw: HardwareSpec) -> Timeline:
    """Replace comm intervals with fluid-model adjusted versions, then apply
    the ratio model for compute<->comm."""
    comm = [i for i in tl.intervals if i.stream != "compute"]
    rest = [i for i in tl.intervals if i.stream == "compute"]
    adjusted = bandwidth_aware_comm(comm)
    tl2 = Timeline(intervals=rest + adjusted)
    return apply_ratio_overlap(tl2, hw)

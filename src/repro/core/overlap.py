"""Operator-overlap modeling (paper §3.4).

Two models:

* **ratio-based** — overlapped portions of compute and comm ops are slowed by
  calibrated per-hardware factors (separate factors for the compute and comm
  sides of compute<->comm overlap; a shared factor for comm<->comm).

* **bandwidth-aware** (fine-grained, comm<->comm under the analytical
  engine) — a progressive-filling fluid model: flows sharing a link domain
  split its effective bandwidth; per overlapped segment each flow advances at
  bw/n_active, reproducing packet-level congestion behaviour (paper Fig. 6).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.backend.hardware import HardwareSpec
from repro.core.scheduler import Interval, Timeline


def _overlap(a: Interval, b: Interval) -> float:
    return max(0.0, min(a.end, b.end) - max(a.start, b.start))


def apply_ratio_overlap(tl: Timeline, hw: HardwareSpec) -> Timeline:
    """Ratio-based slowdown: only the overlapped fraction of an op is slowed
    (paper: 'the slowdown factor only applies to the portion overlapped')."""
    comp = [i for i in tl.intervals if i.stream == "compute"]
    comm = [i for i in tl.intervals if i.stream != "compute"]
    extra: dict[int, float] = {}
    for c in comm:
        for k in comp:
            ov = _overlap(c, k)
            if ov <= 0:
                continue
            extra[id(k)] = extra.get(id(k), 0.0) + ov * (hw.overlap_slowdown_compute - 1.0)
            extra[id(c)] = extra.get(id(c), 0.0) + ov * (hw.overlap_slowdown_comm - 1.0)
    for i, c1 in enumerate(comm):
        for c2 in comm[i + 1:]:
            if c1.stream == c2.stream:
                continue
            ov = _overlap(c1, c2)
            if ov <= 0:
                continue
            s = hw.overlap_slowdown_comm_comm - 1.0
            extra[id(c1)] = extra.get(id(c1), 0.0) + ov * s
            extra[id(c2)] = extra.get(id(c2), 0.0) + ov * s
    for iv in tl.intervals:
        iv.end += extra.get(id(iv), 0.0)
    return tl


def bandwidth_aware_comm(comm_intervals: list[Interval]) -> list[Interval]:
    """Progressive-filling fluid model for concurrent comm flows sharing one
    link domain.  Each flow carries ``comm_bytes`` and a standalone duration;
    rate alone = bytes/duration; with n concurrent flows every flow runs at
    rate/n (fair bandwidth competition).  Returns intervals with adjusted end
    times, preserving start order."""
    flows = sorted(comm_intervals, key=lambda i: i.start)
    if not flows:
        return []
    remaining = {id(f): max(f.comm_bytes, 1e-9) for f in flows}
    rate1 = {id(f): max(f.comm_bytes, 1e-9) / max(f.dur, 1e-9) for f in flows}
    finished: dict[int, float] = {}
    t = flows[0].start
    active: list[Interval] = []
    pending = list(flows)
    while pending or active:
        while pending and pending[0].start <= t + 1e-12:
            active.append(pending.pop(0))
        if not active:
            t = pending[0].start
            continue
        n = len(active)
        # next event: a flow finishing or a new arrival
        t_finish = min(t + remaining[id(f)] / (rate1[id(f)] / n) for f in active)
        t_next = min(t_finish, pending[0].start) if pending else t_finish
        dt = t_next - t
        if dt <= 0.0:
            # numerical stall: remaining/rate underflowed against t, so no
            # event advances the clock — finish the flow closest to done to
            # guarantee forward progress
            f = min(active, key=lambda f: remaining[id(f)] / rate1[id(f)])
            finished[id(f)] = t
            active.remove(f)
            continue
        for f in list(active):
            remaining[id(f)] -= rate1[id(f)] / n * dt
            if remaining[id(f)] <= 1e-9:
                finished[id(f)] = t_next
                active.remove(f)
        t = t_next
    out = []
    for f in flows:
        nf = Interval(f.name, f.kind, f.stream, f.start,
                      finished.get(id(f), f.end), f.phase, f.comm_group,
                      f.comm_bytes, f.repeat, f.engine)
        out.append(nf)
    return out


def apply_bandwidth_aware(tl: Timeline, hw: HardwareSpec) -> Timeline:
    """Replace comm intervals with fluid-model adjusted versions, then apply
    the ratio model for compute<->comm."""
    comm = [i for i in tl.intervals if i.stream != "compute"]
    rest = [i for i in tl.intervals if i.stream == "compute"]
    adjusted = bandwidth_aware_comm(comm)
    tl2 = Timeline(intervals=rest + adjusted)
    return apply_ratio_overlap(tl2, hw)

"""Phi-4-mini (3.8B) — dense, RoPE (partial) + SwiGLU + GQA.  [arXiv:2412.08905]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    attention="gqa",
    act="swiglu",
    rope_style="partial",
    rope_fraction=0.75,
    rope_theta=10_000.0,
    tie_embeddings=True,
    citation="arXiv:2412.08905",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        name="phi4-mini-tiny", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )

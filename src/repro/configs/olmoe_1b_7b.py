"""OLMoE-1B-7B — 64-expert top-8 MoE.  [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    attention="gqa",
    act="swiglu",
    num_experts=64,
    top_k=8,
    num_shared_experts=0,
    moe_d_ff=1024,
    citation="arXiv:2409.02060",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        name="olmoe-tiny", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=32, vocab_size=512,
        num_experts=8, top_k=2, moe_d_ff=32,
    )

"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427].  Block cycle (rec, rec, attn); MQA local attention with a
2048-token window; GeGLU MLP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,           # MQA on the local-attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attention="mqa",
    act="geglu",
    window=2048,
    rms_offset=True,
    scale_embedding=True,
    tie_embeddings=True,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    conv_width=4,
    citation="arXiv:2402.19427",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        name="recurrentgemma-tiny", num_layers=6, d_model=64, num_heads=4,
        num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512,
        window=32, lru_width=64, chunk_size=16,
    )

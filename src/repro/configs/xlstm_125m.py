"""xLSTM-125M — sLSTM + mLSTM blocks.  [arXiv:2405.04517]

d_ff=0 per the assignment: the FFN lives inside the m/sLSTM blocks as their
up-projection (mLSTM pf=2, sLSTM pf=4/3).  Block cycle m,m,m,s (7:1-ish ratio
of the paper rounded to a 12-layer stack).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    attention="mlstm",
    act="gelu",
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    chunk_size=256,
    citation="arXiv:2405.04517",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-tiny", num_layers=4, d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, vocab_size=512, chunk_size=16,
    )

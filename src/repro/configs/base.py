"""Model / run configuration system.

Every assigned architecture gets one module in ``repro.configs`` exporting
``CONFIG`` (the exact public-literature configuration) and ``tiny()`` (a
reduced same-family config for CPU smoke tests).  ``repro.configs.get_config``
is the registry entry point used by the launcher (``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (family-general superset).

    Only fields relevant to a family are consumed by its block builder; the
    rest stay at defaults.  All shapes follow the assignment table verbatim.
    """

    name: str
    family: str                      # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention ---
    attention: str = "gqa"           # gqa | mla | mqa
    qkv_bias: bool = False
    rope_style: str = "standard"     # standard | mrope | partial | none
    rope_fraction: float = 1.0       # fraction of head_dim rotated (phi4 partial rope)
    rope_theta: float = 10_000.0
    window: int = 0                  # sliding-window size (0 = full attention)
    logit_soft_cap: float = 0.0
    attn_score_dtype: str = "float32"   # bfloat16: flash-style low-prec P*V path
    attn_kv_block: int = 512             # blockwise-attention KV tile

    # --- ffn ---
    act: str = "swiglu"              # swiglu | geglu | gelu

    # --- norm / embedding ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rms_offset: bool = False         # gemma-style (1 + w) RMSNorm weight
    scale_embedding: bool = False    # gemma-style sqrt(d_model) embed scale
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- hybrid / ssm block pattern ---
    block_pattern: tuple[str, ...] = ()   # cycle of block kinds, e.g. ("rec","rec","attn")
    lru_width: int = 0                    # RG-LRU recurrence width (0 -> d_model)
    lru_gate_blocks: int = 1              # block-diagonal gate matrices (Griffin App. A)
    conv_width: int = 4                   # temporal conv kernel for recurrent blocks
    mlstm_proj_factor: float = 2.0        # xLSTM mLSTM up-projection
    slstm_proj_factor: float = 4.0 / 3.0  # xLSTM sLSTM FFN factor
    chunk_size: int = 256                 # chunkwise-parallel recurrence chunk

    # --- encoder/decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500               # whisper: 30 s of audio -> 1500 frames
    cross_attention: bool = False

    # --- multimodal stub frontend ---
    frontend: str = "none"                # none | audio_frames | vision_patches

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    citation: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counts (used by roofline MODEL_FLOPS = 6 N D) ----
    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, embedding included."""
        from repro.models.params import count_params
        return count_params(self, active_only=active_only)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode
    sub_quadratic_only: bool = False


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode", sub_quadratic_only=True),
}


@dataclass(frozen=True)
class RunConfig:
    """Parallelism / training-run knobs consumed by the launcher and dry-run."""

    model: ModelConfig
    shape: ShapeConfig
    # mesh logical sizes (products must equal device count)
    pod: int = 1
    data: int = 16
    model_axis: int = 16
    # distribution features
    zero_stage: int = 1              # 0 off, 1 opt-state, 2 +grads, 3 +params (FSDP)
    remat_policy: str = "block"      # none | block | dots
    optimizer: str = "adamw"         # adamw | adafactor
    microbatches: int = 1            # grad-accumulation microbatches
    grad_compression: str = "none"   # none | int8
    extra: dict[str, Any] = field(default_factory=dict)


def supports_shape(model: ModelConfig, shape: ShapeConfig) -> bool:
    """Shape applicability per the assignment.

    ``long_500k`` needs sub-quadratic attention: only hybrid (windowed attn +
    recurrent state) and ssm families qualify; pure full-attention archs skip
    it (recorded in DESIGN.md §Arch-applicability).
    """
    if shape.sub_quadratic_only:
        return model.family in ("hybrid", "ssm")
    return True

"""Whisper-large-v3 — encoder-decoder audio transformer; conv frontend stubbed.

[arXiv:2212.04356].  The assignment's ``32L`` is realised as 32 encoder + 32
decoder layers (the published large-v3 stack); the conv frontend is a stub —
``input_specs()`` provides precomputed frame embeddings (B, 1500, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    attention="gqa",
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
    rope_style="none",        # whisper uses learned/sinusoidal positions
    cross_attention=True,
    frontend="audio_frames",
    encoder_seq=1500,
    tie_embeddings=True,
    citation="arXiv:2212.04356",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-tiny", num_layers=2, encoder_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        encoder_seq=32,
    )

"""Gemma-7B — dense, GeGLU, head_dim=256, scaled embeddings.  [arXiv:2403.08295]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    attention="gqa",
    act="geglu",
    rms_offset=True,
    scale_embedding=True,
    tie_embeddings=True,
    citation="arXiv:2403.08295",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        name="gemma-7b-tiny", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=128, vocab_size=512,
    )

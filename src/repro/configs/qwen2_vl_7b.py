"""Qwen2-VL-7B — VLM; M-RoPE decoder backbone, vision frontend stubbed.

[arXiv:2409.12191].  Per the assignment, ``[vlm]`` entries specify the
transformer backbone only; ``input_specs()`` provides precomputed patch
embeddings alongside token ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    attention="gqa",
    qkv_bias=True,
    act="swiglu",
    rope_style="mrope",       # 3-section (t, h, w) rotary
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    citation="arXiv:2409.12191",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-vl-tiny", num_layers=2, d_model=96, num_heads=4,
        num_kv_heads=2, head_dim=24, d_ff=128, vocab_size=512,
    )

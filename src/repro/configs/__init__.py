"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, SHAPES, supports_shape

_ARCH_MODULES = {
    "qwen2.5-32b": "qwen2_5_32b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "gemma-7b": "gemma_7b",
    "yi-34b": "yi_34b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-large-v3": "whisper_large_v3",
    "xlstm-125m": "xlstm_125m",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    """Full (exact public-literature) config for ``--arch``."""
    return _module(arch).CONFIG


def get_tiny_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return _module(arch).tiny()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells(include_skipped: bool = False):
    """Yield every assigned (arch, shape) cell; skips sub-quadratic-only
    shapes for full-attention archs unless ``include_skipped``."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if include_skipped or supports_shape(cfg, shape):
                yield arch, shape.name


__all__ = [
    "ModelConfig", "RunConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
    "get_config", "get_tiny_config", "get_shape", "all_cells", "supports_shape",
]

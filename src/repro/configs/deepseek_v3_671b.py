"""DeepSeek-V3 671B — MLA attention + 256-expert top-8 MoE (1 shared).

[arXiv:2412.19437].  The assignment specifies d_ff=2048 (per-expert hidden),
MoE 256e top-8, MLA, 128 heads.  MTP is an optional extra head (not part of
the simulated/lowered step; noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,        # MLA: KV latent is shared; head count used for expanded form
    head_dim=128,
    d_ff=2048,               # per-expert hidden (assignment)
    vocab_size=129280,
    attention="mla",
    act="swiglu",
    num_experts=256,
    top_k=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    # MLA dims (paper/HF config)
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    citation="arXiv:2412.19437",
)


def tiny() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v3-tiny", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=32, vocab_size=512,
        num_experts=8, top_k=2, num_shared_experts=1, moe_d_ff=32,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
        qk_rope_head_dim=8, v_head_dim=16,
    )

from repro.models.model import Model
from repro.models.params import (
    abstract_params, block_cycle, count_params, init_params, param_logical_axes,
)
from repro.models.kvcache import abstract_cache, cache_logical_axes, zero_cache

__all__ = [
    "Model", "abstract_params", "block_cycle", "count_params", "init_params",
    "param_logical_axes", "abstract_cache", "cache_logical_axes", "zero_cache",
]

"""Core neural layers for the architecture zoo, in pure JAX.

Everything is functional: ``apply(params, x, ...) -> y``.  Layers insert
logical sharding constraints via :mod:`repro.distributed.sharding` so the same
code lowers correctly on 1 CPU device, a 16x16 pod, or the 2x16x16 multi-pod
mesh.

Attention has three execution strategies:
  * ``dense``     — plain einsum softmax attention (small sequences, tests)
  * ``blockwise`` — lax.scan online-softmax attention (memory-safe at 32k+;
                    the XLA analogue of the Pallas flash kernel)
  * ``pallas``    — repro.kernels flash attention (TPU runtime target)
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import logical_constraint as shard


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rmsnorm(w: jax.Array, x: jax.Array, *, eps: float = 1e-6, offset: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if offset else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def layernorm(w: jax.Array, b: jax.Array, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(p["w"], p["b"], x, eps=cfg.norm_eps)
    return rmsnorm(p["w"], x, eps=cfg.norm_eps, offset=cfg.rms_offset)


# --------------------------------------------------------------------------
# Rotary position embeddings (standard / partial / M-RoPE)
# --------------------------------------------------------------------------

def _rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (..., D_rot) with angles (..., D_rot/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(cfg, x: jax.Array, positions: jax.Array) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32 or (B, S, 3) for M-RoPE."""
    if cfg.rope_style == "none":
        return x
    d = x.shape[-1]
    rot = d if cfg.rope_style != "partial" else int(d * cfg.rope_fraction)
    rot -= rot % 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    inv = _rope_freqs(rot, cfg.rope_theta)  # (half,)
    if cfg.rope_style == "mrope":
        # 3-section rotary (t, h, w): split the half-dim 1/4, 3/8, 3/8
        # (Qwen2-VL mrope_section, e.g. [16, 24, 24] for half=64).
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[..., None], (*positions.shape, 3))
        s0 = half // 4
        s1 = s0 + (3 * half) // 8
        sec = jnp.concatenate([
            jnp.zeros((s0,), jnp.int32),
            jnp.ones((s1 - s0,), jnp.int32),
            jnp.full((half - s1,), 2, jnp.int32),
        ])
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),              # (B, S, 3)
            jnp.broadcast_to(sec, (*positions.shape[:2], half)), axis=-1)
    else:
        pos = positions.astype(jnp.float32)[..., None]  # (B, S, 1)
        pos = jnp.broadcast_to(pos, (*positions.shape, half))
    angles = pos[..., None, :] * inv                     # (B, S, 1, half)
    out = _rotate(x_rot, angles)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1) if rot < d else out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal embedding; positions (B, S) -> (B, S, D)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(10_000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Softmax attention (dense / blockwise) over GQA layouts
# --------------------------------------------------------------------------

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _soft_cap(s: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(s / cap) * cap if cap > 0 else s


def attend_dense(q, k, v, *, q_offset, causal: bool, window: int = 0,
                 kv_valid_len=None, soft_cap: float = 0.0, scale: float | None = None):
    """q: (B, Sq, Hkv, G, Dq), k: (B, T, Hkv, Dq), v: (B, T, Hkv, Dv).

    ``q_offset``: absolute position of q[0] (decode: cache length written so far).
    ``kv_valid_len``: scalar or (B,) — entries >= this in T are masked (ring caches).
    """
    B, Sq, Hkv, G, Dq = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dq)
    s = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s = _soft_cap(s, soft_cap)
    q_pos = q_offset + jnp.arange(Sq)
    t_pos = jnp.arange(T)
    mask = jnp.ones((Sq, T), bool)
    if causal:
        mask &= t_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= t_pos[None, :] > q_pos[:, None] - window
    mask = jnp.broadcast_to(mask, (B, 1, 1, Sq, T))
    if kv_valid_len is not None:
        vl = jnp.asarray(kv_valid_len)
        vl = vl.reshape(-1, 1, 1, 1, 1) if vl.ndim else vl
        mask = mask & (t_pos[None, None, None, None, :] < vl)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def attend_blockwise(q, k, v, *, q_offset, causal: bool, window: int = 0,
                     kv_valid_len=None, soft_cap: float = 0.0,
                     q_block: int = 512, kv_block: int = 1024,
                     scale: float | None = None, skip_masked_blocks: bool = True,
                     score_dtype=jnp.float32):
    """Online-softmax (flash-style) attention in pure JAX.

    Outer Python loop over q blocks (static trip count) so causal runs can
    statically truncate the KV range per q block (``skip_masked_blocks``);
    inner ``lax.scan`` over kv blocks carries the running (m, l, acc).

    ``score_dtype=bfloat16`` keeps the probability tensor (the dominant HBM
    intermediate at 32k sequence) in bf16 for the PV matmul while the running
    max/sum statistics stay fp32 — the XLA analogue of the Pallas kernel's
    VMEM-resident scores (see EXPERIMENTS.md §Perf).
    """
    B, Sq, Hkv, G, Dq = q.shape
    T, Dv = k.shape[1], v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dq)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, T)
    # pad to block multiples
    Sq_p = -(-Sq // q_block) * q_block
    T_p = -(-T // kv_block) * kv_block
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, T_p - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, T_p - T), (0, 0), (0, 0)))
    n_kv = T_p // kv_block
    t_pos_full = jnp.arange(T_p)

    if kv_valid_len is not None:
        vl = jnp.asarray(kv_valid_len)
        vl_b = vl.reshape(-1, 1, 1, 1, 1) if vl.ndim else vl
    outs = []
    for qi in range(Sq_p // q_block):
        q_blk = qp[:, qi * q_block:(qi + 1) * q_block].astype(jnp.float32)
        q_pos = q_offset + qi * q_block + jnp.arange(q_block)
        # static causal truncation: kv blocks strictly after this q block's
        # last row are fully masked -> skip (saves ~2x flops at scale)
        hi = n_kv
        if causal and skip_masked_blocks and isinstance(q_offset, int):
            last = q_offset + (qi + 1) * q_block - 1
            hi = min(n_kv, last // kv_block + 1)
        lo = 0
        if window > 0 and skip_masked_blocks and isinstance(q_offset, int):
            first = max(q_offset + qi * q_block - window + 1, 0)
            lo = min(first // kv_block, hi)

        def step(carry, ti):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kp, ti * kv_block, kv_block, 1).astype(jnp.float32)
            vb = jax.lax.dynamic_slice_in_dim(vp, ti * kv_block, kv_block, 1).astype(jnp.float32)
            s = jnp.einsum("bskgd,btkd->bkgst", q_blk, kb) * scale
            s = _soft_cap(s, soft_cap)
            t_pos = ti * kv_block + jnp.arange(kv_block)
            msk = t_pos[None, :] < T  # padding
            if causal:
                msk &= t_pos[None, :] <= q_pos[:, None]
            if window > 0:
                msk &= t_pos[None, :] > q_pos[:, None] - window
            msk = jnp.broadcast_to(msk, (B, 1, 1, q_block, kv_block))
            if kv_valid_len is not None:
                msk = msk & (t_pos[None, None, None, None, :] < vl_b)
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(score_dtype),
                vb.astype(score_dtype)).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, Dv), jnp.float32)
        if hi > lo:
            (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(lo, hi))
        else:
            m, l, acc = m0, l0, a0
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(jnp.einsum("bkgsd->bskgd", o))
    o = jnp.concatenate(outs, axis=1)[:, :Sq]
    return o.astype(q.dtype)


def attention(q, k, v, *, q_offset=0, causal=True, window=0, kv_valid_len=None,
              soft_cap=0.0, strategy="auto", scale=None,
              q_block=2048, kv_block=512, score_dtype=jnp.float32):
    """Dispatch over attention strategies.  Shapes as in :func:`attend_dense`."""
    T = k.shape[1]
    if strategy == "auto":
        strategy = "blockwise" if (q.shape[1] * T > 2048 * 2048 or T > 1024) else "dense"
    if strategy == "blockwise":
        return attend_blockwise(q, k, v, q_offset=q_offset, causal=causal, window=window,
                                kv_valid_len=kv_valid_len, soft_cap=soft_cap, scale=scale,
                                q_block=q_block, kv_block=kv_block,
                                score_dtype=score_dtype)
    return attend_dense(q, k, v, q_offset=q_offset, causal=causal, window=window,
                        kv_valid_len=kv_valid_len, soft_cap=soft_cap, scale=scale)


# --------------------------------------------------------------------------
# Dense projections / FFN
# --------------------------------------------------------------------------

def linear(p: dict, x: jax.Array) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def ffn(cfg, p: dict, x: jax.Array) -> jax.Array:
    """SwiGLU / GeGLU / plain-GELU feed-forward."""
    if cfg.act in ("swiglu", "geglu"):
        g = linear(p["gate"], x)
        u = linear(p["up"], x)
        g = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = g * u
    else:
        h = jax.nn.gelu(linear(p["up"], x), approximate=True)
    h = shard(h, ("batch", "seq", "ffn"))
    return linear(p["down"], h)


# --------------------------------------------------------------------------
# Mixture of Experts (capacity-factor, sort-based dispatch)
# --------------------------------------------------------------------------

def _moe_dispatch(cfg, xf: jax.Array, router_w: jax.Array, cap: int):
    """Local sort-based top-k dispatch.  xf: (T, D) -> buf (E, cap, D) plus
    combine metadata and the Switch load-balancing aux loss."""
    T, D = xf.shape
    E, K = cfg.num_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, ids = jax.lax.top_k(probs, K)                        # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(jnp.mean(onehot, 0) * jnp.mean(probs, 0)) * cfg.router_aux_coef

    flat_ids = ids.reshape(-1)                                      # (T*K,)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    seg_start = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    pos = jnp.arange(T * K) - seg_start                             # position within expert
    keep = pos < cap
    xs = xf[order // K]
    buf = jnp.zeros((E, cap, D), xf.dtype)
    buf = buf.at[sorted_ids, jnp.where(keep, pos, cap)].set(
        jnp.where(keep[:, None], xs, 0), mode="drop")
    return buf, (order, sorted_ids, pos, keep, gate_vals), aux


def _moe_combine(eo: jax.Array, meta, T: int, K: int, dtype):
    order, sorted_ids, pos, keep, gate_vals = meta
    D = eo.shape[-1]
    back = eo[sorted_ids, jnp.where(keep, pos, 0)] * keep[:, None].astype(eo.dtype)
    unsorted = jnp.zeros_like(back).at[order].set(back)             # (T*K, D)
    return (unsorted.reshape(T, K, D) * gate_vals[..., None].astype(eo.dtype)).sum(1).astype(dtype)


def _expert_mlp(p: dict, buf: jax.Array, dtype) -> jax.Array:
    """(E, C, D) x per-expert SwiGLU weights (E, D, F) -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, p["down"].astype(dtype))


def moe_ffn(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Top-k routed experts with true expert parallelism.

    GSPMD cannot shard the sort/gather/scatter dispatch (it replicates batched
    gathers), so the MoE interior runs under ``shard_map``: each device
    dispatches its local tokens, an ``all_to_all`` over the model axis moves
    capacity rows to the expert owners (Megatron-EP dataflow), expert GEMMs
    run on local expert shards, and a second ``all_to_all`` returns outputs.
    Returns (output, router_aux_loss).
    """
    from repro.distributed.sharding import active_env, resolve_spec

    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    env = active_env()
    mesh = env.mesh if env is not None else None
    m = mesh.shape.get("model", 1) if mesh is not None else 1
    if E % m != 0:
        m = 1  # experts unshardable -> local compute, replicated weights

    if mesh is None or all(s == 1 for s in mesh.shape.values()):
        # single-device path (tests, CPU examples)
        xf = x.reshape(B * S, D)
        cap = max(int(math.ceil(B * S * K / E * cfg.capacity_factor)), 4)
        buf, meta, aux = _moe_dispatch(cfg, xf, p["router"]["w"], cap)
        eo = _expert_mlp(p["experts"], buf, x.dtype)
        out = _moe_combine(eo, meta, B * S, K, x.dtype).reshape(B, S, D)
        if cfg.num_shared_experts > 0:
            out = out + ffn(cfg, p["shared"], x)
        return out, aux

    from jax import shard_map
    P = jax.sharding.PartitionSpec
    x_spec = resolve_spec(env, ("batch", "seq_sp", None), x.shape)
    ew_spec = resolve_spec(env, ("expert", None, None), p["experts"]["gate"].shape)
    rw_spec = P()
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)

    # local token count per device (static)
    def _sh(spec_entry):
        if spec_entry is None:
            return 1
        axes = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
        sz = 1
        for a in axes:
            sz *= mesh.shape[a]
        return sz
    xs_full = list(x_spec) + [None] * (3 - len(list(x_spec)))
    T_loc = (B // _sh(xs_full[0])) * (S // _sh(xs_full[1]))
    cap = max(int(math.ceil(T_loc * K / E * cfg.capacity_factor)), 4)

    def body(x_loc, router_w, gate_w, up_w, down_w):
        b, s, _ = x_loc.shape
        xf = x_loc.reshape(b * s, D)
        buf, meta, aux = _moe_dispatch(cfg, xf, router_w, cap)       # (E, cap, D)
        if m > 1:
            # EP all-to-all: (E, cap, D) -> (E/m, cap*m, D) on expert owners
            buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1, tiled=True)
        eo = _expert_mlp({"gate": gate_w, "up": up_w, "down": down_w}, buf, x_loc.dtype)
        if m > 1:
            eo = jax.lax.all_to_all(eo, "model", split_axis=1, concat_axis=0, tiled=True)
        out = _moe_combine(eo, meta, b * s, K, x_loc.dtype).reshape(b, s, D)
        aux = jax.lax.pmean(aux, all_axes)
        return out, aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, rw_spec, ew_spec, ew_spec, ew_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"]["w"], p["experts"]["gate"], p["experts"]["up"], p["experts"]["down"])

    if cfg.num_shared_experts > 0:
        out = out + ffn(cfg, p["shared"], x)
    return out, aux


# --------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# --------------------------------------------------------------------------

_RGLRU_C = 8.0


def _rglru_gate_matmul(w: jax.Array, x: jax.Array) -> jax.Array:
    """Full (W,W) or block-diagonal (nb,Wb,Wb) gate projection of (..., W)."""
    wf = w.astype(jnp.float32)
    if w.ndim == 3:
        nb, Wb, _ = w.shape
        xs = x.reshape(*x.shape[:-1], nb, Wb)
        xs = shard(xs, tuple([None] * (x.ndim - 1)) + ("lru_width", None))
        y = jnp.einsum("...nw,nwv->...nv", xs, wf)
        return y.reshape(*x.shape)
    return jnp.einsum("...w,wv->...v", x, wf)


def rglru_scan(p: dict, x: jax.Array, h0: jax.Array | None):
    """x: (B, S, W).  Returns (y, h_last).  Diagonal gated linear recurrence:
    a_t = exp(-c softplus(L) * r_t);  h_t = a_t h_{t-1} + sqrt(1-a_t^2) i_t x_t.
    """
    B, S, W = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_rglru_gate_matmul(p["wa"], xf) + p["ba"])
    i = jax.nn.sigmoid(_rglru_gate_matmul(p["wx"], xf) + p["bx"])
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r   # (B,S,W) <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * i * xf
    if h0 is not None:
        # fold initial state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p: dict, x_t: jax.Array, h: jax.Array):
    """Single decode step; x_t, h: (B, W)."""
    xf = x_t.astype(jnp.float32)
    r = jax.nn.sigmoid(_rglru_gate_matmul(p["wa"], xf) + p["ba"])
    i = jax.nn.sigmoid(_rglru_gate_matmul(p["wx"], xf) + p["bx"])
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h_new = a * h.astype(jnp.float32) + beta * i * xf
    return h_new.astype(x_t.dtype), h_new


def causal_conv1d(p: dict, x: jax.Array, state: jax.Array | None):
    """Depthwise causal conv (width K).  x: (B,S,W); state: (B,K-1,W) or None.
    Returns (y, new_state)."""
    Kw = p["w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], Kw - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    y = sum(xx[:, i:i + x.shape[1]] * p["w"][i].astype(x.dtype) for i in range(Kw))
    y = y + p["b"].astype(x.dtype)
    return y, xx[:, -(Kw - 1):] if Kw > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)


# --------------------------------------------------------------------------
# xLSTM cells (mLSTM chunkwise-parallel + sLSTM sequential)
# --------------------------------------------------------------------------

def mlstm_chunkwise(q, k, v, i_gate, f_gate, state=None, *, chunk: int = 256):
    """Stabilised chunkwise mLSTM (matrix-memory) forward.

    q,k,v: (B, S, H, D);  i_gate,f_gate: (B, S, H) pre-activation.
    state: optional (C, n, m) with C:(B,H,D,D), n:(B,H,D), m:(B,H).
    Returns (y, (C,n,m)).  [arXiv:2405.04517], chunkwise form following
    flash-linear-attention GLA-style scan.
    """
    B, S, H, D = q.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        z3 = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        z2 = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        q, k, v = z3(q), z3(k), z3(v)
        i_gate, f_gate = z2(i_gate), z2(f_gate) - 1e9 * (jnp.arange(S + pad) >= S)[None, :, None]
    Sp = q.shape[1]
    NC = Sp // chunk
    shp = lambda t: t.reshape(B, NC, chunk, H, -1).astype(jnp.float32)
    q_, k_, v_ = shp(q), shp(k), shp(v)
    ig = i_gate.reshape(B, NC, chunk, H).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_gate.reshape(B, NC, chunk, H).astype(jnp.float32))
    csum_f = jnp.cumsum(lf, axis=2)                    # within-chunk cumulative log-forget
    total_f = csum_f[:, :, -1]                         # (B, NC, H)

    scale = 1.0 / math.sqrt(D)
    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    else:
        C0, n0, m0 = (s.astype(jnp.float32) for s in state)

    # intra-chunk decay matrix: dm[t, s] = csum_f[t] - csum_f[s] + ig[s] for s <= t
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def chunk_step(carry, inp):
        C, n, m = carry
        qc, kc, vc, igc, cfc, tfc = inp                # (B,chunk,H,*) ...
        # log weights for inter-chunk (state) and intra-chunk paths
        b_state = cfc                                  # (B,chunk,H): decay from chunk start
        g = cfc[:, :, None, :] - cfc[:, None, :, :] + igc[:, None, :, :]  # (B,t,s,H)
        g = jnp.where(causal[None, :, :, None], g, NEG_INF)
        m_intra = g.max(2)                                             # (B,chunk,H)
        m_t = jnp.maximum(b_state + m[:, None, :], m_intra)            # (B,chunk,H)
        w_state = jnp.exp(b_state + m[:, None, :] - m_t)               # (B,chunk,H)
        w_intra = jnp.exp(g - m_t[:, :, None, :])                      # (B,t,s,H)

        s_intra = jnp.einsum("bthd,bshd->btsh", qc, kc) * scale        # (B,t,s,H)
        num = jnp.einsum("btsh,btsh,bshd->bthd", s_intra, w_intra, vc) \
            + jnp.einsum("bthd,bhdk,bth->bthk", qc * scale, C, w_state)
        den = jnp.abs(jnp.einsum("btsh,btsh->bth", s_intra, w_intra)
                      + jnp.einsum("bthd,bhd,bth->bth", qc * scale, n, w_state))
        y = num / jnp.maximum(den, jnp.exp(-m_t))[..., None]           # lower-bound denom (xLSTM eq. 25)

        # state update to end of chunk
        m_next = jnp.maximum(tfc + m, (tfc[:, None, :] - cfc + igc).max(1))
        w_old = jnp.exp(tfc + m - m_next)                              # (B,H)
        kw = jnp.exp(tfc[:, None, :] - cfc + igc - m_next[:, None, :]) # (B,s,H)
        C_next = C * w_old[:, :, None, None] + jnp.einsum("bshd,bsh,bshk->bhdk", kc, kw, vc)
        n_next = n * w_old[:, :, None] + jnp.einsum("bshd,bsh->bhd", kc, kw)
        return (C_next, n_next, m_next), y

    inputs = (q_.transpose(1, 0, 2, 3, 4), k_.transpose(1, 0, 2, 3, 4),
              v_.transpose(1, 0, 2, 3, 4), ig.transpose(1, 0, 2, 3),
              csum_f.transpose(1, 0, 2, 3), total_f.transpose(1, 0, 2))
    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0), inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, D)[:, :S]
    return y.astype(q.dtype), (C, n, m)


def mlstm_step(q_t, k_t, v_t, i_t, f_t, state):
    """Single-token mLSTM update; q_t,k_t,v_t: (B,H,D); i_t,f_t: (B,H)."""
    C, n, m = state
    D = q_t.shape[-1]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q_t, k_t, v_t))
    i_f = i_t.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(f_t.astype(jnp.float32))
    m_new = jnp.maximum(lf + m, i_f)
    C = C * jnp.exp(lf + m - m_new)[..., None, None] + \
        jnp.exp(i_f - m_new)[..., None, None] * jnp.einsum("bhd,bhk->bhdk", kf, vf)
    n = n * jnp.exp(lf + m - m_new)[..., None] + jnp.exp(i_f - m_new)[..., None] * kf
    scale = 1.0 / math.sqrt(D)
    num = jnp.einsum("bhd,bhdk->bhk", qf * scale, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf * scale, n))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return y.astype(q_t.dtype), (C, n, m_new)


def slstm_scan(p: dict, x: jax.Array, state=None):
    """Sequential sLSTM over time.  x: (B, S, W) pre-projected gates packed as
    4W (i, f, z, o contributions); recurrent weights act on h."""
    B, S, W4 = x.shape
    W = W4 // 4
    if state is None:
        z = jnp.zeros((B, W), jnp.float32)
        state = (z, z + 1e-6, z, z - 1e9)  # c, n, h, m

    R = p["r"].astype(jnp.float32)  # (W, 4W) recurrent weights

    def step(carry, x_t):
        c, n, h, m = carry
        g = x_t.astype(jnp.float32) + h @ R
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        lf = jax.nn.log_sigmoid(gf)
        m_new = jnp.maximum(lf + m, gi)
        c_new = c * jnp.exp(lf + m - m_new) + jnp.exp(gi - m_new) * jnp.tanh(gz)
        n_new = n * jnp.exp(lf + m - m_new) + jnp.exp(gi - m_new)
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-9)
        return (c_new, n_new, h_new, m_new), h_new

    (c, n, h, m), ys = jax.lax.scan(step, state, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2).astype(x.dtype), (c, n, h, m)

"""Model assembly: one implementation covering all ten assigned architectures.

``Model`` exposes:
  * ``init(rng)``                          — concrete params (tiny configs)
  * ``forward(params, batch)``             — full-sequence logits (train)
  * ``prefill(params, batch, cache_len)``  — logits + populated KV/state cache
  * ``decode_step(params, cache, batch)``  — one token with a seq_len cache

The decoder stack is ``lax.scan`` over block-cycle repetitions (stacked
params; see models/params.py) so HLO size is depth-independent.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import axis_size, logical_constraint as shard
from repro.models import layers as L
from repro.models.params import block_cycle, build_params, init_params

Pytree = Any


def _heads_shardable(cfg: ModelConfig) -> bool:
    return cfg.num_kv_heads % axis_size("model") == 0


# ==========================================================================
# Attention blocks
# ==========================================================================

def _qkv(cfg, p, x, positions, *, rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["q"]["w"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["k"]["w"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["v"]["w"].astype(x.dtype))
    if "b" in p["q"]:
        q = q + p["q"]["b"].astype(x.dtype)
        k = k + p["k"]["b"].astype(x.dtype)
        v = v + p["v"]["b"].astype(x.dtype)
    if rope:
        q = L.apply_rope(cfg, q, positions)
        k = L.apply_rope(cfg, k, positions)
    return q, k, v


def _attn_out(p, o, x_dtype):
    return jnp.einsum("bshk,hkd->bsd", o, p["o"]["w"].astype(x_dtype))


def _attn_shardings(cfg):
    """Megatron head-TP when kv heads divide the model axis; otherwise
    Ulysses-style context parallelism (q-sequence sharded, kv replicated)."""
    if _heads_shardable(cfg):
        q_ax = ("batch", "seq", "kv_heads", "q_per_kv", "head_dim")
        kv_ax = ("batch", "seq", "kv_heads", "head_dim")
    else:
        q_ax = ("batch", "seq_cp", "kv_heads", "q_per_kv", "head_dim")
        kv_ax = ("batch", None, "kv_heads", "head_dim")
    return q_ax, kv_ax


def gqa_full(cfg, p, x, positions, *, causal=True, window=0, rope=True):
    """Full-sequence GQA/MQA/MHA attention."""
    B, S, _ = x.shape
    Hkv, G, Dh = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    q, k, v = _qkv(cfg, p, x, positions, rope=rope)
    q = q.reshape(B, S, Hkv, G, Dh)
    q_ax, kv_ax = _attn_shardings(cfg)
    q = shard(q, q_ax)
    k = shard(k, kv_ax)
    v = shard(v, kv_ax)
    # context-parallel runs keep q sequence-sharded -> single q block (no
    # python q loop crossing shard boundaries); TP runs use q blocks with
    # static causal truncation.
    q_block = S if not _heads_shardable(cfg) else 2048
    o = L.attention(q, k, v, q_offset=0, causal=causal, window=window, q_block=q_block,
                    kv_block=cfg.attn_kv_block,
                    score_dtype=jnp.dtype(cfg.attn_score_dtype))
    o = o.reshape(B, S, cfg.num_heads, Dh)
    return _attn_out(p, o, x.dtype), (k, v)


def gqa_decode(cfg, p, x, pos, cache, *, window=0, rope=True, positions=None):
    """Single-token attention against a per-slot ring cache {'k','v'}.

    ``pos``: (B,) int32 — per-sequence absolute position (continuous batching
    serves requests at different depths in one batch)."""
    B = x.shape[0]
    Hkv, G, Dh = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    T = cache["k"].shape[1]
    if positions is None:
        positions = pos[:, None]
    q, k_new, v_new = _qkv(cfg, p, x, positions, rope=rope)
    q = q.reshape(B, 1, Hkv, G, Dh)
    slot = (pos % T).astype(jnp.int32)
    b_idx = jnp.arange(B)
    k = cache["k"].at[b_idx, slot].set(k_new[:, 0])
    v = cache["v"].at[b_idx, slot].set(v_new[:, 0])
    if _heads_shardable(cfg):
        kv_ax = ("batch", None, "kv_heads", "head_dim")
    else:
        kv_ax = ("batch", "kv_seq", None, "head_dim")
    k, v = shard(k, kv_ax), shard(v, kv_ax)
    valid = jnp.minimum(pos + 1, T)
    o = L.attention(q, k, v, q_offset=0, causal=False,
                    kv_valid_len=valid, strategy="dense")
    o = o.reshape(B, 1, cfg.num_heads, Dh)
    return _attn_out(p, o, x.dtype), {"k": k, "v": v}


def cross_full(cfg, p, x, enc_out):
    """Cross attention (whisper decoder): q from x, kv from encoder output."""
    B, S, _ = x.shape
    Hkv, G, Dh = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["q"]["w"].astype(x.dtype))
    if "b" in p["q"]:
        q = q + p["q"]["b"].astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["k"]["w"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["v"]["w"].astype(x.dtype))
    if "b" in p["k"]:
        k = k + p["k"]["b"].astype(x.dtype)
        v = v + p["v"]["b"].astype(x.dtype)
    q = q.reshape(B, S, Hkv, G, Dh)
    o = L.attention(q, k, v, q_offset=0, causal=False)
    return _attn_out(p, o.reshape(B, S, cfg.num_heads, Dh), x.dtype), (k, v)


def cross_decode(cfg, p, x, cache):
    B = x.shape[0]
    Hkv, G, Dh = cfg.num_kv_heads, cfg.q_per_kv, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["q"]["w"].astype(x.dtype))
    if "b" in p["q"]:
        q = q + p["q"]["b"].astype(x.dtype)
    q = q.reshape(B, 1, Hkv, G, Dh)
    o = L.attention(q, cache["ck"], cache["cv"], q_offset=0, causal=False,
                    strategy="dense")
    return _attn_out(p, o.reshape(B, 1, cfg.num_heads, Dh), x.dtype)


# --- MLA (deepseek) -------------------------------------------------------

def mla_full(cfg, p, x, positions):
    """Expanded-form MLA for train/prefill; returns compressed cache parts."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    cq = L.rmsnorm(p["q_norm"]["w"], jnp.einsum("bsd,dr->bsr", x, p["dq"]["w"].astype(x.dtype)),
                   eps=cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["uq"]["w"].astype(x.dtype))      # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(cfg, q_rope, positions)
    ckv = L.rmsnorm(p["kv_norm"]["w"], jnp.einsum("bsd,dr->bsr", x, p["dkv"]["w"].astype(x.dtype)),
                    eps=cfg.norm_eps)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["uk"]["w"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["uv"]["w"].astype(x.dtype))
    k_rope = L.apply_rope(cfg, jnp.einsum("bsd,dk->bsk", x, p["kr"]["w"].astype(x.dtype))[:, :, None, :],
                          positions)                                       # (B,S,1,dr)
    q_all = jnp.concatenate([q_nope, q_rope], -1).reshape(B, S, H, 1, dn + dr)
    k_all = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], -1)
    q_all = shard(q_all, ("batch", "seq", "heads", None, "head_dim"))
    k_all = shard(k_all, ("batch", "seq", "heads", "head_dim"))
    v = shard(v, ("batch", "seq", "heads", "head_dim"))
    o = L.attention(q_all, k_all, v, q_offset=0, causal=True,
                    scale=1.0 / math.sqrt(dn + dr),
                    score_dtype=jnp.dtype(cfg.attn_score_dtype))
    o = o.reshape(B, S, H, dv)
    return _attn_out(p, o, x.dtype), (ckv, k_rope[:, :, 0, :])


def mla_decode(cfg, p, x, pos, cache):
    """Absorbed-form MLA decode on the compressed (c_kv, k_rope) cache."""
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    T = cache["ckv"].shape[1]
    positions = pos[:, None]
    cq = L.rmsnorm(p["q_norm"]["w"], jnp.einsum("bsd,dr->bsr", x, p["dq"]["w"].astype(x.dtype)),
                   eps=cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["uq"]["w"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(cfg, q_rope, positions)
    # absorb W_uk: q_c[h] = q_nope[h] @ W_uk[h]^T  -> latent space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["uk"]["w"].astype(x.dtype))
    ckv_new = L.rmsnorm(p["kv_norm"]["w"], jnp.einsum("bsd,dr->bsr", x, p["dkv"]["w"].astype(x.dtype)),
                        eps=cfg.norm_eps)
    kr_new = L.apply_rope(cfg, jnp.einsum("bsd,dk->bsk", x, p["kr"]["w"].astype(x.dtype))[:, :, None, :],
                          positions)[:, :, 0, :]
    slot = (pos % T).astype(jnp.int32)
    b_idx = jnp.arange(B)
    ckv = cache["ckv"].at[b_idx, slot].set(ckv_new[:, 0])
    kr = cache["kr"].at[b_idx, slot].set(kr_new[:, 0])
    ckv = shard(ckv, ("batch", "kv_seq", None))
    kr = shard(kr, ("batch", "kv_seq", None))
    scale = 1.0 / math.sqrt(dn + dr)
    s = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), ckv.astype(jnp.float32))
         + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32), kr.astype(jnp.float32))) * scale
    valid = jnp.minimum(pos + 1, T)
    s = jnp.where(jnp.arange(T)[None, None, None, :] < valid[:, None, None, None], s, L.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhst,btr->bshr", pr, ckv.astype(jnp.float32)).astype(x.dtype)
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["uv"]["w"].astype(x.dtype))  # (B,1,H,dv)
    return _attn_out(p, o, x.dtype), {"ckv": ckv, "kr": kr}


# ==========================================================================
# Block dispatch — full-sequence mode
# ==========================================================================

def apply_block_full(cfg, kind, p, h, aux, collect_cache):
    """Returns (h, cache_out_or_None, aux_loss)."""
    h = shard(h, ("batch", "seq_sp", "embed"))   # Megatron-SP residual stream
    positions = aux["positions"]
    zero = jnp.zeros((), jnp.float32)
    cache_len = aux.get("cache_len", 0)

    def kv_cache(k, v, window=0):
        if not collect_cache:
            return None
        T = min(cache_len, window) if window else cache_len
        S = k.shape[1]
        kc = jnp.zeros((k.shape[0], T, *k.shape[2:]), k.dtype)
        vc = jnp.zeros_like(kc)
        if window and S > T:
            k, v = k[:, -T:], v[:, -T:]
            S = T
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        return {"k": kc, "v": vc}

    if kind == "attn_ffn":
        a, (k, v) = gqa_full(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], h), positions)
        h = h + a
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, kv_cache(k, v), zero

    if kind in ("moe_attn_ffn", "mla_moe"):
        y = L.apply_norm(cfg, p["ln1"], h)
        if kind == "mla_moe":
            a, (ckv, kr) = mla_full(cfg, p["attn"], y, positions)
        else:
            a, (k, v) = gqa_full(cfg, p["attn"], y, positions)
        h = h + a
        m, aux_loss = L.moe_ffn(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], h))
        h = h + m
        if kind == "mla_moe":
            cache = None
            if collect_cache:
                T = cache_len
                ckv_c = jnp.zeros((ckv.shape[0], T, ckv.shape[2]), ckv.dtype)
                kr_c = jnp.zeros((kr.shape[0], T, kr.shape[2]), kr.dtype)
                ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv, (0, 0, 0))
                kr_c = jax.lax.dynamic_update_slice(kr_c, kr, (0, 0, 0))
                cache = {"ckv": ckv_c, "kr": kr_c}
            return h, cache, aux_loss
        return h, kv_cache(k, v), aux_loss

    if kind == "griffin_attn":
        a, (k, v) = gqa_full(cfg, p["attn"], L.apply_norm(cfg, p["ln"], h), positions,
                             window=cfg.window)
        h = h + a
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, kv_cache(k, v, window=cfg.window), zero

    if kind == "griffin_rec":
        y = L.apply_norm(cfg, p["ln"], h)
        g = jax.nn.gelu(L.linear(p["in_gate"], y), approximate=True)
        r = L.linear(p["in_rec"], y)
        r = shard(r, ("batch", "seq", "lru_width"))
        r, conv_state = L.causal_conv1d(p["conv"], r, None)
        r, h_last = L.rglru_scan(p["rglru"], r, None)
        h = h + L.linear(p["out"], g * r)
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        cache = {"h": h_last.astype(h.dtype), "conv": conv_state} if collect_cache else None
        return h, cache, zero

    if kind == "mlstm":
        B, S, D = h.shape
        H, Dh = cfg.num_heads, cfg.head_dim
        y = L.apply_norm(cfg, p["ln"], h)
        u = L.linear(p["up"], y)
        cv, conv_state = L.causal_conv1d(p["conv"], u, None)
        c = jax.nn.silu(cv)
        q = L.linear(p["q"], c).reshape(B, S, H, Dh)
        k = L.linear(p["k"], c).reshape(B, S, H, Dh)
        v = L.linear(p["v"], u).reshape(B, S, H, Dh)
        gates = L.linear(p["gates"], c)
        i_g, f_g = gates[..., :H], gates[..., H:]
        yc, state = L.mlstm_chunkwise(q, k, v, i_g, f_g, chunk=cfg.chunk_size)
        yn = L.rmsnorm(p["out_norm"]["w"], yc.reshape(B, S, H * Dh), eps=cfg.norm_eps)
        out = yn * jax.nn.silu(L.linear(p["z"], y))
        h = h + L.linear(p["o"], out)
        cache = None
        if collect_cache:
            C, n, m = state
            cache = {"conv": conv_state, "C": C.astype(jnp.float32), "n": n, "m": m}
        return h, cache, zero

    if kind == "slstm":
        y = L.apply_norm(cfg, p["ln"], h)
        g_in = L.linear(p["gates_in"], y)
        hs, state = L.slstm_scan(p, g_in, None)
        hn = L.rmsnorm(p["out_norm"]["w"], hs, eps=cfg.norm_eps)
        ff = L.linear(p["ffn_down"], jax.nn.gelu(L.linear(p["ffn_up"], hn), approximate=True))
        h = h + ff
        cache = {"c": state[0], "n": state[1], "h": state[2], "m": state[3]} if collect_cache else None
        return h, cache, zero

    if kind == "xattn":
        a, (k, v) = gqa_full(cfg, p["self_attn"], L.apply_norm(cfg, p["ln1"], h), positions,
                             rope=False)
        h = h + a
        ca, (ck, cv) = cross_full(cfg, p["cross_attn"], L.apply_norm(cfg, p["ln2"], h),
                                  aux["enc_out"])
        h = h + ca
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln3"], h))
        cache = None
        if collect_cache:
            cache = kv_cache(k, v)
            cache["ck"], cache["cv"] = ck, cv
        return h, cache, zero

    if kind == "enc":
        a, _ = gqa_full(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], h), positions,
                        causal=False, rope=False)
        h = h + a
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, None, zero

    raise ValueError(kind)


# ==========================================================================
# Block dispatch — decode mode
# ==========================================================================

def apply_block_decode(cfg, kind, p, h, cache, aux):
    """Returns (h, new_cache)."""
    pos = aux["pos"]
    positions = aux.get("decode_positions")

    if kind == "attn_ffn":
        a, c = gqa_decode(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], h), pos, cache,
                          positions=positions)
        h = h + a
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, c

    if kind == "moe_attn_ffn":
        a, c = gqa_decode(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], h), pos, cache)
        h = h + a
        m, _ = L.moe_ffn(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], h))
        return h + m, c

    if kind == "mla_moe":
        a, c = mla_decode(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], h), pos, cache)
        h = h + a
        m, _ = L.moe_ffn(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], h))
        return h + m, c

    if kind == "griffin_attn":
        a, c = gqa_decode(cfg, p["attn"], L.apply_norm(cfg, p["ln"], h), pos, cache,
                          window=cfg.window)
        h = h + a
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, c

    if kind == "griffin_rec":
        y = L.apply_norm(cfg, p["ln"], h)
        g = jax.nn.gelu(L.linear(p["in_gate"], y), approximate=True)
        r = L.linear(p["in_rec"], y)
        r, conv_state = L.causal_conv1d(p["conv"], r, cache["conv"])
        r_t, h_state = L.rglru_step(p["rglru"], r[:, 0], cache["h"])
        h = h + L.linear(p["out"], g * r_t[:, None, :])
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], h))
        return h, {"h": h_state.astype(h.dtype), "conv": conv_state}

    if kind == "mlstm":
        B = h.shape[0]
        H, Dh = cfg.num_heads, cfg.head_dim
        y = L.apply_norm(cfg, p["ln"], h)
        u = L.linear(p["up"], y)
        cv, conv_state = L.causal_conv1d(p["conv"], u, cache["conv"])
        c = jax.nn.silu(cv)
        q = L.linear(p["q"], c).reshape(B, H, Dh)
        k = L.linear(p["k"], c).reshape(B, H, Dh)
        v = L.linear(p["v"], u).reshape(B, H, Dh)
        gates = L.linear(p["gates"], c)[:, 0]
        i_g, f_g = gates[..., :H], gates[..., H:]
        yc, (C, n, m) = L.mlstm_step(q, k, v, i_g, f_g, (cache["C"], cache["n"], cache["m"]))
        yn = L.rmsnorm(p["out_norm"]["w"], yc.reshape(B, 1, H * Dh), eps=cfg.norm_eps)
        out = yn * jax.nn.silu(L.linear(p["z"], y))
        h = h + L.linear(p["o"], out)
        return h, {"conv": conv_state, "C": C, "n": n, "m": m}

    if kind == "slstm":
        y = L.apply_norm(cfg, p["ln"], h)
        g_in = L.linear(p["gates_in"], y)
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
        hs, state = L.slstm_scan(p, g_in, state)
        hn = L.rmsnorm(p["out_norm"]["w"], hs, eps=cfg.norm_eps)
        ff = L.linear(p["ffn_down"], jax.nn.gelu(L.linear(p["ffn_up"], hn), approximate=True))
        h = h + ff
        return h, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}

    if kind == "xattn":
        self_cache = {"k": cache["k"], "v": cache["v"]}
        a, c = gqa_decode(cfg, p["self_attn"], L.apply_norm(cfg, p["ln1"], h), pos,
                          self_cache, rope=False)
        h = h + a
        h = h + cross_decode(cfg, p["cross_attn"], L.apply_norm(cfg, p["ln2"], h), cache)
        h = h + L.ffn(cfg, p["mlp"], L.apply_norm(cfg, p["ln3"], h))
        return h, {"k": c["k"], "v": c["v"], "ck": cache["ck"], "cv": cache["cv"]}

    raise ValueError(kind)


# ==========================================================================
# Model facade
# ==========================================================================

class Model:
    def __init__(self, cfg: ModelConfig, *, remat_policy: str = "none"):
        self.cfg = cfg
        self.remat_policy = remat_policy
        self.cycle, self.n_cycles, self.tail = block_cycle(cfg)

    # ---- params ----
    def init(self, rng: jax.Array) -> Pytree:
        return init_params(self.cfg, rng)

    # ---- embedding / head ----
    def _embed(self, params, tokens, positions, batch):
        cfg = self.cfg
        h = jnp.take(params["embed"]["w"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
        if cfg.scale_embedding:
            h = h * math.sqrt(cfg.d_model)
        if cfg.rope_style == "none":
            pos2d = positions if positions.ndim == 2 else positions[..., 0]
            h = h + L.sinusoidal_positions(pos2d, cfg.d_model).astype(h.dtype)
        if cfg.frontend == "vision_patches" and batch.get("patch_embeds") is not None:
            pe = batch["patch_embeds"].astype(h.dtype)
            h = jax.lax.dynamic_update_slice(h, pe, (0, 0, 0))
        return shard(h, ("batch", "seq_sp", "embed"))

    def _logits(self, params, h):
        cfg = self.cfg
        w = params["embed"]["w"].T if cfg.tie_embeddings else params["lm_head"]["w"]
        logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype)).astype(jnp.float32)
        # seq-sharded logits (full local vocab) -> local per-token CE; decode
        # (S=1) falls through to vocab sharding via divisibility resolution.
        return shard(logits, ("batch", "seq_sp", "vocab"))

    # ---- encoder (whisper) ----
    def encode(self, params, frame_embeds):
        cfg = self.cfg
        h = frame_embeds.astype(jnp.dtype(cfg.dtype))
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        h = h + L.sinusoidal_positions(positions, cfg.d_model).astype(h.dtype)
        aux = {"positions": positions}

        def body(carry, p_slice):
            hh = carry
            hh, _, _ = apply_block_full(cfg, "enc", p_slice[0], hh, aux, False)
            return hh, None

        body_fn = self._maybe_remat(body)
        h, _ = jax.lax.scan(body_fn, h, params["encoder"]["blocks"]["cycle"])
        return L.apply_norm(cfg, params["encoder"]["final_norm"], h)

    def _maybe_remat(self, fn):
        if self.remat_policy == "block":
            return jax.checkpoint(fn)
        if self.remat_policy == "dots":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
        return fn

    # ---- full-sequence stack ----
    def _run_stack(self, params, h, aux, collect_cache):
        cfg = self.cfg
        cycle = self.cycle

        def body(carry, xs):
            hh, aux_acc = carry
            cache_outs = []
            for j, kind in enumerate(cycle):
                hh, c_out, al = apply_block_full(cfg, kind, xs[j], hh, aux, collect_cache)
                cache_outs.append(c_out)
                aux_acc = aux_acc + al
            return (hh, aux_acc), (cache_outs if collect_cache else None)

        body_fn = self._maybe_remat(body)
        (h, aux_loss), cycle_caches = jax.lax.scan(
            body_fn, (h, jnp.zeros((), jnp.float32)), params["blocks"]["cycle"])
        tail_caches = []
        for j, kind in enumerate(self.tail):
            h, c_out, al = apply_block_full(cfg, kind, params["blocks"]["tail"][j], h, aux,
                                            collect_cache)
            tail_caches.append(c_out)
            aux_loss = aux_loss + al
        return h, aux_loss, cycle_caches, tail_caches

    # ---- public entry points ----
    def forward(self, params, batch):
        """Full-sequence forward.  batch: tokens (B,S)[, positions, frame_embeds,
        patch_embeds].  Returns (logits, aux_loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        aux = {"positions": positions}
        if cfg.encoder_layers > 0:
            aux["enc_out"] = self.encode(params, batch["frame_embeds"])
        h = self._embed(params, tokens, positions, batch)
        h, aux_loss, _, _ = self._run_stack(params, h, aux, collect_cache=False)
        h = L.apply_norm(cfg, params["final_norm"], h)
        return self._logits(params, h), aux_loss

    def prefill(self, params, batch, cache_len: int):
        """Full-sequence forward that also populates a decode cache."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        aux = {"positions": positions, "cache_len": cache_len}
        if cfg.encoder_layers > 0:
            aux["enc_out"] = self.encode(params, batch["frame_embeds"])
        h = self._embed(params, tokens, positions, batch)
        h, aux_loss, cycle_caches, tail_caches = self._run_stack(params, h, aux,
                                                                 collect_cache=True)
        h = L.apply_norm(cfg, params["final_norm"], h)
        logits = self._logits(params, h[:, -1:])
        cache = {"blocks": {"cycle": cycle_caches, "tail": tail_caches},
                 "pos": jnp.full((B,), S, jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, batch):
        """One-token decode.  batch: tokens (B,1)[, positions (B,1[,3])].
        Returns (logits, new_cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B = tokens.shape[0]
        pos = cache["pos"]                    # (B,) per-slot positions
        positions = batch.get("positions")
        if positions is None:
            positions = pos[:, None]
        aux = {"pos": pos, "decode_positions": positions}
        h = self._embed(params, tokens, positions, batch)
        cycle = self.cycle

        def body(hh, xs):
            p_slice, c_slice = xs
            new_c = []
            for j, kind in enumerate(cycle):
                hh, cj = apply_block_decode(cfg, kind, p_slice[j], hh, c_slice[j], aux)
                new_c.append(cj)
            return hh, new_c

        h, cycle_caches = jax.lax.scan(
            body, h, (params["blocks"]["cycle"], cache["blocks"]["cycle"]))
        tail_caches = []
        for j, kind in enumerate(self.tail):
            h, cj = apply_block_decode(cfg, kind, params["blocks"]["tail"][j], h,
                                       cache["blocks"]["tail"][j], aux)
            tail_caches.append(cj)
        h = L.apply_norm(cfg, params["final_norm"], h)
        logits = self._logits(params, h)
        new_cache = {"blocks": {"cycle": cycle_caches, "tail": tail_caches},
                     "pos": pos + 1}
        return logits, new_cache

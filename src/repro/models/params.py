"""Parameter-tree builder for the architecture zoo.

One builder (`build_params`) drives four consumers via a creator callback:
  * abstract shapes   (`abstract_params`)  — ShapeDtypeStruct, no allocation
  * concrete init     (`init_params`)      — PRNG-initialised arrays
  * sharding specs    (`param_pspecs`)     — logical axes -> PartitionSpec
  * parameter counts  (`count_params`)

Block parameters are *stacked* over cycle repetitions (leading 'layer' dim)
so the model can `lax.scan` over depth; a non-divisible remainder lives under
``blocks['tail']`` unstacked.
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Creator = Callable[..., object]  # creator(path, shape, logical, fan_in) -> leaf


# --------------------------------------------------------------------------
# Block cycle resolution
# --------------------------------------------------------------------------

def block_cycle(cfg: ModelConfig) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
    """Return (cycle_kinds, n_cycles, tail_kinds) for the decoder stack."""
    if cfg.family in ("dense", "vlm"):
        cycle = ("attn_ffn",)
    elif cfg.family == "moe":
        cycle = ("moe_attn_ffn" if cfg.attention != "mla" else "mla_moe",)
    elif cfg.family == "hybrid":
        cycle = tuple("griffin_rec" if k == "rec" else "griffin_attn" for k in cfg.block_pattern)
    elif cfg.family == "ssm":
        cycle = cfg.block_pattern
    elif cfg.family == "audio":
        cycle = ("xattn",)
    else:
        raise ValueError(cfg.family)
    n = cfg.num_layers // len(cycle)
    tail_len = cfg.num_layers - n * len(cycle)
    return cycle, n, cycle[:tail_len]


# --------------------------------------------------------------------------
# Per-kind parameter definitions
# --------------------------------------------------------------------------

def _norm(cfg, c: Creator, path):
    p = {"w": c(path + ("w",), (cfg.d_model,), ("embed",), 0)}
    if cfg.norm == "layernorm":
        p["b"] = c(path + ("b",), (cfg.d_model,), ("embed",), 0)
    return p


def _vec_norm(cfg, c, path, dim):
    return {"w": c(path + ("w",), (dim,), (None,), 0)}


def _gqa_attn(cfg, c: Creator, path, *, kv_heads=None, bias=None):
    D, H = cfg.d_model, cfg.num_heads
    Hkv = kv_heads if kv_heads is not None else cfg.num_kv_heads
    Dh = cfg.head_dim
    bias = cfg.qkv_bias if bias is None else bias
    p = {
        "q": {"w": c(path + ("q", "w"), (D, H, Dh), ("embed", "heads", "head_dim"), D)},
        "k": {"w": c(path + ("k", "w"), (D, Hkv, Dh), ("embed", "kv_heads", "head_dim"), D)},
        "v": {"w": c(path + ("v", "w"), (D, Hkv, Dh), ("embed", "kv_heads", "head_dim"), D)},
        "o": {"w": c(path + ("o", "w"), (H, Dh, D), ("heads", "head_dim", "embed"), H * Dh)},
    }
    if bias:
        p["q"]["b"] = c(path + ("q", "b"), (H, Dh), ("heads", "head_dim"), 0)
        p["k"]["b"] = c(path + ("k", "b"), (Hkv, Dh), ("kv_heads", "head_dim"), 0)
        p["v"]["b"] = c(path + ("v", "b"), (Hkv, Dh), ("kv_heads", "head_dim"), 0)
    return p


def _mla_attn(cfg, c: Creator, path):
    D, H = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "dq": {"w": c(path + ("dq", "w"), (D, qr), ("embed", None), D)},
        "q_norm": _vec_norm(cfg, c, path + ("q_norm",), qr),
        "uq": {"w": c(path + ("uq", "w"), (qr, H, dn + dr), (None, "heads", "head_dim"), qr)},
        "dkv": {"w": c(path + ("dkv", "w"), (D, kvr), ("embed", None), D)},
        "kv_norm": _vec_norm(cfg, c, path + ("kv_norm",), kvr),
        "uk": {"w": c(path + ("uk", "w"), (kvr, H, dn), (None, "heads", "head_dim"), kvr)},
        "uv": {"w": c(path + ("uv", "w"), (kvr, H, dv), (None, "heads", "head_dim"), kvr)},
        "kr": {"w": c(path + ("kr", "w"), (D, dr), ("embed", None), D)},
        "o": {"w": c(path + ("o", "w"), (H, dv, D), ("heads", "head_dim", "embed"), H * dv)},
    }


def _mlp(cfg, c: Creator, path, d_ff=None, *, bias=False):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    p = {}
    if cfg.act in ("swiglu", "geglu"):
        p["gate"] = {"w": c(path + ("gate", "w"), (D, F), ("embed", "ffn"), D)}
    p["up"] = {"w": c(path + ("up", "w"), (D, F), ("embed", "ffn"), D)}
    p["down"] = {"w": c(path + ("down", "w"), (F, D), ("ffn", "embed"), F)}
    if bias:
        p["up"]["b"] = c(path + ("up", "b"), (F,), ("ffn",), 0)
        p["down"]["b"] = c(path + ("down", "b"), (D,), ("embed",), 0)
        if "gate" in p:
            p["gate"]["b"] = c(path + ("gate", "b"), (F,), ("ffn",), 0)
    return p


def _moe(cfg, c: Creator, path):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": {"w": c(path + ("router", "w"), (D, E), ("embed", None), D)},
        "experts": {
            "gate": c(path + ("experts", "gate"), (E, D, F), ("expert", "embed", "expert_ffn"), D),
            "up": c(path + ("experts", "up"), (E, D, F), ("expert", "embed", "expert_ffn"), D),
            "down": c(path + ("experts", "down"), (E, F, D), ("expert", "expert_ffn", "embed"), F),
        },
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = _mlp(cfg, c, path + ("shared",), cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def _rglru_gates(cfg, c: Creator, path, W: int):
    nb = max(cfg.lru_gate_blocks, 1)
    if nb > 1:
        # Griffin Appendix: block-diagonal recurrence/input gates — keeps the
        # gate matmuls local under width sharding (no TP all-reduce)
        Wb = W // nb
        shp, ax = (nb, Wb, Wb), ("lru_width", None, None)
    else:
        shp, ax = (W, W), ("lru_width", None)
    return {
        "wa": c(path + ("rglru", "wa"), shp, ax, shp[-1]),
        "ba": c(path + ("rglru", "ba"), (W,), (None,), 0),
        "wx": c(path + ("rglru", "wx"), shp, ax, shp[-1]),
        "bx": c(path + ("rglru", "bx"), (W,), (None,), 0),
        "lam": c(path + ("rglru", "lam"), (W,), (None,), 0),
    }


def _griffin_rec(cfg, c: Creator, path):
    D, W, K = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.conv_width
    return {
        "ln": _norm(cfg, c, path + ("ln",)),
        "in_gate": {"w": c(path + ("in_gate", "w"), (D, W), ("embed", "lru_width"), D)},
        "in_rec": {"w": c(path + ("in_rec", "w"), (D, W), ("embed", "lru_width"), D)},
        "conv": {"w": c(path + ("conv", "w"), (K, W), (None, "lru_width"), 0),
                 "b": c(path + ("conv", "b"), (W,), ("lru_width",), 0)},
        "rglru": _rglru_gates(cfg, c, path, W),
        "out": {"w": c(path + ("out", "w"), (W, D), ("lru_width", "embed"), W)},
        "ln2": _norm(cfg, c, path + ("ln2",)),
        "mlp": _mlp(cfg, c, path + ("mlp",)),
    }


def _griffin_attn(cfg, c: Creator, path):
    return {
        "ln": _norm(cfg, c, path + ("ln",)),
        "attn": _gqa_attn(cfg, c, path + ("attn",)),
        "ln2": _norm(cfg, c, path + ("ln2",)),
        "mlp": _mlp(cfg, c, path + ("mlp",)),
    }


def _mlstm_block(cfg, c: Creator, path):
    D = cfg.d_model
    Di = int(cfg.mlstm_proj_factor * D)
    H, Dh = cfg.num_heads, cfg.head_dim
    DQ = H * Dh
    return {
        "ln": _norm(cfg, c, path + ("ln",)),
        "up": {"w": c(path + ("up", "w"), (D, Di), ("embed", "ffn"), D)},
        "conv": {"w": c(path + ("conv", "w"), (cfg.conv_width, Di), (None, "ffn"), 0),
                 "b": c(path + ("conv", "b"), (Di,), ("ffn",), 0)},
        "q": {"w": c(path + ("q", "w"), (Di, DQ), ("ffn", None), Di)},
        "k": {"w": c(path + ("k", "w"), (Di, DQ), ("ffn", None), Di)},
        "v": {"w": c(path + ("v", "w"), (Di, DQ), ("ffn", None), Di)},
        "gates": {"w": c(path + ("gates", "w"), (Di, 2 * H), ("ffn", None), Di),
                  "b": c(path + ("gates", "b"), (2 * H,), (None,), 0)},
        "out_norm": _vec_norm(cfg, c, path + ("out_norm",), DQ),
        "z": {"w": c(path + ("z", "w"), (D, DQ), ("embed", None), D)},
        "o": {"w": c(path + ("o", "w"), (DQ, D), (None, "embed"), DQ)},
    }


def _slstm_block(cfg, c: Creator, path):
    D = cfg.d_model
    W = D
    F = int(cfg.slstm_proj_factor * D)
    return {
        "ln": _norm(cfg, c, path + ("ln",)),
        "gates_in": {"w": c(path + ("gates_in", "w"), (D, 4 * W), ("embed", None), D)},
        "r": c(path + ("r",), (W, 4 * W), (None, None), W),
        "out_norm": _vec_norm(cfg, c, path + ("out_norm",), W),
        "ffn_up": {"w": c(path + ("ffn_up", "w"), (W, F), ("embed", "ffn"), W)},
        "ffn_down": {"w": c(path + ("ffn_down", "w"), (F, D), ("ffn", "embed"), F)},
    }


def _xattn_block(cfg, c: Creator, path):
    """Whisper decoder block: self-attn + cross-attn + FFN (LayerNorm, biases)."""
    return {
        "ln1": _norm(cfg, c, path + ("ln1",)),
        "self_attn": _gqa_attn(cfg, c, path + ("self_attn",)),
        "ln2": _norm(cfg, c, path + ("ln2",)),
        "cross_attn": _gqa_attn(cfg, c, path + ("cross_attn",)),
        "ln3": _norm(cfg, c, path + ("ln3",)),
        "mlp": _mlp(cfg, c, path + ("mlp",), bias=True),
    }


def _enc_block(cfg, c: Creator, path):
    return {
        "ln1": _norm(cfg, c, path + ("ln1",)),
        "attn": _gqa_attn(cfg, c, path + ("attn",)),
        "ln2": _norm(cfg, c, path + ("ln2",)),
        "mlp": _mlp(cfg, c, path + ("mlp",), bias=True),
    }


def _attn_ffn(cfg, c: Creator, path):
    return {
        "ln1": _norm(cfg, c, path + ("ln1",)),
        "attn": _gqa_attn(cfg, c, path + ("attn",)),
        "ln2": _norm(cfg, c, path + ("ln2",)),
        "mlp": _mlp(cfg, c, path + ("mlp",)),
    }


def _moe_attn_ffn(cfg, c: Creator, path):
    return {
        "ln1": _norm(cfg, c, path + ("ln1",)),
        "attn": _gqa_attn(cfg, c, path + ("attn",)),
        "ln2": _norm(cfg, c, path + ("ln2",)),
        "moe": _moe(cfg, c, path + ("moe",)),
    }


def _mla_moe(cfg, c: Creator, path):
    return {
        "ln1": _norm(cfg, c, path + ("ln1",)),
        "attn": _mla_attn(cfg, c, path + ("attn",)),
        "ln2": _norm(cfg, c, path + ("ln2",)),
        "moe": _moe(cfg, c, path + ("moe",)),
    }


BLOCK_BUILDERS = {
    "attn_ffn": _attn_ffn,
    "moe_attn_ffn": _moe_attn_ffn,
    "mla_moe": _mla_moe,
    "griffin_rec": _griffin_rec,
    "griffin_attn": _griffin_attn,
    "mlstm": _mlstm_block,
    "slstm": _slstm_block,
    "xattn": _xattn_block,
    "enc": _enc_block,
}


# --------------------------------------------------------------------------
# Tree assembly
# --------------------------------------------------------------------------

def _stacked_creator(c: Creator, n: int) -> Creator:
    def sc(path, shape, logical, fan_in):
        return c(path, (n, *shape), ("layer", *logical), fan_in)
    return sc


def build_params(cfg: ModelConfig, creator: Creator) -> dict:
    cycle, n, tail = block_cycle(cfg)
    tree: dict = {
        "embed": {"w": creator(("embed", "w"), (cfg.vocab_size, cfg.d_model),
                               ("vocab", "embed"), cfg.d_model)},
        "final_norm": _norm(cfg, creator, ("final_norm",)),
    }
    sc = _stacked_creator(creator, n)
    tree["blocks"] = {
        "cycle": [BLOCK_BUILDERS[kind](cfg, sc, ("blocks", "cycle", str(j), kind))
                  for j, kind in enumerate(cycle)],
        "tail": [BLOCK_BUILDERS[kind](cfg, creator, ("blocks", "tail", str(j), kind))
                 for j, kind in enumerate(tail)],
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = {"w": creator(("lm_head", "w"), (cfg.d_model, cfg.vocab_size),
                                        ("embed", "vocab"), cfg.d_model)}
    if cfg.encoder_layers > 0:
        esc = _stacked_creator(creator, cfg.encoder_layers)
        tree["encoder"] = {
            "blocks": {"cycle": [_enc_block(cfg, esc, ("encoder", "blocks", "cycle", "0", "enc"))],
                       "tail": []},
            "final_norm": _norm(cfg, creator, ("encoder", "final_norm")),
        }
    return tree


# --------------------------------------------------------------------------
# Creators
# --------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, dtype=None):
    dt = dtype or jnp.dtype(cfg.param_dtype)

    def c(path, shape, logical, fan_in):
        return jax.ShapeDtypeStruct(shape, dt)

    return build_params(cfg, c)


def param_logical_axes(cfg: ModelConfig):
    def c(path, shape, logical, fan_in):
        return tuple(logical)

    return build_params(cfg, c)


def init_params(cfg: ModelConfig, rng: jax.Array, dtype=None):
    """Concrete init (tiny configs only — full configs are dry-run-only)."""
    dt = dtype or jnp.dtype(cfg.param_dtype)
    counter = [0]

    def c(path, shape, logical, fan_in):
        counter[0] += 1
        key = jax.random.fold_in(rng, counter[0])
        if fan_in <= 0:  # biases / norm scales / gates
            name, parent = path[-1], path[-2] if len(path) > 1 else ""
            is_norm = parent.startswith("ln") or "norm" in parent
            if name == "w" and is_norm:
                # (1+w)-style RMSNorm (gemma) initialises w=0; plain norms w=1
                return jnp.zeros(shape, dt) if cfg.rms_offset else jnp.ones(shape, dt)
            if name == "lam":
                # RG-LRU: a in [0.9, 0.999] at init (Griffin appendix)
                u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
                lam = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))
                return lam.astype(jnp.float32)
            return jnp.zeros(shape, dt)
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)

    tree = build_params(cfg, c)
    # norm weights default to ones (rms/ln scale)
    return tree


@functools.lru_cache(maxsize=512)
def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Total parameter count; ``active_only`` counts top-k routed experts only
    (MoE active params for MODEL_FLOPS = 6 * N_active * D).  Pure in the
    frozen config, so memoized — the simulator calls it on every report and
    sweeps call it per candidate."""
    total = [0]

    def c(path, shape, logical, fan_in):
        n = int(np.prod(shape))
        if active_only and "experts" in path:
            n = n * (cfg.top_k / cfg.num_experts)
        total[0] += n
        return None

    build_params(cfg, c)
    return int(total[0])

"""Decode-cache construction: concrete zeros, abstract specs, and shardings.

Cache layout mirrors ``params['blocks']`` (stacked over cycle repetitions so
``decode_step`` can scan over depth) plus a global ``pos`` scalar.

KV sharding policy (divisibility-aware, see DESIGN.md):
  * kv_heads % model-axis == 0  -> heads sharded (Megatron TP decode)
  * otherwise                   -> KV sequence sharded (flash-decode style)
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import axis_size
from repro.models.params import block_cycle

CacheCreator = Callable[..., object]  # creator(shape, logical, dtype) -> leaf


def _kind_cache(cfg: ModelConfig, kind: str, c: CacheCreator, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.dtype)
    Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
    heads_ok = Hkv % axis_size("model") == 0
    kv_ax = ("batch", None, "kv_heads", "head_dim") if heads_ok else \
            ("batch", "kv_seq", None, "head_dim")

    def kv(T, H=Hkv, D=Dh):
        return {"k": c((batch, T, H, D), kv_ax, dt),
                "v": c((batch, T, H, D), kv_ax, dt)}

    if kind in ("attn_ffn", "moe_attn_ffn"):
        return kv(cache_len)
    if kind == "griffin_attn":
        return kv(min(cache_len, cfg.window) if cfg.window else cache_len)
    if kind == "mla_moe":
        return {"ckv": c((batch, cache_len, cfg.kv_lora_rank), ("batch", "kv_seq", None), dt),
                "kr": c((batch, cache_len, cfg.qk_rope_head_dim), ("batch", "kv_seq", None), dt)}
    if kind == "griffin_rec":
        W = cfg.lru_width or cfg.d_model
        return {"h": c((batch, W), ("batch", "lru_width"), dt),
                "conv": c((batch, cfg.conv_width - 1, W), ("batch", None, "lru_width"), dt)}
    if kind == "mlstm":
        H, D = cfg.num_heads, cfg.head_dim
        Di = int(cfg.mlstm_proj_factor * cfg.d_model)
        f32 = jnp.float32
        return {"conv": c((batch, cfg.conv_width - 1, Di), ("batch", None, "ffn"), dt),
                "C": c((batch, H, D, D), ("batch", None, None, None), f32),
                "n": c((batch, H, D), ("batch", None, None), f32),
                "m": c((batch, H), ("batch", None), f32)}
    if kind == "slstm":
        W = cfg.d_model
        f32 = jnp.float32
        return {k: c((batch, W), ("batch", None), f32) for k in ("c", "n", "h", "m")}
    if kind == "xattn":
        d = kv(cache_len, cfg.num_kv_heads, cfg.head_dim)
        d["ck"] = c((batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), kv_ax, dt)
        d["cv"] = c((batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), kv_ax, dt)
        return d
    raise ValueError(kind)


def build_cache(cfg: ModelConfig, creator: CacheCreator, batch: int, cache_len: int):
    cycle, n, tail = block_cycle(cfg)

    def stacked(shape, logical, dtype):
        return creator((n, *shape), ("layer", *logical), dtype)

    return {
        "blocks": {
            "cycle": [_kind_cache(cfg, k, stacked, batch, cache_len) for k in cycle],
            "tail": [_kind_cache(cfg, k, creator, batch, cache_len) for k in tail],
        },
        "pos": creator((batch,), ("batch",), jnp.int32),
    }


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return build_cache(cfg, lambda s, l, d: jax.ShapeDtypeStruct(s, d), batch, cache_len)


def cache_logical_axes(cfg: ModelConfig, batch: int, cache_len: int):
    return build_cache(cfg, lambda s, l, d: tuple(l), batch, cache_len)


def zero_cache(cfg: ModelConfig, batch: int, cache_len: int):
    cache = build_cache(cfg, lambda s, l, d: jnp.zeros(s, d), batch, cache_len)
    cache["pos"] = jnp.full((batch,), cache_len, jnp.int32)  # cache "full" semantics
    return cache


@functools.lru_cache(maxsize=1024)
def cache_bytes(cfg: ModelConfig, batch: int, cache_len: int) -> int:
    """Total cache bytes; pure in (cfg, batch, cache_len) — the mesh-dependent
    sharding policy only picks logical axes, never shapes — so memoized for
    sweeps that query it per candidate."""
    total = [0]

    def c(s, l, d):
        total[0] += int(np.prod(s)) * jnp.dtype(d).itemsize
        return None

    build_cache(cfg, c, batch, cache_len)
    return total[0]

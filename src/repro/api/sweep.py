"""Declarative design-space sweeps over :class:`~repro.api.spec.SimSpec`.

The legacy ``explore()`` hardcoded its grid to (tp, pp, batch, micro).  A
:class:`SweepSpace` instead names *any* spec field as an axis — parallelism
degrees, batch, sequence length, quantization, remat policy, even the
hardware target — and :func:`sweep` enumerates the cross product, applies
the same pruning rules, groups candidates by
:meth:`~repro.api.spec.SimSpec.reuse_key` so the simulator's cache layers
stay warm within a group, and ranks the survivors under the step-time or
request-level goodput objective.  The result is the same
:class:`~repro.core.explorer.ExplorationResult` the old surface returned,
so Pareto/SLO/ranking queries are unchanged.

Axis names are resolved against the spec components: use a dotted path
(``"parallel.tp"``, ``"workload.seq_len"``, ``"cluster.hardware"``) or a
bare field name, which is looked up in parallel -> workload -> cluster ->
model order.  ``"batch"`` and ``"micro"`` alias ``workload.global_batch``
and ``parallel.microbatches``.

When ``cluster.chips`` is set and ``dp`` is not itself an axis, data
parallelism is derived per candidate as ``chips // (tp*pp*pods*cp)`` and
non-divisible combinations are skipped — the legacy enumeration rule.  For
MoE models expert parallelism follows tp unless ``ep`` is an explicit axis.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import os
import traceback
from dataclasses import dataclass
from typing import Callable, Iterable

from repro.analysis.chaos import FaultPlan
from repro.api.pool import (
    RetryPolicy, SweepJournal, _compact_tb, get_pool,
)
from repro.api.spec import ServingWorkload, SimSpec
from repro.core.backend.collectives import collective_memo_stats
from repro.obs.clock import wall_s
from repro.core.explorer import (
    Candidate, DEFAULT_RULES, EvalResult, ExplorationResult,
    FailedCandidate, _stats_delta, rule_memory_fit,
)
from repro.core.simulator import Simulator, merge_cache_shards
from repro.obs.metrics import MetricsRegistry
from repro.obs.recorder import NULL_RECORDER

_ALIASES = {"batch": "workload.global_batch", "micro": "parallel.microbatches",
            "hardware": "cluster.hardware", "hw": "cluster.hardware"}
_COMPONENTS = ("parallel", "workload", "cluster", "model")


def _resolve_axis(spec: SimSpec, name: str) -> tuple[str, ...]:
    """Axis name -> (component, field, ...) path.  Dotted paths are explicit
    and may reach into nested spec objects (``workload.fleet.replicas``);
    bare names search parallel -> workload -> cluster -> model."""
    name = _ALIASES.get(name, name)
    if "." in name:
        comp, rest = name.split(".", 1)
        if comp not in _COMPONENTS:
            raise KeyError(f"unknown spec component {comp!r} in axis {name!r}")
        obj = getattr(spec, comp)
        parts = rest.split(".")
        for i, f in enumerate(parts):
            if not dataclasses.is_dataclass(obj) or f not in {
                    x.name for x in dataclasses.fields(obj)}:
                raise KeyError(f"{type(obj).__name__} has no field {f!r} "
                               f"(axis {name!r})")
            if i < len(parts) - 1:
                obj = getattr(obj, f)
                if obj is None:
                    raise KeyError(
                        f"axis {name!r} descends through a None field — set "
                        f"a non-None default on the base spec (or sweep "
                        f"{'.'.join([comp] + parts[:i + 1])!r} as whole "
                        "objects)")
        return (comp, *parts)
    for comp in _COMPONENTS:
        obj = getattr(spec, comp)
        if name in {x.name for x in dataclasses.fields(obj)}:
            return (comp, name)
    raise KeyError(f"axis {name!r} matches no field of any spec component")


def _nested_replace(obj, path: tuple, value):
    """``dataclasses.replace`` along a field path, rebuilding each frozen
    level from the inside out."""
    if len(path) == 1:
        return dataclasses.replace(obj, **{path[0]: value})
    inner = _nested_replace(getattr(obj, path[0]), path[1:], value)
    return dataclasses.replace(obj, **{path[0]: inner})


def spec_replace(spec: SimSpec, changes: dict) -> SimSpec:
    """Rebuild a spec with dotted-path (or bare-name) field changes."""
    parts: dict[str, object] = {}
    for name, value in changes.items():
        comp, *path = _resolve_axis(spec, name)
        parts[comp] = _nested_replace(parts.get(comp, getattr(spec, comp)),
                                      tuple(path), value)
    return dataclasses.replace(spec, **parts)


@dataclass(frozen=True)
class SweepSpace:
    """A base spec plus named axes; hashable like every other spec object.

    ``axes`` accepts a mapping ``{axis_name: values}`` (normalized to a
    tuple of ``(name, tuple(values))`` pairs, preserving insertion order —
    the cross product enumerates the last axis fastest).
    """
    base: SimSpec
    axes: tuple = ()

    def __post_init__(self):
        ax = self.axes
        pairs = ax.items() if isinstance(ax, dict) else ax
        norm = []
        for k, v in pairs:
            if isinstance(v, (str, bytes)):
                raise TypeError(
                    f"axis {k!r}: values must be a sequence, got the bare "
                    f"string {v!r} — wrap it in a tuple")
            norm.append((str(k), tuple(v)))
        norm = tuple(norm)
        for k, _ in norm:
            _resolve_axis(self.base, k)          # fail fast on bad names
        object.__setattr__(self, "axes", norm)

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(k for k, _ in self.axes)

    def size(self) -> int:
        n = 1
        for _, vals in self.axes:
            n *= len(vals)
        return n

    def points(self) -> Iterable[SimSpec]:
        """Enumerate candidate specs: cross product of the axes, then the
        chip-budget dp derivation (and MoE ep) unless explicitly swept."""
        names = self.axis_names
        resolved = {n: _resolve_axis(self.base, n) for n in names}
        derive_dp = ("parallel", "dp") not in resolved.values()
        derive_ep = ("parallel", "ep") not in resolved.values()
        for combo in itertools.product(*(v for _, v in self.axes)):
            spec = spec_replace(self.base, dict(zip(names, combo)))
            par, chips = spec.parallel, spec.cluster.chips
            if chips:
                denom = par.tp * par.pp * par.pods * par.cp
                if derive_dp:
                    if chips % denom:
                        continue                  # budget not divisible
                    par = dataclasses.replace(par, dp=chips // denom)
                elif par.chips != chips:
                    continue                      # explicit dp over budget
            if derive_ep and spec.model.num_experts:
                par = dataclasses.replace(par, ep=par.tp)
            if par is not spec.parallel:
                spec = dataclasses.replace(spec, parallel=par)
            yield spec


def _sim_for(cluster, sims: dict, engine: str,
             persist: str | None = None) -> Simulator:
    key = cluster.hardware
    if key not in sims:
        sims[key] = Simulator(cluster.resolve(), engine=engine,
                              persist=persist)
    return sims[key]


def _merge_stats(deltas: list[dict]) -> dict:
    """Sum per-simulator cache-stat deltas layer-wise.  The ``collectives``
    layer is excluded here — its counters are process-global, so every
    simulator reports the same window and summing would multi-count; the
    caller patches in one global delta instead."""
    out: dict[str, dict] = {}
    for d in deltas:
        for layer, st in d.items():
            if layer == "collectives":
                continue
            acc = out.setdefault(layer, {"hits": 0, "misses": 0})
            acc["hits"] += st.get("hits", 0)
            acc["misses"] += st.get("misses", 0)
    return out


def _serving_probe(spec: SimSpec) -> SimSpec:
    """The steady-state spec a serving candidate is step-probed with: one
    replica's decode iteration at the policy's admission cap and the
    oracle's context floor, bucketed exactly like the oracle buckets it —
    so the probe's priced report is the first entry of the serving run's
    own step table (shared through the SimCache), and it carries the memory
    footprint the post-simulation ``memory_limit`` filter needs."""
    from repro.api.spec import Cluster, DecodeWorkload
    from repro.serving.sim.oracle import pow2_bucket
    w = spec.workload
    ctx = pow2_bucket(w.ctx_floor)
    return SimSpec(
        model=spec.model,
        cluster=Cluster(spec.cluster.resolve(),
                        memory_limit=spec.cluster.memory_limit),
        parallel=dataclasses.replace(spec.parallel, dp=1, pods=1,
                                     microbatches=1),
        workload=DecodeWorkload(global_batch=pow2_bucket(w.max_batch),
                                seq_len=ctx, cache_len=ctx))


def _resolve_scenario(objective: str, scenario):
    """Normalize the user-facing ``scenario=`` argument once per process
    (idempotent: an already-resolved scenario passes through).  Deferred
    import: repro.serving pulls the real-model serving stack, which the
    step-time-only path never needs."""
    if objective != "goodput":
        return scenario
    from repro.serving.sim import ServingScenario
    if scenario is None:
        return ServingScenario.default()
    if isinstance(scenario, ServingWorkload):
        return scenario.scenario()
    return scenario


def _evaluate_one(idx: int, spec: SimSpec, cand: Candidate, sims: dict,
                  stats0: dict, engine: str, objective: str, scenario,
                  persist: str | None = None, timings: list | None = None,
                  faults=None, attempt: int = 1) -> EvalResult:
    """Evaluate one candidate end to end: step/probe pricing, the
    post-simulation memory filter, then the objective's serving/resilience
    replay.  THE single evaluation code path — the serial loop and every
    pool worker run exactly this function, which is why parallel sweeps
    (under any fault schedule) are bit-identical to serial ones.

    ``timings`` (a list, when given) collects ``(idx, phase, t0, t1)``
    wall-clock rows per evaluation stage — raw material for the sweep's
    per-worker trace lanes.  ``faults`` is the chaos hook
    (:class:`~repro.analysis.chaos.FaultPlan`): only ``candidate_error``
    fires here, *before* any pricing, so an injected failure can never
    change a simulated number."""
    t0 = wall_s()
    s = _sim_for(spec.cluster, sims, engine, persist)
    # snapshot a lazily-created simulator's counters before its first
    # run: the collectives memo is process-global, not zero at birth
    if spec.cluster.hardware not in stats0:
        stats0[spec.cluster.hardware] = s.cache_stats()
    if faults is not None:
        faults.maybe_raise(spec.json_hash(), attempt)
    serving_mode = spec.workload.mode == "serving"
    rep = s.run(_serving_probe(spec) if serving_mode else spec)
    res = EvalResult(cand, rep, spec=spec)
    limit = spec.cluster.memory_limit
    if limit and rep.memory and rep.memory.total > limit:
        res.pruned = True
        res.reason = f"memory {rep.memory.total/1e9:.1f}GB > limit"
    if timings is not None:
        timings.append((idx, "probe" if serving_mode else "step",
                        t0, wall_s()))
    if res.pruned:
        return res
    if objective == "goodput":
        from repro.serving.sim import ServingSimulator
        t0 = wall_s()
        if serving_mode:
            # the spec IS the scenario: trace, SLO, policy and fleet all
            # come from the ServingWorkload (FleetReports are system-
            # level — EvalResult.goodput_rps passes them through)
            res.serving = ServingSimulator(s).run(spec)
        else:
            res.serving = scenario.evaluate(s, spec.model, cand)
        if timings is not None:
            timings.append((idx, "serving", t0, wall_s()))
    elif objective == "goodput_under_failures":
        from repro.resilience import ResilienceSimulator
        t0 = wall_s()
        res.resilience = ResilienceSimulator(s).run(spec)
        if timings is not None:
            timings.append((idx, "resilience", t0, wall_s()))
    return res


def _evaluate(items: list, sims: dict, stats0: dict, engine: str,
              objective: str, scenario, persist: str | None = None,
              timings: list | None = None,
              progress: Callable | None = None) -> list:
    """Evaluate ``(idx, spec, cand)`` triples in order via
    :func:`_evaluate_one`; returns ``(idx, EvalResult)`` pairs."""
    scenario = _resolve_scenario(objective, scenario)
    results: list[tuple[int, EvalResult]] = []
    for idx, spec, cand in items:
        res = _evaluate_one(idx, spec, cand, sims, stats0, engine,
                            objective, scenario, persist, timings)
        results.append((idx, res))
        if progress is not None:
            progress(res)
    return results


def _shard_items(items: list, workers: int) -> list[list]:
    """Deterministically shard ``(idx, spec, cand)`` triples over workers.

    Whole trace-affinity clusters — contiguous runs of reuse groups that
    share a traced-graph (``ingest``) key — are kept together, so each
    worker's per-process ingest cache traces any given shape exactly once
    and no two workers duplicate a trace.  Clusters go to the currently
    lightest shard (greedy balance; ties break on shard index), which is a
    pure function of the candidate list, so the shard layout — and thus
    every worker-local cache interaction — is reproducible."""
    def trace_key(spec: SimSpec) -> tuple:
        # serving candidates sharing a bucket family would all land on one
        # worker (their trace shapes are identical by design), yet their
        # cost is the Python event loop, not JAX traces — spread them by
        # full workload identity instead
        extra = (spec.workload,) if spec.workload.mode == "serving" else ()
        return (spec.cluster.hardware, spec.model,
                spec.workload.mode) + spec.trace_shapes() + extra

    clusters: dict[tuple, list] = {}
    for item in items:
        clusters.setdefault(trace_key(item[1]), []).append(item)
    shards: list[list] = [[] for _ in range(workers)]
    for cluster in clusters.values():
        target = min(range(workers), key=lambda i: (len(shards[i]), i))
        shards[target].extend(cluster)
    return [s for s in shards if s]


def _write_manifest(path: str, space: SweepSpace,
                    result: ExplorationResult) -> None:
    """Sweep provenance: the space, every candidate's full spec JSON (keyed
    by :meth:`~repro.api.spec.SimSpec.json_hash`), its outcome, and the
    final ranking — enough to re-run or audit any row without the process
    that produced it."""
    import json

    from repro.obs.explain import (
        compact_report, compact_resilience, compact_serving,
    )

    def row(res: EvalResult, rank: dict) -> dict:
        h = res.spec.json_hash()
        # compact attribution: every surviving candidate carries its "why"
        # (dominant phase / SLO-violation cause / loss bucket) so ranking
        # flips are explainable straight from the manifest
        explain = None
        if not res.pruned:
            explain = {}
            if res.report is not None:
                explain["step"] = compact_report(res.report)
            if res.serving is not None:
                explain["serving"] = compact_serving(res.serving)
            if res.resilience is not None:
                explain["resilience"] = compact_resilience(res.resilience)
        return {
            "json_hash": h,
            "spec": json.loads(res.spec.to_json()),
            "status": "pruned" if res.pruned else "completed",
            "pruned": res.pruned,
            "reason": res.reason or None,
            "step_time_us": (round(res.report.step_time_us, 3)
                             if res.report is not None else None),
            "goodput_rps": (round(res.goodput_rps, 4)
                            if res.serving is not None else None),
            "goodput_under_failures": (
                round(res.resilience.goodput, 6)
                if res.resilience is not None else None),
            "explain": explain,
            "rank": rank.get(h),
        }

    def failed_row(rec) -> dict:
        # quarantined candidates stay visible: downstream tooling must be
        # able to see *every* enumerated candidate's outcome
        return {
            "json_hash": rec.spec.json_hash(),
            "spec": json.loads(rec.spec.to_json()),
            "status": "failed",
            "pruned": False,
            "reason": rec.reason,
            "attempts": rec.attempts,
            "traceback": rec.traceback or None,
            "rank": None,
        }

    try:
        ranking = [r.spec.json_hash() for r in result.ranked()]
    except ValueError:        # mixed objectives: manifest still records rows
        ranking = []
    rank = {h: i for i, h in enumerate(ranking)}
    doc = {
        "kind": "charon-sweep-manifest",
        "version": 1,
        "base_hash": space.base.json_hash(),
        "base": json.loads(space.base.to_json()),
        "axes": {name: list(vals) for name, vals in space.axes},
        "objective": result.objective,
        "workers": result.workers,
        "wall_time_s": round(result.wall_time_s, 3),
        "n_evaluated": len(result.evaluated),
        "n_pruned": len(result.pruned),
        "n_failed": len(result.failed),
        "metrics": result.metrics or None,
        "ranking": ranking,
        "candidates": [row(r, rank)
                       for r in result.evaluated + result.pruned]
                      + [failed_row(rec) for rec in result.failed],
    }
    with open(path, "w") as f:
        # default=str absorbs non-JSON axis values (HardwareSpec and
        # friends) the same way the spec's own serializer names them
        json.dump(doc, f, indent=1, sort_keys=True, default=str)
        f.write("\n")


def _progress_line(reg: MetricsRegistry, n_total: int, t0: float, *,
                   final: bool = False) -> None:
    """One stderr progress line, driven entirely by the sweep's metrics
    registry (configs done, rate, ETA, prune count)."""
    import sys
    done = int(reg.counters.get("sweep.configs_done", 0))
    npruned = int(reg.counters.get("sweep.pruned", 0))
    el = wall_s() - t0
    rate = done / el if el > 0 else 0.0
    eta = (n_total - done) / rate if rate > 0 else float("inf")
    eta_s = f"{eta:.0f}s" if math.isfinite(eta) else "?"
    print(f"\rsweep {done}/{n_total} configs · {rate:.1f} cfg/s · "
          f"eta {eta_s} · pruned {npruned}",
          file=sys.stderr, end="\n" if final else "", flush=True)


def _record_sweep_lanes(rec, sweep_t0: float, lane: str, timings: list,
                        by_idx: dict) -> None:
    """Per-candidate evaluation spans on one worker's trace lane (timings
    are epoch seconds from :func:`_evaluate`; normalized to sweep-relative
    time here), with prune instants carrying their reasons."""
    if not rec.enabled:
        return
    for idx, phase, a, b in timings:
        res = by_idx.get(idx)
        args: dict = {"idx": idx}
        if res is not None:
            args["json_hash"] = res.spec.json_hash()[:12]
        rec.span("sweep", lane, f"cand{idx}:{phase}", a - sweep_t0, b - a,
                 cat="sweep", args=args)
        if res is not None and res.pruned and phase in ("step", "probe"):
            rec.instant("sweep", lane, f"prune:cand{idx}", b - sweep_t0,
                        cat="prune", args={"idx": idx, "reason": res.reason})


def _journal_header(space: SweepSpace, objective: str, engine: str) -> dict:
    """The identity a journal is keyed by: resuming against a journal whose
    base spec, axes, objective or engine differ must fail loudly rather
    than silently mix results from two different sweeps."""
    return {"base_hash": space.base.json_hash(),
            "axes": {name: list(vals) for name, vals in space.axes},
            "objective": objective, "engine": engine}


def sweep(space: SweepSpace, *, sim: Simulator | None = None,
          engine: str = "analytical", rules: list[Callable] | None = None,
          max_evals: int = 10_000, objective: str = "step_time",
          scenario=None, workers: int = 1, persist: str | None = None,
          mp_context: str | None = None, manifest: str | None = None,
          journal: str | None = None, resume: str | None = None,
          strict: bool = False, faults: FaultPlan | None = None,
          retry: RetryPolicy | None = None,
          recorder=None, metrics: MetricsRegistry | None = None,
          progress: bool = False) -> ExplorationResult:
    """Enumerate, prune, simulate and rank every spec in ``space``.

    ``sim`` seeds the per-hardware simulator registry (its caches stay warm
    across sweeps); hardware axes beyond it get fresh ``engine`` simulators.
    Pruning uses the classic rules plus, when ``cluster.memory_limit`` is
    set, the closed-form memory-fit lower bound before simulation and the
    full memory report after.  ``objective="goodput"`` replays a
    request-level scenario per candidate — pass a
    :class:`~repro.serving.sim.ServingScenario`, a
    :class:`~repro.api.spec.ServingWorkload`, or None for the default.
    ``objective="goodput_under_failures"`` replays each candidate's seeded
    failure trace through :class:`~repro.resilience.ResilienceSimulator`
    (the base must be a ``TrainWorkload`` with ``resilience=`` set, whose
    nested fields — checkpoint interval, MTBFs, spares — are then ordinary
    dotted axes); results carry ``EvalResult.resilience``.

    A :class:`~repro.api.spec.ServingWorkload` *base* (goodput objective
    only) sweeps the request-level simulator itself: each candidate replays
    the spec's own trace/SLO/policy — including its
    :class:`~repro.api.spec.FleetSpec`, so ``workload.fleet.replicas`` or
    ``workload.fleet.prefill_replicas`` are axes like any other — and is
    step-probed once (one bucketed decode iteration) for the memory filter
    and ranking tie-breaks.

    ``workers > 1`` shards candidate groups by reuse/trace key over a
    long-lived :class:`~repro.api.pool.WorkerPool` (a process-wide
    singleton: the second sweep reuses warm workers, skipping the spawn +
    jax-import tax and keeping worker-local simulator caches hot).
    ``mp_context=None`` picks ``fork`` where the platform offers it, else
    ``spawn``.  Results, rankings and pruned reasons are bit-identical to
    the serial sweep, with the merged ``cache_stats`` summing the
    per-worker deltas.  ``sim=`` is not used for evaluation in that case
    (worker processes own their simulators); pass ``persist=`` (a
    directory) to warm-start every worker from the on-disk cache tier —
    workers write their new entries back as atomic per-worker shards,
    merged (and corruption-quarantined) into the main cache file when the
    sweep completes.

    Execution contract (``retry=``, a :class:`~repro.api.pool.RetryPolicy`):
    each candidate gets a wall-clock timeout and heartbeat-based liveness
    checks; a worker crash/hang/timeout retries the candidate with
    exponential backoff on a respawned worker up to ``max_retries`` times,
    after which the candidate is *quarantined* — recorded on
    ``ExplorationResult.failed`` (and as ``status: failed`` in the
    manifest) instead of aborting the sweep.  ``strict=True`` opts back
    into fail-fast: the serial path re-raises the underlying exception, the
    pool raises :class:`~repro.api.pool.CandidateFailedError`.  ``faults=``
    (a :class:`~repro.analysis.chaos.FaultPlan`; default: parsed from the
    ``CHARON_FAULTS`` env var) deterministically injects worker crashes,
    hangs, poison candidates and cache-shard corruption to exercise exactly
    those recovery paths — see docs/robustness.md.

    ``journal=`` (a file path) appends one fsync'd JSONL row per finished
    candidate as the sweep runs; after a crash or kill, re-running with the
    same ``journal=`` (or pointing ``resume=`` at the file) validates the
    sweep identity, injects the recorded results and evaluates only the
    remainder — merged rankings are bit-identical to an uninterrupted run.

    ``manifest=`` (a file path) writes a JSON provenance record after the
    sweep: the space, every candidate's full spec (keyed by its
    ``json_hash``), per-row ``status`` (completed/pruned/failed), pruned
    reasons, objective values, a compact ``explain`` attribution per
    surviving row, the metrics snapshot and the final ranking.

    Observability (all off by default, zero cost when off): ``recorder`` (a
    :class:`~repro.obs.TraceRecorder`) captures per-worker lanes of
    per-candidate evaluation spans plus prune instants; ``metrics`` (a
    :class:`~repro.obs.MetricsRegistry`) accumulates sweep counters — a
    snapshot always lands in ``ExplorationResult.metrics`` and the
    manifest; ``progress=True`` prints a stderr progress line (configs
    done, rate, ETA, prune counts) as candidates complete.  None of the
    three changes results or rankings.
    """
    if objective not in ("step_time", "goodput", "goodput_under_failures"):
        raise ValueError(f"unknown objective {objective!r}")
    if objective == "goodput_under_failures":
        w = space.base.workload
        if getattr(w, "mode", None) != "train" or w.resilience is None:
            raise TypeError(
                "goodput_under_failures sweeps price TrainWorkload specs "
                "with a non-None resilience= — set workload.resilience on "
                "the base spec (its fields are then sweep axes, e.g. "
                "'workload.resilience.ckpt.interval_steps')")
    serving_base = isinstance(space.base.workload, ServingWorkload)
    if serving_base and objective != "goodput":
        raise TypeError(
            "a ServingWorkload base sweeps the request-level simulator — "
            "pass objective='goodput' (step_time needs a steady-state "
            "Train/Prefill/Decode workload)")
    if serving_base and scenario is not None:
        raise TypeError(
            "a ServingWorkload base carries its own trace/SLO/policy; "
            "scenario= would be ignored — drop one of the two")
    rules = list(DEFAULT_RULES if rules is None else rules)
    reg = metrics if metrics is not None else MetricsRegistry()
    rec = recorder if recorder is not None else NULL_RECORDER
    policy = retry if retry is not None else RetryPolicy()
    if faults is None:
        faults = FaultPlan.from_env()
    if faults is not None and not faults.enabled:
        faults = None
    t0 = wall_s()
    coll0 = collective_memo_stats().as_dict()
    pruned: list[EvalResult] = []
    cands: list[tuple[SimSpec, Candidate]] = []
    for spec in space.points():
        w = spec.workload
        cand = Candidate(spec.parallel, getattr(w, "global_batch", None)
                         or w.max_batch)
        reason = next((r for rule in rules
                       if (r := rule(spec.model, cand))), None)
        if reason is None and spec.cluster.memory_limit \
                and w.mode != "serving":
            # serving specs have no single step shape for the closed-form
            # bound; the probe's full memory report post-filters them
            fit = rule_memory_fit(spec.cluster.memory_limit, mode=w.mode,
                                  seq_len=w.seq_len, cache_len=w.cache_len)
            reason = fit(spec.model, cand)
        if reason:
            pruned.append(EvalResult(cand, None, pruned=True, reason=reason,
                                     spec=spec))
            reg.inc("sweep.pruned")
            reg.inc("sweep.pruned_rules")
            if rec.enabled:
                rec.instant("sweep", "prune", "prune:rule", 0.0, cat="prune",
                            args={"json_hash": spec.json_hash()[:12],
                                  "reason": reason})
            continue
        cands.append((spec, cand))

    # evaluate group-by-group so every candidate after the first in a group
    # hits the simulator's block-stage cache while it is warm
    cands.sort(key=lambda sc: (sc[0].reuse_key(), sc[1].key()))
    n_groups = len({s.reuse_key() for s, _ in cands})
    items = [(i, spec, cand)
             for i, (spec, cand) in enumerate(cands[:max_evals])]

    # ---- journal / resume: skip candidates with recorded outcomes --------
    header = _journal_header(space, objective, engine)
    expect = {"kind": SweepJournal.KIND, "version": SweepJournal.VERSION,
              **header}
    prior_rows: dict[str, dict] = {}
    if resume and not (journal and os.path.abspath(str(resume))
                       == os.path.abspath(str(journal))):
        prior_rows.update(SweepJournal.load(str(resume), expect=expect))
    jr = SweepJournal(str(journal), header) if journal else None
    if jr is not None:
        prior_rows.update(jr.rows)

    injected: list[tuple[int, EvalResult]] = []
    todo: list = []
    for idx, spec, cand in items:
        row = prior_rows.get(spec.json_hash()) if prior_rows else None
        # failed rows are re-attempted: a resume is an explicit second
        # chance for transient (crash/timeout) failures
        if row is not None and row["status"] in ("completed", "pruned"):
            injected.append((idx, SweepJournal.result_from(row)))
            reg.inc("sweep.resumed")
        else:
            todo.append((idx, spec, cand))

    def count_result(res: EvalResult) -> None:
        reg.inc("sweep.configs_done")
        if res.pruned:
            reg.inc("sweep.pruned")
            reg.inc("sweep.pruned_memory")
        else:
            reg.inc("sweep.evaluated")

    for _, res in injected:
        count_result(res)

    failed: list[FailedCandidate] = []

    def on_result(res: EvalResult, attempt: int = 1) -> None:
        count_result(res)
        if jr is not None:
            jr.append_result(res)
        if progress:
            _progress_line(reg, len(items), t0)

    def on_failed(recf: FailedCandidate) -> None:
        reg.inc("sweep.configs_done")
        reg.inc("sweep.failed")
        if jr is not None:
            jr.append_failed(recf)
        if rec.enabled:
            rec.instant("sweep", "quarantine", "quarantine",
                        wall_s() - t0, cat="fault",
                        args={"json_hash": recf.spec.json_hash()[:12],
                              "reason": recf.reason,
                              "attempts": recf.attempts})
        if progress:
            _progress_line(reg, len(items), t0)

    workers = max(int(workers), 1)
    pooled = workers > 1 and len(todo) > 1
    try:
        if pooled:
            shards = _shard_items(todo, workers)
            pool = get_pool(workers, mp_context)
            eval_results, pool_failed, merged, coll, lanes, shard_files = \
                pool.run(shards, engine=engine, objective=objective,
                         scenario=scenario, persist=persist, faults=faults,
                         policy=policy, strict=strict,
                         shard_tag=space.base.json_hash()[:8],
                         metrics=reg, recorder=rec, sweep_t0=t0,
                         on_result=on_result, on_failed=on_failed)
            failed.extend(pool_failed)
            by_idx = dict(eval_results)
            for wid in sorted(lanes):
                for _, phase, a, b in lanes[wid]:
                    reg.observe(f"sweep.eval_s.{phase}", b - a)
                _record_sweep_lanes(rec, t0, f"worker{wid}", lanes[wid],
                                    by_idx)
            # workers wrote their persistent-cache entries as atomic
            # shards; union them back into the main file(s) now
            for main, shard_list in sorted(shard_files.items()):
                merge_cache_shards(main, shard_list, metrics=reg)
            merged["collectives"] = coll
        else:
            sims: dict[str, Simulator] = {}
            if sim is not None:
                sims[sim.hw.name] = sim
            stats0 = {k: s.cache_stats() for k, s in sims.items()}
            timings: list = []
            scenario_r = _resolve_scenario(objective, scenario)
            eval_results = []
            for idx, spec, cand in todo:
                attempt = 1
                while True:
                    try:
                        res = _evaluate_one(
                            idx, spec, cand, sims, stats0, engine,
                            objective, scenario_r, persist, timings,
                            faults=faults, attempt=attempt)
                    except Exception as e:
                        if strict:
                            raise
                        reg.inc("pool.candidate_errors")
                        if attempt <= policy.max_retries:
                            attempt += 1
                            reg.inc("pool.retries")
                            continue
                        recf = FailedCandidate(
                            cand, spec, attempt,
                            f"{type(e).__name__}: {e}",
                            _compact_tb(traceback.format_exc()))
                        reg.inc("pool.quarantined")
                        failed.append(recf)
                        on_failed(recf)
                        break
                    eval_results.append((idx, res))
                    on_result(res, attempt)
                    break
            for _, phase, a, b in timings:
                reg.observe(f"sweep.eval_s.{phase}", b - a)
            _record_sweep_lanes(rec, t0, "worker0", timings,
                                dict(eval_results))
            if persist:
                for s in sims.values():
                    s.save_cache()
            deltas = [_stats_delta(s.cache_stats(), stats0.get(k, {}))
                      for k, s in sims.items()]
            merged = _merge_stats(deltas)
            coll1 = collective_memo_stats().as_dict()
            merged["collectives"] = {k: coll1[k] - coll0[k]
                                     for k in ("hits", "misses")}
    finally:
        if jr is not None:
            jr.close()

    wall = wall_s() - t0
    evaluated = []
    for _, res in sorted(eval_results + injected, key=lambda r: r[0]):
        (pruned if res.pruned else evaluated).append(res)
    # deterministic quarantine order regardless of which worker/attempt
    # recorded the failure
    failed.sort(key=lambda f: f.spec.json_hash())
    if progress:
        _progress_line(reg, len(items), t0, final=True)
    reg.set("sweep.n_groups", n_groups)
    reg.set("sweep.wall_s", round(wall, 6))
    reg.set("sweep.configs_per_sec",
            round(len(items) / wall, 4) if wall > 0 else 0.0)
    reg.update_nested(merged, prefix="sweep.cache")
    result = ExplorationResult(
        tuple(evaluated), tuple(pruned), wall, n_groups=n_groups,
        configs_per_sec=(len(items) / wall) if wall > 0 else 0.0,
        cache_stats=merged, objective=objective,
        workers=workers if pooled else 1,
        metrics=reg.snapshot(), failed=tuple(failed))
    if manifest:
        _write_manifest(manifest, space, result)
    return result

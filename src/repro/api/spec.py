"""Declarative simulation specs — the single typed entry surface.

Charon's headline claim is a *unified* simulator; this module is the unified
*API*: one frozen, hashable :class:`SimSpec` describes any simulation —
training, prefill, decode, or request-level serving — as

    SimSpec(model, cluster, parallel, workload)

* :class:`Cluster` — where it runs (hardware spec or registry name, chip
  budget, pods, per-device memory limit),
* :class:`ParallelConfig` (re-used from ``core.passes.base``) — how the model
  is sharded,
* a workload variant — what one step (or one request trace) looks like:
  :class:`TrainWorkload` / :class:`PrefillWorkload` / :class:`DecodeWorkload`
  for steady-state step simulation, :class:`ServingWorkload` for the
  discrete-event request-level simulator.

Every spec component is frozen and hashable, so a ``SimSpec`` *is* a cache
key (the simulator's serving bucket and the sweep reuse-grouping key both use
it directly) and any field can be a sweep axis (see ``repro.api.sweep``).

Entry points: ``Simulator.run(spec) -> Report`` and
``ServingSimulator.run(spec) -> ServingReport``.  The legacy kwargs surfaces
(``Simulator.simulate(...)``, ``explore(sim, cfg, tp_choices=...)``) survive
as thin shims that construct specs internally and emit
:class:`CharonDeprecationWarning` — they are for external users only; CI
escalates the warning to an error for intra-repo callers.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from dataclasses import dataclass, field
from typing import ClassVar

from repro.configs.base import ModelConfig
from repro.core.backend.hardware import HARDWARE, HardwareSpec, LinkDomain
from repro.core.passes.base import ParallelConfig


class CharonDeprecationWarning(DeprecationWarning):
    """Emitted by the legacy kwargs shims.  Intra-repo code must use the
    spec API; tests and benchmarks escalate this warning to an error."""


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Cluster:
    """Where a simulation runs.

    ``hardware`` accepts a registry name (``"tpu_v5e"``) or a
    :class:`HardwareSpec` instance; instances are normalized to their name
    for hashing/equality and kept for :meth:`resolve` (custom specs compare
    by name).  ``chips`` is the total chip budget a sweep distributes over
    data parallelism (0 = derived from the parallel config).  ``pods``
    defaults the parallel config's pod count when that is left at 1.
    ``memory_limit`` (bytes per device, 0 = unlimited) drives both the
    closed-form memory-fit pre-pruning and the post-simulation filter in
    sweeps.
    """
    hardware: str | HardwareSpec = "tpu_v5e"
    chips: int = 0
    pods: int = 1
    memory_limit: float = 0.0
    # derived: a custom HardwareSpec handed in via ``hardware``.  Kept as an
    # init field so dataclasses.replace carries it through non-hardware
    # changes (chips/pods/memory_limit on a custom cluster), but dropped the
    # moment a replace renames ``hardware`` — a stale spec never survives.
    _custom: HardwareSpec | None = field(default=None, repr=False,
                                         compare=False)

    def __post_init__(self):
        if isinstance(self.hardware, HardwareSpec):
            object.__setattr__(self, "_custom", self.hardware)
            object.__setattr__(self, "hardware", self.hardware.name)
        elif self._custom is not None and self._custom.name != self.hardware:
            object.__setattr__(self, "_custom", None)
        if self._custom is None and self.hardware not in HARDWARE:
            raise KeyError(
                f"unknown hardware {self.hardware!r}; registry has "
                f"{sorted(HARDWARE)} (or pass a HardwareSpec instance)")

    def resolve(self) -> HardwareSpec:
        return self._custom or HARDWARE[self.hardware]


# ---------------------------------------------------------------------------
# Resilience spec types: fault processes, checkpoint pricing, and the
# resilience scenario itself.  Frozen and hashable like every other spec
# component, so ``workload.resilience.ckpt.interval_steps`` is a sweep axis
# and a seeded fault model participates in cache keys / manifests for free.

@dataclass(frozen=True)
class FaultModel:
    """Seeded MTBF fault process per component class.

    Each ``*_mtbf_s`` is the mean time between failures of *one* component
    of that class, in seconds of simulated wall time; ``0`` (or ``inf``)
    disables the class entirely.  Component failures are independent renewal
    processes — exponential inter-arrivals by default, or Weibull with shape
    ``weibull_shape`` (``k < 1`` front-loads infant mortality) scaled so the
    mean stays at the configured MTBF.  The whole failure trace is a pure
    function of ``seed`` + component counts: it is sampled in wall-clock
    time, independent of the checkpoint schedule, so interval sweeps replay
    the *same* failures.
    """
    chip_mtbf_s: float = 0.0
    host_mtbf_s: float = 0.0
    link_mtbf_s: float = 0.0
    dist: str = "exponential"       # exponential | weibull
    weibull_shape: float = 0.7
    seed: int = 0

    def __post_init__(self):
        if self.dist not in ("exponential", "weibull"):
            raise ValueError(
                f"fault dist {self.dist!r} not in ('exponential', 'weibull')")
        if self.dist == "weibull" and self.weibull_shape <= 0:
            raise ValueError("weibull_shape must be positive")
        for name in ("chip_mtbf_s", "host_mtbf_s", "link_mtbf_s"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0 (0 disables)")

    @property
    def active(self) -> bool:
        """True when any component class can actually fail."""
        return any(0 < m < math.inf for m in
                   (self.chip_mtbf_s, self.host_mtbf_s, self.link_mtbf_s))


@dataclass(frozen=True)
class CheckpointSpec:
    """How (and how often) training state is checkpointed.

    Save cost is priced from the memory report's per-device state bytes
    (weights + optimizer state) over ``write_gbps``; ``write_gbps = 0``
    derives the per-device write bandwidth from the cluster's inter-host
    link (``hw.inter.bandwidth``).  ``mode="sync"`` stalls the full save on
    the step boundary; ``mode="async"`` stalls only
    ``async_overhead x save_s`` (the device-to-host snapshot) and the
    checkpoint becomes *durable* ``save_s`` later — a failure while the
    write is in flight falls back to the previous durable checkpoint.
    ``restore_s = restore_factor x save_s``.
    """
    interval_steps: int = 0         # checkpoint every N steps; 0 = never
    mode: str = "sync"              # sync | async
    write_gbps: float = 0.0         # GB/s per device; 0 = derive from hw
    restore_factor: float = 1.0
    async_overhead: float = 0.05

    def __post_init__(self):
        if self.interval_steps < 0:
            raise ValueError("interval_steps must be >= 0 (0 = never)")
        if self.mode not in ("sync", "async"):
            raise ValueError(f"ckpt mode {self.mode!r} not in "
                             "('sync', 'async')")
        if self.write_gbps < 0 or self.restore_factor < 0:
            raise ValueError("write_gbps / restore_factor must be >= 0")
        if not 0 <= self.async_overhead <= 1:
            raise ValueError("async_overhead must be in [0, 1]")


@dataclass(frozen=True)
class ResilienceSpec:
    """A resilience scenario: run ``total_steps`` training steps against a
    seeded fault process, pricing checkpoints, restarts, elastic resharding
    and stragglers.  Attach to ``TrainWorkload.resilience`` and run through
    ``repro.resilience.ResilienceSimulator`` — the plain step simulation is
    untouched (``resilience`` never reaches ``sim_kwargs``), so an inactive
    fault model reproduces the failure-free report bit-for-bit.

    ``chips_per_host`` maps the parallel config's chip count onto failure
    domains (a host failure takes all its chips).  ``spares`` are warm
    standby hosts consumed before the mesh degrades; with ``elastic`` the
    mesh then shrinks dp via ``ElasticPlan.rescale`` (re-priced through the
    step oracle), otherwise the run stalls until a repair completes
    (``repair_s`` per host).  Stragglers: each host each step is slowed by
    ``U(1, straggler_mult)`` with probability ``straggler_prob``; a
    gang-synchronized step costs the max over hosts.
    """
    total_steps: int = 1000
    faults: FaultModel = FaultModel()
    ckpt: CheckpointSpec = CheckpointSpec()
    chips_per_host: int = 8
    spares: int = 0
    elastic: bool = True
    restart_delay_s: float = 60.0   # detection + reschedule + re-init
    repair_s: float = 1800.0        # failed host returns as a spare after
    straggler_prob: float = 0.0     # per host, per step
    straggler_mult: float = 1.0     # max slowdown multiplier
    optimize_interval: bool = True  # also replay a grid around Young/Daly
    max_wall_factor: float = 1000.0  # divergence guard (x ideal wall time)

    def __post_init__(self):
        if self.total_steps < 1:
            raise ValueError("total_steps must be >= 1")
        if self.chips_per_host < 1:
            raise ValueError("chips_per_host must be >= 1")
        if self.spares < 0:
            raise ValueError("spares must be >= 0")
        if not 0 <= self.straggler_prob <= 1:
            raise ValueError("straggler_prob must be in [0, 1]")
        if self.straggler_mult < 1:
            raise ValueError("straggler_mult must be >= 1")
        if self.restart_delay_s < 0 or self.repair_s < 0:
            raise ValueError("restart_delay_s / repair_s must be >= 0")


# ---------------------------------------------------------------------------
# Workload variants.  ``mode`` is a real (init=False) field so it survives
# ``dataclasses.asdict`` round-trips and discriminates reconstruction.

@dataclass(frozen=True)
class _StepWorkload:
    """Shared shape of one steady-state simulated step."""
    global_batch: int = 8
    seq_len: int = 2048
    cache_len: int = 0              # 0 -> seq_len where a KV cache exists
    fusion: bool = False
    quantize: str | None = None     # None | "int8" | "f8" (QuantizePass)

    def sim_kwargs(self) -> dict:
        """The exact legacy ``Simulator.simulate`` kwargs this spec means —
        the one translation point between the spec and kwargs surfaces."""
        return dict(mode=self.mode, global_batch=self.global_batch,
                    seq_len=self.seq_len, cache_len=self.cache_len,
                    fusion=self.fusion, quantize=self.quantize,
                    remat=getattr(self, "remat", "none"),
                    optimizer=getattr(self, "optimizer", "adamw"))


@dataclass(frozen=True)
class TrainWorkload(_StepWorkload):
    mode: str = field(default="train", init=False)
    remat: str = "block"            # none | block | dots
    optimizer: str = "adamw"        # adamw | adafactor
    # resilience scenario (None = plain failure-free step simulation).
    # Deliberately excluded from sim_kwargs(): step pricing is identical
    # with or without it, only ResilienceSimulator consumes it.
    resilience: ResilienceSpec | None = None


@dataclass(frozen=True)
class PrefillWorkload(_StepWorkload):
    mode: str = field(default="prefill", init=False)


@dataclass(frozen=True)
class DecodeWorkload(_StepWorkload):
    """One decode iteration: ``global_batch`` sequences at context
    ``seq_len`` (``cache_len`` overrides the KV-cache depth)."""
    mode: str = field(default="decode", init=False)


# ---------------------------------------------------------------------------
# Fleet spec types: replica pools, routing, autoscaling — frozen and hashable
# like every other spec component, so ``workload.fleet.replicas`` is a sweep
# axis and a fleet spec participates in cache keys / manifests for free.

@dataclass(frozen=True)
class RouterSpec:
    """Which replica an arriving request lands on.

    ``kind``: ``round_robin`` (arrival order — with a fixed fleet this is
    exactly ``Workload.shard``), ``least_loaded`` (fewest in-flight
    requests), or ``session_affinity`` (rendezvous-hash requests of one
    session onto one replica, keeping its prompt prefix warm in that
    replica's cache; sessionless requests use ``fallback``).
    """
    kind: str = "round_robin"
    fallback: str = "least_loaded"  # session_affinity's sessionless policy

    def __post_init__(self):
        kinds = ("round_robin", "least_loaded", "session_affinity")
        if self.kind not in kinds:
            raise ValueError(f"router kind {self.kind!r} not in {kinds}")
        if self.fallback not in kinds or self.fallback == "session_affinity":
            raise ValueError(
                f"router fallback {self.fallback!r} must be one of "
                "('round_robin', 'least_loaded')")


@dataclass(frozen=True)
class AutoscalerSpec:
    """Queue-depth autoscaling with hysteresis.

    Every ``interval_s`` of simulated time the mean in-flight depth over
    active replicas is sampled; above ``scale_up_queue`` a standby replica
    activates (taking traffic ``provision_s`` later), below
    ``scale_down_queue`` the least-loaded active replica deactivates (it
    drains what it holds, so no request is ever dropped).  The up/down gap
    plus ``cooldown_s`` between actions is the hysteresis.
    """
    min_replicas: int = 1
    max_replicas: int = 8
    scale_up_queue: float = 8.0
    scale_down_queue: float = 1.0
    interval_s: float = 2.0
    cooldown_s: float = 4.0
    provision_s: float = 5.0

    def __post_init__(self):
        if not 1 <= self.min_replicas <= self.max_replicas:
            raise ValueError(
                f"need 1 <= min_replicas={self.min_replicas} <= "
                f"max_replicas={self.max_replicas}")
        if self.scale_down_queue >= self.scale_up_queue:
            raise ValueError(
                f"scale_down_queue={self.scale_down_queue} must be below "
                f"scale_up_queue={self.scale_up_queue} (the hysteresis gap)")
        if self.interval_s <= 0:
            raise ValueError("interval_s must be positive")


@dataclass(frozen=True)
class ReplicaFaultSpec:
    """Seeded whole-replica failure injection for the fleet simulator.

    Each replica fails as an independent renewal process with mean
    ``mtbf_s`` (``0``/``inf`` disables) and recovers ``restart_s`` later.
    On failure the replica's in-flight and queued requests are rerouted
    through the fleet router (progress on the failed replica is lost — the
    requests re-prefill elsewhere); the autoscaler never activates a
    replica that is currently down.  The trace is a pure function of
    ``seed`` + replica index, so reports are bit-deterministic.
    """
    mtbf_s: float = 0.0
    restart_s: float = 30.0
    dist: str = "exponential"       # exponential | weibull
    weibull_shape: float = 0.7
    seed: int = 0

    def __post_init__(self):
        if self.mtbf_s < 0:
            raise ValueError("mtbf_s must be >= 0 (0 disables)")
        if self.restart_s < 0:
            raise ValueError("restart_s must be >= 0")
        if self.dist not in ("exponential", "weibull"):
            raise ValueError(
                f"fault dist {self.dist!r} not in ('exponential', 'weibull')")
        if self.dist == "weibull" and self.weibull_shape <= 0:
            raise ValueError("weibull_shape must be positive")

    @property
    def active(self) -> bool:
        return 0 < self.mtbf_s < math.inf


@dataclass(frozen=True)
class FleetSpec:
    """A replica fleet: how many engine instances, routed and scaled how.

    ``replicas`` engine instances run the workload's policy behind
    ``router``; a non-None ``autoscaler`` turns ``replicas`` into the
    *initial* active count (clamped to its [min, max]) with standbys up to
    ``max_replicas``.  ``prefill_replicas > 0`` disaggregates at the fleet
    level: arrivals prefill on that many dedicated prefill replicas
    (admission ``prefill_batch``), then migrate — paying ``transfer_s`` of
    KV-transfer latency — to the least-loaded decode replica.

    The default is :meth:`trivial`: exactly the single-replica simulator,
    so every existing serving spec is already a fleet spec.
    """
    replicas: int = 1
    router: RouterSpec = RouterSpec()
    autoscaler: AutoscalerSpec | None = None
    prefill_replicas: int = 0
    prefill_batch: int = 4
    transfer_s: float = 0.002
    faults: ReplicaFaultSpec | None = None

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.prefill_replicas < 0:
            raise ValueError("prefill_replicas must be >= 0")
        if self.prefill_replicas > 0 and self.prefill_batch < 1:
            raise ValueError("prefill_batch must be >= 1")

    @property
    def trivial(self) -> bool:
        """True when this fleet is exactly one plain replica — the single-
        replica event loop handles it without the fleet layer."""
        return (self.replicas == 1 and self.prefill_replicas == 0
                and self.autoscaler is None and self.faults is None)


def _default_prompt():
    from repro.serving.sim.workload import LengthDist
    return LengthDist("lognormal", median=512.0, sigma=0.7, cap=4096)


def _default_output():
    from repro.serving.sim.workload import LengthDist
    return LengthDist("lognormal", median=128.0, sigma=0.7, cap=1024)


def _default_slo():
    from repro.serving.sim.report import SLO
    return SLO()


@dataclass(frozen=True)
class ServingWorkload:
    """A request-level trace spec for the discrete-event serving simulator.

    Carries the arrival process, length distributions, SLO and batching
    policy in frozen hashable form — the trace itself is synthesized
    deterministically from ``seed`` by :meth:`build` (or replayed from
    ``trace`` rows when given).  ``max_batch`` is the policy's admission
    cap; in goodput sweeps the candidate's per-replica batch overrides it.
    """
    mode: str = field(default="serving", init=False)
    n_requests: int = 200
    arrival: str = "poisson"        # poisson | uniform | bursty
                                    # | diurnal | flash_crowd
    rate_rps: float = 8.0
    burst_factor: float = 4.0
    switch_prob: float = 0.1
    period_s: float = 600.0         # diurnal: one day, compressed
    diurnal_amp: float = 0.8        # diurnal: rate swings rate*(1 +/- amp)
    flash_start_s: float = 60.0     # flash_crowd: spike onset
    flash_dur_s: float = 30.0       # flash_crowd: spike duration
    flash_mult: float = 8.0         # flash_crowd: rate multiplier in spike
    sessions: int = 0               # >0: tag requests with session ids
    prompt: object = field(default_factory=_default_prompt)    # LengthDist
    output: object = field(default_factory=_default_output)    # LengthDist
    seed: int = 0
    trace: tuple = ()               # ((arrival_s, prompt, output), ...) rows
    slo: object = field(default_factory=_default_slo)          # SLO
    policy: str = "continuous"      # continuous | chunked | static
    max_batch: int = 32
    token_budget: int = 256         # chunked-prefill budget
    ctx_floor: int = 256            # oracle context-bucket floor
    fleet: FleetSpec = FleetSpec()  # replica pool / router / autoscaler

    def build(self):
        """Materialize the deterministic request trace (a ``Workload``)."""
        from repro.serving.sim.workload import Workload, synthesize
        if self.trace:
            return Workload.from_trace(self.trace)
        return synthesize(self.n_requests, arrival=self.arrival,
                          rate_rps=self.rate_rps,
                          burst_factor=self.burst_factor,
                          switch_prob=self.switch_prob,
                          period_s=self.period_s,
                          diurnal_amp=self.diurnal_amp,
                          flash_start_s=self.flash_start_s,
                          flash_dur_s=self.flash_dur_s,
                          flash_mult=self.flash_mult,
                          sessions=self.sessions, prompt=self.prompt,
                          output=self.output, seed=self.seed)

    def make_policy(self, max_batch: int | None = None):
        from repro.serving.sim.policies import make_policy
        return make_policy(self.policy, max_batch or self.max_batch,
                           token_budget=self.token_budget)

    def scenario(self):
        """The explorer-facing view: a :class:`ServingScenario` whose
        per-candidate admission cap is the candidate's replica batch."""
        from repro.serving.sim.sim import ServingScenario
        return ServingScenario(self.build(), slo=self.slo, policy=self.policy,
                               token_budget=self.token_budget,
                               ctx_floor=self.ctx_floor,
                               fleet=None if self.fleet.trivial
                               else self.fleet)


STEP_WORKLOADS = {"train": TrainWorkload, "prefill": PrefillWorkload,
                  "decode": DecodeWorkload}


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SimSpec:
    """One fully-specified simulation.  Frozen and hashable: equal specs
    mean bit-identical simulations, so a spec can serve as a cache key."""
    model: ModelConfig
    cluster: Cluster = Cluster()
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    workload: object = field(default_factory=TrainWorkload)

    def __post_init__(self):
        # a pods-bearing cluster defaults the parallel config's pod count
        if self.cluster.pods > 1 and self.parallel.pods == 1:
            object.__setattr__(self, "parallel", dataclasses.replace(
                self.parallel, pods=self.cluster.pods))
        elif self.cluster.pods > 1 and self.parallel.pods != self.cluster.pods:
            raise ValueError(
                f"cluster.pods={self.cluster.pods} conflicts with "
                f"parallel.pods={self.parallel.pods}")

    def __hash__(self):
        # memoized: specs are cache keys on hot paths (the serving oracle
        # probes the SimCache once per engine step) and every component is
        # immutable by contract, so the nested hash is computed once
        h = getattr(self, "_hash", None)
        if h is None:
            h = hash((self.model, self.cluster, self.parallel, self.workload))
            object.__setattr__(self, "_hash", h)
        return h

    def __getstate__(self):
        # string hashes are salted per process: a pickled memo would poison
        # dict lookups in the loading process (persistent SimCache tier)
        d = dict(self.__dict__)
        d.pop("_hash", None)
        return d

    # ------------------------------------------------------------------
    @property
    def mode(self) -> str:
        return self.workload.mode

    def B_local(self) -> int:
        """Per-replica batch after the data-parallel split.  Serving specs
        have no global batch; the policy's admission cap plays that role."""
        if self.mode == "serving":
            return self.workload.max_batch
        dp = max(self.parallel.dp * self.parallel.pods, 1)
        return max(self.workload.global_batch // dp, 1)

    def trace_shapes(self) -> tuple:
        """``(B_local, seq, cache)`` as the simulator's ingest stage sees
        them — the shape part of the traced-graph identity.  Single source
        of truth for :meth:`reuse_key` and the sweep's worker sharding
        (``repro.api.sweep._shard_items``): the two must agree or workers
        duplicate JAX traces.  A serving spec prices many bucketed shapes
        through its oracle; the admission cap and context floor bound that
        bucket family, so they stand in as its shape identity."""
        w = self.workload
        if w.mode == "serving":
            return (w.max_batch, w.ctx_floor, -1)
        seq = w.seq_len if w.mode != "decode" else 1
        cache = w.cache_len or (w.seq_len if w.mode == "decode" else 0)
        return (self.B_local(), seq, cache)

    def reuse_key(self) -> tuple:
        """Specs with equal reuse keys share traced/transformed/priced block
        graphs inside one simulator — the sweep sorts candidates by this key
        so each group pays the expensive stages once (``shard_key`` leads so
        legacy tp/pp/batch sweeps keep their historical evaluation order)."""
        w = self.workload
        remat = getattr(w, "remat", "none") if w.mode == "train" else "none"
        return (self.cluster.hardware, self.model.name, w.mode,
                self.parallel.shard_key()) + self.trace_shapes() + (
                getattr(w, "fusion", False), getattr(w, "quantize", None)
                or "", remat)

    # ------------------------------------------------------------------
    def asdict(self) -> dict:
        """Nested plain-dict form (tuples preserved); inverse of
        :meth:`from_dict`."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SimSpec":
        cl = dict(d["cluster"])
        custom = cl.pop("_custom", None)
        if custom is not None:          # non-registry hardware: rebuild it
            custom = dict(custom)
            custom["intra"] = LinkDomain(**custom["intra"])
            custom["inter"] = LinkDomain(**custom["inter"])
            cl["hardware"] = HardwareSpec(**custom)
        w = dict(d["workload"])
        mode = w.pop("mode")
        if mode == "serving":
            from repro.serving.sim.report import SLO
            from repro.serving.sim.workload import LengthDist
            w["prompt"] = LengthDist(**w["prompt"])
            w["output"] = LengthDist(**w["output"])
            w["slo"] = SLO(**w["slo"])
            fl = dict(w.get("fleet") or {})
            if fl:
                fl["router"] = RouterSpec(**fl.get("router", {}))
                scaler = fl.get("autoscaler")
                fl["autoscaler"] = (AutoscalerSpec(**scaler)
                                    if scaler is not None else None)
                faults = fl.get("faults")
                fl["faults"] = (ReplicaFaultSpec(**faults)
                                if faults is not None else None)
                w["fleet"] = FleetSpec(**fl)
            workload = ServingWorkload(**w)
        else:
            res = w.get("resilience")
            if res is not None:
                res = dict(res)
                res["faults"] = FaultModel(**res["faults"])
                res["ckpt"] = CheckpointSpec(**res["ckpt"])
                w["resilience"] = ResilienceSpec(**res)
            workload = STEP_WORKLOADS[mode](**w)
        return cls(model=ModelConfig(**d["model"]), cluster=Cluster(**cl),
                   parallel=ParallelConfig(**d["parallel"]),
                   workload=workload)

    # ------------------------------------------------------------------
    def to_json(self) -> str:
        """Stable JSON form: sorted keys, compact separators, tuples as
        arrays.  ``from_json(to_json())`` rebuilds an equal spec with an
        equal hash, so the string (and :meth:`json_hash`) can serve as a
        cross-process cache key, a sweep-manifest row, or a result
        provenance record."""
        return json.dumps(self.asdict(), sort_keys=True,
                          separators=(",", ":"))

    def json_hash(self) -> str:
        """sha256 hex digest of :meth:`to_json` — the persistent SimCache's
        report key (stable across processes, unlike ``hash()``)."""
        return hashlib.sha256(self.to_json().encode()).hexdigest()

    @classmethod
    def from_json(cls, s: str) -> "SimSpec":
        """Inverse of :meth:`to_json` (hash-preserving round trip)."""
        d = json.loads(s)
        # JSON has no tuples: restore the fields whose types (and therefore
        # the spec's hash) depend on them
        m = d.get("model", {})
        if "block_pattern" in m:
            m["block_pattern"] = tuple(m["block_pattern"])
        w = d.get("workload", {})
        if "trace" in w:
            w["trace"] = tuple(tuple(row) for row in w["trace"])
        return cls.from_dict(d)

    @staticmethod
    def from_legacy(cfg: ModelConfig, hw, *, mode: str = "train",
                    global_batch: int = 8, seq_len: int = 2048,
                    par: ParallelConfig | None = None, remat: str = "block",
                    optimizer: str = "adamw", fusion: bool = False,
                    quantize: str | None = None,
                    cache_len: int = 0) -> "SimSpec":
        """Translate the legacy ``simulate()`` kwargs surface into a spec.

        ``remat``/``optimizer`` only shape train workloads — for prefill and
        decode the legacy simulator never consumed them (no RecomputePass,
        no optimizer step), so dropping them preserves bit-identity.
        """
        kw = dict(global_batch=global_batch, seq_len=seq_len,
                  cache_len=cache_len, fusion=fusion, quantize=quantize)
        if mode == "train":
            kw.update(remat=remat, optimizer=optimizer)
        return SimSpec(model=cfg, cluster=Cluster(hw),
                       parallel=par or ParallelConfig(),
                       workload=STEP_WORKLOADS[mode](**kw))

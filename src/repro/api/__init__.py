"""Typed spec API: one declarative surface for train, inference and serving.

    from repro.api import (Cluster, DecodeWorkload, SimSpec, SweepSpace,
                           TrainWorkload, sweep)

    spec = SimSpec(model=cfg, cluster=Cluster("tpu_v5e"),
                   parallel=ParallelConfig(tp=16, dp=16),
                   workload=TrainWorkload(global_batch=256, seq_len=4096))
    report = Simulator("tpu_v5e").run(spec)

See ``docs/api.md`` for the full surface and the legacy-kwargs migration
table.
"""
from repro.api.spec import (
    STEP_WORKLOADS, AutoscalerSpec, CharonDeprecationWarning, CheckpointSpec,
    Cluster, DecodeWorkload, FaultModel, FleetSpec, PrefillWorkload,
    ReplicaFaultSpec, ResilienceSpec, RouterSpec, ServingWorkload, SimSpec,
    TrainWorkload,
)
from repro.api.sweep import SweepSpace, spec_replace, sweep

__all__ = [
    "STEP_WORKLOADS", "AutoscalerSpec", "CharonDeprecationWarning",
    "CheckpointSpec", "Cluster", "DecodeWorkload", "FaultModel", "FleetSpec",
    "PrefillWorkload", "ReplicaFaultSpec", "ResilienceSpec", "RouterSpec",
    "ServingWorkload", "SimSpec", "TrainWorkload",
    "SweepSpace", "spec_replace", "sweep",
]

"""Crash-safe, long-lived worker pool for design-space sweeps.

``sweep(workers=N)`` used to spin up a fresh ``ProcessPoolExecutor`` per
call: every worker paid ~2 s of spawn + jax import before pricing its first
candidate (ROADMAP item 4a — parallel sweeps were 5x *slower* than serial),
and a single worker crash, hang or poison candidate took the whole sweep
down with no partial results.  This module replaces that with a pool built
for sweep-scale robustness:

* **long-lived** — :func:`get_pool` returns a process-wide singleton keyed
  by (workers, context); worker processes survive across ``sweep()`` calls,
  so the jax import is paid once and worker-local simulator caches stay
  warm between sweeps (the steady-state throughput win);
* **fork where safe** — the default context is ``fork`` when the platform
  offers it (workers inherit the parent's already-imported jax at zero
  cost) with ``spawn`` as the fallback; pass ``mp_context=`` to override;
* **per-candidate execution contracts** — each candidate is dispatched as
  its own task with a wall-clock timeout; workers send ``started`` markers,
  results, and daemon-thread heartbeats, so the parent can tell a slow
  candidate from a dead or wedged worker;
* **bounded retry + quarantine** — a candidate whose worker died, timed
  out, or raised is retried with exponential backoff up to
  ``RetryPolicy.max_retries`` times on a respawned worker; a candidate
  that exhausts its attempts is *quarantined* — recorded as a
  :class:`~repro.core.explorer.FailedCandidate` (``status: failed`` in
  manifests) instead of aborting the sweep;
* **journaled results** — :class:`SweepJournal` appends one fsync'd JSONL
  row per finished candidate, so ``sweep(..., resume=journal)`` skips
  completed work after a process kill;
* **cache write-back** — on completion each worker writes its persistent
  cache tier as an atomic per-worker shard, merged (and corruption-
  quarantined) by :func:`repro.core.simulator.merge_cache_shards`;
* **per-incarnation channels** — each spawn gets a fresh task queue and a
  private result pipe.  A shared ``mp.Queue`` is *not* crash-safe: its
  writes happen on a feeder thread under a cross-process semaphore, and a
  worker SIGKILLed (or ``os._exit``-ing) mid-write leaves that semaphore
  acquired forever, silently wedging every other worker and every respawn
  sharing the channel — observed as cascading timeouts and spurious
  quarantines under chaos testing.  Private pipes make sends synchronous
  in the calling thread, scope any poisoned state to the incarnation that
  dies with it, and give the parent EOF as a prompt death signal.

The headline contract (tests/test_pool_robustness.py): results, rankings
and pruned reasons are **bit-identical to the serial sweep** — under any
injected :class:`~repro.analysis.chaos.FaultPlan` schedule that doesn't
exhaust a candidate's retries.  The pool owns *execution* only; every
simulated number comes from the same ``_evaluate_one`` code path serial
sweeps run.

Not in charon-lint's R2 determinism scope: liveness math (timeouts,
heartbeat staleness, backoff deadlines) is wall-clock by nature — it uses
the sanctioned :func:`repro.obs.clock.wall_s` epoch clock throughout so
worker-side timestamps remain comparable with the parent's.
"""
from __future__ import annotations

import atexit
import os
import pickle
import queue as queue_mod
import signal
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass
from multiprocessing.connection import wait as _conn_wait

from repro.core.explorer import FailedCandidate
from repro.obs.clock import wall_s


@dataclass(frozen=True)
class RetryPolicy:
    """The per-candidate execution contract.

    ``max_retries`` is the number of *re*-attempts after the first try; a
    candidate is quarantined after ``max_retries + 1`` failed attempts.
    Backoff before attempt ``n`` is ``min(backoff_s * 2**(n-2),
    backoff_max_s)`` seconds.  ``timeout_s`` bounds one attempt's wall
    clock (measured from dispatch, so a worker stuck importing or hung
    mid-candidate both trip it).  A worker whose heartbeat goes silent for
    ``miss_heartbeats * heartbeat_s`` while a task is in flight is treated
    as dead even if the OS still reports the process alive."""
    max_retries: int = 2
    timeout_s: float = 120.0
    backoff_s: float = 0.05
    backoff_max_s: float = 2.0
    heartbeat_s: float = 0.25
    miss_heartbeats: int = 120

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.timeout_s <= 0 or self.heartbeat_s <= 0:
            raise ValueError("timeout_s and heartbeat_s must be positive")

    def backoff_for(self, attempt: int) -> float:
        """Seconds to wait before dispatching attempt ``attempt`` (>= 2)."""
        return min(self.backoff_s * 2.0 ** max(attempt - 2, 0),
                   self.backoff_max_s)


class CandidateFailedError(RuntimeError):
    """Raised by ``sweep(..., strict=True)`` when a candidate exhausts its
    execution contract: carries the :class:`FailedCandidate` record."""

    def __init__(self, failed: FailedCandidate):
        self.failed = failed
        super().__init__(
            f"candidate {getattr(failed.spec, 'json_hash', lambda: '?')()[:12]}"
            f" failed after {failed.attempts} attempt(s): {failed.reason}")


# --------------------------------------------------------------------------
# worker process
# --------------------------------------------------------------------------

def _worker_main(wid: int, seq: int, task_q, wconn, parent_pid: int,
                 heartbeat_s: float) -> None:
    """Worker loop: apply ``begin`` sweep configs, evaluate ``task``s with
    process-local simulators (kept warm across sweeps — the pool's point),
    answer ``flush`` with cache-stat deltas + persistent-cache shards.

    Robustness details: SIGINT is ignored (the parent owns Ctrl-C and
    shuts the pool down); a daemon heartbeat thread beats even while the
    main thread evaluates; the task-get timeout doubles as an orphan check
    (``getppid`` changes when the parent is SIGKILLed — exit instead of
    lingering).  Results go over ``wconn``, this incarnation's private
    pipe: ``Connection.send`` writes the whole frame synchronously in the
    calling thread (no feeder thread), so dying right after a send can
    never strand a half-written message, and dying mid-send poisons only
    a pipe that is discarded with this incarnation."""
    signal.signal(signal.SIGINT, signal.SIG_IGN)

    stop = threading.Event()
    send_lock = threading.Lock()            # beat + main thread share wconn

    def _send(msg) -> bool:
        try:
            with send_lock:
                wconn.send(msg)
            return True
        except (OSError, ValueError):
            return False                    # parent gone (or seat retired)

    def _beat() -> None:
        while not stop.is_set():
            if not _send(("hb", wid, seq, wall_s())):
                return                      # parent gone: let the loop exit
            stop.wait(heartbeat_s)

    threading.Thread(target=_beat, daemon=True).start()

    # the one-time heavy import (jax via the simulator stack); under fork
    # this is inherited from the parent and effectively free
    from repro.core.backend.collectives import collective_memo_stats
    from repro.api.sweep import (
        _evaluate_one, _merge_stats, _resolve_scenario,
    )
    from repro.core.explorer import _stats_delta

    # simulators stay warm across sweeps, but only for the same (engine,
    # persist) configuration — a sweep pricing with a different engine or
    # cache dir must never reuse a simulator built for another
    sims_by_cfg: dict = {}
    sims: dict = {}
    stats0: dict = {}
    coll0: dict = {}
    cfg: dict = {}

    while True:
        if os.getppid() != parent_pid:
            os._exit(0)                     # orphaned by a killed parent
        try:
            msg = task_q.get(timeout=0.5)
        except queue_mod.Empty:
            continue
        kind = msg[0]
        if kind == "stop":
            stop.set()
            os._exit(0)
        if kind == "begin":
            _, engine, objective, scenario, persist, faults, shard_tag = msg
            cfg = {"engine": engine, "objective": objective,
                   "scenario": _resolve_scenario(objective, scenario),
                   "persist": persist, "faults": faults,
                   "shard_tag": shard_tag}
            sims = sims_by_cfg.setdefault((engine, persist), {})
            # warm sims carry counters from previous sweeps: re-baseline
            stats0 = {k: s.cache_stats() for k, s in sims.items()}
            coll0 = collective_memo_stats().as_dict()
            continue
        if kind == "task":
            _, task_id, idx, spec, cand, attempt = msg
            faults = cfg.get("faults")
            h = spec.json_hash()
            # injected crash: after "started" so the parent attributes the
            # death to this candidate exactly like a real mid-eval segfault
            if not _send(("started", wid, seq, task_id, wall_s())):
                os._exit(0)
            if faults is not None and faults.should(
                    "worker_crash", (h,), attempt):
                os._exit(137)
            if faults is not None and faults.should(
                    "worker_hang", (h,), attempt):
                time.sleep(faults.hang_s)   # parent's timeout kills us
            timings: list = []
            try:
                res = _evaluate_one(
                    idx, spec, cand, sims, stats0, cfg["engine"],
                    cfg["objective"], cfg["scenario"], cfg["persist"],
                    timings, faults=faults, attempt=attempt)
                if not _send(("done", wid, seq, task_id, idx, res,
                              timings)):
                    os._exit(0)
            except Exception as e:
                tb = traceback.format_exc(limit=8)
                if not _send(("failed", wid, seq, task_id, idx,
                              f"{type(e).__name__}: {e}", tb)):
                    os._exit(0)
            continue
        if kind == "flush":
            deltas = [_stats_delta(s.cache_stats(), stats0.get(k, {}))
                      for k, s in sims.items()]
            coll1 = collective_memo_stats().as_dict()
            coll = {k: coll1[k] - coll0.get(k, 0)
                    for k in ("hits", "misses")}
            shards: list = []
            faults = cfg.get("faults")
            if cfg.get("persist"):
                for s in sims.values():
                    p = s.save_cache_shard(cfg.get("shard_tag") or "sweep")
                    if p is None:
                        continue
                    if faults is not None and faults.should(
                            "cache_corrupt", (s.cache.persist_path.name,
                                              wid)):
                        from repro.analysis.chaos import corrupt_shard
                        corrupt_shard(str(p))
                    shards.append((str(s.cache.persist_path), str(p)))
            if not _send(("flushed", wid, seq, _merge_stats(deltas), coll,
                          shards)):
                os._exit(0)


# --------------------------------------------------------------------------
# parent-side pool
# --------------------------------------------------------------------------

class _Task:
    __slots__ = ("task_id", "idx", "spec", "cand", "attempt", "dispatched",
                 "started")

    def __init__(self, task_id, idx, spec, cand):
        self.task_id = task_id
        self.idx = idx
        self.spec = spec
        self.cand = cand
        self.attempt = 1
        self.dispatched = 0.0
        self.started = 0.0


class _Slot:
    """One worker seat: a process (respawned in place on death), its task
    queue and result pipe, a monotonically increasing spawn ``seq``
    (stale-message guard), its parent-side pending work and in-flight
    task."""
    __slots__ = ("wid", "proc", "task_q", "rconn", "seq", "last_hb",
                 "inflight", "pending", "retry_at", "flushed")

    def __init__(self, wid: int):
        self.wid = wid
        self.proc = None
        self.task_q = None
        self.rconn = None                    # parent end of the result pipe
        self.seq = 0
        self.last_hb = 0.0
        self.inflight: _Task | None = None
        self.pending: deque = deque()
        self.retry_at = 0.0                  # backoff deadline for pending[0]
        self.flushed = None


class WorkerPool:
    """A crash-tolerant pool of long-lived sweep evaluation processes.

    Use :func:`get_pool` rather than constructing directly — reuse across
    ``sweep()`` calls is where the spawn/import amortization comes from.
    """

    def __init__(self, workers: int, mp_context: str | None = None,
                 heartbeat_s: float = 0.25):
        import multiprocessing as mp
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.workers = int(workers)
        self.context_name = mp_context or default_context()
        self.heartbeat_s = heartbeat_s
        self._ctx = mp.get_context(self.context_name)
        self._slots = [_Slot(i) for i in range(self.workers)]
        self._next_task_id = 0
        self._closed = False
        # re-sent to seats respawned mid-sweep; run() refreshes it
        self._begin_msg: tuple = ("begin", "analytical", "step_time", None,
                                  None, None, "sweep")
        for s in self._slots:
            self._spawn(s)

    # -------------------------------------------------- lifecycle
    @property
    def alive(self) -> bool:
        return not self._closed and any(
            s.proc is not None and s.proc.is_alive() for s in self._slots)

    def _spawn(self, slot: _Slot) -> None:
        """(Re)start a worker seat with fresh channels and a new seq.

        Both the task queue and the result pipe are **per-incarnation**: a
        worker killed mid-message (injected crash, timeout SIGKILL) can
        leave a shared multiprocessing channel's write/read semaphore
        permanently acquired — the holder's death never releases a POSIX
        semaphore — which would wedge every worker and every later
        incarnation on the same channel.  Rebuilding the channels at spawn
        means a poisoned lock dies with the incarnation that poisoned it.
        Any message still in flight from the previous incarnation is
        dropped by the seq guard (and can't even arrive once the old pipe
        is closed)."""
        slot.seq += 1
        slot.task_q = self._ctx.Queue()
        if slot.rconn is not None:
            try:
                slot.rconn.close()
            except OSError:
                pass
        rconn, wconn = self._ctx.Pipe(duplex=False)
        slot.rconn = rconn
        slot.last_hb = wall_s()
        slot.proc = self._ctx.Process(
            target=_worker_main,
            args=(slot.wid, slot.seq, slot.task_q, wconn,
                  os.getpid(), self.heartbeat_s),
            daemon=True, name=f"charon-sweep-w{slot.wid}")
        slot.proc.start()
        # drop the parent's copy of the write end: the child then holds the
        # only one, so its death (however abrupt) delivers EOF on rconn
        wconn.close()

    def _revive(self, slot: _Slot) -> None:
        """Respawn a dead seat mid-sweep: the fresh incarnation missed the
        sweep's ``begin``, so re-send it before any task."""
        if slot.proc is not None and slot.proc.is_alive():
            return
        self._spawn(slot)
        slot.task_q.put(self._begin_msg)

    def _kill(self, slot: _Slot) -> None:
        if slot.proc is not None and slot.proc.is_alive():
            slot.proc.kill()
            slot.proc.join(timeout=5.0)

    def shutdown(self) -> None:
        if self._closed:
            return
        self._closed = True
        for s in self._slots:
            try:
                s.task_q.put(("stop",))
            except Exception:
                pass
        deadline = wall_s() + 2.0
        for s in self._slots:
            if s.proc is not None:
                s.proc.join(timeout=max(deadline - wall_s(), 0.1))
                if s.proc.is_alive():
                    s.proc.kill()
            if s.rconn is not None:
                try:
                    s.rconn.close()
                except OSError:
                    pass
                s.rconn = None

    def _reset_all(self) -> None:
        """Abort path (strict failure): kill every worker and respawn fresh
        seats so queued/in-flight state can't leak into the next sweep."""
        for s in self._slots:
            self._kill(s)
            s.inflight = None
            s.pending.clear()
            self._spawn(s)
        self._drain(0.0)

    # -------------------------------------------------- run a sweep
    def run(self, shards: list, *, engine: str, objective: str, scenario,
            persist: str | None, faults=None, policy: RetryPolicy | None = None,
            strict: bool = False, shard_tag: str = "sweep",
            metrics=None, recorder=None, sweep_t0: float = 0.0,
            on_result=None, on_failed=None):
        """Evaluate pre-sharded ``(idx, spec, cand)`` triples.

        ``shards[k]`` seeds seat ``k``'s pending queue (trace-affinity
        layout from ``_shard_items`` — retries stay on the same seat, so a
        respawned worker rebuilds the same cache neighborhood).  Returns
        ``(results, failed, stats, coll, lanes, shard_files)`` where
        ``results`` is ``[(idx, EvalResult)]``, ``failed`` is
        ``[FailedCandidate]``, ``stats``/``coll`` are the merged cache-stat
        deltas, ``lanes`` maps seat -> timing rows and ``shard_files`` maps
        main cache path -> list of written shard paths."""
        if self._closed:
            raise RuntimeError("pool is shut down")
        policy = policy or RetryPolicy()
        self._begin_msg = ("begin", engine, objective, scenario, persist,
                           faults, shard_tag)
        for s in self._slots:
            s.inflight = None
            s.pending.clear()
            s.retry_at = 0.0
            s.flushed = None
            s.last_hb = wall_s()
            self._ensure_alive(s)
            s.task_q.put(self._begin_msg)
        for k, shard in enumerate(shards):
            seat = self._slots[k % self.workers]
            for idx, spec, cand in shard:
                self._next_task_id += 1
                seat.pending.append(_Task(self._next_task_id, idx, spec,
                                          cand))

        results: list = []
        failed: list = []
        lanes: dict[int, list] = {s.wid: [] for s in self._slots}

        def outstanding() -> bool:
            return any(s.pending or s.inflight for s in self._slots)

        try:
            while outstanding():
                self._dispatch_ready()
                self._drain(timeout=0.05, results=results, failed=failed,
                            lanes=lanes, policy=policy, strict=strict,
                            metrics=metrics, recorder=recorder,
                            sweep_t0=sweep_t0, on_result=on_result,
                            on_failed=on_failed)
                self._liveness_scan(policy, failed, strict, metrics,
                                    recorder, sweep_t0, on_failed)
        except BaseException:
            # strict failure or Ctrl-C mid-sweep: never leave tasks queued
            # on live workers — the next sweep would receive their results
            self._reset_all()
            raise

        stats, coll, shard_files = self._flush(policy, metrics)
        results.sort(key=lambda r: r[0])
        return results, failed, stats, coll, lanes, shard_files

    # -------------------------------------------------- internals
    def _ensure_alive(self, slot: _Slot) -> None:
        if slot.proc is None or not slot.proc.is_alive():
            self._spawn(slot)

    def _dispatch_ready(self) -> None:
        now = wall_s()
        for s in self._slots:
            if s.inflight is not None or not s.pending:
                continue
            if now < s.retry_at:
                continue                     # backoff window still open
            self._revive(s)                  # idle seat may have died
            task = s.pending.popleft()
            task.dispatched = wall_s()
            task.started = 0.0
            s.inflight = task
            s.task_q.put(("task", task.task_id, task.idx, task.spec,
                          task.cand, task.attempt))

    def _drain(self, timeout: float, results=None, failed=None, lanes=None,
               policy=None, strict=False, metrics=None, recorder=None,
               sweep_t0=0.0, on_result=None, on_failed=None) -> None:
        deadline = wall_s() + timeout
        while True:
            conns = {s.rconn: s for s in self._slots
                     if s.rconn is not None}
            if not conns:
                return                       # every seat dead: liveness
            budget = deadline - wall_s()     # scan will respawn them
            ready = _conn_wait(list(conns), timeout=max(budget, 0.0))
            if not ready:
                return
            for c in ready:
                slot = conns[c]
                try:
                    msg = c.recv()
                except (EOFError, OSError):
                    # the incarnation died (EOF on its private pipe); the
                    # liveness scan attributes the death and respawns
                    try:
                        c.close()
                    except OSError:
                        pass
                    if slot.rconn is c:
                        slot.rconn = None
                    continue
                kind, wid, seq = msg[0], msg[1], msg[2]
                if seq != slot.seq:
                    continue                 # stale incarnation: drop
                if kind == "hb":
                    slot.last_hb = msg[3]
                elif kind == "started":
                    if slot.inflight is not None and \
                            slot.inflight.task_id == msg[3]:
                        slot.inflight.started = wall_s()
                elif kind == "done":
                    _, _, _, task_id, idx, res, timings = msg
                    if slot.inflight is None or \
                            slot.inflight.task_id != task_id:
                        continue             # superseded attempt: drop
                    task = slot.inflight
                    slot.inflight = None
                    if results is not None:
                        results.append((idx, res))
                    if lanes is not None:
                        lanes[wid].extend(timings)
                    if on_result is not None:
                        on_result(res, task.attempt)
                    return  # a seat opened: dispatch before draining more
                elif kind == "failed":
                    _, _, _, task_id, idx, reason, tb = msg
                    if slot.inflight is None or \
                            slot.inflight.task_id != task_id:
                        continue
                    task = slot.inflight
                    slot.inflight = None
                    if metrics is not None:
                        metrics.inc("pool.candidate_errors")
                    self._retry_or_quarantine(
                        slot, task, reason, tb, policy, failed, strict,
                        metrics, recorder, sweep_t0, on_failed, kill=False)
                    return  # seat freed (retry queued or quarantined)
                elif kind == "flushed":
                    slot.flushed = msg[3:]
            if wall_s() >= deadline:
                return

    def _liveness_scan(self, policy: RetryPolicy, failed, strict,
                       metrics, recorder, sweep_t0, on_failed) -> None:
        now = wall_s()
        for s in self._slots:
            task = s.inflight
            if task is None:
                # an idle seat that died (e.g. injected crash raced the
                # result) just gets respawned lazily at next dispatch
                continue
            dead = s.proc is None or not s.proc.is_alive()
            t_ref = task.started or task.dispatched
            timed_out = now - t_ref > policy.timeout_s
            wedged = (not dead and
                      now - s.last_hb >
                      policy.miss_heartbeats * self.heartbeat_s)
            if not (dead or timed_out or wedged):
                continue
            reason = ("worker died" if dead else
                      f"timeout after {policy.timeout_s:.1f}s" if timed_out
                      else "heartbeat lost")
            if metrics is not None:
                metrics.inc("pool.worker_deaths" if dead
                            else "pool.timeouts")
            s.inflight = None
            self._retry_or_quarantine(
                s, task, reason, "", policy, failed, strict, metrics,
                recorder, sweep_t0, on_failed, kill=True)

    def _retry_or_quarantine(self, slot: _Slot, task: _Task, reason: str,
                             tb: str, policy: RetryPolicy, failed, strict,
                             metrics, recorder, sweep_t0, on_failed,
                             kill: bool) -> None:
        """One attempt failed: respawn the seat if needed, then either
        requeue the candidate (front of the same seat, after backoff) or
        quarantine it."""
        if kill:
            self._kill(slot)
            self._spawn(slot)
            if metrics is not None:
                metrics.inc("pool.respawns")
            # the fresh incarnation missed this sweep's begin
            slot.task_q.put(self._begin_msg)
        if recorder is not None and recorder.enabled:
            recorder.instant(
                "sweep", f"worker{slot.wid}",
                f"fault:cand{task.idx}", wall_s() - sweep_t0, cat="fault",
                args={"idx": task.idx, "attempt": task.attempt,
                      "reason": reason})
        if task.attempt <= policy.max_retries:
            task.attempt += 1
            slot.retry_at = wall_s() + policy.backoff_for(task.attempt)
            slot.pending.appendleft(task)
            if metrics is not None:
                metrics.inc("pool.retries")
            return
        rec = FailedCandidate(task.cand, task.spec, task.attempt, reason,
                              _compact_tb(tb))
        if metrics is not None:
            metrics.inc("pool.quarantined")
        if strict:
            raise CandidateFailedError(rec)
        if failed is not None:
            failed.append(rec)
        if on_failed is not None:
            on_failed(rec)

    def _flush(self, policy: RetryPolicy, metrics):
        """Collect per-worker cache-stat deltas and persistent-cache shard
        paths.  A worker that dies during flush forfeits its stats/shards
        (results are already safe in the parent) — never fatal."""
        for s in self._slots:
            if s.proc is not None and s.proc.is_alive():
                s.task_q.put(("flush",))
        deadline = wall_s() + policy.timeout_s
        while (any(s.flushed is None and s.proc is not None
                   and s.proc.is_alive() for s in self._slots)
               and wall_s() < deadline):
            self._drain(timeout=0.05)
        stats: dict = {}
        coll = {"hits": 0, "misses": 0}
        shard_files: dict[str, list] = {}
        for s in self._slots:
            if s.flushed is None:
                if metrics is not None:
                    metrics.inc("pool.flush_lost")
                continue
            wstats, wcoll, shards = s.flushed
            for layer, st in wstats.items():
                acc = stats.setdefault(layer, {"hits": 0, "misses": 0})
                acc["hits"] += st["hits"]
                acc["misses"] += st["misses"]
            for k in coll:
                coll[k] += wcoll.get(k, 0)
            for main, shard in shards:
                shard_files.setdefault(main, []).append(shard)
        return stats, coll, shard_files


def _compact_tb(tb: str, max_lines: int = 12) -> str:
    """Last frames only: enough to identify a poison candidate's failure
    site without shipping a whole traceback into manifests."""
    lines = tb.strip().splitlines()
    return "\n".join(lines[-max_lines:])


def default_context() -> str:
    """``fork`` where the platform offers it (workers inherit the parent's
    imported jax — near-zero startup), else ``spawn``."""
    import multiprocessing as mp
    return "fork" if "fork" in mp.get_all_start_methods() else "spawn"


# --------------------------------------------------------------------------
# pool registry (the long-lived part)
# --------------------------------------------------------------------------

_POOLS: dict[tuple, WorkerPool] = {}


def get_pool(workers: int, mp_context: str | None = None) -> WorkerPool:
    """Process-wide singleton pool per (workers, context): the second
    ``sweep(workers=N)`` in a process reuses warm workers — no respawn, no
    re-import, warm per-worker simulator caches."""
    key = (int(workers), mp_context or default_context())
    pool = _POOLS.get(key)
    if pool is None or pool._closed:
        pool = WorkerPool(workers, mp_context=key[1])
        _POOLS[key] = pool
    return pool


def shutdown_pools() -> None:
    """Stop every registered pool (atexit hook; also useful in tests)."""
    for pool in _POOLS.values():
        pool.shutdown()
    _POOLS.clear()


atexit.register(shutdown_pools)


# --------------------------------------------------------------------------
# sweep journal: resumable execution
# --------------------------------------------------------------------------

class SweepJournal:
    """Append-only JSONL record of per-candidate sweep outcomes.

    Line 1 is a header identifying the sweep (base spec hash, axes,
    objective, engine); every following line is one finished candidate:
    ``{"h": json_hash, "status": completed|pruned|failed, ...}`` with the
    full :class:`EvalResult` hex-pickled for completed/pruned rows.  Rows
    are flushed *and fsync'd* per append, so a SIGKILL loses at most the
    in-flight candidate; a torn final line (killed mid-write) is tolerated
    on load.  ``sweep(..., resume=path)`` injects the recorded results and
    skips their candidates; ``failed`` rows are re-attempted on resume (a
    resume is an explicit second chance for transient failures)."""

    KIND = "charon-sweep-journal"
    VERSION = 1

    def __init__(self, path: str, header: dict):
        import json
        self.path = str(path)
        self.rows: dict[str, dict] = {}
        full = {"kind": self.KIND, "version": self.VERSION, **header}
        if os.path.exists(self.path) and os.path.getsize(self.path):
            existing = self.load(self.path, expect=full)
            self.rows = existing
            self._f = open(self.path, "a")
        else:
            self._f = open(self.path, "w")
            self._write_line(json.dumps(full, sort_keys=True, default=str))

    @classmethod
    def load(cls, path: str, expect: dict | None = None) -> dict[str, dict]:
        """Read a journal into ``{json_hash: row}``.  Raises ``ValueError``
        when the header disagrees with ``expect`` (resuming a *different*
        sweep would silently mix results); tolerates one torn final line."""
        import json
        rows: dict[str, dict] = {}
        with open(path) as f:
            lines = f.read().splitlines()
        if not lines:
            raise ValueError(f"journal {path} is empty")
        header = json.loads(lines[0])
        if header.get("kind") != cls.KIND:
            raise ValueError(f"{path} is not a {cls.KIND} file")
        if expect is not None:
            mismatched = [k for k, v in expect.items()
                          if json.loads(json.dumps(header.get(k),
                                                   default=str))
                          != json.loads(json.dumps(v, default=str))]
            if mismatched:
                raise ValueError(
                    f"journal {path} belongs to a different sweep "
                    f"(mismatched: {', '.join(sorted(mismatched))}) — "
                    "remove it or pass a fresh journal path")
        for i, line in enumerate(lines[1:], start=2):
            if not line.strip():
                continue
            try:
                row = json.loads(line)
            except ValueError:
                if i == len(lines):
                    break                    # torn final line: SIGKILL race
                raise
            rows[row["h"]] = row
        return rows

    def _write_line(self, line: str) -> None:
        self._f.write(line + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    def append_result(self, res) -> None:
        import json
        row = {"h": res.spec.json_hash(),
               "status": "pruned" if res.pruned else "completed",
               "res": pickle.dumps(res, protocol=pickle.HIGHEST_PROTOCOL
                                   ).hex()}
        self.rows[row["h"]] = row
        self._write_line(json.dumps(row))

    def append_failed(self, rec: FailedCandidate) -> None:
        import json
        row = {"h": rec.spec.json_hash(), "status": "failed",
               "attempts": rec.attempts, "reason": rec.reason,
               "tb": rec.traceback}
        self.rows[row["h"]] = row
        self._write_line(json.dumps(row))

    @staticmethod
    def result_from(row: dict):
        """Rehydrate a completed/pruned row's :class:`EvalResult`."""
        return pickle.loads(bytes.fromhex(row["res"]))

    def close(self) -> None:
        self._f.close()

"""Discrete-event resilience timeline: priced steps vs. a failure trace.

``replay`` walks a training run step by step against a lazy failure trace
(:class:`~repro.resilience.faults.FailureGen`), charging every second of
simulated wall time to exactly one bucket::

    wall_s == useful_s + rework_s + straggler_s + checkpoint_s + downtime_s

Steps are priced through a caller-supplied ``price(hosts)`` callback (the
step oracle underneath), so elastic resharding re-prices degraded meshes
for free; stragglers are a per-(step, host) multiplier table sampled once
and replayed identically on rework — a gang-synchronized step costs the
max over its hosts.

The loop is sequential (one job, one mesh), but failures are *exogenous*:
component clocks tick in wall time whether the job computes, checkpoints,
or sits in a restart, which is what makes a checkpoint-interval sweep
against a fixed seeded trace meaningful.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable

from repro.obs.recorder import CNAMES, NULL_RECORDER
from repro.resilience.faults import FailureEvent, FailureGen

# steps are counted as "completed steps so far", so checkpoint boundaries
# land after step % interval == 0 and restore rolls back to that count


@dataclass
class ReplayStats:
    """Raw tallies out of one :func:`replay` pass."""
    wall_s: float = 0.0
    useful_s: float = 0.0
    rework_s: float = 0.0
    straggler_s: float = 0.0
    checkpoint_s: float = 0.0
    downtime_s: float = 0.0
    steps_done: int = 0
    useful_tokens: float = 0.0
    n_failures: dict[str, int] = field(default_factory=dict)
    n_restarts: int = 0
    n_checkpoints: int = 0
    n_spare_swaps: int = 0
    n_reshards: int = 0
    degraded_steps: int = 0
    completed: bool = True
    events: list[FailureEvent] = field(default_factory=list)


def replay(*, total_steps: int, interval: int,
           price: Callable[[int], tuple[float, float]],
           failgen: FailureGen,
           straggler_mult: Callable[[int, int], float] | None,
           n_hosts: int, min_hosts: int, spares: int, elastic: bool,
           save_s: float, restore_s: float, sync: bool,
           async_overhead: float, restart_delay_s: float, repair_s: float,
           max_wall_s: float, rec=NULL_RECORDER) -> ReplayStats:
    """Replay ``total_steps`` priced steps against the failure trace.

    ``price(hosts) -> (base_step_s, tokens_per_step)`` for a mesh of
    ``hosts`` hosts (memoized by the caller).  ``straggler_mult(step,
    hosts)`` is the gang-max slowdown of that step index on that mesh
    (``None`` = no stragglers).  ``interval == 0`` means never checkpoint:
    any failure rolls back to step 0.

    ``rec`` (a :class:`~repro.obs.TraceRecorder`) captures the bucket
    partition as colored trace spans — useful/rework step windows (known
    only retroactively, at commit vs. wipe), straggler tails, checkpoint
    stalls, downtime windows, failure instants.  The stats are identical
    with recording on or off.
    """
    st = ReplayStats()
    wall = 0.0
    step = 0                 # completed steps
    last_ckpt = 0            # last durable checkpoint (in completed steps)
    hosts = n_hosts          # hosts currently in the mesh
    spares_free = spares
    repairs: list[float] = []       # repair-completion times (min-heap)
    pending: tuple[float, int] | None = None   # async (durable_at, step)
    # steps since the last durable checkpoint: (step_count, base_s, tokens)
    uncommitted: list[tuple[int, float, float]] = []
    # trace-only mirror of ``uncommitted``: (step_count, start_s, base_dur)
    # — useful vs. rework is decided retroactively, so open step windows
    # stay here until a commit (useful span) or a failure wipe (rework span)
    windows: list[tuple[int, float, float]] = []
    prev_price_hosts: int | None = None
    _PID = "resilience"

    def flush_windows(upto: int, cname_key: str):
        keep = []
        for (i, s0, d) in windows:
            if i <= upto:
                rec.span(_PID, "steps", f"step{i}", s0, d, cat="bucket",
                         cname=CNAMES[cname_key])
            else:
                keep.append((i, s0, d))
        windows[:] = keep

    def commit(upto: int):
        nonlocal last_ckpt
        keep = []
        for (i, b, tok) in uncommitted:
            if i <= upto:
                st.useful_s += b
                st.useful_tokens += tok
            else:
                keep.append((i, b, tok))
        uncommitted[:] = keep
        last_ckpt = upto
        st.n_checkpoints += 1
        if rec.enabled:
            flush_windows(upto, "useful")

    def check_async(now: float):
        nonlocal pending
        if pending is not None and pending[0] <= now:
            commit(pending[1])
            pending = None

    def process_repairs(now: float):
        nonlocal spares_free
        while repairs and repairs[0] <= now:
            heapq.heappop(repairs)
            spares_free += 1

    def capacity(ev: FailureEvent):
        # link failures are transient (restart, reroute around) — no host
        # leaves; a chip failure drains its whole host, like a host failure
        nonlocal hosts, spares_free
        if ev.kind == "link":
            return
        if spares_free > 0:
            spares_free -= 1
            st.n_spare_swaps += 1          # hot swap: mesh size kept
        else:
            hosts -= 1
        heapq.heappush(repairs, ev.t_s + repair_s)

    def record(ev: FailureEvent):
        st.events.append(ev)
        st.n_failures[ev.kind] = st.n_failures.get(ev.kind, 0) + 1
        if rec.enabled:
            rec.instant(_PID, "faults", f"FAILURE:{ev.kind}", ev.t_s,
                        cat="fault", args={"kind": ev.kind})

    def handle_failure(ev: FailureEvent):
        nonlocal wall, step, pending, hosts, spares_free
        # an in-flight async save that became durable before the failure
        # still counts; anything later is lost with the job state
        check_async(ev.t_s)
        pending = None
        process_repairs(ev.t_s)
        record(ev)
        st.n_restarts += 1
        for (_, b, _tok) in uncommitted:   # wiped: replayed from last_ckpt
            st.rework_s += b
        uncommitted.clear()
        if rec.enabled:
            flush_windows(total_steps + 1, "rework")  # wipe: all are rework

        def restart_end(t: float) -> float:
            return t + restart_delay_s + (restore_s if last_ckpt > 0 else 0.0)

        capacity(ev)
        end = restart_end(ev.t_s)
        # absorb failures that land inside the restart window — each one
        # restarts the restart
        while failgen.peek() <= end:
            ev2 = failgen.pop()
            record(ev2)
            capacity(ev2)
            end = max(end, restart_end(ev2.t_s))
            if end > max_wall_s:
                break
        # a mesh below the feasibility floor (or any degradation, when not
        # elastic) stalls until repairs bring hosts back
        required = min_hosts if elastic else n_hosts
        while hosts < required and repairs:
            t = heapq.heappop(repairs)
            end = max(end, restart_end(t))
            hosts += 1
        if hosts < required:
            st.completed = False
            end = max(end, max_wall_s) + 1.0   # trip the divergence guard
        # restarting anyway: refill the mesh from free spares
        while hosts < n_hosts and spares_free > 0:
            hosts += 1
            spares_free -= 1
            st.n_spare_swaps += 1
        st.downtime_s += end - ev.t_s
        if rec.enabled:
            rec.span(_PID, "downtime", f"restart:{ev.kind}", ev.t_s,
                     end - ev.t_s, cat="bucket", cname=CNAMES["downtime"],
                     args={"rollback_to_step": last_ckpt, "hosts": hosts})
        wall = end
        step = last_ckpt

    while step < total_steps:
        check_async(wall)
        process_repairs(wall)
        if wall > max_wall_s:
            st.completed = False
            break
        base_s, tokens = price(hosts)
        if prev_price_hosts is not None and hosts != prev_price_hosts:
            st.n_reshards += 1
        prev_price_hosts = hosts
        mult = straggler_mult(step, hosts) if straggler_mult else 1.0
        dt = base_s * mult
        if failgen.peek() <= wall + dt:
            ev = failgen.pop()
            st.rework_s += ev.t_s - wall   # the partial step is wiped too
            if rec.enabled and ev.t_s > wall:
                rec.span(_PID, "steps", f"step{step + 1}:partial", wall,
                         ev.t_s - wall, cat="bucket", cname=CNAMES["rework"])
            wall = ev.t_s
            handle_failure(ev)
            continue
        if rec.enabled:
            windows.append((step + 1, wall, base_s))
            if dt > base_s:
                rec.span(_PID, "straggler", f"step{step + 1}:straggle",
                         wall + base_s, dt - base_s, cat="bucket",
                         cname=CNAMES["straggler"],
                         args={"mult": round(mult, 4)})
        wall += dt
        step += 1
        uncommitted.append((step, base_s, tokens))
        st.straggler_s += dt - base_s
        if hosts < n_hosts:
            st.degraded_steps += 1
        if interval and step % interval == 0 and step < total_steps:
            # the boundary stall: full save when sync, snapshot when async
            stall = save_s if sync else async_overhead * save_s
            if failgen.peek() <= wall + stall:
                ev = failgen.pop()
                st.checkpoint_s += ev.t_s - wall
                if rec.enabled and ev.t_s > wall:
                    rec.span(_PID, "checkpoint", f"save@{step}:partial",
                             wall, ev.t_s - wall, cat="bucket",
                             cname=CNAMES["checkpoint"])
                wall = ev.t_s
                handle_failure(ev)
                continue
            if rec.enabled and stall > 0:
                rec.span(_PID, "checkpoint", f"save@{step}", wall, stall,
                         cat="bucket", cname=CNAMES["checkpoint"],
                         args={"mode": "sync" if sync else "async"})
            wall += stall
            st.checkpoint_s += stall
            if sync:
                commit(step)
            else:
                # durable once the background write lands; a failure before
                # then falls back to the previous durable checkpoint
                pending = (wall + save_s, step)

    # final completion (or the divergence guard) covers whatever survived
    for (_, b, tok) in uncommitted:
        st.useful_s += b
        st.useful_tokens += tok
    uncommitted.clear()
    if rec.enabled:
        flush_windows(total_steps + 1, "useful")
    st.wall_s = wall
    st.steps_done = step
    if not math.isfinite(wall):
        st.completed = False
    return st

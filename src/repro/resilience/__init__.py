"""Resilience-aware simulation: fault injection, checkpoint pricing, and
goodput under MTBF.

Attach a :class:`~repro.api.spec.ResilienceSpec` to a ``TrainWorkload``
and run it through :class:`ResilienceSimulator`; sweep checkpoint interval
x MTBF x spares with ``sweep(space, objective="goodput_under_failures")``.
See ``docs/resilience.md``.
"""
from repro.resilience.faults import KINDS, FailureEvent, FailureGen
from repro.resilience.report import ResilienceReport
from repro.resilience.sim import ResilienceSimulator
from repro.resilience.timeline import ReplayStats, replay

__all__ = [
    "KINDS", "FailureEvent", "FailureGen", "ReplayStats",
    "ResilienceReport", "ResilienceSimulator", "replay",
]

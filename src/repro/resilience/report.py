"""Resilience report: goodput, lost-work breakdown, optimal intervals."""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.simulator import Report
from repro.resilience.faults import FailureEvent


@dataclass
class ResilienceReport:
    """What a training run costs under failures.

    Wall-time accounting identity (asserted in tests)::

        wall_s == useful_s + rework_s + straggler_s
                  + checkpoint_s + downtime_s

    * ``useful_s`` — base step time of steps that survived to the end
      (covered by a durable checkpoint or by final completion).
    * ``rework_s`` — step time wiped by a failure and replayed (includes
      the partial step cut short by the failure itself).
    * ``straggler_s`` — slowdown excess of completed steps over their base
      cost (kept *and* later-reworked steps both count here).
    * ``checkpoint_s`` — save stalls (full save when sync; the snapshot
      fraction when async).
    * ``downtime_s`` — restart delay + checkpoint restore + any wait for
      repairs when the mesh cannot run.

    ``goodput`` is ``useful_s / wall_s`` — the fraction of wall-clock the
    cluster spent on steps that counted.  ``step_report`` is the
    failure-free :class:`~repro.core.simulator.Report` for the full mesh —
    bit-identical to ``Simulator.run`` on the same spec without
    ``resilience``.
    """
    # headline
    goodput: float
    wall_s: float
    ideal_s: float                  # total_steps x failure-free step time
    completed: bool                 # False if the divergence guard tripped
    steps_done: int
    total_steps: int
    useful_tokens: float
    tokens_per_s: float             # useful tokens over wall time
    # breakdown (sums to wall_s)
    useful_s: float
    rework_s: float
    straggler_s: float
    checkpoint_s: float
    downtime_s: float
    # failure / recovery counters
    n_failures: dict[str, int]
    n_restarts: int
    n_checkpoints: int
    n_spare_swaps: int
    n_reshards: int
    degraded_steps: int
    # checkpoint pricing inputs
    state_bytes_per_device: float
    write_gbps: float
    save_s: float
    restore_s: float
    interval_steps: int
    # optimal-interval analysis
    mtbf_system_s: float            # 1 / sum of component failure rates
    young_daly_interval_steps: int | None
    simulated_optimal_interval_steps: int | None
    goodput_by_interval: dict[int, float] = field(default_factory=dict)
    # provenance
    step_report: Report | None = None
    failure_trace: tuple[FailureEvent, ...] = ()

    def explain_dict(self) -> dict:
        """Compact attribution (what sweep manifests embed): goodput,
        per-bucket wall-clock fractions, the dominant loss bucket."""
        from repro.obs.explain import compact_resilience
        return compact_resilience(self)

    def summary(self) -> dict:
        """Flat dict for benchmarks and manifests."""
        return {
            "goodput": round(self.goodput, 6),
            "completed": self.completed,
            "wall_s": round(self.wall_s, 3),
            "ideal_s": round(self.ideal_s, 3),
            "steps_done": self.steps_done,
            "total_steps": self.total_steps,
            "tokens_per_s": round(self.tokens_per_s, 1),
            "useful_s": round(self.useful_s, 3),
            "rework_s": round(self.rework_s, 3),
            "straggler_s": round(self.straggler_s, 3),
            "checkpoint_s": round(self.checkpoint_s, 3),
            "downtime_s": round(self.downtime_s, 3),
            "n_failures": dict(self.n_failures),
            "n_restarts": self.n_restarts,
            "n_checkpoints": self.n_checkpoints,
            "n_spare_swaps": self.n_spare_swaps,
            "n_reshards": self.n_reshards,
            "degraded_steps": self.degraded_steps,
            "save_s": round(self.save_s, 3),
            "restore_s": round(self.restore_s, 3),
            "interval_steps": self.interval_steps,
            "mtbf_system_s": round(self.mtbf_system_s, 1),
            "young_daly_interval_steps": self.young_daly_interval_steps,
            "simulated_optimal_interval_steps":
                self.simulated_optimal_interval_steps,
        }

"""Deterministic failure traces from seeded MTBF renewal processes.

Every component (chip, host, link) is an independent renewal process: its
inter-failure gaps are drawn from its own ``random.Random`` stream, seeded
by splitmix64-mixing the fault model's seed with the component's class and
index.  The merged trace is therefore a pure function of
``(FaultModel, component counts)`` — independent of Python hash
randomization, of how far the consumer reads, and crucially of the
checkpoint schedule: failures happen in wall-clock time whether or not the
job checkpoints, so a checkpoint-interval sweep replays the *same* trace.

The generator is lazy (a heap of per-component next-failure times), so the
horizon never needs to be known up front — the resilience timeline just
pulls failures until the run completes.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from random import Random

from repro.api.spec import FaultModel

KINDS = ("chip", "host", "link")


def _mix(*parts: int) -> int:
    """splitmix64 over the parts — stable across processes and platforms
    (same construction as the serving router's rendezvous hash)."""
    h = 0x9E3779B97F4A7C15
    for p in parts:
        h ^= (p & 0xFFFFFFFFFFFFFFFF) * 0xBF58476D1CE4E5B9
        h &= 0xFFFFFFFFFFFFFFFF
        h ^= h >> 27
        h *= 0x94D049BB133111EB
        h &= 0xFFFFFFFFFFFFFFFF
        h ^= h >> 31
    return h


@dataclass(frozen=True)
class FailureEvent:
    """One component failure at wall-clock ``t_s``."""
    t_s: float
    kind: str       # chip | host | link
    index: int      # component index within its class

    def asdict(self) -> dict:
        return {"t_s": self.t_s, "kind": self.kind, "index": self.index}


class _Stream:
    """One component's renewal process."""

    __slots__ = ("rng", "draw")

    def __init__(self, model: FaultModel, kind: str, index: int,
                 mtbf_s: float):
        self.rng = Random(_mix(model.seed, KINDS.index(kind) + 1, index))
        if model.dist == "weibull":
            # scale so the mean stays at the configured MTBF:
            # E[Weibull(scale, k)] = scale * Gamma(1 + 1/k)
            scale = mtbf_s / math.gamma(1.0 + 1.0 / model.weibull_shape)
            k = model.weibull_shape
            self.draw = lambda: self.rng.weibullvariate(scale, k)
        else:
            rate = 1.0 / mtbf_s
            self.draw = lambda: self.rng.expovariate(rate)


class FailureGen:
    """Lazy merged failure trace over all components of a fault model.

    ``peek()`` returns the next failure time (``inf`` when the model is
    inactive); ``pop()`` consumes it and schedules that component's next
    renewal.  Ties break deterministically by (time, class, index).
    """

    def __init__(self, model: FaultModel, *, n_chips: int, n_hosts: int,
                 n_links: int):
        self._heap: list[tuple[float, int, int]] = []
        self._streams: dict[tuple[int, int], _Stream] = {}
        counts = {"chip": n_chips, "host": n_hosts, "link": n_links}
        for ki, kind in enumerate(KINDS):
            mtbf = getattr(model, f"{kind}_mtbf_s")
            if not 0 < mtbf < math.inf:
                continue
            for idx in range(counts[kind]):
                s = _Stream(model, kind, idx, mtbf)
                self._streams[(ki, idx)] = s
                heapq.heappush(self._heap, (s.draw(), ki, idx))

    def peek(self) -> float:
        return self._heap[0][0] if self._heap else math.inf

    def pop(self) -> FailureEvent:
        t, ki, idx = heapq.heappop(self._heap)
        heapq.heappush(self._heap,
                       (t + self._streams[(ki, idx)].draw(), ki, idx))
        return FailureEvent(t, KINDS[ki], idx)


def replica_fault_stream(spec, index: int):
    """Lazy inter-failure gap stream for one serving replica.

    Returns a zero-arg callable yielding successive up-time gaps (seconds
    between recovery and the next failure).  The stream depends only on
    ``(spec.seed, index)`` — not on traffic or the rest of the fleet — so
    fleet fault traces are bit-deterministic.  ``spec`` is a
    :class:`~repro.api.spec.ReplicaFaultSpec`.
    """
    rng = Random(_mix(spec.seed, 101, index))
    if spec.dist == "weibull":
        scale = spec.mtbf_s / math.gamma(1.0 + 1.0 / spec.weibull_shape)
        k = spec.weibull_shape
        return lambda: rng.weibullvariate(scale, k)
    rate = 1.0 / spec.mtbf_s
    return lambda: rng.expovariate(rate)

"""ResilienceSimulator: price a training run under injected failures.

Wraps a :class:`~repro.core.simulator.Simulator` the same way the serving
simulator does: the step oracle prices steps (full mesh and every elastic
degraded mesh, memoized), the resilience timeline replays them against the
spec's seeded failure trace, and the result is a
:class:`~repro.resilience.report.ResilienceReport`.

    sim = Simulator("tpu_v5e", engine="analytical")
    spec = SimSpec(cfg, cluster=Cluster("tpu_v5e", pods=1),
                   parallel=ParallelConfig(tp=4, dp=8),
                   workload=TrainWorkload(
                       global_batch=256, resilience=ResilienceSpec(
                           total_steps=2000,
                           faults=FaultModel(host_mtbf_s=4 * 3600, seed=7),
                           ckpt=CheckpointSpec(interval_steps=100))))
    rep = ResilienceSimulator(sim).run(spec)
    rep.goodput, rep.young_daly_interval_steps, rep.summary()

Determinism contract: the failure trace, the straggler table and therefore
the whole report are pure functions of the spec — same spec, same report,
across runs and across ``sweep(workers=N)``.  An inactive fault model with
checkpointing off reproduces the failure-free report exactly
(``rep.step_report`` is bit-identical to ``Simulator.run`` on the same
spec without ``resilience``, and ``goodput == 1.0``).
"""
from __future__ import annotations

import dataclasses
import math

from repro.api.spec import ResilienceSpec, SimSpec
from repro.resilience.faults import FailureGen, _mix
from repro.resilience.report import ResilienceReport
from repro.resilience.timeline import ReplayStats, replay
from repro.training.fault_tolerance import ElasticPlan

# replayed candidate multipliers around the Young/Daly interval when
# optimize_interval is set — a geometric grid is enough to bracket the
# optimum, and every candidate replays the *same* failure trace
_INTERVAL_GRID = (0.25, 0.5, 1.0, 2.0, 4.0)
# straggler table size guard: (total_steps x n_hosts) doubles, three arrays
_MAX_STRAGGLER_CELLS = 200_000_000


class ResilienceSimulator:
    """Discrete-event resilience pricing over a core step simulator."""

    def __init__(self, sim):
        self.sim = sim

    # ------------------------------------------------------------------
    def run(self, spec: SimSpec, *, recorder=None,
            metrics=None) -> ResilienceReport:
        """Price ``spec`` under its failure model.

        ``recorder`` captures the bucket partition of the *configured*
        interval's replay as colored trace spans (interval-grid candidates
        replayed for ``optimize_interval`` are not recorded — one timeline
        per run); ``metrics`` accumulates failure/restart/checkpoint
        counters.  Reports are bit-identical with either on or off.
        """
        w = spec.workload
        if getattr(w, "mode", None) != "train":
            raise TypeError(
                "ResilienceSimulator prices TrainWorkload specs; got mode="
                f"{getattr(w, 'mode', None)!r}")
        rspec = w.resilience or ResilienceSpec()

        # failure-free baseline: the stripped spec is the plain training
        # spec, so this report is bit-identical to Simulator.run without
        # resilience (and shares its cache entry)
        base_spec = dataclasses.replace(
            spec, workload=dataclasses.replace(w, resilience=None))
        base = self.sim.run(base_spec)
        base_step_s = base.step_time_us / 1e6
        ideal_s = rspec.total_steps * base_step_s

        par = spec.parallel
        chips = par.chips
        cph = rspec.chips_per_host
        n_hosts = max(1, -(-chips // cph))              # ceil
        shard_chips = par.tp * par.pp * par.cp
        min_hosts = max(1, -(-shard_chips // cph))

        # checkpoint pricing: per-device training state over the write path
        mem = base.memory
        state_bytes = float(mem.weights + mem.opt_state) if mem else 0.0
        write_gbps = rspec.ckpt.write_gbps or (
            self.sim.hw.inter.bandwidth / 1e9)
        save_s = state_bytes / (write_gbps * 1e9) if write_gbps > 0 else 0.0
        restore_s = rspec.ckpt.restore_factor * save_s

        price = self._make_pricer(spec, rspec, base, n_hosts)
        stragglers = _straggler_table(rspec, n_hosts)

        def one(interval: int, rec=None) -> ReplayStats:
            # a fresh generator per replay: every interval candidate sees
            # the identical seeded trace
            from repro.obs.recorder import NULL_RECORDER
            gen = FailureGen(rspec.faults, n_chips=chips, n_hosts=n_hosts,
                             n_links=n_hosts)
            return replay(
                total_steps=rspec.total_steps, interval=interval,
                price=price, failgen=gen, straggler_mult=stragglers,
                n_hosts=n_hosts, min_hosts=min_hosts, spares=rspec.spares,
                elastic=rspec.elastic, save_s=save_s, restore_s=restore_s,
                sync=rspec.ckpt.mode == "sync",
                async_overhead=rspec.ckpt.async_overhead,
                restart_delay_s=rspec.restart_delay_s,
                repair_s=rspec.repair_s,
                max_wall_s=rspec.max_wall_factor * max(ideal_s, 1e-9),
                rec=rec if rec is not None else NULL_RECORDER)

        interval = rspec.ckpt.interval_steps
        st = one(interval, rec=recorder)

        # system MTBF and the Young/Daly closed form, in steps
        rate = 0.0
        for mtbf, count in ((rspec.faults.chip_mtbf_s, chips),
                            (rspec.faults.host_mtbf_s, n_hosts),
                            (rspec.faults.link_mtbf_s, n_hosts)):
            if 0 < mtbf < math.inf:
                rate += count / mtbf
        mtbf_system = 1.0 / rate if rate > 0 else math.inf
        yd_steps = None
        if rate > 0 and save_s > 0 and base_step_s > 0:
            yd_steps = max(1, round(
                math.sqrt(2.0 * save_s * mtbf_system) / base_step_s))

        # simulated optimum: replay the same trace over a grid around
        # Young/Daly (plus the configured interval) and keep the argmax
        sim_opt = None
        by_interval: dict[int, float] = {}
        if rspec.optimize_interval and rate > 0 and yd_steps is not None:
            cands = {max(1, round(yd_steps * f)) for f in _INTERVAL_GRID}
            if interval > 0:
                cands.add(interval)
            for c in sorted(cands):
                stc = st if c == interval else one(c)
                by_interval[c] = _goodput(stc)
            sim_opt = max(sorted(by_interval),
                          key=lambda c: (by_interval[c], -c))

        if metrics is not None:
            metrics.inc("resilience.failures", sum(st.n_failures.values()))
            for kind, n in st.n_failures.items():
                metrics.inc(f"resilience.failures.{kind}", n)
            metrics.inc("resilience.restarts", st.n_restarts)
            metrics.inc("resilience.checkpoints", st.n_checkpoints)
            metrics.inc("resilience.reshards", st.n_reshards)
            metrics.inc("resilience.degraded_steps", st.degraded_steps)
            metrics.observe("resilience.goodput", _goodput(st))
        return ResilienceReport(
            goodput=_goodput(st), wall_s=st.wall_s, ideal_s=ideal_s,
            completed=st.completed, steps_done=st.steps_done,
            total_steps=rspec.total_steps,
            useful_tokens=st.useful_tokens,
            tokens_per_s=st.useful_tokens / max(st.wall_s, 1e-9),
            useful_s=st.useful_s, rework_s=st.rework_s,
            straggler_s=st.straggler_s, checkpoint_s=st.checkpoint_s,
            downtime_s=st.downtime_s, n_failures=st.n_failures,
            n_restarts=st.n_restarts, n_checkpoints=st.n_checkpoints,
            n_spare_swaps=st.n_spare_swaps, n_reshards=st.n_reshards,
            degraded_steps=st.degraded_steps,
            state_bytes_per_device=state_bytes, write_gbps=write_gbps,
            save_s=save_s, restore_s=restore_s, interval_steps=interval,
            mtbf_system_s=mtbf_system,
            young_daly_interval_steps=yd_steps,
            simulated_optimal_interval_steps=sim_opt,
            goodput_by_interval=by_interval,
            step_report=base, failure_trace=tuple(st.events))

    # ------------------------------------------------------------------
    def _make_pricer(self, spec: SimSpec, rspec: ResilienceSpec, base,
                     n_hosts: int):
        """``price(hosts) -> (step_s, tokens_per_step)``, memoized.

        The full mesh uses the baseline report verbatim; degraded meshes
        shrink dp via :meth:`ElasticPlan.rescale` (tp/pp/cp shards intact,
        per-replica batch preserved) and re-price through the step oracle.
        Degraded specs flatten pods: after losing arbitrary hosts the
        original pod structure no longer holds, so the shrunk mesh is
        priced as a single pod — a modeling choice, documented in
        docs/resilience.md.
        """
        w = spec.workload
        par = spec.parallel
        cph = rspec.chips_per_host
        full = (base.step_time_us / 1e6, float(base.tokens_per_step))
        memo: dict[int, tuple[float, float]] = {}

        def price(hosts: int) -> tuple[float, float]:
            if hosts >= n_hosts:
                return full
            got = memo.get(hosts)
            if got is not None:
                return got
            plan = ElasticPlan(tp=par.tp * par.cp, pp=par.pp,
                               dp=par.dp * par.pods,
                               global_batch=w.global_batch)
            new = plan.rescale(min(hosts * cph, par.chips))
            gb = new.global_batch or new.dp   # floor: one sample per replica
            degraded = SimSpec(
                model=spec.model,
                cluster=dataclasses.replace(spec.cluster, pods=1, chips=0),
                parallel=dataclasses.replace(par, dp=new.dp, pods=1),
                workload=dataclasses.replace(w, global_batch=gb,
                                             resilience=None))
            rep = self.sim.run(degraded)
            got = (rep.step_time_us / 1e6, float(rep.tokens_per_step))
            memo[hosts] = got
            return got

        return price


def _goodput(st: ReplayStats) -> float:
    return st.useful_s / st.wall_s if st.wall_s > 0 else 1.0


def _straggler_table(rspec: ResilienceSpec, n_hosts: int):
    """Per-(step, host) slowdown table, sampled once per spec.

    Returns ``mult(step, hosts) -> float`` — the max multiplier over the
    first ``hosts`` hosts at that step (prefix-max precomputed), so a
    shrunk mesh deterministically sees a subset of the full mesh's
    stragglers and a reworked step replays its original slowdown.
    """
    if rspec.straggler_prob <= 0 or rspec.straggler_mult <= 1:
        return None
    cells = rspec.total_steps * n_hosts
    if cells > _MAX_STRAGGLER_CELLS:
        raise ValueError(
            f"straggler table of {cells} cells (total_steps={rspec.total_steps}"
            f" x hosts={n_hosts}) exceeds {_MAX_STRAGGLER_CELLS}; lower "
            "total_steps or disable stragglers")
    import numpy as np
    rng = np.random.default_rng(_mix(rspec.faults.seed, 777, n_hosts))
    shape = (rspec.total_steps, n_hosts)
    slow = rng.random(shape) < rspec.straggler_prob
    draws = 1.0 + rng.random(shape) * (rspec.straggler_mult - 1.0)
    table = np.maximum.accumulate(np.where(slow, draws, 1.0), axis=1)

    def mult(step: int, hosts: int) -> float:
        return float(table[step, min(hosts, n_hosts) - 1])

    return mult

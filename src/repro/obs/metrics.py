"""MetricsRegistry: one snapshot-and-diff surface for simulator telemetry.

Before this module every subsystem kept its own ad-hoc stats dict —
``Simulator.cache_stats()`` (nested per-layer hit/miss), the step oracle's
serving-bucket delta, ``ingest_extrapolation_stats()``, sweep configs/sec —
and every consumer re-implemented "snapshot before, subtract after".  The
registry unifies them:

* **counters** — monotonically increasing floats (``inc``), or absolute
  gauges adopted from an existing nested stats dict (``update_nested`` /
  ``update_from_simulator``), flattened to dotted names
  (``cache.pricing.hits``);
* **histograms** — streaming count/total/min/max (``observe``), e.g.
  per-candidate sweep wall time;
* **snapshot / diff** — ``snapshot()`` is a plain JSON-serializable dict;
  ``MetricsRegistry.diff(after, before)`` subtracts counters (and histogram
  counts/totals) so "what did this run cost" is one call regardless of
  which subsystem produced the numbers.

Attach one to a run (``ServingSimulator.run(..., metrics=reg)``,
``sweep(..., metrics=reg)``) and the snapshot lands in the report's
``metrics`` field / the sweep manifest's ``metrics`` section.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class HistStat:
    count: int = 0
    total: float = 0.0
    min: float = float("inf")
    max: float = float("-inf")

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def as_dict(self, nd: int = 6) -> dict:
        if not self.count:
            return {"count": 0, "total": 0.0, "mean": 0.0,
                    "min": 0.0, "max": 0.0}
        return {"count": self.count, "total": round(self.total, nd),
                "mean": round(self.total / self.count, nd),
                "min": round(self.min, nd), "max": round(self.max, nd)}


@dataclass
class MetricsRegistry:
    counters: dict = field(default_factory=dict)     # name -> float
    histograms: dict = field(default_factory=dict)   # name -> HistStat

    # ------------------------------------------------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + value

    def set(self, name: str, value: float) -> None:
        """Adopt an externally-maintained cumulative counter (a gauge)."""
        self.counters[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = HistStat()
        h.observe(value)

    # ------------------------------------------------------------------
    def update_nested(self, nested: dict, prefix: str = "") -> None:
        """Flatten a nested dict of numbers (``cache_stats()`` shape) into
        dotted counter names; non-numeric leaves are skipped."""
        for k, v in nested.items():
            name = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, dict):
                self.update_nested(v, name)
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                self.counters[name] = float(v)

    def update_from_simulator(self, sim) -> None:
        """Adopt every stats surface a core Simulator exposes: the layered
        cache counters (incl. oracle/serving hits and engine pricing) plus
        the module-level batch-extrapolation tallies."""
        from repro.core.model_ingest import ingest_extrapolation_stats
        self.update_nested(sim.cache_stats(), "cache")
        self.update_nested(ingest_extrapolation_stats(), "ingest_extrap")

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        return {"counters": dict(self.counters),
                "histograms": {k: h.as_dict()
                               for k, h in self.histograms.items()}}

    @staticmethod
    def diff(after: dict, before: dict) -> dict:
        """Delta of two :meth:`snapshot` dicts: counters subtract (keys
        absent before count from zero); histograms subtract count/total and
        keep the after-side min/max."""
        bc = before.get("counters", {})
        counters = {k: v - bc.get(k, 0.0)
                    for k, v in after.get("counters", {}).items()}
        bh = before.get("histograms", {})
        hists = {}
        for k, h in after.get("histograms", {}).items():
            b = bh.get(k, {})
            hists[k] = {"count": h["count"] - b.get("count", 0),
                        "total": round(h["total"] - b.get("total", 0.0), 6),
                        "min": h["min"], "max": h["max"]}
        return {"counters": counters, "histograms": hists}

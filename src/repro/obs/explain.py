"""``explain()`` attribution: where did the predicted time go, and why.

A ranked sweep winner is only actionable with its "why" attached (the
paper's per-op breakdowns are the whole point of fine-grained simulation),
so every report can explain itself:

* :func:`explain_report` — critical-path extraction over the priced block
  timelines (when ``keep_timelines=True``), top-k ops by time and by comm
  bytes, and a compute-vs-comm-vs-exposed-overlap decomposition.  Without
  timelines it degrades gracefully to the per-kind/per-phase sums every
  report carries.
* :func:`explain_serving` — the request-level analogue for
  ``ServingReport``/``FleetReport``: the dominant SLO-violation cause
  (queueing vs prefill vs decode), utilization and step mix.

Each has a ``render_*`` plain-text form (what ``Report.explain()``
returns) and a ``compact_*`` form that rides along in
``sweep(..., manifest=)`` rows.
"""
from __future__ import annotations


def _cat(kind: str) -> str:
    from repro.core.timeline import _CAT
    return _CAT.get(kind, "other")


# ---------------------------------------------------------------------------
# interval-set arithmetic (for exposed-comm on priced timelines)

def _union(segs: list[tuple[float, float]]) -> list[tuple[float, float]]:
    out: list[list[float]] = []
    for s, e in sorted(segs):
        if out and s <= out[-1][1]:
            out[-1][1] = max(out[-1][1], e)
        else:
            out.append([s, e])
    return [(s, e) for s, e in out]

def _covered(seg: tuple[float, float], union: list[tuple[float, float]]
             ) -> float:
    """Length of ``seg`` overlapped by the (sorted, disjoint) union."""
    s, e = seg
    return sum(max(0.0, min(e, ue) - max(s, us)) for us, ue in union
               if us < e and ue > s)


def critical_path(tl, *, limit: int = 4096) -> list:
    """Extract the binding chain of a list-scheduled timeline.

    Walks back from the interval that ends last: the predecessor of an
    interval is the one whose end coincides with its start (the scheduler
    sets ``start = max(stream_free, dep_ready)``, so some interval always
    binds), preferring a same-stream predecessor on ties; if nothing ends
    exactly there, the latest-ending earlier interval binds (a dependency
    wait).  Timelines larger than ``limit`` intervals return ``[]`` rather
    than going quadratic.
    """
    ivs = tl.intervals
    if not ivs or len(ivs) > limit:
        return []
    cur = max(ivs, key=lambda iv: iv.end)
    path = [cur]
    tol = 1e-6
    while cur.start > tol:
        preds = [iv for iv in ivs if iv is not cur and iv.end <= cur.start + tol]
        if not preds:
            break
        exact = [iv for iv in preds if cur.start - iv.end <= tol]
        pool = exact or preds
        stream = cur.stream
        cur = max(pool, key=lambda iv: (iv.end, iv.stream == stream))
        path.append(cur)
    path.reverse()
    return path


# ---------------------------------------------------------------------------
def explain_report(rep, top_k: int = 8) -> dict:
    """Structured attribution for a core :class:`~repro.core.simulator.Report`."""
    kind_us = dict(rep.kind_us)
    total_kind = sum(kind_us.values()) or 1.0
    by_cat = {"compute": 0.0, "comm": 0.0, "other": 0.0}
    for k, v in kind_us.items():
        by_cat[_cat(k)] += v
    top_time = sorted(kind_us.items(), key=lambda kv: -kv[1])[:top_k]

    out = {
        "mode": rep.mode,
        "step_time_us": round(rep.step_time_us, 3),
        "mfu": round(rep.mfu, 4),
        "breakdown_us": {k: round(v, 3) for k, v in rep.breakdown_us.items()},
        "dominant_phase": max(rep.breakdown_us, key=rep.breakdown_us.get)
        if rep.breakdown_us else None,
        "top_ops_by_time_us": [(k, round(v, 3)) for k, v in top_time],
        "compute_frac": round(by_cat["compute"] / total_kind, 4),
        "comm_frac": round(by_cat["comm"] / total_kind, 4),
        "other_frac": round(by_cat["other"] / total_kind, 4),
    }

    # timeline-backed sections (keep_timelines=True runs only)
    tls = getattr(rep, "block_timelines", None) or {}
    if tls:
        comm_bytes: dict[str, float] = {}
        op_time: dict[str, float] = {}
        exposed = overlapped = compute_busy = 0.0
        for tl in tls.values():
            compute_segs = [(iv.start, iv.end) for iv in tl.intervals
                            if iv.stream == "compute"]
            cover = _union(compute_segs)
            compute_busy += sum(e - s for s, e in cover)
            for iv in tl.intervals:
                op_time[iv.name] = op_time.get(iv.name, 0.0) + iv.dur
                if iv.comm_bytes:
                    comm_bytes[iv.name] = comm_bytes.get(iv.name, 0.0) \
                        + iv.comm_bytes
                if iv.stream != "compute":
                    hid = _covered((iv.start, iv.end), cover)
                    overlapped += hid
                    exposed += iv.dur - hid
        out["top_ops_by_comm_bytes"] = sorted(
            comm_bytes.items(), key=lambda kv: -kv[1])[:top_k]
        out["block_exposed_comm_us"] = round(exposed, 3)
        out["block_overlapped_comm_us"] = round(overlapped, 3)
        out["block_compute_busy_us"] = round(compute_busy, 3)
        kind, tl = max(tls.items(), key=lambda kv: kv[1].total_time)
        path = critical_path(tl)
        ctime: dict[str, float] = {}
        for iv in path:
            ctime[iv.name] = ctime.get(iv.name, 0.0) + iv.dur
        out["critical_path"] = {
            "block": kind, "n_ops": len(path),
            "total_us": round(sum(iv.dur for iv in path), 3),
            "top_contributors_us": sorted(
                ctime.items(), key=lambda kv: -kv[1])[:top_k],
        }
    return out


def compact_report(rep, top_k: int = 3) -> dict:
    """The manifest-row form: small, JSON-safe, no timelines required."""
    d = explain_report(rep, top_k=top_k)
    return {"dominant_phase": d["dominant_phase"],
            "top_ops_by_time_us": d["top_ops_by_time_us"],
            "compute_frac": d["compute_frac"],
            "comm_frac": d["comm_frac"]}


def render_report(rep, top_k: int = 8) -> str:
    d = explain_report(rep, top_k=top_k)
    lines = [f"step report · mode={d['mode']} · "
             f"step {d['step_time_us']:.1f} us · mfu {d['mfu'] * 100:.1f}%"]
    lines.append("phase breakdown:")
    total = sum(d["breakdown_us"].values()) or 1.0
    for k, v in sorted(d["breakdown_us"].items(), key=lambda kv: -kv[1]):
        lines.append(f"  {k:<18} {v:>12.1f} us  {100 * v / total:>5.1f}%")
    lines.append(f"per-block serial sums: compute {d['compute_frac']:.1%} · "
                 f"comm {d['comm_frac']:.1%} · other {d['other_frac']:.1%}")
    lines.append(f"top op kinds by time (top {top_k}):")
    for k, v in d["top_ops_by_time_us"]:
        lines.append(f"  {k:<18} {v:>12.1f} us")
    if "top_ops_by_comm_bytes" in d:
        lines.append("top ops by comm bytes:")
        for k, v in d["top_ops_by_comm_bytes"]:
            lines.append(f"  {k:<28} {v / 1e6:>10.2f} MB")
        lines.append(
            f"comm exposure (priced block timelines): "
            f"{d['block_exposed_comm_us']:.1f} us exposed · "
            f"{d['block_overlapped_comm_us']:.1f} us hidden under compute")
        cp = d["critical_path"]
        lines.append(f"critical path (block {cp['block']!r}): "
                     f"{cp['n_ops']} ops, {cp['total_us']:.1f} us")
        for k, v in cp["top_contributors_us"]:
            lines.append(f"  {k:<28} {v:>10.1f} us")
    else:
        lines.append("(run with keep_timelines=True for per-op critical "
                     "path and comm-byte attribution)")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
def explain_serving(rep, top_k: int = 8) -> dict:
    """Structured attribution for a ``ServingReport`` or ``FleetReport``.

    The SLO-violation classifier charges each violating request to the
    phase that dominated it: a TTFT miss is ``queueing`` when the queue
    delay exceeds the prefill execution time (arrival→scheduled vs
    scheduled→first token), else ``prefill``; a TPOT miss is ``decode``.
    A request can contribute to both a TTFT and a TPOT cause.
    """
    slo = rep.slo
    causes = {"queueing": 0, "prefill": 0, "decode": 0}
    n_violating = 0
    for r in rep.requests:
        if slo is None or slo.met(r):
            continue
        n_violating += 1
        if r.ttft_s > slo.ttft_s:
            qd = r.queue_delay_s
            causes["queueing" if qd >= r.ttft_s - qd else "prefill"] += 1
        if r.tpot_ms > slo.tpot_ms:
            causes["decode"] += 1
    dominant = max(causes, key=causes.get) if n_violating else None
    steps = dict(rep.steps_by_kind)
    return {
        "n_requests": rep.n_requests,
        "makespan_s": round(rep.makespan_s, 3),
        "slo_attainment": round(rep.slo_attainment, 4),
        "goodput_rps": round(rep.goodput_rps, 4),
        "n_violating": n_violating,
        "slo_violation_cause": causes,
        "dominant_violation_cause": dominant,
        "queue_delay_share_of_ttft": round(
            rep.queue_delay_s.mean / rep.ttft_s.mean, 4)
        if rep.ttft_s.mean > 0 else 0.0,
        "steps_by_kind": steps,
        "utilization": {k: dict(v) for k, v in sorted(
            rep.utilization.items(),
            key=lambda kv: -kv[1].get("busy_frac", 0.0))[:top_k]},
    }


def compact_serving(rep) -> dict:
    d = explain_serving(rep, top_k=3)
    return {"dominant_violation_cause": d["dominant_violation_cause"],
            "slo_violation_cause": d["slo_violation_cause"],
            "queue_delay_share_of_ttft": d["queue_delay_share_of_ttft"],
            "slo_attainment": d["slo_attainment"]}


def render_serving(rep, top_k: int = 8) -> str:
    d = explain_serving(rep, top_k=top_k)
    lines = [f"serving report · {d['n_requests']} requests over "
             f"{d['makespan_s']:.1f} s · SLO attainment "
             f"{d['slo_attainment']:.1%} · goodput {d['goodput_rps']:.2f} rps"]
    if d["n_violating"]:
        c = d["slo_violation_cause"]
        lines.append(
            f"SLO violations ({d['n_violating']} requests) — dominant cause: "
            f"{d['dominant_violation_cause']} "
            f"(queueing {c['queueing']} · prefill {c['prefill']} · "
            f"decode {c['decode']})")
    else:
        lines.append("no SLO violations" if rep.slo is not None
                     else "no SLO attached")
    lines.append(f"queue delay is {d['queue_delay_share_of_ttft']:.1%} of "
                 "mean TTFT")
    lines.append("steps by kind: " + (", ".join(
        f"{k}={v}" for k, v in sorted(d["steps_by_kind"].items())) or "none"))
    lines.append(f"busiest lanes (top {top_k}):")
    for name, u in d["utilization"].items():
        phases = " ".join(f"{k[:-5]}={v:.0%}" for k, v in sorted(u.items())
                          if k.endswith("_frac") and k != "busy_frac")
        lines.append(f"  {name:<20} busy {u.get('busy_frac', 0.0):>6.1%}  "
                     f"{phases}")
    return "\n".join(lines)


def compact_resilience(rep) -> dict:
    """Manifest-row attribution for a ``ResilienceReport``: which bucket ate
    the wall clock."""
    wall = rep.wall_s or 1.0
    fr = {k: round(getattr(rep, f"{k}_s") / wall, 4)
          for k in ("useful", "rework", "straggler", "checkpoint", "downtime")}
    worst = max((k for k in fr if k != "useful"), key=fr.get)
    return {"goodput": round(rep.goodput, 6), "bucket_fracs": fr,
            "dominant_loss": worst if fr[worst] > 0 else None}

"""Unified observability layer: tracing, metrics and attribution.

Three pieces, shared by every Charon simulator (core step, serving, fleet,
resilience) and the sweep engine — see ``docs/observability.md``:

* :class:`TraceRecorder` / :data:`NULL_RECORDER` — span/instant/counter
  events merged into one Perfetto/chrome JSON; the null object keeps the
  recorder-off hot paths at a single branch per event.
* :class:`MetricsRegistry` — counters + histograms with a snapshot-and-diff
  API that unifies the scattered ``cache_stats()`` / oracle-hit /
  extrapolation dicts.
* ``explain()`` attribution (:mod:`repro.obs.explain`) — critical paths,
  top-k ops, compute-vs-comm decomposition, SLO-violation causes; surfaced
  as ``Report.explain()`` / ``ServingReport.explain()`` and in sweep
  manifest rows.
"""
from repro.obs.explain import (
    compact_report, compact_resilience, compact_serving, critical_path,
    explain_report, explain_serving, render_report, render_serving,
)
from repro.obs.metrics import HistStat, MetricsRegistry
from repro.obs.recorder import CNAMES, NULL_RECORDER, NullRecorder, TraceRecorder

__all__ = [
    "CNAMES", "NULL_RECORDER", "NullRecorder", "TraceRecorder",
    "HistStat", "MetricsRegistry",
    "compact_report", "compact_resilience", "compact_serving",
    "critical_path", "explain_report", "explain_serving",
    "render_report", "render_serving",
]

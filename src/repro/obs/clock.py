"""The one sanctioned wall clock for telemetry.

Simulated time comes from the event loops; hardware measurement time comes
from the measurement engines (``core/backend/profiling.py``,
``serving/sim/workload.py``).  Everything else that wants a wall-clock
reading — sweep progress lines, trace-lane epochs, wall_time_s telemetry —
must go through :func:`wall_s` so charon-lint rule R2 can ban ``time.time``
outright inside the deterministic scopes.

Epoch time (not a monotonic clock) is deliberate: sweep worker processes
stamp trace events independently, and only an epoch base lines their lanes
up in one merged Perfetto view.  Callers must never let these values feed
simulation results, cache keys, or report fields other than telemetry.
"""
from __future__ import annotations

import time


def wall_s() -> float:
    """Seconds since the epoch, for telemetry only (never simulation)."""
    return time.time()


def wall_span_s(t0: float) -> float:
    """Elapsed seconds since *t0* (a prior :func:`wall_s` reading)."""
    return time.time() - t0

"""Unified trace recording: one Perfetto/chrome JSON across all simulators.

Every Charon simulator — the core step simulator, the request-level serving
simulator, the fleet simulator and the resilience timeline — accepts a
``recorder=`` and emits its events into the same three primitives:

* ``span(pid, tid, name, start_s, dur_s)`` — a complete ("X") event on a
  lane, e.g. one engine iteration on a replica's pool, or a rework window
  on the resilience timeline;
* ``instant(pid, tid, name, ts_s)`` — a point ("i") event, e.g. a replica
  FAILURE, an autoscaler action, a KV-transfer migration, a sweep prune;
* ``counter(pid, name, ts_s, value)`` — a "C" series, e.g. queue depth.

Lanes are ``(pid, tid)`` string pairs — Perfetto groups tracks by pid — and
timestamps are *simulated seconds* (converted to the chrome convention of
microseconds at record time).  ``extend()`` adopts pre-built chrome events
(already in microseconds), which is how the core simulator's per-block
:func:`~repro.core.timeline.to_chrome_trace` output merges into the same
file.

The default everywhere is :data:`NULL_RECORDER`, a null object whose
``enabled`` is False; hot event loops guard each emission with one
attribute check (``if rec.enabled:``), so the off-mode cost is a branch —
the recorder-off contract (bit-identical reports, <2% wall overhead on
bench_fleet) is asserted in tests and guarded in CI.
"""
from __future__ import annotations

import json
from pathlib import Path

# chrome-trace "cname" palette entries used for the resilience buckets —
# Perfetto ignores unknown names gracefully, chrome://tracing colors them
CNAMES = {"useful": "good", "rework": "bad", "downtime": "terrible",
          "checkpoint": "grey", "straggler": "yellow"}


class NullRecorder:
    """Zero-overhead default: every hook is a no-op.

    Simulators store whatever recorder they are given and guard hot-path
    emissions with ``if rec.enabled:`` — with this object that is a single
    false attribute test per event, and no argument tuples are ever built.
    """

    enabled = False

    def span(self, pid, tid, name, start_s, dur_s, *, cat="", args=None,
             cname=None):
        return None

    def instant(self, pid, tid, name, ts_s, *, cat="", args=None):
        return None

    def counter(self, pid, name, ts_s, value):
        return None

    def extend(self, events):
        return None

    def events(self):
        return []


NULL_RECORDER = NullRecorder()


class TraceRecorder(NullRecorder):
    """Collects span/instant/counter events and exports one merged
    Perfetto-loadable chrome JSON (see :meth:`write` / :meth:`to_json`).

    ``max_request_lanes`` caps how many per-request lanes the serving
    simulators emit (a 100k-request trace would otherwise create 100k
    tracks); per the no-silent-caps rule the simulators emit a
    ``request_lanes_dropped`` metadata instant — and bump the matching
    metrics counter — whenever the cap bites.
    """

    enabled = True

    def __init__(self, *, max_request_lanes: int = 64):
        self.max_request_lanes = max_request_lanes
        self._events: list[dict] = []

    # ------------------------------------------------------------------
    def span(self, pid, tid, name, start_s, dur_s, *, cat="", args=None,
             cname=None):
        ev = {"name": name, "ph": "X", "ts": start_s * 1e6,
              "dur": max(dur_s, 0.0) * 1e6, "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        if cname:
            ev["cname"] = cname
        if args:
            ev["args"] = dict(args)
        self._events.append(ev)

    def instant(self, pid, tid, name, ts_s, *, cat="", args=None):
        ev = {"name": name, "ph": "i", "s": "t", "ts": ts_s * 1e6,
              "pid": pid, "tid": tid}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = dict(args)
        self._events.append(ev)

    def counter(self, pid, name, ts_s, value):
        series = value if isinstance(value, dict) else {"value": value}
        self._events.append({"name": name, "ph": "C", "ts": ts_s * 1e6,
                             "pid": pid, "tid": name,
                             "args": {k: float(v) for k, v in series.items()}})

    def extend(self, events):
        """Adopt pre-built chrome events (timestamps already in us) — the
        bridge from :func:`~repro.core.timeline.to_chrome_trace` /
        ``pp_trace`` output into the merged file."""
        self._events.extend(events)

    # ------------------------------------------------------------------
    def events(self) -> list[dict]:
        """All events, sorted by timestamp (ties keep insertion order) —
        the monotone-``ts`` form the exporter tests schema-validate."""
        return sorted(self._events, key=lambda e: e.get("ts", 0.0))

    def __len__(self) -> int:
        return len(self._events)

    def to_json(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def write(self, path: str | Path) -> Path:
        """Write the merged trace; load the file in ui.perfetto.dev or
        chrome://tracing."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json()))
        return path

"""Paper Fig. 1 — simulation cost vs cluster profiling cost.

One simulated design point costs seconds of one CPU core; profiling the same
point on the target fleet costs (cold launch + warmups) x chips.  The paper
reports >30,000x cost reduction for large-scale experiments.

This bench also tracks simulation *throughput* as a first-class metric:
``configs_per_sec`` for warm (cache-served) re-evaluations plus per-layer
cache hit rates, so ``BENCH_*.json`` records the perf trajectory of the
memoization stack (docs/performance.md).  Since PR 5 it additionally
exercises the persistent cross-run tier: a cold run populates an on-disk
cache, a fresh ``Simulator`` warm-starts from it, and the recorded
``ingest_hit_rate`` is that warm-from-disk run's rate — a *new* spec sharing
traced shapes skips JAX tracing entirely, and an exact repeat is served
whole from the ``reports`` tier.
"""
from __future__ import annotations

import dataclasses
import shutil
import tempfile
import time

from repro.api import Cluster, SimSpec, TrainWorkload
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.core.model_ingest import ingest_extrapolation_clear

# conservative profiling-run cost model (paper §2.2: cold launches + warmups
# consume hundreds of GPU hours per design point at cluster scale)
PROFILE_MINUTES_PER_POINT = 12.0     # one cold launch + 3 warm steps @ scale
CHIPS = 512                          # the multi-pod mesh


def run() -> list[dict]:
    # cache=False: this row measures the cost of one *new* design point (the
    # paper's comparison); cache-served repeats are measured separately below
    sim = Simulator("tpu_v5e", engine="analytical", cache=False)
    cfg = get_config("qwen2.5-32b")
    par = ParallelConfig(tp=16, dp=16, pods=2, sp=16, zero_stage=1)
    spec = SimSpec(cfg, cluster=Cluster("tpu_v5e", pods=2), parallel=par,
                   workload=TrainWorkload(global_batch=256, seq_len=4096))
    t0 = time.time()
    n = 6
    for i in range(n):
        # each rep must cost what a genuinely NEW design point costs: clear
        # the module-level batch-extrapolation memo so repeats re-trace
        ingest_extrapolation_clear()
        sim.run(spec)
    sim_s = (time.time() - t0) / n
    cluster_chip_seconds = PROFILE_MINUTES_PER_POINT * 60 * CHIPS
    sim_chip_seconds = sim_s  # one CPU core
    rows = [{
        "bench": "fig1_sim_cost", "case": "qwen2.5-32b train@512 chips",
        "sim_seconds_per_point": round(sim_s, 2),
        "cluster_chip_seconds_per_point": int(cluster_chip_seconds),
        "cost_reduction_x": int(cluster_chip_seconds / sim_chip_seconds),
        "paper_claim": ">30,000x cost reduction vs cluster profiling",
    }]

    # ---- cold vs warm: what the in-process memoization stack buys ----
    warm_sim = Simulator("tpu_v5e", engine="analytical", cache=True)
    ingest_extrapolation_clear()     # a true cold first call (re-traces)
    t0 = time.time()
    warm_sim.run(spec)
    cold_s = time.time() - t0        # first call on a fresh cache
    n_warm = 20
    t0 = time.time()
    for _ in range(n_warm):
        warm_sim.run(spec)
    warm_s = (time.time() - t0) / n_warm
    stats = warm_sim.cache_stats()

    # ---- persistent tier: a fresh process-equivalent warm-starts from disk
    # (fresh Simulator + SimCache; the pickle file is the only reuse channel)
    cache_dir = tempfile.mkdtemp(prefix="charon-cache-")
    try:
        seed = Simulator("tpu_v5e", engine="analytical", persist=cache_dir)
        seed.run(spec)
        seed.save_cache()
        # fresh-process equivalence: the pickle must be the only warm
        # channel, so drop the in-process extrapolation memo too
        ingest_extrapolation_clear()
        disk_sim = Simulator("tpu_v5e", engine="analytical",
                             persist=cache_dir)
        t0 = time.time()
        rep_repeat = disk_sim.run(spec)          # exact repeat: reports tier
        disk_first_s = time.time() - t0
        # a *changed* sweep point sharing traced shapes: new shard key means
        # passes/pricing rerun, but the persisted ingest entry skips tracing
        variant = dataclasses.replace(
            spec, parallel=dataclasses.replace(par, tp=8, sp=8))
        t0 = time.time()
        rep_variant = disk_sim.run(variant)
        disk_variant_s = time.time() - t0
        dstats = disk_sim.cache_stats()
        assert rep_repeat.step_time_us == seed.run(spec).step_time_us
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    rows.append({
        "bench": "fig1_sim_cost", "case": "cache_warm_vs_cold",
        "cold_seconds": round(cold_s, 3),
        "warm_seconds": round(warm_s, 4),
        "configs_per_sec": round(1.0 / warm_s, 1) if warm_s else 0.0,
        "speedup_x": round(cold_s / warm_s, 1) if warm_s else 0.0,
        "pricing_hit_rate": stats["pricing"]["hit_rate"],
        "block_stage_hit_rate": stats["block_times"]["hit_rate"],
        # warm-from-disk rate (a cold run can only ever report 0.0 here:
        # its single ingest miss is the trace that fills the cache)
        "ingest_hit_rate": dstats["ingest"]["hit_rate"],
        "memory_hit_rate": stats["memory"]["hit_rate"],
        "persistent_first_call_s": round(disk_first_s, 4),
        "persistent_variant_call_s": round(disk_variant_s, 4),
        "persistent_report_hits": dstats["reports"]["hits"],
        "persistent_ingest_hit_rate": dstats["ingest"]["hit_rate"],
        "mfu_checksum": rep_variant.mfu,
    })
    return rows

"""Paper Fig. 9 — memory prediction accuracy.

Ground truth: XLA's buffer-assignment (``compiled.memory_analysis()``) for a
tiny MoE train step on one device (the paper's FSDP=8 run measured allocator
stats on GPUs).  Simulated: liveness-based peak from core/memory.py plus the
static weight/grad/optimizer ledger.  Also cross-checks the dry-run records:
simulator per-device totals vs XLA per-device temp+args for a full-scale cell.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import PAR1, make_cpu_simulator
from repro.api import Cluster, SimSpec, TrainWorkload
from repro.configs import get_tiny_config
from repro.launch.specs import input_specs
from repro.models import Model, abstract_params
from repro.training.optimizer import make_optimizer
from repro.training.train_step import make_train_step
from repro.configs.base import RunConfig, ShapeConfig

REPO = Path(__file__).resolve().parents[1]


def run() -> list[dict]:
    rows = []
    # ---- tiny MoE train step vs XLA buffer assignment ----
    cfg = get_tiny_config("olmoe-1b-7b")
    B, S = 2, 512
    run_cfg = RunConfig(model=cfg, shape=ShapeConfig("m", S, B, "train"))
    opt = make_optimizer("adamw")
    step = make_train_step(cfg, run_cfg, opt)
    params = abstract_params(cfg)
    opt_abs = jax.eval_shape(opt.init, params)
    state = {"params": params, "opt": opt_abs, "step": jax.ShapeDtypeStruct((), jnp.int32)}
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    compiled = jax.jit(step).lower(state, batch).compile()
    ma = compiled.memory_analysis()
    xla_total = ma.argument_size_in_bytes + ma.temp_size_in_bytes

    sim = make_cpu_simulator("analytical")
    rep = sim.run(SimSpec(cfg, cluster=Cluster(sim.hw), parallel=PAR1,
                          workload=TrainWorkload(global_batch=B, seq_len=S,
                                                 remat="none")))
    sim_total = rep.memory.total
    rows.append({"bench": "fig9_memory", "case": "olmoe-tiny/train(B2,S512)",
                 "xla_bytes": int(xla_total), "sim_bytes": int(sim_total),
                 "error_pct": round((sim_total - xla_total) / xla_total * 100, 2),
                 "paper_claim": "max-allocated error +0.39%"})
    # component ledger for the record
    rows.append({"bench": "fig9_memory", "case": "olmoe-tiny/ledger",
                 **{k: int(v) for k, v in rep.memory.summary().items()}})

    # ---- full-scale cross-check against the dry-run record ----
    rec_path = REPO / "results" / "dryrun" / "gemma-7b__train_4k__single.json"
    if rec_path.exists():
        rec = json.loads(rec_path.read_text())
        xla_dev = (rec["memory_analysis"]["argument_bytes"]
                   + rec["memory_analysis"]["temp_bytes"])
        from repro.configs import get_config
        from repro.core import ParallelConfig, Simulator
        sim2 = Simulator("tpu_v5e", engine="analytical")
        par = ParallelConfig(tp=16, dp=16, sp=16, zero_stage=rec["zero_stage"])
        rep2 = sim2.run(SimSpec(
            get_config("gemma-7b"), cluster=Cluster("tpu_v5e"), parallel=par,
            workload=TrainWorkload(global_batch=256, seq_len=4096,
                                   remat="block")))
        rows.append({"bench": "fig9_memory", "case": "gemma-7b/train_4k@v5e-256",
                     "xla_bytes_per_dev": int(xla_dev),
                     "sim_bytes_per_dev": int(rep2.memory.total),
                     "ratio": round(rep2.memory.total / xla_dev, 3),
                     "note": "XLA temp is buffer-assignment upper bound (no donation aliasing)"})
    return rows

"""Paper Fig. 8 — simulated vs profiled execution traces.

Emits the simulator's single-layer chrome trace (PyTorch-profiler style) and
the 3D multi-rank pipeline trace, and structurally compares the simulated
single-layer op sequence with the real XLA execution (op-class counts).
Artifacts: results/traces/*.json — load in chrome://tracing / Perfetto.
"""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import PAR1, make_cpu_simulator
from repro.api import Cluster, PrefillWorkload, SimSpec
from repro.configs import get_tiny_config
from repro.core.passes.pipeline import make_schedule
from repro.core.timeline import pp_trace, to_chrome_trace, write_trace

OUT = Path(__file__).resolve().parents[1] / "results" / "traces"


def run() -> list[dict]:
    sim = make_cpu_simulator("fused")
    cfg = get_tiny_config("qwen2.5-32b")
    rep = sim.run(SimSpec(cfg, cluster=Cluster(sim.hw), parallel=PAR1,
                          workload=PrefillWorkload(global_batch=2,
                                                   seq_len=256)),
                  keep_timelines=True)
    kind = next(iter(rep.block_timelines))
    tl = rep.block_timelines[kind]
    p1 = write_trace(to_chrome_trace(tl, pid="layer0"), OUT / "single_layer.json")

    # 3D pipeline trace (16 ranks x 1F1B)
    sched = make_schedule("1f1b", 4, 8, 1000.0, 2000.0, 50.0)
    evs = []
    for dp in range(2):
        evs += pp_trace(sched, dp_rank=dp)
    p2 = write_trace(evs, OUT / "pp_3d_timeline.json")
    sim.db.save()
    return [{"bench": "fig8_traces", "single_layer_trace": str(p1),
             "n_ops": len(tl.intervals),
             "pp_3d_trace": str(p2), "n_pp_events": len(evs),
             "compute_us": round(tl.stream_time("compute"), 1)}]

"""Paper §5.1 — dynamic sequence-parallel planning case study.

Static zigzag (every request at full SP with zigzag chunking) vs the
simulator-planned per-request SP assignment over batches with heterogeneous
sequence lengths.  Paper: ~15% average attention-latency reduction on
LLaMA-3 70B / 8 GPUs, driven by short requests avoiding all-gather overhead.
We mirror with qwen2.5-32b head geometry on an 8-chip v5e SP group.
"""
from __future__ import annotations

import numpy as np

from repro.serving.sp_planner import plan_batch

WORKLOADS = {
    "uniform_short": [256, 384, 512, 256, 448, 320, 512, 384],
    "uniform_long": [16384, 12288, 16384, 8192],
    "bimodal(paper-like)": [512, 16384, 256, 8192, 384, 32768, 640, 1024],
    "power_law": [int(x) for x in np.random.default_rng(0).pareto(1.5, 10) * 2000 + 256],
}


def run() -> list[dict]:
    rows = []
    gains = []
    for name, lens in WORKLOADS.items():
        static = plan_batch(lens, d_head=128, n_heads=40, sp_world=8, dynamic=False)
        dyn = plan_batch(lens, d_head=128, n_heads=40, sp_world=8, dynamic=True)
        gain = 1.0 - dyn.makespan_us / static.makespan_us
        gains.append(gain)
        rows.append({"bench": "sec51_dynamic_sp", "workload": name,
                     "static_zigzag_us": round(static.makespan_us, 1),
                     "dynamic_sp_us": round(dyn.makespan_us, 1),
                     "latency_reduction_pct": round(gain * 100, 1),
                     "sp_choices": [f"sp{c.sp}{'z' if c.zigzag else ''}"
                                    for c in dyn.choices]})
    rows.append({"bench": "sec51_dynamic_sp", "workload": "AVERAGE",
                 "latency_reduction_pct": round(float(np.mean(gains)) * 100, 1),
                 "paper_claim": "~15% average attention latency reduction"})
    return rows

"""Paper Table 2 — operator-class time breakdown (sim vs measured).

Classes follow the paper: Attention / Feed-Forward / Others, forward and
backward for training, prefill and decode for inference.  Measured numbers
time the isolated jitted sub-module with identical shapes; simulated numbers
aggregate the block timeline by operator class.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import PAR1, make_cpu_simulator, median_time_us
from repro.api import Cluster, PrefillWorkload, SimSpec
from repro.configs import get_tiny_config
from repro.models import Model, init_params, layers as L
from repro.models.params import block_cycle

ATTN_KINDS = {"attention"}
FFN_KINDS = set()


def _classify(name_kind_flops, cfg):
    pass


def run() -> list[dict]:
    cfg = get_tiny_config("qwen2.5-32b")  # paper uses Qwen3-8B
    sim = make_cpu_simulator("fused")
    B, S = 2, 256
    params = init_params(cfg, jax.random.PRNGKey(0))
    block = jax.tree.map(lambda x: x[0], params["blocks"]["cycle"][0])
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) \
        .astype(jnp.bfloat16)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    # ---- measured per-class (isolated sub-modules) ----
    from repro.models.model import gqa_full
    attn_fn = jax.jit(lambda p, x: gqa_full(cfg, p, L.apply_norm(cfg, p_ln1, x),
                                            positions)[0])
    p_ln1 = block["ln1"]
    t_attn = median_time_us(attn_fn, block["attn"], x)
    ffn_fn = jax.jit(lambda p, x: L.ffn(cfg, p, L.apply_norm(cfg, block["ln2"], x)))
    t_ffn = median_time_us(ffn_fn, block["mlp"], x)
    model = Model(cfg)
    full_fn = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    t_total = median_time_us(full_fn, params, toks)
    n_layers = cfg.num_layers if False else len(params["blocks"]["cycle"][0])
    n_layers = jax.tree.leaves(params["blocks"]["cycle"][0])[0].shape[0]
    t_others = max(t_total - n_layers * (t_attn + t_ffn), 0.0)

    # ---- simulated per-class ----
    rep = sim.run(SimSpec(cfg, cluster=Cluster(sim.hw), parallel=PAR1,
                          workload=PrefillWorkload(global_batch=B, seq_len=S)),
                  keep_timelines=True)
    tl = rep.block_timelines[list(rep.block_timelines)[0]]
    sim_attn = sim_ffn = sim_other = 0.0
    for iv in tl.intervals:
        if iv.kind == "attention":
            sim_attn += iv.dur
        elif iv.kind in ("matmul", "fused"):
            # qkv/o projections belong to Attention; gate/up/down to FFN —
            # split by output size heuristic (ffn ops have d_ff dims)
            sim_ffn += iv.dur
        else:
            sim_other += iv.dur
    # move the 4 projection matmuls (of 7 per block) into attention by flop share
    proj_share = 4 * cfg.d_model * cfg.num_heads * cfg.head_dim / (
        4 * cfg.d_model * cfg.num_heads * cfg.head_dim + 3 * cfg.d_model * cfg.d_ff)
    sim_attn += sim_ffn * proj_share
    sim_ffn *= (1 - proj_share)
    head_time = rep.detail["t_fwd"].get("head", 0.0)
    sim_layer_other = sim_other
    sim_total = rep.step_time_us

    rows = [
        {"bench": "table2_breakdown", "class": "Attention(per-layer)",
         "measured_us": round(t_attn, 1), "sim_us": round(sim_attn, 1),
         "error_pct": round(abs(sim_attn - t_attn) / t_attn * 100, 1)},
        {"bench": "table2_breakdown", "class": "Feed-Forward(per-layer)",
         "measured_us": round(t_ffn, 1), "sim_us": round(sim_ffn, 1),
         "error_pct": round(abs(sim_ffn - t_ffn) / t_ffn * 100, 1)},
        {"bench": "table2_breakdown", "class": "Others(total)",
         "measured_us": round(t_others, 1),
         "sim_us": round(sim_layer_other * n_layers + head_time, 1),
         "error_pct": round(abs(sim_layer_other * n_layers + head_time - t_others)
                            / max(t_others, 1) * 100, 1)},
        {"bench": "table2_breakdown", "class": "End-to-end",
         "measured_us": round(t_total, 1), "sim_us": round(sim_total, 1),
         "error_pct": round(abs(sim_total - t_total) / t_total * 100, 1)},
    ]
    sim.db.save()
    return rows

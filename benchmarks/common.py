"""Shared benchmark utilities: real CPU measurement vs simulation.

The paper validates against GPU clusters; this container's ground truth is
XLA-CPU.  Methodology is identical: profile operators on the target ->
simulate -> compare end-to-end against real execution.  A single calibration
factor (framework dispatch overhead, measured once on a calibration model)
is applied across all models — matching the paper's "calibrated from
profiling" knobs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.api import Cluster, SimSpec, STEP_WORKLOADS
from repro.configs import get_tiny_config
from repro.core import ParallelConfig, Simulator
from repro.core.backend.profiling import ProfileDB
from repro.launch.specs import concrete_batch
from repro.models import Model, zero_cache
from repro.training.optimizer import make_optimizer
from repro.training.train_step import make_train_step
from repro.configs.base import RunConfig, ShapeConfig

PAR1 = ParallelConfig()  # single device


def median_time_us(fn, *args, iters: int = 12, warmup: int = 2) -> float:
    """Robust microbenchmark: min of N (the shared CPU core makes medians
    noisy; min approximates uncontended time, same on both sides)."""
    out = fn(*args)
    jax.block_until_ready(out)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    deadline = time.perf_counter() + 4.0
    n = 0
    while n < iters or (time.perf_counter() < deadline and n < 60):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
        n += 1
        if ts[-1] > 1.0:  # long steps: few iters suffice
            break
    return min(ts) * 1e6


def measure_real(cfg, *, mode: str, B: int, S: int, cache_len: int = 0) -> float:
    """Real wall time (us) of one step on XLA-CPU."""
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if mode == "train":
        run = RunConfig(model=cfg, shape=ShapeConfig("b", S, B, "train"))
        opt = make_optimizer("adamw")
        step = jax.jit(make_train_step(cfg, run, opt))
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        batch = {k: jnp.asarray(v) for k, v in concrete_batch(cfg, B, S, kind="train").items()}
        return median_time_us(lambda: step(state, batch))
    if mode == "prefill":
        batch = concrete_batch(cfg, B, S, kind="prefill")
        fn = jax.jit(lambda p, b: model.prefill(p, b, cache_len=S)[0])
        return median_time_us(fn, params, batch)
    # decode: donate the cache (in-place update, as production serving does)
    batch = concrete_batch(cfg, B, 1, kind="decode")
    step = jax.jit(lambda p, c, b: model.decode_step(p, c, b),
                   donate_argnums=(1,))
    cache = zero_cache(cfg, B, cache_len or S)
    logits, cache = step(params, cache, batch)  # compile
    jax.block_until_ready(logits)
    ts = []
    for _ in range(20):
        t0 = time.perf_counter()
        logits, cache = step(params, cache, batch)
        jax.block_until_ready(logits)
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


def make_cpu_simulator(engine: str = "fused") -> Simulator:
    return Simulator("xla_cpu", engine=engine, db=ProfileDB(),
                     measure_on_miss=True)


def simulate(sim: Simulator, cfg, *, mode: str, B: int, S: int,
             cache_len: int = 0, calib: float = 1.0) -> float:
    kw = dict(global_batch=B, seq_len=S, cache_len=cache_len)
    if mode == "train":
        kw["remat"] = "none"     # ground-truth CPU step runs without remat
    spec = SimSpec(cfg, cluster=Cluster(sim.hw), parallel=PAR1,
                   workload=STEP_WORKLOADS[mode](**kw))
    return sim.run(spec).step_time_us * calib


def calibration_factor(sim: Simulator) -> float:
    """Framework-overhead calibration on one model (gemma tiny prefill)."""
    cfg = get_tiny_config("gemma-7b")
    real = measure_real(cfg, mode="prefill", B=2, S=128)
    pred = simulate(sim, cfg, mode="prefill", B=2, S=128)
    return real / pred

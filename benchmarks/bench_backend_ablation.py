"""Paper Fig. 10 — analytical vs prediction engine on unseen shapes.

Profile a grid of Linear (matmul), RMSNorm and Attention (our FlashAttn-3
analogue) shapes on the local backend; hold out a set of unseen shapes; train
the random-forest prediction engine on the rest; compare MAE of the
prediction engine vs the analytical (roofline) engine on the held-out set.
Paper: analytical 31.84% MAE on FlashAttention vs prediction 1-2%.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.backend.analytical import AnalyticalEngine
from repro.core.backend.hardware import XLA_CPU
from repro.core.backend.prediction import PredictionEngine
from repro.core.backend.profiling import (ProfileDB, ProfilingEngine, node_key,
                                          synthesize_and_measure)
from repro.core.ir import OpNode


def _matmul_node(m, n, k):
    return OpNode(f"mm{m}x{n}x{k}", "matmul", flops=2.0 * m * n * k,
                  bytes_in=4.0 * (m * k + k * n), bytes_out=4.0 * m * n,
                  dtype="f32", out_shape=(m, n), attrs={"mm_dims": (m, n, k)})


def _norm_node(r, d):
    return OpNode(f"rms{r}x{d}", "norm", flops=3.0 * r * d,
                  bytes_in=4.0 * r * d, bytes_out=4.0 * r * d,
                  dtype="f32", out_shape=(r, d))


def _attn_node(b, h, sq, skv, d):
    fl = 2.0 * b * h * sq * skv * d * 2
    byts = 4.0 * b * h * (sq * d + 2 * skv * d + sq * skv)
    return OpNode(f"attn{b}x{h}x{sq}x{skv}", "attention", flops=fl,
                  bytes_in=byts, bytes_out=4.0 * b * h * sq * d, dtype="f32",
                  out_shape=(b, h, sq, d), attrs={"attn_dims": (b, h, sq, skv, d)})


def _grid():
    mats = [_matmul_node(m, n, k)
            for m, n, k in itertools.product((16, 64, 256, 1024),
                                             (32, 128, 512, 2048),
                                             (32, 128, 512, 2048))]
    norms = [_norm_node(r, d)
             for r, d in itertools.product((32, 128, 1024, 8192, 32768),
                                           (64, 256, 1024, 4096))]
    attns = [_attn_node(b, h, s, sk, 64)
             for b, h, s, sk in itertools.product((1, 2, 8), (2, 8, 16),
                                                  (64, 256, 1024), (256, 1024))]
    return mats, norms, attns


def run() -> list[dict]:
    db = ProfileDB()
    mats, norms, attns = _grid()
    nodes = mats + norms + attns
    # profile everything (cached across runs)
    for nd in nodes:
        key = node_key(nd, XLA_CPU.name)
        if db.get(key) is None:
            us = synthesize_and_measure(nd)
            if us is not None:
                db.put(key, us, {"kind": nd.kind,
                                 "dims": list(nd.attrs.get("mm_dims")
                                              or nd.attrs.get("attn_dims")
                                              or nd.out_shape),
                                 "dtype": nd.dtype, "flops": nd.flops,
                                 "bytes": nd.total_bytes})
    db.save()
    # hold out every 5th shape per kind (unseen at training time)
    holdout = {node_key(nd, XLA_CPU.name): nd for i, nd in enumerate(nodes)
               if i % 5 == 1}
    pred_eng = PredictionEngine(XLA_CPU, db)
    pred_eng.train(exclude_keys=set(holdout))
    ana_eng = AnalyticalEngine(XLA_CPU)

    rows = []
    for kind in ("matmul", "norm", "attention"):
        errs_p, errs_a = [], []
        for key, nd in holdout.items():
            if nd.kind != kind:
                continue
            real = db.get(key)
            if real is None:
                continue
            p = pred_eng.latency_us(nd)
            a = ana_eng.latency_us(nd)
            if p is not None:
                errs_p.append(abs(p - real) / real * 100)
            errs_a.append(abs(a - real) / real * 100)
        label = {"matmul": "Linear", "norm": "RMSNorm",
                 "attention": "FlashAttn(analogue)"}[kind]
        rows.append({"bench": "fig10_backend_ablation", "operator": label,
                     "n_holdout": len(errs_a),
                     "analytical_mae_pct": round(float(np.mean(errs_a)), 2),
                     "prediction_mae_pct": round(float(np.mean(errs_p)), 2)
                     if errs_p else None})
    rows.append({"bench": "fig10_backend_ablation", "operator": "paper_claim",
                 "analytical_mae_pct": "31.84 (FlashAttn-3)",
                 "prediction_mae_pct": "1.44/1.12/2.22"})
    return rows

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo contract, plus the
full JSON record to results/benchmarks.json and a compact perf-trajectory
summary (configs/sec, cache hit rates, serving req/s) to the repo-root
``BENCH_sim.json`` so the numbers are comparable across PRs.

The shimmed legacy surfaces (``simulate()``/``explore()`` kwargs) are for
external users only: this harness escalates ``CharonDeprecationWarning`` to
an error so no benchmark silently regresses onto the deprecated path.
"""
from __future__ import annotations

import datetime
import json
import sys
import time
import traceback
import warnings
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results"
BENCH_SIM = REPO / "BENCH_sim.json"

BENCHES = [
    ("fig7_accuracy", "benchmarks.bench_accuracy"),
    ("table2_breakdown", "benchmarks.bench_breakdown"),
    ("fig8_traces", "benchmarks.bench_trace"),
    ("fig9_memory", "benchmarks.bench_memory"),
    ("fig10_backend_ablation", "benchmarks.bench_backend_ablation"),
    ("fig11_scale", "benchmarks.bench_scale"),
    ("fig13_dse", "benchmarks.bench_explore"),
    ("sec51_dynamic_sp", "benchmarks.bench_dynamic_sp"),
    ("fig1_sim_cost", "benchmarks.bench_sim_speed"),
    ("sec53_serving", "benchmarks.bench_serving"),
    ("sec53_fleet", "benchmarks.bench_fleet"),
    ("sec54_resilience", "benchmarks.bench_resilience"),
]


def _perf_summary(rows: list[dict]) -> dict:
    """Extract the cross-PR perf-trajectory metrics from benchmark rows."""
    out: dict = {}
    for r in rows:
        bench, case = r.get("bench"), r.get("case", "")
        if bench == "fig1_sim_cost" and case == "cache_warm_vs_cold":
            out["warm_configs_per_sec"] = r.get("configs_per_sec")
            out["cold_seconds"] = r.get("cold_seconds")
            # ingest is the warm-from-disk run's rate (a cold run's would
            # always read 0.0: its one miss is the trace filling the cache)
            out["cache_hit_rates"] = {
                k: r.get(f"{k}_hit_rate")
                for k in ("pricing", "block_stage", "ingest", "memory")}
            out["persistent_cache"] = {
                "first_call_s": r.get("persistent_first_call_s"),
                "variant_call_s": r.get("persistent_variant_call_s"),
                "report_hits": r.get("persistent_report_hits"),
                "ingest_hit_rate": r.get("persistent_ingest_hit_rate")}
        elif bench == "fig13_dse" and case == "exploration":
            out["sweep_configs_per_sec"] = r.get("configs_per_sec")
            out["sweep_wall_s"] = r.get("wall_s")
            out["sweep_pricing_hit_rate"] = r.get("pricing_hit_rate")
            out["sweep_n_reuse_groups"] = r.get("n_reuse_groups")
        elif bench == "fig13_dse" and case == "exploration_workers":
            out["sweep_workers"] = r.get("workers")
            # steady state on the long-lived pool (warm workers + caches);
            # the cold key tracks the one-time spawn/import tax separately
            out["sweep_workers_configs_per_sec"] = r.get("configs_per_sec")
            out["sweep_workers_cold_configs_per_sec"] = \
                r.get("cold_configs_per_sec")
        elif bench == "serving_sim" and "sim_requests_per_sec" in r:
            out.setdefault("serving_requests_per_sec", {})[case] = \
                r["sim_requests_per_sec"]
            out.setdefault("serving_oracle_hit_rate", {})[case] = \
                r.get("oracle_hit_rate")
        elif bench == "fleet_sim" and "sim_requests_per_sec" in r:
            out.setdefault("fleet_requests_per_sec", {})[case] = \
                r["sim_requests_per_sec"]
            out.setdefault("fleet_oracle_hit_rate", {})[case] = \
                r.get("oracle_hit_rate")
        elif bench == "fleet_sim" and case == "fleet_sweep":
            out["fleet_sweep_wall_s"] = r.get("wall_s")
        elif bench == "fleet_sim" and case == "fleet_obs_overhead":
            out["obs_overhead_pct"] = r.get("obs_overhead_pct")
            out["obs_off_requests_per_sec"] = r.get("off_requests_per_sec")
            out["obs_trace_events"] = r.get("trace_events")
        elif bench == "resilience_sim" and case == "goodput_under_mtbf":
            out["resilience_goodput"] = r.get("goodput")
            out["resilience_timeline_steps_per_sec"] = \
                r.get("timeline_steps_per_sec")
            out["resilience_optimal_interval"] = \
                r.get("simulated_optimal_interval_steps")
        elif bench == "resilience_sim" and case == "interval_sweep":
            out["resilience_sweep_wall_s"] = r.get("wall_s")
    return out


def _write_bench_sim(rows: list[dict]) -> None:
    summary = _perf_summary(rows)
    if not summary:
        return
    # partial runs (run.py <filter>) update only the keys they produce
    prev = {}
    if BENCH_SIM.exists():
        try:
            prev = json.loads(BENCH_SIM.read_text())
        except (json.JSONDecodeError, OSError):
            prev = {}
    prev.update(summary)
    # UTC: CI's freshness check compares against `date -u +%F`
    prev["updated"] = datetime.datetime.now(datetime.timezone.utc) \
        .date().isoformat()
    BENCH_SIM.write_text(json.dumps(prev, indent=1, sort_keys=True) + "\n")


def main() -> None:
    import importlib

    from repro.api import CharonDeprecationWarning
    warnings.simplefilter("error", CharonDeprecationWarning)
    all_rows = []
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, module in BENCHES:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            rows = importlib.import_module(module).run()
            status = "ok"
        except Exception as e:
            rows = [{"bench": name, "error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()[-2000:]}]
            status = "error"
        wall_us = (time.time() - t0) * 1e6
        derived = json.dumps(rows[-1], default=str).replace(",", ";")
        print(f"{name},{wall_us:.0f},{status}:{derived[:240]}")
        all_rows.extend(rows)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "benchmarks.json").write_text(json.dumps(all_rows, indent=1, default=str))
    _write_bench_sim(all_rows)
    # human-readable dump
    for r in all_rows:
        print("  ", json.dumps(r, default=str)[:400])


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo contract, plus the
full JSON record to results/benchmarks.json.
"""
from __future__ import annotations

import json
import sys
import time
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results"

BENCHES = [
    ("fig7_accuracy", "benchmarks.bench_accuracy"),
    ("table2_breakdown", "benchmarks.bench_breakdown"),
    ("fig8_traces", "benchmarks.bench_trace"),
    ("fig9_memory", "benchmarks.bench_memory"),
    ("fig10_backend_ablation", "benchmarks.bench_backend_ablation"),
    ("fig11_scale", "benchmarks.bench_scale"),
    ("fig13_dse", "benchmarks.bench_explore"),
    ("sec51_dynamic_sp", "benchmarks.bench_dynamic_sp"),
    ("fig1_sim_cost", "benchmarks.bench_sim_speed"),
    ("sec53_serving", "benchmarks.bench_serving"),
]


def main() -> None:
    import importlib
    all_rows = []
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, module in BENCHES:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            rows = importlib.import_module(module).run()
            status = "ok"
        except Exception as e:
            rows = [{"bench": name, "error": f"{type(e).__name__}: {e}",
                     "traceback": traceback.format_exc()[-2000:]}]
            status = "error"
        wall_us = (time.time() - t0) * 1e6
        derived = json.dumps(rows[-1], default=str).replace(",", ";")
        print(f"{name},{wall_us:.0f},{status}:{derived[:240]}")
        all_rows.extend(rows)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "benchmarks.json").write_text(json.dumps(all_rows, indent=1, default=str))
    # human-readable dump
    for r in all_rows:
        print("  ", json.dumps(r, default=str)[:400])


if __name__ == "__main__":
    main()

"""Resilience-aware training simulation (goodput under MTBF).

Two cases on a qwen2.5-32b training spec (v5e, tp=4 x dp=8, 4 hosts):

* ``goodput_under_mtbf`` — the headline scenario: 2000 steps under a
  4-hour host MTBF with priced sync checkpoints every 100 steps.  Reports
  goodput, the lost-work breakdown, and the goodput-vs-checkpoint-interval
  curve replayed against the *same* seeded failure trace — with the
  simulated optimal interval next to the Young/Daly closed form.  The perf
  number is replayed timeline steps per second of wall time (the step
  oracle prices each mesh once; the replay itself is bookkeeping).
* ``interval_sweep`` — checkpoint cadence x spare capacity ranked by
  useful tokens/sec via ``sweep(objective="goodput_under_failures")``,
  with the provenance manifest written next to the results.  Every
  candidate replays the identical trace, so the ranking isolates the
  policy, not the luck of the failure draw.
"""
from __future__ import annotations

import os
import time
from pathlib import Path

from repro.api import (
    CheckpointSpec, Cluster, FaultModel, ResilienceSpec, SimSpec, SweepSpace,
    TrainWorkload, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.resilience import ResilienceSimulator

RESULTS = Path(__file__).resolve().parents[1] / "results"


def _base(res: ResilienceSpec | None) -> SimSpec:
    cfg = get_config("qwen2.5-32b")
    return SimSpec(cfg, cluster=Cluster("tpu_v5e"),
                   parallel=ParallelConfig(tp=4, dp=8),
                   workload=TrainWorkload(global_batch=256, seq_len=4096,
                                          resilience=res))


def run() -> list[dict]:
    sim = Simulator("tpu_v5e", engine="analytical")
    rows = []

    # -- goodput under a 4h host MTBF ----------------------------------
    res = ResilienceSpec(
        total_steps=2000,
        faults=FaultModel(host_mtbf_s=4 * 3600.0, seed=7),
        ckpt=CheckpointSpec(interval_steps=100),
        chips_per_host=8, spares=1, restart_delay_s=60.0, repair_s=1800.0,
        optimize_interval=True)
    t0 = time.time()
    rep = ResilienceSimulator(sim).run(_base(res))
    wall = time.time() - t0
    s = rep.summary()
    # timeline work: the configured run plus every interval candidate
    # replays total_steps priced steps against the same trace
    replays = 1 + sum(1 for c in rep.goodput_by_interval
                      if c != rep.interval_steps)
    rows.append({
        "bench": "resilience_sim", "case": "goodput_under_mtbf",
        "total_steps": rep.total_steps, "wall_s": round(wall, 2),
        "timeline_steps_per_sec": round(
            replays * rep.total_steps / max(wall, 1e-9), 1),
        "goodput": s["goodput"],
        "wall_clock_s": s["wall_s"], "ideal_s": s["ideal_s"],
        "useful_s": s["useful_s"], "rework_s": s["rework_s"],
        "checkpoint_s": s["checkpoint_s"], "downtime_s": s["downtime_s"],
        "n_failures": s["n_failures"], "n_restarts": s["n_restarts"],
        "n_spare_swaps": s["n_spare_swaps"],
        "save_s": s["save_s"], "mtbf_system_s": s["mtbf_system_s"],
        "young_daly_interval_steps": s["young_daly_interval_steps"],
        "simulated_optimal_interval_steps":
            s["simulated_optimal_interval_steps"],
        "goodput_by_interval": {str(k): round(v, 4)
                                for k, v in sorted(
                                    rep.goodput_by_interval.items())},
        "paper_claim": "goodput-under-MTBF with priced checkpoints; "
                       "simulated optimal interval vs Young/Daly",
    })

    # -- checkpoint cadence x spares, ranked by useful tokens/sec ------
    workers = min(4, os.cpu_count() or 1)
    base = _base(ResilienceSpec(
        total_steps=1000,
        faults=FaultModel(host_mtbf_s=2 * 3600.0, seed=7),
        ckpt=CheckpointSpec(interval_steps=100),
        chips_per_host=8, restart_delay_s=60.0, repair_s=1800.0,
        optimize_interval=False))
    space = SweepSpace(base, {
        "workload.resilience.ckpt.interval_steps": (25, 50, 100, 200, 400),
        "workload.resilience.spares": (0, 1)})
    RESULTS.mkdir(parents=True, exist_ok=True)
    manifest = RESULTS / "resilience_sweep_manifest.json"
    t0 = time.time()
    swept = sweep(space, objective="goodput_under_failures", workers=workers,
                  manifest=str(manifest))
    wall = time.time() - t0
    ranked = swept.ranked()
    rows.append({
        "bench": "resilience_sim", "case": "interval_sweep",
        "n_candidates": len(swept.evaluated), "workers": swept.workers,
        "wall_s": round(wall, 2),
        "under_60s": wall < 60.0,
        "manifest": manifest.name,
        "ranking": [{
            "interval_steps": r.spec.workload.resilience.ckpt.interval_steps,
            "spares": r.spec.workload.resilience.spares,
            "goodput": round(r.resilience.goodput, 4),
            "useful_tokens_per_s": round(r.resilience.tokens_per_s, 1),
        } for r in ranked],
        "paper_claim": "checkpoint-cadence x spare-capacity ranking under "
                       "a fixed seeded failure trace",
    })
    return rows

"""Fleet-scale serving simulation (replica pools, routing, autoscaling).

Three cases on qwen2.5-32b decode replicas (v5e, tp=8):

* ``fleet_diurnal`` — the headline scale claim: a 100k-request diurnal trace
  (~an hour of simulated traffic) through 8 least-loaded-routed replicas.
  The number that matters is simulated requests/sec of wall time and the
  step-oracle hit rate — the whole fleet prices through one bucketed step
  table, so fleet size adds queue bookkeeping, not simulator calls.
* ``fleet_autoscale_flash`` — a flash crowd against a 2..8-replica
  autoscaler: scale events, post-flash drain, attainment.
* ``fleet_obs_overhead`` — the observability cost claim: the same diurnal
  trace with the trace recorder off (warm) vs on.  The off number guards
  the zero-overhead-when-off contract (CI fails if it regresses >2% vs
  the committed baseline); the on run writes the merged Perfetto trace to
  ``results/fleet_trace.json`` (uploaded as a CI artifact).
* ``fleet_sweep`` — the deployment question the API redesign exists for:
  rank replicas x prefill-disaggregation by fleet SLO goodput on a
  100k-request diurnal trace (one candidate per worker process, up to the
  core count), with the provenance manifest written next to the results.
"""
from __future__ import annotations

import os
import time
from pathlib import Path

from repro.api import (
    AutoscalerSpec, Cluster, FleetSpec, RouterSpec, ServingWorkload, SimSpec,
    SweepSpace, spec_replace, sweep,
)
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.serving.sim import SLO, LengthDist, ServingSimulator

RESULTS = Path(__file__).resolve().parents[1] / "results"


def _base(n: int, **kw) -> SimSpec:
    cfg = get_config("qwen2.5-32b")
    w = dict(
        n_requests=n, arrival="diurnal", rate_rps=20.0, period_s=600.0,
        diurnal_amp=0.8,
        prompt=LengthDist("lognormal", median=512.0, sigma=0.6, cap=3072),
        output=LengthDist("lognormal", median=48.0, sigma=0.5, cap=192),
        seed=7, slo=SLO(ttft_s=2.0, tpot_ms=60.0), max_batch=32,
        fleet=FleetSpec(replicas=8, router=RouterSpec("least_loaded")))
    w.update(kw)
    return SimSpec(cfg, cluster=Cluster("tpu_v5e"),
                   parallel=ParallelConfig(tp=8),
                   workload=ServingWorkload(**w))


def run() -> list[dict]:
    sim = Simulator("tpu_v5e", engine="analytical")
    rows = []

    # -- 100k-request diurnal trace, 8 replicas ------------------------
    spec = _base(100_000)
    t0 = time.time()
    rep = ServingSimulator(sim).run(spec)
    wall = time.time() - t0
    s = rep.summary()
    counts = sorted(rep.replica_requests.values())
    rows.append({
        "bench": "fleet_sim", "case": "fleet_diurnal",
        "n_requests": rep.n_requests, "n_replicas": rep.n_replicas,
        "router": rep.router, "trace_hours": round(rep.makespan_s / 3600, 2),
        "wall_s": round(wall, 2),
        "sim_requests_per_sec": round(rep.n_requests / max(wall, 1e-9), 1),
        "engine_steps": s["n_steps"],
        "oracle_hit_rate": s["oracle_stats"].get("hit_rate", 0.0),
        "oracle_distinct_steps": s["oracle_stats"].get("distinct_steps", 0),
        "replica_requests_min_max": [counts[0], counts[-1]],
        "ttft_p99_s": s["ttft_p99_s"], "tpot_p99_ms": s["tpot_p99_ms"],
        "slo_attainment": s["slo_attainment"],
        "goodput_rps": s["goodput_rps"],
    })

    # -- observability overhead: recorder off (warm) vs on -------------
    # the cold run above already warmed the step oracle, so both timed
    # runs below measure event-loop cost, not simulator pricing
    from repro.obs import MetricsRegistry, TraceRecorder
    spec = _base(100_000)
    t0 = time.time()
    rep_off = ServingSimulator(sim).run(spec)
    wall_off = time.time() - t0
    rec = TraceRecorder()
    t0 = time.time()
    rep_on = ServingSimulator(sim).run(spec, recorder=rec,
                                       metrics=MetricsRegistry())
    wall_on = time.time() - t0
    n_events_full = len(rec)
    del rec  # ~1.3M event dicts; don't hold them across the sweep below
    # the uploadable sample trace comes from a shorter slice of the same
    # workload — the full 100k-request trace is a couple hundred MB of
    # JSON, which neither CI artifacts nor ui.perfetto.dev want
    sample = _base(10_000)
    rec = TraceRecorder()
    ServingSimulator(sim).run(sample, recorder=rec)
    RESULTS.mkdir(parents=True, exist_ok=True)
    trace_path = RESULTS / "fleet_trace.json"
    rec.write(trace_path)
    rows.append({
        "bench": "fleet_sim", "case": "fleet_obs_overhead",
        "n_requests": rep_off.n_requests,
        "wall_off_s": round(wall_off, 3), "wall_on_s": round(wall_on, 3),
        "obs_overhead_pct": round(
            (wall_on - wall_off) / max(wall_off, 1e-9) * 100.0, 1),
        "off_requests_per_sec": round(
            rep_off.n_requests / max(wall_off, 1e-9), 1),
        "reports_identical": rep_off.summary() == rep_on.summary(),
        "recorded_events": n_events_full,
        "trace_file": trace_path.name,
        "trace_events": len(rec),
        "trace_n_requests": sample.workload.n_requests,
    })
    del rec

    # -- flash crowd vs autoscaler -------------------------------------
    spec = _base(
        20_000, arrival="flash_crowd", rate_rps=8.0, flash_start_s=120.0,
        flash_dur_s=300.0, flash_mult=6.0,
        fleet=FleetSpec(replicas=2, router=RouterSpec("least_loaded"),
                        autoscaler=AutoscalerSpec(
                            min_replicas=2, max_replicas=8,
                            scale_up_queue=8.0, scale_down_queue=1.0,
                            interval_s=5.0, cooldown_s=20.0,
                            provision_s=30.0)))
    t0 = time.time()
    rep = ServingSimulator(sim).run(spec)
    wall = time.time() - t0
    s = rep.summary()
    actions = [e["action"] for e in rep.autoscaler_trace]
    rows.append({
        "bench": "fleet_sim", "case": "fleet_autoscale_flash",
        "n_requests": rep.n_requests, "wall_s": round(wall, 2),
        "sim_requests_per_sec": round(rep.n_requests / max(wall, 1e-9), 1),
        "oracle_hit_rate": s["oracle_stats"].get("hit_rate", 0.0),
        "scale_ups": sum(1 for a in actions if a.startswith("scale_up")),
        "scale_downs": sum(1 for a in actions if a.startswith("scale_down")),
        "replicas_used": sum(1 for v in rep.replica_requests.values() if v),
        "ttft_p99_s": s["ttft_p99_s"],
        "slo_attainment": s["slo_attainment"],
        "goodput_rps": s["goodput_rps"],
    })

    # -- fleet goodput sweep: replicas x disaggregation ----------------
    # shorter outputs + batch 64 keep the per-candidate event count down;
    # candidates shard one-per-worker (bit-identical to serial), but on a
    # 1-2 core CI runner extra spawned workers only add jax-import overhead
    workers = min(4, os.cpu_count() or 1)
    # short chat outputs; prefill_batch=1 because batched FCFS prefill pads
    # to the longest prompt in the batch and prefill is compute-bound anyway
    base = spec_replace(
        _base(100_000),
        {"workload.rate_rps": 16.0,
         "workload.output": LengthDist("lognormal", median=12.0, sigma=0.5,
                                       cap=48),
         "workload.max_batch": 64,
         "workload.fleet.prefill_batch": 1})
    space = SweepSpace(base, {
        "workload.fleet.replicas": (4, 8),
        "workload.fleet.prefill_replicas": (0, 4)})
    RESULTS.mkdir(parents=True, exist_ok=True)
    manifest = RESULTS / "fleet_sweep_manifest.json"
    t0 = time.time()
    res = sweep(space, objective="goodput", workers=workers,
                manifest=str(manifest))
    wall = time.time() - t0
    ranked = res.ranked()
    rows.append({
        "bench": "fleet_sim", "case": "fleet_sweep",
        "n_candidates": len(res.evaluated), "workers": res.workers,
        "n_requests_each": base.workload.n_requests,
        "wall_s": round(wall, 2),
        "under_60s": wall < 60.0,
        "manifest": manifest.name,
        "ranking": [{
            "replicas": r.spec.workload.fleet.replicas,
            "prefill_replicas": r.spec.workload.fleet.prefill_replicas,
            "goodput_rps": round(r.goodput_rps, 2),
            "slo_attainment": round(r.serving.slo_attainment, 4),
        } for r in ranked],
        "paper_claim": "fleet-level deployment ranking (replicas x "
                       "disaggregation) on 100k-request traces in tens of "
                       "seconds",
    })
    return rows

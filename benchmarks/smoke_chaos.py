"""Chaos smoke: a pooled sweep under an injected fault schedule must be
bit-identical to the fault-free serial sweep.

CI runs this with ``CHARON_FAULTS`` set (crash + hang + poison-candidate +
shard-corruption rates); locally it falls back to a built-in schedule.
Deliberately standalone — it must NOT go through ``benchmarks/run.py``
(which rewrites BENCH_sim.json and would skew the committed throughput
baselines the regression guards compare against).

Checks, in order:

* the fault plan parsed from the env actually *fires* (nonzero injected
  fault counters — a schedule that never fires verifies nothing);
* rankings, reports and pruned reasons of the chaotic pooled sweep equal
  the fault-free serial sweep's exactly (or, if retries were exhausted,
  every quarantined candidate is reported with its reason and the
  surviving rows still match serial);
* corrupted cache shards were quarantined, never merged.

Exits non-zero on any divergence.
"""
from __future__ import annotations

import os
import sys
import tempfile

# a schedule verified to fire on this space (seed-scanned; see
# tests/test_pool_robustness.py for the methodology)
DEFAULT_FAULTS = ("worker_crash:0.3,worker_hang:0.15,candidate_error:0.2,"
                  "cache_corrupt:1.0,seed:3,hang_s:60")
os.environ.setdefault("CHARON_FAULTS", DEFAULT_FAULTS)

from repro.analysis.chaos import FaultPlan
from repro.api import Cluster, DecodeWorkload, SimSpec, SweepSpace, sweep
from repro.api.pool import RetryPolicy, shutdown_pools
from repro.configs import get_config


def _space():
    base = SimSpec(get_config("xlstm-125m"),
                   cluster=Cluster("tpu_v5e", chips=16, memory_limit=16e9),
                   workload=DecodeWorkload(global_batch=8, seq_len=1024))
    return SweepSpace(base, {"tp": (1, 2, 4), "pp": (1, 2),
                             "batch": (8, 16, 32)})


def _key(res):
    return ([(r.cand.key(), r.report.step_time_us,
              sorted(r.report.kind_us.items())) for r in res.evaluated],
            [(r.cand.key(), r.reason) for r in res.pruned],
            [(r.cand.key(), r.report.step_time_us) for r in res.ranked()])


def main() -> int:
    plan = FaultPlan.from_env()
    assert plan is not None and plan.enabled, "CHARON_FAULTS not set"
    print(f"chaos schedule: {plan}")

    serial = sweep(_space(), faults=FaultPlan())        # fault-free baseline
    with tempfile.TemporaryDirectory() as tmp:
        chaotic = sweep(
            _space(), workers=2, persist=tmp, faults=plan,
            retry=RetryPolicy(timeout_s=3.0, backoff_s=0.01,
                              backoff_max_s=0.1))
        corrupt = [f for f in os.listdir(tmp) if f.endswith(".corrupt")]
        leftover = [f for f in os.listdir(tmp) if f.endswith(".shard")]
    c = chaotic.metrics.get("counters", {})
    injected = {k: int(c.get(f"pool.{k}", 0))
                for k in ("worker_deaths", "timeouts", "candidate_errors",
                          "retries", "respawns", "cache_shards_quarantined")}
    print(f"injected/recovered: {injected}")
    assert sum(injected.values()) > 0, \
        "fault schedule never fired — the smoke verified nothing"
    if plan.cache_corrupt > 0:
        assert injected["cache_shards_quarantined"] >= 1 and corrupt, \
            "corrupt shards were not quarantined"
    assert not leftover, f"unmerged shards left behind: {leftover}"

    if chaotic.failed:
        # retries exhausted (a repeat:1 schedule): quarantine must be clean
        print(f"quarantined {len(chaotic.failed)} candidate(s):")
        for f in chaotic.failed:
            print(f"  {f.spec.json_hash()[:12]} after {f.attempts} "
                  f"attempt(s): {f.reason}")
        survived = {r.spec.json_hash() for r in chaotic.evaluated}
        s_key = _key(serial)
        ch = _key(chaotic)
        assert [x for x in s_key[0]
                if x[0] in {r.cand.key() for r in chaotic.evaluated}] \
            and all(row in s_key[0] for row in ch[0]), \
            "surviving rows diverged from serial"
        assert survived, "every candidate quarantined — schedule too hot"
    else:
        assert _key(chaotic) == _key(serial), \
            "chaotic pooled sweep diverged from fault-free serial"
        print(f"bit-identical to serial: {len(chaotic.evaluated)} evaluated,"
              f" {len(chaotic.pruned)} pruned, 0 quarantined")

    shutdown_pools()
    print("chaos smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

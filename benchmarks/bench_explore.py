"""Paper Fig. 13 + §5.2 — design-space exploration for inference serving.

Explores (tp, pp, batch) for qwen2.5-32b decode on a v5e-256 pod (the paper
used LLaMA-3 70B on Hopper GPUs), prunes invalid configs, reports the Pareto
frontier of TPS/chip vs TPS/user, the best config under a TPOT SLO, and the
improvement over an "engineering baseline" (hand-tuned heuristic: tp=8,
batch=64).  Also records the exploration wall-time (paper: "within two
minutes").
"""
from __future__ import annotations

import time

from repro.api import Cluster, DecodeWorkload, SimSpec, SweepSpace, sweep
from repro.configs import get_config
from repro.core import Simulator


def _warm_runtime(cfg) -> None:
    """Absorb one-time process init (jax trace machinery, jnp ufunc jit
    caches, dtype registries) before the clock starts, so a standalone
    ``run.py fig13`` measures sweep throughput — not interpreter warmup —
    and matches the full-suite run where earlier benches already paid it.
    Touches no simulator cache: it traces one throwaway *tiny* block
    (ingest caches are per-``Simulator``; this calls ``block_graphs``
    directly), never a sweep shape."""
    import dataclasses

    from repro.core.model_ingest import block_graphs
    tiny = dataclasses.replace(cfg, name="warmup-tiny", num_layers=2,
                               d_model=128, num_heads=2, num_kv_heads=2,
                               d_ff=256, vocab_size=512, head_dim=0)
    block_graphs(tiny, 1, 1, "decode", cache_len=256)


def run() -> list[dict]:
    cfg = get_config("qwen2.5-32b")
    sim = Simulator("tpu_v5e", engine="analytical")
    base = SimSpec(cfg, cluster=Cluster("tpu_v5e", chips=256,
                                        memory_limit=16e9),
                   workload=DecodeWorkload(seq_len=8192))
    space = SweepSpace(base, {"tp": (4, 8, 16, 32), "pp": (1, 2, 4),
                              "batch": (16, 32, 64, 128, 256, 512)})
    _warm_runtime(cfg)
    t0 = time.time()
    res = sweep(space, sim=sim)
    wall = time.time() - t0
    front = res.pareto()
    pr = res.cache_stats.get("pricing", {"hits": 0, "misses": 0})
    pr_rate = pr["hits"] / max(pr["hits"] + pr["misses"], 1)
    rows = [{"bench": "fig13_dse", "case": "exploration",
             "n_evaluated": len(res.evaluated), "n_pruned": len(res.pruned),
             "wall_s": round(wall, 1),
             "configs_per_sec": round(res.configs_per_sec, 1),
             "n_reuse_groups": res.n_groups,
             "workers": res.workers,
             "pricing_hit_rate": round(pr_rate, 3),
             "cache_stats": res.cache_stats,
             "paper_claim": "completes within two minutes"}]

    # ---- crash-safe long-lived worker pool: cold call, then steady state --
    # The first workers=2 sweep pays the one-time pool spawn (plus a jax
    # import per worker under the spawn context; near-free under fork).
    # The second sweep is what the long-lived pool exists for: warm worker
    # processes with warm per-worker simulator caches surviving across
    # sweep() calls — the steady-state rate is the headline
    # ``sweep_workers_configs_per_sec`` (explicitly a warm-over-sweeps
    # number, unlike the cold in-process serial row above).
    rank = lambda r: [(x.cand.key(), x.report.step_time_us)
                      for x in r.ranked()]
    t0 = time.time()
    res2 = sweep(space, workers=2)
    cold_wall = time.time() - t0
    assert rank(res2) == rank(res), "workers=2 sweep diverged from serial"
    t0 = time.time()
    res3 = sweep(space, workers=2)
    warm_wall = time.time() - t0
    assert rank(res3) == rank(res), "warm-pool sweep diverged from serial"
    rows.append({"bench": "fig13_dse", "case": "exploration_workers",
                 "workers": 2,
                 "cold_wall_s": round(cold_wall, 1),
                 "cold_configs_per_sec": round(res2.configs_per_sec, 1),
                 "wall_s": round(warm_wall, 2),
                 "configs_per_sec": round(res3.configs_per_sec, 1),
                 "pool_reused": res3.workers == 2,
                 "bit_identical_to_serial": True})
    for r in front[:8]:
        p = r.cand.par
        rows.append({"bench": "fig13_dse", "case": "pareto",
                     "tp": p.tp, "pp": p.pp, "dp": p.dp,
                     "batch": r.cand.global_batch,
                     "tpot_ms": round(r.report.step_time_us / 1e3, 2),
                     "tps_user": round(r.tps_per_user, 1),
                     "tps_chip": round(r.tps_per_chip, 2),
                     "mem_gb": round(r.report.memory.total / 1e9, 1)})
    # engineering baseline: tp=8, pp=1, batch=64 (common 32B heuristic)
    base = next((r for r in res.evaluated
                 if r.cand.par.tp == 8 and r.cand.par.pp == 1
                 and r.cand.global_batch == 64), None)
    slo = 20.0  # ms TPOT SLO
    best = res.best_under_slo(tpot_ms=slo)
    if base and best:
        rows.append({"bench": "fig13_dse", "case": f"best_under_{slo}ms_TPOT",
                     "baseline_tps_chip": round(base.tps_per_chip, 2),
                     "baseline_tpot_ms": round(base.report.step_time_us / 1e3, 2),
                     "best_tps_chip": round(best.tps_per_chip, 2),
                     "best_tpot_ms": round(best.report.step_time_us / 1e3, 2),
                     "best_config": f"tp{best.cand.par.tp}/pp{best.cand.par.pp}"
                                    f"/b{best.cand.global_batch}",
                     "throughput_gain": round(best.tps_per_chip
                                              / base.tps_per_chip, 2),
                     "paper_claim": "DSE config beats engineering-tuned baseline"})
    # frontier spread (paper: up to 7x TPS/GPU by relaxing user SLO)
    if front:
        spread = max(r.tps_per_chip for r in front) / max(
            min(r.tps_per_chip for r in front), 1e-9)
        rows.append({"bench": "fig13_dse", "case": "frontier_spread",
                     "tps_chip_ratio": round(spread, 1),
                     "paper_claim": "up to 7x TPS/GPU across the frontier"})
    return rows

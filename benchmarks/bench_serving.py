"""Request-level serving simulation (§5.3-style deployment what-ifs).

Replays a 600-request Poisson trace for qwen2.5-32b decode on a v5e tp=8
replica through every batching policy.  The headline numbers are the
simulation *speed* (simulated requests/sec — the whole point of pricing
engine steps with the simulator instead of running a cluster) and the
step-oracle cache hit rate; the per-policy TTFT/TPOT/goodput rows are the
deployment comparison a real operator would read.
"""
from __future__ import annotations

import time

from repro.api import Cluster, ServingWorkload, SimSpec
from repro.configs import get_config
from repro.core import ParallelConfig, Simulator
from repro.serving.sim import (
    SLO, DisaggregatedPD, LengthDist, ServingSimulator,
)


def run() -> list[dict]:
    cfg = get_config("qwen2.5-32b")
    sim = Simulator("tpu_v5e", engine="analytical")
    par = ParallelConfig(tp=8)
    # rate tuned to ~0.85 utilization of the tp=8 replica (~3.3k tok/s at
    # batch 32): loaded enough that policies separate, not collapsed
    base = SimSpec(cfg, cluster=Cluster("tpu_v5e"), parallel=par,
                   workload=ServingWorkload(
                       n_requests=600, arrival="poisson", rate_rps=4.0,
                       prompt=LengthDist("lognormal", median=512.0, sigma=0.6,
                                         cap=3072),
                       output=LengthDist("lognormal", median=96.0, sigma=0.5,
                                         cap=256),
                       seed=7, slo=SLO(ttft_s=2.0, tpot_ms=60.0),
                       max_batch=32, token_budget=512))
    wl = base.workload.build()
    policies = [
        ("continuous", "continuous"),
        ("chunked_prefill", "chunked"),
        ("static", "static"),
        ("disaggregated", DisaggregatedPD(prefill_batch=4, decode_batch=32,
                                          transfer_s=0.002)),
    ]
    rows = []
    total_wall = 0.0
    for name, pol in policies:
        t0 = time.time()
        if isinstance(pol, str):                 # spec-carried policy
            from repro.api import spec_replace
            rep = ServingSimulator(sim).run(
                spec_replace(base, {"workload.policy": pol}))
        else:                                    # custom policy object
            rep = ServingSimulator(sim, cfg, par=par, policy=pol).run(
                wl, slo=base.workload.slo)
        wall = time.time() - t0
        total_wall += wall
        s = rep.summary()
        rows.append({
            "bench": "serving_sim", "case": name,
            "n_requests": wl.n_requests,
            "wall_s": round(wall, 2),
            "sim_requests_per_sec": round(wl.n_requests / max(wall, 1e-9), 1),
            "engine_steps": s["n_steps"],
            "oracle_hit_rate": s["oracle_stats"].get("hit_rate", 0.0),
            "ttft_p50_s": s["ttft_p50_s"], "ttft_p99_s": s["ttft_p99_s"],
            "tpot_p50_ms": s["tpot_p50_ms"], "tpot_p99_ms": s["tpot_p99_ms"],
            "tokens_per_s": s["tokens_per_s"],
            "slo_attainment": s["slo_attainment"],
            "goodput_rps": s["goodput_rps"],
        })
    st = sim.cache_stats()
    rows.append({
        "bench": "serving_sim", "case": "summary",
        "total_wall_s": round(total_wall, 2),
        "serving_cache": st["serving"],
        "pricing_cache_hit_rate": st["pricing"]["hit_rate"],
        "paper_claim": "request-level what-ifs at simulation speed "
                       "(600-request trace per policy in seconds, not hours)",
    })
    return rows
